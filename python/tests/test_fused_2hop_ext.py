"""Extended fused kernel (paper §9 future work): weighted mean + max
aggregators, verified against straightforward numpy recomputation from the
saved indices/positions, plus gradient replay checks."""
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref
from compile.kernels.fused_2hop_ext import (fused_sample_agg_2hop_ext,
                                            make_fsa2_max_op,
                                            make_fsa2_weighted_op,
                                            sample_positions)

from .conftest import make_csr


def setup(seed=0, n=150, d=8, b=16):
    rng = np.random.default_rng(seed)
    rowptr, col = make_csr(n, 10, seed, isolated_fraction=0.15)
    x = rng.standard_normal((n, d)).astype(np.float32)
    ew = rng.random(len(col)).astype(np.float32) + 0.1
    seeds = rng.integers(0, n, b).astype(np.int32)
    return rowptr, col, ew, x, seeds


def test_sample_positions_consistent_with_ids():
    rowptr, col, _, _, seeds = setup(1)
    ids, pos = sample_positions(jnp.asarray(rowptr), jnp.asarray(col),
                                jnp.asarray(seeds), 5, jnp.uint64(7), hop=0)
    ids, pos = np.asarray(ids), np.asarray(pos)
    assert ids.shape == pos.shape
    mask = ids >= 0
    np.testing.assert_array_equal(ids[mask], col[pos[mask]])
    assert (pos[~mask] == -1).all()
    # identical ids to the plain sampling rule
    want = np.array([ref.sample_neighbors(rowptr, col, int(u), 5, 7, 0)
                     for u in seeds])
    np.testing.assert_array_equal(ids, want)


def test_uniform_weights_equal_plain_mean():
    rowptr, col, _, x, seeds = setup(2)
    base = np.array([3], np.uint64)
    ones = np.ones(len(col), np.float32)
    agg_w, s2, _ = fused_sample_agg_2hop_ext(rowptr, col, ones, x, seeds,
                                             base, k1=4, k2=3)
    ragg, rs1, rs2 = ref.fused_2hop(rowptr, col, x, seeds, 3, 4, 3)
    np.testing.assert_array_equal(np.asarray(s2), rs2)
    np.testing.assert_allclose(np.asarray(agg_w), ragg, rtol=1e-4, atol=1e-5)


def test_weighted_mean_matches_numpy_recompute():
    rowptr, col, ew, x, seeds = setup(3)
    base = np.array([11], np.uint64)
    k1, k2 = 4, 3
    agg, s2, p2 = fused_sample_agg_2hop_ext(rowptr, col, ew, x, seeds, base,
                                            k1=k1, k2=k2)
    agg, s2, p2 = np.asarray(agg), np.asarray(s2), np.asarray(p2)
    for bi, root in enumerate(seeds):
        # k1_eff counts every valid hop-1 sample (paper Alg. 2 rule), even
        # ones whose own neighborhood is empty
        s1 = ref.sample_neighbors(rowptr, col, int(root), k1, 11, 0)
        k1_eff = max(1, sum(1 for u in s1 if u >= 0))
        acc = np.zeros(x.shape[1])
        for ui in range(k1):
            valid = s2[bi, ui] >= 0
            if not valid.any():
                continue
            w = ew[p2[bi, ui][valid]]
            acc += (x[s2[bi, ui][valid]] * w[:, None]).sum(0) / w.sum()
        want = acc / k1_eff
        np.testing.assert_allclose(agg[bi], want, rtol=1e-4, atol=1e-5)


def test_max_matches_numpy_recompute():
    rowptr, col, _, x, seeds = setup(4)
    base = np.array([5], np.uint64)
    agg, s2, _ = fused_sample_agg_2hop_ext(rowptr, col, None, x, seeds, base,
                                           k1=5, k2=2, aggregator="max")
    agg, s2 = np.asarray(agg), np.asarray(s2)
    for bi in range(len(seeds)):
        ids = s2[bi][s2[bi] >= 0]
        want = x[ids].max(0) if len(ids) else np.zeros(x.shape[1])
        np.testing.assert_allclose(agg[bi], want, rtol=1e-5, atol=1e-6)


def test_weighted_grad_replay():
    rowptr, col, ew, x, seeds = setup(5)
    op = make_fsa2_weighted_op(k1=4, k2=3)
    base = np.array([21], np.uint64)

    def fused_loss(x_in):
        return (op(rowptr, col, ew, x_in, seeds, base)
                * jnp.arange(1.0, x.shape[1] + 1.0)).sum()

    # differentiable recomputation from saved indices
    _, s2, p2 = fused_sample_agg_2hop_ext(rowptr, col, ew, x, seeds, base,
                                          k1=4, k2=3)

    from compile.kernels.sampling import sample_neighbors
    s1 = sample_neighbors(jnp.asarray(rowptr), jnp.asarray(col),
                          jnp.asarray(seeds), 4, jnp.uint64(21), hop=0)

    def indexed_loss(x_in):
        valid = (s2 >= 0)
        w = ew[jnp.maximum(p2, 0)] * valid
        num = (x_in[jnp.maximum(s2, 0)] * w[..., None]).sum(2)
        den = jnp.maximum(w.sum(-1), 1e-12)
        inner = num / den[..., None]
        valid1 = s1 >= 0
        k1_eff = jnp.maximum(valid1.sum(-1), 1)
        outer = (inner * valid1[..., None]).sum(1) / k1_eff[..., None]
        return (outer * jnp.arange(1.0, x.shape[1] + 1.0)).sum()

    g_fused = np.asarray(jax.grad(fused_loss)(x))
    g_ref = np.asarray(jax.grad(indexed_loss)(x))
    np.testing.assert_allclose(g_fused, g_ref, rtol=1e-4, atol=1e-5)


def test_max_grad_goes_to_argmax():
    # hand-built graph: root 0 -> {1,2}; 1 -> {3}; 2 -> {4}
    rowptr = np.array([0, 2, 3, 4, 4, 4], np.int32)
    col = np.array([1, 2, 3, 4], np.int32)
    x = np.array([[0.0], [0.0], [0.0], [5.0], [9.0]], np.float32)
    seeds = np.array([0], np.int32)
    op = make_fsa2_max_op(k1=2, k2=1)
    base = np.array([1], np.uint64)

    out = op(rowptr, col, x, seeds, base)
    np.testing.assert_allclose(np.asarray(out), [[9.0]])
    g = np.asarray(jax.grad(
        lambda x_in: op(rowptr, col, x_in, seeds, base).sum())(x))
    want = np.zeros_like(x)
    want[4, 0] = 1.0  # only the argmax node receives gradient
    np.testing.assert_array_equal(g, want)


def test_ext_determinism():
    rowptr, col, ew, x, seeds = setup(6)
    base = np.array([8], np.uint64)
    a = fused_sample_agg_2hop_ext(rowptr, col, ew, x, seeds, base, k1=3, k2=2)
    b = fused_sample_agg_2hop_ext(rowptr, col, ew, x, seeds, base, k1=3, k2=2)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
