"""AOT configuration registry + manifest integrity (the rust contract)."""
import json
import pathlib

import numpy as np
import pytest

from compile import aot, configs


def test_artifact_names_unique():
    names = [c.name for c in configs.all_configs()]
    assert len(names) == len(set(names))


def test_main_grid_present():
    cfgs = {c.name for c in configs.all_configs()}
    for ds in configs.MAIN_DATASETS:
        for (k1, k2) in configs.MAIN_FANOUTS:
            for b in configs.MAIN_BATCHES:
                for v in ("fsa2", "dgl2"):
                    name = f"{v}_train_{ds}_f{k1}x{k2}_b{b}_ampOn"
                    assert name in cfgs, name


def test_profile_stages_present():
    stages = [c for c in configs.all_configs() if c.kind == "stage"]
    assert sorted(c.variant for c in stages) == sorted(
        ["gather", "layer1", "layer2", "loss", "bwd_layer2", "bwd_layer1",
         "adamw"])


def test_train_io_contract():
    cfg = next(c for c in configs.all_configs()
               if c.name == "fsa2_train_tiny_f5x3_b64_ampOn")
    names = [s.name for s in cfg.inputs]
    # params..., m..., v..., step, then data
    assert names[:5] == ["w_self", "w_neigh", "b_hidden", "w_out", "b_out"]
    assert names[5] == "m_w_self" and names[10] == "v_w_self"
    assert names[15] == "step"
    assert names[16:] == ["rowptr", "col", "x", "seeds", "labels",
                          "base_seed"]
    out_names = [s.name for s in cfg.outputs]
    assert out_names[0] == "new_w_self"
    assert out_names[-1] == "loss"


def test_dgl_train_io_contract():
    cfg = next(c for c in configs.all_configs()
               if c.name == "dgl2_train_tiny_f5x3_b64_ampOn")
    names = [s.name for s in cfg.inputs]
    assert len([n for n in names if n.startswith("m_")]) == 6
    assert names[-4:] == ["x", "f1", "s2", "labels"]
    s2 = next(s for s in cfg.inputs if s.name == "s2")
    assert tuple(s2.shape) == (64, 1 + 5, 3)


def test_tile_recorded_for_fsa_only():
    for c in configs.all_configs():
        if c.kind == "train" and c.variant.startswith("fsa"):
            assert c.tile > 0 and c.batch % c.tile == 0
        if c.variant.startswith("dgl"):
            assert c.tile == 0


def test_lowering_matches_contract_tiny():
    """Actually lower the tiny configs and check output arity (the same
    assertion aot.py enforces for every artifact at build time)."""
    for name in ["fsa2_train_tiny_f5x3_b64_ampOn",
                 "dgl1_train_tiny_f5_b64_ampOn"]:
        cfg = next(c for c in configs.all_configs() if c.name == name)
        import jax
        fn = aot.build_fn(cfg)
        avals = [aot.spec_to_aval(s) for s in cfg.inputs]
        lowered = jax.jit(fn).lower(*avals)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and len(text) > 1000


def test_manifest_dict_serializable_and_complete():
    m = configs.manifest_dict()
    text = json.dumps(m)
    back = json.loads(text)
    assert back["version"] == 1
    assert set(back["datasets"]) == {"tiny", "arxiv_sim", "reddit_sim",
                                     "products_sim"}
    assert len(back["artifacts"]) == len(configs.all_configs())
    a = back["artifacts"][0]
    for key in ["name", "file", "kind", "variant", "inputs", "outputs"]:
        assert key in a


def test_spec_to_aval_dtypes():
    s = configs.TensorSpec("x", (2, 3), "uint64")
    aval = aot.spec_to_aval(s)
    assert aval.shape == (2, 3)
    assert aval.dtype == np.dtype("uint64")


def test_built_manifest_on_disk_matches_registry():
    path = pathlib.Path(__file__).parents[2] / "artifacts" / "manifest.json"
    if not path.exists():
        pytest.skip("artifacts not built")
    on_disk = json.loads(path.read_text())
    assert len(on_disk["artifacts"]) == len(configs.all_configs())
    for c in configs.all_configs():
        assert (path.parent / c.file).exists(), f"missing {c.file}"
