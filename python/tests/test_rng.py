"""Cross-language RNG contract: jnp implementation vs the independent
python-int oracle, plus the golden vectors pinned in rust/src/rng/mod.rs."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, rng

U64 = st.integers(min_value=0, max_value=(1 << 64) - 1)


def test_mix_golden_vectors():
    # the same constants are asserted in rust/src/rng/mod.rs
    assert ref.mix(0x0) == 0xE220A8397B1DCDAF
    assert ref.mix(0x1) == 0x910A2DEC89025CC1
    assert ref.mix(0x2A) == 0xBDD732262FEB6E95
    assert ref.mix(0xDEADBEEF) == 0x4ADFB90F68C9EB9B
    assert ref.mix((1 << 64) - 1) == 0xE4D971771B652C20


def test_rand_counter_golden_vectors():
    assert ref.rand_counter(42, 0, 0, 0) == 0xFE554343B462A664
    assert ref.rand_counter(42, 7, 0, 3) == 0xCAA4B86D13EAFA09
    assert ref.rand_counter(42, 7, 1, 3) == 0xD75D107DE516873C
    assert ref.rand_counter(123456789, 19999, 1, 24) == 0xDFA619AE6464B6DD
    assert ref.rand_counter(1 << 63, 11999, 0, 99) == 0x6F954A2ED0C8C743


@given(U64)
@settings(max_examples=200, deadline=None)
def test_jnp_mix_matches_oracle(z):
    got = int(rng.mix(jnp.uint64(z)))
    assert got == ref.mix(z)


@given(U64, st.integers(0, 2**31 - 1), st.integers(0, 3), st.integers(0, 1000))
@settings(max_examples=200, deadline=None)
def test_jnp_rand_counter_matches_oracle(base, node, hop, slot):
    got = int(rng.rand_counter(jnp.uint64(base), jnp.int32(node), hop,
                               jnp.uint64(slot)))
    assert got == ref.rand_counter(base, node, hop, slot)


def test_vectorized_equals_scalar():
    nodes = jnp.arange(100, dtype=jnp.int32)
    slots = jnp.arange(8, dtype=jnp.uint64)
    words = rng.rand_counter(jnp.uint64(5), nodes[:, None], 1, slots)
    assert words.shape == (100, 8)
    for i in [0, 3, 99]:
        for j in [0, 7]:
            assert int(words[i, j]) == ref.rand_counter(5, i, 1, j)


def test_word_distribution_is_uniform_ish():
    nodes = jnp.arange(20_000, dtype=jnp.int32)
    words = rng.rand_counter(jnp.uint64(1), nodes, 0, jnp.uint64(0))
    # top bit should be set about half the time
    top = (words >> jnp.uint64(63)).astype(np.float64).mean()
    assert 0.47 < float(top) < 0.53
    # low 10 bits roughly uniform
    low = np.asarray(words & jnp.uint64(1023), dtype=np.float64)
    assert abs(low.mean() - 511.5) < 15
