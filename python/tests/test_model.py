"""L2 model layer: fused forward/train-step semantics, AMP, AdamW."""
import jax
import jax.numpy as jnp
import numpy as np

from compile import model, optim

from .conftest import make_csr


def setup(seed=0, n=120, d=8, h=16, c=5, b=16):
    rng = np.random.default_rng(seed)
    rowptr, col = make_csr(n, 8, seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    seeds = rng.integers(0, n, b).astype(np.int32)
    labels = rng.integers(0, c, b).astype(np.int32)
    params = (
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.standard_normal((h, c)) * 0.2).astype(np.float32),
        np.zeros(c, np.float32),
    )
    return rowptr, col, x, seeds, labels, params


def test_forward_shapes_and_determinism():
    rowptr, col, x, seeds, _, params = setup()
    base = np.array([42], np.uint64)
    a = model.fsa_forward(params, rowptr, col, x, seeds, base,
                          hops=2, k1=4, k2=3, amp=False)
    b = model.fsa_forward(params, rowptr, col, x, seeds, base,
                          hops=2, k1=4, k2=3, amp=False)
    assert a.shape == (16, 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_amp_close_to_fp32():
    rowptr, col, x, seeds, _, params = setup(1)
    base = np.array([1], np.uint64)
    full = model.fsa_forward(params, rowptr, col, x, seeds, base,
                             hops=2, k1=4, k2=3, amp=False)
    amp = model.fsa_forward(params, rowptr, col, x, seeds, base,
                            hops=2, k1=4, k2=3, amp=True)
    np.testing.assert_allclose(np.asarray(amp), np.asarray(full),
                               rtol=0.05, atol=0.05)


def test_cross_entropy_known_value():
    logits = jnp.array([[0.0, 0.0], [100.0, 0.0]])
    labels = jnp.array([0, 0], jnp.int32)
    got = float(model.cross_entropy(logits, labels))
    want = (np.log(2.0) + 0.0) / 2.0
    assert abs(got - want) < 1e-5


def test_train_step_reduces_loss():
    rowptr, col, x, seeds, labels, params = setup(2)
    ts = model.make_fsa_train_step(hops=2, k1=4, k2=3, amp=True)
    m = tuple(np.zeros_like(p) for p in params)
    v = tuple(np.zeros_like(p) for p in params)
    jts = jax.jit(ts)
    base = np.array([42], np.uint64)
    losses = []
    p = params
    for step in range(25):
        out = jts(p, m, v, jnp.float32(step), rowptr, col, x, seeds, labels,
                  base)
        p, m, v = out[:5], out[5:10], out[10:15]
        losses.append(float(out[15]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_1hop_train_step_runs():
    rowptr, col, x, seeds, labels, params = setup(3)
    ts = model.make_fsa_train_step(hops=1, k1=5, k2=0, amp=False)
    m = tuple(np.zeros_like(p) for p in params)
    v = tuple(np.zeros_like(p) for p in params)
    out = jax.jit(ts)(params, m, v, jnp.float32(0), rowptr, col, x, seeds,
                      labels, np.array([1], np.uint64))
    assert len(out) == 16
    assert np.isfinite(float(out[15]))


def test_adamw_matches_manual_formula():
    p = (np.array([1.0, -2.0], np.float32),)
    g = (np.array([0.5, 0.5], np.float32),)
    m = (np.zeros(2, np.float32),)
    v = (np.zeros(2, np.float32),)
    (new_p,), (new_m,), (new_v,) = optim.adamw_update(p, g, m, v,
                                                      jnp.float32(0))
    lr, b1, b2, eps, wd = 3e-3, 0.9, 0.999, 1e-8, 5e-4
    m1 = (1 - b1) * 0.5
    v1 = (1 - b2) * 0.25
    mhat = m1 / (1 - b1)
    vhat = v1 / (1 - b2)
    want = np.array([1.0, -2.0]) - lr * (mhat / (np.sqrt(vhat) + eps)
                                         + wd * np.array([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(new_p), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_m), [m1, m1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_v), [v1, v1], rtol=1e-5)


def test_adamw_weight_decay_is_decoupled():
    """zero gradient still decays weights (AdamW, not Adam+L2)."""
    p = (np.array([10.0], np.float32),)
    g = (np.array([0.0], np.float32),)
    m = (np.zeros(1, np.float32),)
    v = (np.zeros(1, np.float32),)
    (new_p,), _, _ = optim.adamw_update(p, g, m, v, jnp.float32(0))
    want = 10.0 - 3e-3 * (5e-4 * 10.0)
    np.testing.assert_allclose(np.asarray(new_p), [want], rtol=1e-6)


def test_eval_fn_matches_forward():
    rowptr, col, x, seeds, _, params = setup(4)
    ev = model.make_fsa_eval(hops=2, k1=4, k2=3)
    base = np.array([9], np.uint64)
    (logits,) = jax.jit(ev)(params, rowptr, col, x, seeds, base)
    want = model.fsa_forward(params, rowptr, col, x, seeds, base,
                             hops=2, k1=4, k2=3, amp=False,
                             save_indices=False)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
