"""Backward-pass correctness: the custom_vjp saved-index replay (paper §3.3)
vs (a) the numpy oracle backward and (b) jax autodiff of a differentiable
reference built from the same saved indices."""
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import fused_sample_agg_2hop, ref
from compile.model import make_fsa1_op, make_fsa2_op

from .conftest import make_csr


def setup(seed=0, n=100, d=8, b=16):
    rng = np.random.default_rng(seed)
    rowptr, col = make_csr(n, 9, seed, isolated_fraction=0.15)
    x = rng.standard_normal((n, d)).astype(np.float32)
    seeds = rng.integers(0, n, b).astype(np.int32)
    return rowptr, col, x, seeds


def test_2hop_grad_matches_oracle():
    rowptr, col, x, seeds = setup(1)
    op = make_fsa2_op(k1=4, k2=3)
    base = np.array([42], np.uint64)

    def loss(x_in):
        return (op(rowptr, col, x_in, seeds, base) ** 2).sum()

    gx = np.asarray(jax.grad(loss)(x))

    # oracle: g_agg = 2*agg; scatter with 1/(k1_eff*k2_eff)
    agg, s1, s2 = fused_sample_agg_2hop(rowptr, col, x, seeds, base,
                                        k1=4, k2=3)
    g_up = 2.0 * np.asarray(agg, np.float64)
    want = ref.backward_2hop_sized(np.asarray(s1), np.asarray(s2), g_up,
                                   x.shape[0])
    np.testing.assert_allclose(gx, want, rtol=1e-4, atol=1e-5)


def test_2hop_grad_matches_autodiff_of_indexed_ref():
    rowptr, col, x, seeds = setup(2)
    k1, k2 = 5, 2
    base = np.array([7], np.uint64)
    op = make_fsa2_op(k1=k1, k2=k2)
    _, s1, s2 = fused_sample_agg_2hop(rowptr, col, x, seeds, base,
                                      k1=k1, k2=k2)

    def indexed_ref(x_in):
        # differentiable recomputation of Alg. 2 from the saved indices
        v2 = (s2 >= 0)
        feats = x_in[jnp.maximum(s2, 0)]
        k2_eff = jnp.maximum(v2.sum(-1), 1)
        inner = (feats * v2[..., None]).sum(2) / k2_eff[..., None]
        v1 = (s1 >= 0)
        k1_eff = jnp.maximum(v1.sum(-1), 1)
        outer = (inner * v1[..., None]).sum(1) / k1_eff[..., None]
        return (outer * jnp.arange(1.0, x.shape[1] + 1.0)).sum()

    def fused(x_in):
        return (op(rowptr, col, x_in, seeds, base)
                * jnp.arange(1.0, x.shape[1] + 1.0)).sum()

    g_ref = np.asarray(jax.grad(indexed_ref)(x))
    g_fused = np.asarray(jax.grad(fused)(x))
    np.testing.assert_allclose(g_fused, g_ref, rtol=1e-4, atol=1e-5)


def test_1hop_grad_matches_oracle():
    rowptr, col, x, seeds = setup(3)
    from compile.kernels import fused_sample_agg_1hop
    k = 5
    base = np.array([13], np.uint64)
    op = make_fsa1_op(k=k)

    def loss(x_in):
        return op(rowptr, col, x_in, seeds, base).sum()

    gx = np.asarray(jax.grad(loss)(x))
    _, samples, takes = fused_sample_agg_1hop(rowptr, col, x, seeds, base,
                                              k=k)
    g_up = np.ones((len(seeds), x.shape[1]))
    want = ref.backward_1hop_sized(np.asarray(samples), np.asarray(takes),
                                   g_up, x.shape[0])
    np.testing.assert_allclose(gx, want, rtol=1e-5, atol=1e-6)


def test_no_save_indices_gives_zero_grad():
    """paper §3.2: without saved indices the backward returns zeros for X."""
    rowptr, col, x, seeds = setup(4)
    op = make_fsa2_op(k1=3, k2=2, save_indices=False)
    base = np.array([1], np.uint64)

    def loss(x_in):
        return op(rowptr, col, x_in, seeds, base).sum()

    gx = np.asarray(jax.grad(loss)(x))
    np.testing.assert_array_equal(gx, np.zeros_like(x))


def test_grad_accumulates_over_duplicate_seeds():
    """two identical seeds double the scatter contribution."""
    rowptr, col, x, _ = setup(5)
    op = make_fsa2_op(k1=3, k2=2)
    base = np.array([2], np.uint64)
    one = np.array([10], np.int32)
    two = np.array([10, 10], np.int32)

    g1 = np.asarray(jax.grad(
        lambda x_in: op(rowptr, col, x_in, one, base).sum())(x))
    g2 = np.asarray(jax.grad(
        lambda x_in: op(rowptr, col, x_in, two, base).sum())(x))
    np.testing.assert_allclose(g2, 2.0 * g1, rtol=1e-5, atol=1e-6)
