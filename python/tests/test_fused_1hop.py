"""Fused 1-hop Pallas kernel vs the numpy oracle (paper Alg. 1)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_sample_agg_1hop, ref

from .conftest import make_csr


def run_both(rowptr, col, x, seeds, base, k, tile=None):
    agg, samples, takes = fused_sample_agg_1hop(
        rowptr, col, x, seeds, np.array([base], np.uint64), k=k, tile=tile)
    ragg, rsamples, rtakes = ref.fused_1hop(rowptr, col, x, seeds, base, k)
    return (np.asarray(agg), np.asarray(samples), np.asarray(takes),
            ragg, rsamples, rtakes)


def test_matches_oracle(small_graph):
    rowptr, col, x = small_graph
    seeds = np.arange(0, 64, dtype=np.int32)
    agg, samples, takes, ragg, rsamples, rtakes = run_both(
        rowptr, col, x, seeds, 42, k=6)
    np.testing.assert_array_equal(samples, rsamples)
    np.testing.assert_array_equal(takes, rtakes)
    np.testing.assert_allclose(agg, ragg, rtol=1e-5, atol=1e-6)


def test_deterministic(small_graph):
    rowptr, col, x = small_graph
    seeds = np.arange(32, dtype=np.int32)
    a = fused_sample_agg_1hop(rowptr, col, x, seeds,
                              np.array([7], np.uint64), k=5)
    b = fused_sample_agg_1hop(rowptr, col, x, seeds,
                              np.array([7], np.uint64), k=5)
    for x1, x2 in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def test_base_seed_changes_result(medium_graph):
    rowptr, col, x = medium_graph
    seeds = np.arange(64, dtype=np.int32)
    a, sa, _ = fused_sample_agg_1hop(rowptr, col, x, seeds,
                                     np.array([1], np.uint64), k=4)
    b, sb, _ = fused_sample_agg_1hop(rowptr, col, x, seeds,
                                     np.array([2], np.uint64), k=4)
    assert not np.array_equal(np.asarray(sa), np.asarray(sb))


def test_save_indices_off_returns_agg_only(small_graph):
    rowptr, col, x = small_graph
    seeds = np.arange(16, dtype=np.int32)
    out = fused_sample_agg_1hop(rowptr, col, x, seeds,
                                np.array([3], np.uint64), k=4,
                                save_indices=False)
    assert out.shape == (16, 16)
    with_idx, _, _ = fused_sample_agg_1hop(
        rowptr, col, x, seeds, np.array([3], np.uint64), k=4)
    # same samples, same means up to XLA reassociation between the two graphs
    np.testing.assert_allclose(np.asarray(out), np.asarray(with_idx),
                               rtol=1e-4, atol=1e-6)


def test_rejects_non_f32():
    rowptr, col = make_csr(20, 4, 0)
    x = np.zeros((20, 8), np.float16)
    with pytest.raises(TypeError, match="FP32"):
        fused_sample_agg_1hop(rowptr, col, x, np.zeros(8, np.int32),
                              np.array([0], np.uint64), k=2)


def test_rejects_indivisible_tile(small_graph):
    rowptr, col, x = small_graph
    with pytest.raises(ValueError, match="divisible"):
        fused_sample_agg_1hop(rowptr, col, x, np.zeros(10, np.int32),
                              np.array([0], np.uint64), k=2, tile=4)


@given(
    gseed=st.integers(0, 1000),
    base=st.integers(0, (1 << 64) - 1),
    k=st.integers(1, 10),
    b=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([1, 5, 16]),
    tile=st.sampled_from([None, 8]),
)
@settings(max_examples=25, deadline=None)
def test_sweep_matches_oracle(gseed, base, k, b, d, tile):
    rng = np.random.default_rng(gseed)
    rowptr, col = make_csr(80, 15, gseed)
    x = rng.standard_normal((80, d)).astype(np.float32)
    seeds = rng.integers(0, 80, b).astype(np.int32)
    agg, samples, takes, ragg, rsamples, rtakes = run_both(
        rowptr, col, x, seeds, base, k, tile)
    np.testing.assert_array_equal(samples, rsamples)
    np.testing.assert_array_equal(takes, rtakes)
    np.testing.assert_allclose(agg, ragg, rtol=1e-4, atol=1e-5)
