"""The bf16 feature path (paper §4 dtype dispatch; §Perf optimization):
train step with bfloat16 features must lower, run, and learn."""
import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, model

from .conftest import make_csr


def test_bf16_artifact_registered():
    cfg = next((c for c in configs.all_configs()
                if c.name.endswith("_xbf16")), None)
    assert cfg is not None
    x_spec = next(s for s in cfg.inputs if s.name == "x")
    assert x_spec.dtype == "bfloat16"
    # tile accounts for the 2-byte element size (more seeds fit the budget)
    f32_twin = next(c for c in configs.all_configs()
                    if c.name == "fsa2_train_products_sim_f15x10_b1024_ampOn")
    assert cfg.tile >= f32_twin.tile


def test_bf16_train_step_learns():
    rng = np.random.default_rng(0)
    n, d, h, c, b = 120, 8, 16, 5, 16
    rowptr, col = make_csr(n, 8, 0)
    x = rng.standard_normal((n, d)).astype(np.float32)
    seeds = rng.integers(0, n, b).astype(np.int32)
    labels = rng.integers(0, c, b).astype(np.int32)
    params = (
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.standard_normal((h, c)) * 0.2).astype(np.float32),
        np.zeros(c, np.float32),
    )
    m = tuple(np.zeros_like(p) for p in params)
    v = tuple(np.zeros_like(p) for p in params)
    ts = jax.jit(model.make_fsa_train_step(hops=2, k1=4, k2=3, amp=True))
    x_bf16 = jnp.asarray(x, jnp.bfloat16)
    base = np.array([42], np.uint64)
    losses = []
    p = params
    for step in range(25):
        out = ts(p, m, v, jnp.float32(step), rowptr, col, x_bf16, seeds,
                 labels, base)
        p, m, v = out[:5], out[5:10], out[10:15]
        losses.append(float(out[15]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.85, losses


def test_bf16_forward_close_to_f32():
    rng = np.random.default_rng(1)
    n, d, h, c, b = 100, 8, 16, 5, 16
    rowptr, col = make_csr(n, 8, 1)
    x = rng.standard_normal((n, d)).astype(np.float32)
    seeds = rng.integers(0, n, b).astype(np.int32)
    params = (
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.standard_normal((h, c)) * 0.2).astype(np.float32),
        np.zeros(c, np.float32),
    )
    base = np.array([9], np.uint64)
    f32 = model.fsa_forward(params, rowptr, col, x, seeds, base,
                            hops=2, k1=4, k2=3, amp=False)
    bf16 = model.fsa_forward(params, rowptr, col,
                             jnp.asarray(x, jnp.bfloat16), seeds, base,
                             hops=2, k1=4, k2=3, amp=False)
    np.testing.assert_allclose(np.asarray(bf16, np.float32),
                               np.asarray(f32), rtol=0.1, atol=0.1)
