"""Vectorized sampling rule vs the line-by-line oracle (DESIGN.md §5)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sampling import masked_mean, sample_neighbors

from .conftest import make_csr


def oracle_frontier(rowptr, col, nodes, k, base, hop):
    return np.array([
        ref.sample_neighbors(rowptr, col, int(u), k, base, hop)
        for u in nodes
    ], np.int32)


def test_matches_oracle_basic(small_graph):
    rowptr, col, _ = small_graph
    nodes = jnp.arange(200, dtype=jnp.int32)
    for k in [1, 3, 8]:
        got = sample_neighbors(jnp.asarray(rowptr), jnp.asarray(col), nodes,
                               k, jnp.uint64(42), hop=0)
        want = oracle_frontier(rowptr, col, np.arange(200), k, 42, 0)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_invalid_nodes_propagate(small_graph):
    rowptr, col, _ = small_graph
    nodes = jnp.array([-1, 0, -1, 5], jnp.int32)
    got = np.asarray(sample_neighbors(jnp.asarray(rowptr), jnp.asarray(col),
                                      nodes, 4, jnp.uint64(1), hop=1))
    assert (got[0] == -1).all()
    assert (got[2] == -1).all()


def test_nested_shape(small_graph):
    rowptr, col, _ = small_graph
    nodes = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    got = sample_neighbors(jnp.asarray(rowptr), jnp.asarray(col), nodes, 5,
                           jnp.uint64(9), hop=0)
    assert got.shape == (3, 4, 5)


@given(
    seed=st.integers(0, 2**32),
    base=st.integers(0, (1 << 64) - 1),
    k=st.integers(1, 12),
    hop=st.integers(0, 1),
    max_deg=st.integers(0, 25),
)
@settings(max_examples=40, deadline=None)
def test_matches_oracle_random_graphs(seed, base, k, hop, max_deg):
    rowptr, col = make_csr(50, max_deg, seed)
    nodes = np.arange(50)
    got = sample_neighbors(jnp.asarray(rowptr), jnp.asarray(col),
                           jnp.asarray(nodes, jnp.int32), k,
                           jnp.uint64(base), hop=hop)
    want = oracle_frontier(rowptr, col, nodes, k, base, hop)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_masked_mean_counts_only_valid():
    feats = jnp.array([[[1.0, 2.0], [3.0, 4.0], [100.0, 100.0]]])
    valid = jnp.array([[True, True, False]])
    got = np.asarray(masked_mean(feats, valid, axis=1))
    np.testing.assert_allclose(got, [[2.0, 3.0]])


def test_masked_mean_all_invalid_gives_zero():
    feats = jnp.ones((2, 3, 4))
    valid = jnp.zeros((2, 3), bool)
    got = np.asarray(masked_mean(feats, valid, axis=1))
    np.testing.assert_allclose(got, np.zeros((2, 4)))


def test_reservoir_oracle_is_without_replacement(medium_graph):
    rowptr, col, _ = medium_graph
    hub = int(np.argmax(np.diff(rowptr)))
    k = 16
    s = ref.reservoir_sample(rowptr, col, hub, k, base=3, hop=0)
    assert len(s) == k
    # positions (not necessarily values — parallel edges exist) are distinct:
    # re-derive chosen positions by running the replacement trace
    deg = int(rowptr[hub + 1] - rowptr[hub])
    pos = list(range(k))
    for i in range(k, deg):
        j = ref.rand_counter(3, hub, 0, i) % (i + 1)
        if j < k:
            pos[j] = i
    assert len(set(pos)) == k
    want = [int(col[rowptr[hub] + p]) for p in pos]
    assert s == want
