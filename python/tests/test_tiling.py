"""Seed-tile selection properties (the TPU VMEM adaptation, DESIGN.md §4)."""
from hypothesis import given, settings, strategies as st

from compile.kernels import tiling


def test_known_cases():
    # b=1024, 15x10 fanout, D=64, f32: tile*150*64*4 <= 4MiB -> tile<=109 -> 64
    assert tiling.seed_tile(1024, 150, 64) == 64
    # tiny problem: whole batch fits
    assert tiling.seed_tile(64, 15, 16) == 64
    # huge fanout: floor at min_tile
    assert tiling.seed_tile(1024, 10_000, 512) == 8


def test_tile_bytes_formula():
    assert tiling.tile_bytes(2, 3, 4, 4) == 2 * 3 * 4 * 4 + 2 * 3 * 4 + 2 * 4 * 4


@given(
    batch=st.sampled_from([8, 16, 64, 128, 512, 1024, 2048]),
    fp=st.integers(1, 2000),
    d=st.sampled_from([1, 16, 64, 256]),
    dtype_bytes=st.sampled_from([2, 4]),
)
@settings(max_examples=200, deadline=None)
def test_properties(batch, fp, d, dtype_bytes):
    tb = tiling.seed_tile(batch, fp, d, dtype_bytes)
    assert 1 <= tb <= batch
    assert batch % tb == 0, "tile must divide the batch"
    # fits budget unless floored at min_tile
    if tb > 8:
        assert tiling.tile_bytes(tb, fp, d, dtype_bytes) <= tiling.VMEM_BUDGET_BYTES


def test_estimate_structure():
    e = tiling.estimate(1024, 15, 10, 64)
    assert e.tile * e.grid >= 1024
    assert e.vmem_tile_bytes <= tiling.VMEM_BUDGET_BYTES
    assert 0 < e.vmem_utilization <= 1.0
    assert e.hbm_bytes_per_step == 1024 * 150 * 64 * 4
    # mean reduction: one add per element -> intensity = 1/dtype_bytes
    assert abs(e.arithmetic_intensity - 0.25) < 1e-9


def test_estimate_1hop():
    e = tiling.estimate(512, 10, 0, 64)
    assert e.flops_per_step == 512 * 10 * 64
