"""Fused 2-hop Pallas kernel vs the numpy oracle (paper Alg. 2),
including the dtype dispatch (f32/bf16/f16) of the paper's §4."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_sample_agg_2hop, ref

from .conftest import make_csr


def run_both(rowptr, col, x, seeds, base, k1, k2, tile=None):
    agg, s1, s2 = fused_sample_agg_2hop(
        rowptr, col, x, seeds, np.array([base], np.uint64), k1=k1, k2=k2,
        tile=tile)
    ragg, rs1, rs2 = ref.fused_2hop(rowptr, col, x, seeds, base, k1, k2)
    return np.asarray(agg), np.asarray(s1), np.asarray(s2), ragg, rs1, rs2


def test_matches_oracle(small_graph):
    rowptr, col, x = small_graph
    seeds = np.arange(32, dtype=np.int32)
    agg, s1, s2, ragg, rs1, rs2 = run_both(rowptr, col, x, seeds, 42, 5, 3)
    np.testing.assert_array_equal(s1, rs1)
    np.testing.assert_array_equal(s2, rs2)
    np.testing.assert_allclose(agg, ragg, rtol=1e-5, atol=1e-6)


def test_k_eff_semantics_with_isolated_neighbors():
    # node 0 -> {1, 2}; node 1 isolated; node 2 -> {0}
    rowptr = np.array([0, 2, 2, 3], np.int32)
    col = np.array([1, 2, 0], np.int32)
    x = np.array([[10.0], [20.0], [30.0]], np.float32)
    seeds = np.array([0], np.int32)
    agg, s1, s2 = fused_sample_agg_2hop(
        rowptr, col, x, seeds, np.array([0], np.uint64), k1=2, k2=2)
    # u=1 valid but deg 0 -> contributes 0, still counts in k1_eff (=2);
    # u=2 contributes mean(X[0]) = 10. So agg = (0 + 10)/2 = 5.
    np.testing.assert_allclose(np.asarray(agg), [[5.0]])
    ragg, _, _ = ref.fused_2hop(rowptr, col, x, seeds, 0, 2, 2)
    np.testing.assert_allclose(np.asarray(agg), ragg)


def test_second_hop_uses_hop1_counter(small_graph):
    """s2 rows must equal 1-hop sampling of the s1 nodes at hop=1 — the
    property that makes baseline/fused comparisons paired."""
    rowptr, col, x = small_graph
    seeds = np.arange(16, dtype=np.int32)
    _, s1, s2, _, _, _ = run_both(rowptr, col, x, seeds, 99, 4, 3)
    for bi in range(16):
        for ui in range(4):
            u = int(s1[bi, ui])
            want = ref.sample_neighbors(rowptr, col, u, 3, 99, hop=1)
            np.testing.assert_array_equal(s2[bi, ui], want)


@pytest.mark.parametrize("dtype,rtol", [(jnp.bfloat16, 0.05),
                                        (jnp.float16, 0.01)])
def test_dtype_dispatch(small_graph, dtype, rtol):
    rowptr, col, x = small_graph
    seeds = np.arange(32, dtype=np.int32)
    agg, s1, s2 = fused_sample_agg_2hop(
        rowptr, col, jnp.asarray(x, dtype), seeds,
        np.array([5], np.uint64), k1=4, k2=3)
    assert agg.dtype == jnp.dtype(dtype)
    ragg, rs1, rs2 = ref.fused_2hop(rowptr, col, x, seeds, 5, 4, 3)
    np.testing.assert_array_equal(np.asarray(s1), rs1)  # indices exact
    np.testing.assert_allclose(np.asarray(agg, np.float64), ragg,
                               rtol=rtol, atol=rtol)


def test_save_indices_off(small_graph):
    rowptr, col, x = small_graph
    seeds = np.arange(16, dtype=np.int32)
    out = fused_sample_agg_2hop(rowptr, col, x, seeds,
                                np.array([3], np.uint64), k1=4, k2=2,
                                save_indices=False)
    assert out.shape == (16, 16)
    with_idx, _, _ = fused_sample_agg_2hop(
        rowptr, col, x, seeds, np.array([3], np.uint64), k1=4, k2=2)
    # same samples, same means up to XLA reassociation between the two graphs
    np.testing.assert_allclose(np.asarray(out), np.asarray(with_idx),
                               rtol=1e-4, atol=1e-6)


def test_tile_override_changes_nothing(medium_graph):
    rowptr, col, x = medium_graph
    seeds = np.arange(64, dtype=np.int32)
    base = np.array([11], np.uint64)
    a = fused_sample_agg_2hop(rowptr, col, x, seeds, base, k1=5, k2=4,
                              tile=8)
    b = fused_sample_agg_2hop(rowptr, col, x, seeds, base, k1=5, k2=4,
                              tile=64)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))


def test_hubs_sampled_with_replacement(medium_graph):
    """deg > k nodes use the counter-hash rule (the documented
    with-replacement substitution, DESIGN.md §3)."""
    rowptr, col, x = medium_graph
    hub = int(np.argmax(np.diff(rowptr)))
    seeds = np.full(8, hub, np.int32)
    _, s1, _ = fused_sample_agg_2hop(rowptr, col, x, seeds,
                                     np.array([1], np.uint64), k1=6, k2=2)
    s1 = np.asarray(s1)
    # every row identical (same node, same counters)
    for r in range(1, 8):
        np.testing.assert_array_equal(s1[r], s1[0])
    deg = int(rowptr[hub + 1] - rowptr[hub])
    start = int(rowptr[hub])
    want = [int(col[start + ref.rand_counter(1, hub, 0, i) % deg])
            for i in range(6)]
    np.testing.assert_array_equal(s1[0], want)


@given(
    gseed=st.integers(0, 500),
    base=st.integers(0, (1 << 64) - 1),
    k1=st.integers(1, 6),
    k2=st.integers(1, 5),
    b=st.sampled_from([8, 16]),
    d=st.sampled_from([1, 7, 16]),
)
@settings(max_examples=20, deadline=None)
def test_sweep_matches_oracle(gseed, base, k1, k2, b, d):
    rng = np.random.default_rng(gseed)
    rowptr, col = make_csr(60, 10, gseed, isolated_fraction=0.2)
    x = rng.standard_normal((60, d)).astype(np.float32)
    seeds = rng.integers(0, 60, b).astype(np.int32)
    agg, s1, s2, ragg, rs1, rs2 = run_both(rowptr, col, x, seeds, base,
                                           k1, k2)
    np.testing.assert_array_equal(s1, rs1)
    np.testing.assert_array_equal(s2, rs2)
    np.testing.assert_allclose(agg, ragg, rtol=1e-4, atol=1e-5)
