"""Shared fixtures: deterministic random CSR graphs + feature tensors."""
import numpy as np
import pytest


def make_csr(n, max_deg, seed, isolated_fraction=0.1, e_pad=0):
    """Random CSR with controlled degree range and some isolated nodes."""
    rng = np.random.default_rng(seed)
    deg = rng.integers(0, max_deg + 1, n)
    deg[rng.random(n) < isolated_fraction] = 0
    rowptr = np.zeros(n + 1, np.int32)
    rowptr[1:] = np.cumsum(deg)
    e = int(rowptr[-1])
    col = rng.integers(0, n, e + e_pad).astype(np.int32)
    return rowptr, col


@pytest.fixture
def small_graph():
    """(rowptr, col, x) on 200 nodes, 16 features."""
    rowptr, col = make_csr(200, 12, seed=7)
    rng = np.random.default_rng(8)
    x = rng.standard_normal((200, 16)).astype(np.float32)
    return rowptr, col, x


@pytest.fixture
def medium_graph():
    """(rowptr, col, x) on 2000 nodes with hubs, 32 features."""
    rng = np.random.default_rng(9)
    deg = rng.integers(0, 20, 2000)
    deg[::97] = 300  # hubs
    rowptr = np.zeros(2001, np.int32)
    rowptr[1:] = np.cumsum(deg)
    col = rng.integers(0, 2000, int(rowptr[-1])).astype(np.int32)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    return rowptr, col, x
