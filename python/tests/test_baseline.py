"""Baseline (DGL-like) model + the stage-split pipeline (Table 3 stages):
the chained stages must reproduce the monolithic train step exactly."""
import jax
import jax.numpy as jnp
import numpy as np

from compile import baseline, stages
from compile.optim import adamw_update

from .conftest import make_csr


def setup(seed=0, n=120, d=8, h=16, c=5, b=8, k1=4, k2=3):
    rng = np.random.default_rng(seed)
    rowptr, col = make_csr(n, 8, seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    f1 = rng.integers(0, n, (b, 1 + k1)).astype(np.int32)
    s2 = rng.integers(0, n, (b, 1 + k1, k2)).astype(np.int32)
    # sprinkle padding
    f1[0, 2] = -1
    s2[1, :, 1] = -1
    labels = rng.integers(0, c, b).astype(np.int32)
    params = (
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        (rng.standard_normal((d, h)) * 0.2).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.standard_normal((h, c)) * 0.2).astype(np.float32),
        (rng.standard_normal((h, c)) * 0.2).astype(np.float32),
        np.zeros(c, np.float32),
    )
    return x, f1, s2, labels, params


def test_dgl2_forward_shape_and_padding():
    x, f1, s2, labels, params = setup()
    logits = baseline.dgl2_forward(params, x, f1, s2, amp=False)
    assert logits.shape == (8, 5)
    # padding a frontier slot must not change other rows
    f1_mod = f1.copy()
    f1_mod[3, 4] = -1
    logits2 = baseline.dgl2_forward(params, x, f1_mod, s2, amp=False)
    np.testing.assert_array_equal(np.asarray(logits[:3]),
                                  np.asarray(logits2[:3]))
    assert not np.array_equal(np.asarray(logits[3]), np.asarray(logits2[3]))


def test_dgl2_mean_semantics_tiny_case():
    # B=1, k1=1, k2=1: hand-computable
    x = np.array([[1.0], [2.0], [4.0]], np.float32)
    f1 = np.array([[0, 1]], np.int32)       # seed 0, neighbor 1
    s2 = np.array([[[2], [0]]], np.int32)   # seed's hop2 = {2}, nbr's = {0}
    d, h, c = 1, 1, 1
    eye = np.ones((d, h), np.float32)
    params = (eye, eye, np.zeros(h, np.float32),
              np.ones((h, c), np.float32), np.ones((h, c), np.float32),
              np.zeros(c, np.float32))
    logits = baseline.dgl2_forward(params, x, f1, s2, amp=False)
    # h1[seed] = relu(x0 + x2) = 5 ; h1[nbr] = relu(x1 + x0) = 3
    # logits = h_self + mean(h_neigh) = 5 + 3 = 8
    np.testing.assert_allclose(np.asarray(logits), [[8.0]], rtol=1e-6)


def test_dgl1_forward_runs_and_masks():
    x, f1, _, labels, params = setup(1)
    logits = baseline.dgl1_forward(params, x, f1, amp=False)
    assert logits.shape == (8, 5)


def test_train_step_reduces_loss():
    x, f1, s2, labels, params = setup(2)
    ts = baseline.make_dgl_train_step(hops=2, amp=True)
    m = tuple(np.zeros_like(p) for p in params)
    v = tuple(np.zeros_like(p) for p in params)
    jts = jax.jit(ts)
    losses = []
    p = params
    for step in range(25):
        out = jts(p, m, v, jnp.float32(step), x, f1, s2, labels)
        p, m, v = out[:6], out[6:12], out[12:18]
        losses.append(float(out[18]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_stage_pipeline_equals_monolithic_step():
    """gather→layer1→layer2→loss→bwd2→bwd1→adamw == one train step."""
    x, f1, s2, labels, params = setup(3)
    m = tuple(np.zeros_like(p) for p in params)
    v = tuple(np.zeros_like(p) for p in params)
    step = jnp.float32(0)

    # monolithic (same AMP mode as the stages)
    ts = baseline.make_dgl_train_step(hops=2, amp=stages.AMP)
    mono = jax.jit(ts)(params, m, v, step, x, f1, s2, labels)

    # staged
    xf1, block = stages.stage_gather(x, f1, s2)
    (h1,) = stages.stage_layer1(xf1, block, s2, *params[:3])
    (logits,) = stages.stage_layer2(h1, f1, *params[3:])
    loss, glogits = stages.stage_loss(logits, labels)
    gw2s, gw2n, gb2, gh1 = stages.stage_bwd_layer2(h1, f1, glogits,
                                                   params[3], params[4])
    gw1s, gw1n, gb1 = stages.stage_bwd_layer1(xf1, block, s2, h1, gh1,
                                              *params[:3])
    grads = (gw1s, gw1n, gb1, gw2s, gw2n, gb2)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step)

    np.testing.assert_allclose(float(mono[18]), float(loss), rtol=1e-5)
    for i in range(6):
        np.testing.assert_allclose(np.asarray(mono[i]), np.asarray(new_p[i]),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(mono[6 + i]),
                                   np.asarray(new_m[i]),
                                   rtol=2e-4, atol=2e-5)


def test_materialization_barrier_present():
    """the gather stage must survive into the lowered HLO as a real
    intermediate (opt-barrier), not be fused away."""
    x, f1, s2, labels, params = setup(4)
    lowered = jax.jit(
        lambda x_, f1_, s2_: baseline.gather_blocks(x_, f1_, s2_)).lower(
            x, f1, s2)
    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    assert "opt-barrier" in hlo, "materialization barrier was optimized away"
