"""L2: the FuseSampleAgg model — fused op + light SAGE head (paper §5).

The fused operator is wrapped in ``jax.custom_vjp`` implementing the paper's
§3.3 saved-index replay backward: the forward emits the sampled indices, and
the backward scatter-adds the upstream gradient with weights 1/max(1,take)
(1-hop) or 1/(k1_eff · k2_eff) (2-hop). With ``save_indices=False`` the
backward returns zeros for X — the paper's forward-profiling mode.

Head (paper: "fused sampler + mean aggregator followed by a light SAGE-style
head"):   h = relu(X[seeds] @ W_self + agg @ W_neigh + b)
          logits = h @ W_out + b_out
AMP mode runs the head matmuls in bf16 with f32 accumulation/master weights;
the fused op itself stays in the feature dtype (paper §5).
"""
import jax
import jax.numpy as jnp

from .kernels import fused_sample_agg_1hop, fused_sample_agg_2hop
from .optim import adamw_update

# ---------------------------------------------------------------------------
# fused ops with saved-index replay backward
# ---------------------------------------------------------------------------


def make_fsa2_op(k1, k2, save_indices=True, tile=None):
    """2-hop fused op with custom vjp. Fanouts are static (closed over)."""

    @jax.custom_vjp
    def op(rowptr, col, x, seeds, base_seed):
        if save_indices:
            out, _, _ = fused_sample_agg_2hop(
                rowptr, col, x, seeds, base_seed, k1=k1, k2=k2,
                save_indices=True, tile=tile)
            return out
        return fused_sample_agg_2hop(
            rowptr, col, x, seeds, base_seed, k1=k1, k2=k2,
            save_indices=False, tile=tile)

    def fwd(rowptr, col, x, seeds, base_seed):
        if save_indices:
            out, s1, s2 = fused_sample_agg_2hop(
                rowptr, col, x, seeds, base_seed, k1=k1, k2=k2,
                save_indices=True, tile=tile)
            return out, (s1, s2, x.shape[0])
        out = fused_sample_agg_2hop(
            rowptr, col, x, seeds, base_seed, k1=k1, k2=k2,
            save_indices=False, tile=tile)
        return out, (None, None, x.shape[0])

    def bwd(res, g):
        s1, s2, n = res
        xdtype = g.dtype  # fused 2-hop output dtype == feature dtype
        if s1 is None:
            # paper §3.2: without saved indices the autograd path returns
            # zeros for X (forward-profiling only)
            dx = jnp.zeros((n, g.shape[1]), xdtype)
            return None, None, dx, None, None
        g = g.astype(jnp.float32)
        valid1 = (s1 >= 0).astype(jnp.float32)              # [B,k1]
        valid2 = (s2 >= 0).astype(jnp.float32)              # [B,k1,k2]
        k1_eff = jnp.maximum(valid1.sum(-1), 1.0)           # [B]
        k2_eff = jnp.maximum(valid2.sum(-1), 1.0)           # [B,k1]
        w = valid2 / (k1_eff[:, None, None] * k2_eff[:, :, None])
        contrib = g[:, None, None, :] * w[..., None]        # [B,k1,k2,D]
        flat = jnp.maximum(s2.reshape(-1), 0)
        dx = jnp.zeros((n, g.shape[1]), jnp.float32).at[flat].add(
            contrib.reshape(-1, g.shape[1]))
        return None, None, dx.astype(xdtype), None, None

    op.defvjp(fwd, bwd)
    return op


def make_fsa1_op(k, save_indices=True, tile=None):
    """1-hop fused op with custom vjp (FP32-only, paper §4)."""

    @jax.custom_vjp
    def op(rowptr, col, x, seeds, base_seed):
        if save_indices:
            out, _, _ = fused_sample_agg_1hop(
                rowptr, col, x, seeds, base_seed, k=k,
                save_indices=True, tile=tile)
            return out
        return fused_sample_agg_1hop(
            rowptr, col, x, seeds, base_seed, k=k,
            save_indices=False, tile=tile)

    def fwd(rowptr, col, x, seeds, base_seed):
        if save_indices:
            out, samples, takes = fused_sample_agg_1hop(
                rowptr, col, x, seeds, base_seed, k=k,
                save_indices=True, tile=tile)
            return out, (samples, takes, x.shape[0])
        out = fused_sample_agg_1hop(
            rowptr, col, x, seeds, base_seed, k=k,
            save_indices=False, tile=tile)
        return out, (None, None, x.shape[0])

    def bwd(res, g):
        samples, takes, n = res
        if samples is None:
            return None, None, jnp.zeros((n, g.shape[1]), jnp.float32), None, None
        valid = (samples >= 0).astype(jnp.float32)          # [B,k]
        t = jnp.maximum(takes.astype(jnp.float32), 1.0)     # [B]
        w = valid / t[:, None]                              # [B,k]
        contrib = g[:, None, :] * w[..., None]              # [B,k,D]
        flat = jnp.maximum(samples.reshape(-1), 0)
        dx = jnp.zeros((n, g.shape[1]), jnp.float32).at[flat].add(
            contrib.reshape(-1, g.shape[1]))
        return None, None, dx, None, None

    op.defvjp(fwd, bwd)
    return op


# ---------------------------------------------------------------------------
# head / loss / train step
# ---------------------------------------------------------------------------


def _mm(a, w, amp):
    """Matmul with optional bf16 AMP compute and f32 accumulation."""
    if amp:
        return jnp.matmul(a.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
    return jnp.matmul(a, w)


def sage_head(params, x_self, agg, amp):
    """Light SAGE-style head (paper §5): one mean-combine layer + classifier."""
    w_self, w_neigh, b_hidden, w_out, b_out = params
    h = jax.nn.relu(_mm(x_self, w_self, amp)
                    + _mm(agg.astype(jnp.float32), w_neigh, amp)
                    + b_hidden)
    return _mm(h, w_out, amp) + b_out


def cross_entropy(logits, labels):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.take_along_axis(lp, labels[:, None].astype(jnp.int32), axis=1).mean()


def fsa_forward(params, rowptr, col, x, seeds, base_seed, *, hops, k1, k2,
                amp, save_indices=True, tile=None):
    """Forward pass of the fused model; returns logits [B, C]."""
    if hops == 2:
        op = make_fsa2_op(k1, k2, save_indices, tile)
    else:
        op = make_fsa1_op(k1, save_indices, tile)
    agg = op(rowptr, col, x, seeds, base_seed)
    x_self = x[seeds]
    return sage_head(params, x_self, agg, amp)


def make_fsa_train_step(*, hops, k1, k2, amp, save_indices=True, tile=None):
    """Builds the jittable train step:
    (params, m, v, step, rowptr, col, x, seeds, labels, base_seed)
        -> (new_params..., new_m..., new_v..., loss)
    Arg/result order is the contract recorded in the manifest.
    """

    def loss_fn(params, rowptr, col, x, seeds, labels, base_seed):
        logits = fsa_forward(params, rowptr, col, x, seeds, base_seed,
                             hops=hops, k1=k1, k2=k2, amp=amp,
                             save_indices=save_indices, tile=tile)
        return cross_entropy(logits, labels)

    def train_step(params, m, v, step, rowptr, col, x, seeds, labels, base_seed):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, rowptr, col, x, seeds, labels, base_seed)
        new_p, new_m, new_v = adamw_update(params, grads, m, v, step)
        return new_p + new_m + new_v + (loss,)

    return train_step


def make_fsa_eval(*, hops, k1, k2, tile=None):
    """Eval pass: (params, rowptr, col, x, seeds, base_seed) -> (logits,)."""

    def eval_fn(params, rowptr, col, x, seeds, base_seed):
        return (fsa_forward(params, rowptr, col, x, seeds, base_seed,
                            hops=hops, k1=k1, k2=k2, amp=False,
                            save_indices=False, tile=tile),)

    return eval_fn
