"""Build-time compile package: L1 Pallas kernels + L2 JAX models + AOT driver.

Python in this package runs ONLY at build time (``make artifacts``); the Rust
coordinator executes the lowered HLO via PJRT and never imports any of this.

x64 must be enabled before any jax array is created: the deterministic
counter RNG (kernels/rng.py) is defined over uint64.
"""
import jax

jax.config.update("jax_enable_x64", True)
