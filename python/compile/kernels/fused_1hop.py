"""Fused 1-hop sample + mean-aggregate Pallas kernel (paper Alg. 1).

CUDA original: warp-per-seed, lanes stride the D feature dims, reservoir
sampling in registers. TPU re-expression (DESIGN.md §4): seed-tile per grid
step; the whole [TB, k] index tile is computed vectorized on the VPU and the
[TB, k, D] gathered feature tile lives only in VMEM for the duration of one
grid step — no block tensor is ever materialized in HBM.

The 1-hop path is FP32-only, matching the paper (§4 Implementation).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling
from .sampling import masked_mean, sample_neighbors


def _kernel(rowptr_ref, col_ref, x_ref, seeds_ref, base_ref,
            out_ref, samples_ref, takes_ref, *, k, save_indices):
    seeds = seeds_ref[...]                      # [TB] i32 seed tile
    base = base_ref[0]
    samples = sample_neighbors(rowptr_ref[...], col_ref[...], seeds, k, base, hop=0)
    valid = samples >= 0                        # [TB, k]
    gathered = x_ref[jnp.maximum(samples.reshape(-1), 0), :]
    gathered = gathered.reshape(samples.shape + (x_ref.shape[-1],))
    out_ref[...] = masked_mean(gathered, valid, axis=1)
    if save_indices:
        samples_ref[...] = samples
        takes_ref[...] = valid.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "save_indices", "tile"))
def fused_sample_agg_1hop(rowptr, col, x, seeds, base_seed, *, k,
                          save_indices=True, tile=None):
    """Fused 1-hop GraphSAGE-mean forward.

    Args:
      rowptr: [N+1] int32 CSR row pointers.
      col:    [E] int32 CSR column indices (E may be E_cap-padded).
      x:      [N, D] float32 node features (1-hop is FP32-only, per paper §4).
      seeds:  [B] int32 frontier; B must be divisible by the seed tile.
      base_seed: [1] uint64 — the paper's ``base_seed``.
      k:      fanout (static).
      save_indices: also emit ``samples [B,k]`` and ``takes [B]`` for the
        deterministic backward replay (paper §3.3).
      tile:   seed-tile override; default picked by tiling.seed_tile.

    Returns:
      (agg [B,D] f32, samples [B,k] i32, takes [B] i32) when save_indices,
      else agg only.
    """
    if x.dtype != jnp.float32:
        raise TypeError(f"1-hop kernel is FP32-only (paper §4), got {x.dtype}")
    b = seeds.shape[0]
    n, d = x.shape
    tb = tile or tiling.seed_tile(b, k, d)
    if b % tb != 0:
        raise ValueError(f"batch {b} not divisible by seed tile {tb}")
    grid = b // tb

    out_shapes = [jax.ShapeDtypeStruct((b, d), jnp.float32)]
    out_specs = [pl.BlockSpec((tb, d), lambda i: (i, 0))]
    if save_indices:
        out_shapes += [
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        out_specs += [
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ]

    kernel = functools.partial(_kernel, k=k, save_indices=save_indices)
    if not save_indices:
        def kernel(rp, c, xr, s, bs, o, *, _inner=_kernel):  # noqa: F811
            return _inner(rp, c, xr, s, bs, o, None, None, k=k, save_indices=False)

    res = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(rowptr.shape, lambda i: (0,)),
            pl.BlockSpec(col.shape, lambda i: (0,)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec(base_seed.shape, lambda i: (0,)),
        ],
        out_specs=out_specs if save_indices else out_specs[0],
        out_shape=out_shapes if save_indices else out_shapes[0],
        interpret=True,  # CPU-PJRT execution; real-TPU lowering is Mosaic-only
    )(rowptr, col, x, seeds, base_seed)
    return tuple(res) if save_indices else res
