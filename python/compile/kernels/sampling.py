"""Vectorized neighbor sampling shared by the 1-hop and 2-hop kernels.

Sampling rule (DESIGN.md §5), per node u with degree deg, fanout k, slot i:
  * u invalid (-1) or deg == 0      -> -1 (padded, paper §3.2)
  * deg <= k ("take-all")           -> neighbor i if i < deg else -1
  * deg >  k                        -> col[start + rand(base,u,hop,i) % deg]

The rule is pure elementwise u64 math over (base, node, hop, slot), so a
whole [TB, k] tile of sample indices is computed in one vectorized pass —
this is the VPU-friendly re-expression of the paper's per-warp reservoir
loop (DESIGN.md §4). The same rule is implemented by the Rust host sampler
(rust/src/sampler) so the baseline and fused paths draw identical
neighborhoods for a given (base_seed, seed order).
"""
import jax.numpy as jnp

from . import rng


def sample_neighbors(rowptr, col, nodes, k, base, hop):
    """Sample up to ``k`` neighbors for each node in ``nodes``.

    Args:
      rowptr: [N+1] int32 CSR row pointers (jnp array or pallas-read value).
      col:    [E] int32 CSR column indices.
      nodes:  int32 array of any shape; -1 entries are invalid and propagate.
      k:      static fanout.
      base:   scalar uint64 base seed.
      hop:    static hop counter (0 = first hop, 1 = second hop, ...).

    Returns:
      int32 array of shape nodes.shape + (k,), -1-padded.
    """
    if col.shape[0] == 0:
        # edgeless graph (static property): everything pads to -1
        return jnp.full(nodes.shape + (k,), -1, jnp.int32)
    valid_node = nodes >= 0
    u = jnp.maximum(nodes, 0).astype(jnp.int32)
    start = rowptr[u]
    deg = rowptr[u + jnp.int32(1)] - start

    slots_u = jnp.arange(k, dtype=jnp.uint64)
    slots_i = jnp.arange(k, dtype=jnp.int32)
    r = rng.rand_counter(base, u[..., None], hop, slots_u)  # [..., k] u64
    deg_u = jnp.maximum(deg, 1).astype(jnp.uint64)
    idx_rand = (r % deg_u[..., None]).astype(jnp.int32)

    take_all = deg <= k
    # take-all path: slot i -> neighbor i (clamped; masked below)
    pos_seq = start[..., None] + jnp.minimum(slots_i, jnp.maximum(deg - 1, 0)[..., None])
    pos = jnp.where(take_all[..., None], pos_seq, start[..., None] + idx_rand)
    v = col[jnp.maximum(pos, 0)]

    invalid = (
        ~valid_node[..., None]
        | (deg[..., None] == 0)
        | (take_all[..., None] & (slots_i >= deg[..., None]))
    )
    return jnp.where(invalid, jnp.int32(-1), v.astype(jnp.int32))


def masked_mean(feats, valid, axis):
    """Mean of ``feats`` over ``axis`` counting only ``valid`` slots.

    Divides by max(1, #valid) — the paper's k_eff rule (Alg. 1 line 13,
    Alg. 2 lines 7/9). ``feats`` is accumulated in f32 regardless of input
    dtype (the MXU/VPU accumulate in f32 as well).
    """
    vf = valid.astype(jnp.float32)
    num = (feats.astype(jnp.float32) * vf[..., None]).sum(axis=axis)
    den = jnp.maximum(vf.sum(axis=axis), 1.0)
    return num / den[..., None]
