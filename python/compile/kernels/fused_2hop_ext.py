"""Extended fused 2-hop kernel — the paper's §9 future-work items made real:

  (i)  *weighted / importance sampling*: an optional per-edge weight array
       changes the per-edge contribution inside the fused reduction while
       reusing the same index-save/replay path (the paper's exact plan:
       "simply change the per-edge contribution in the fused reduction");
  (ii) *richer aggregators*: ``max`` alongside ``mean``, with the kernel's
       memory footprint unchanged (one gathered tile, one output tile).

Weighted mean per root r:
    X̂_r[d] = (1/k1_eff) Σ_{u valid} ( Σ_{w valid} ew(u,w)·X_w[d] / Σ ew )
Max:
    X̂_r[d] = max_{(u,w) valid} X_w[d]          (0 where nothing is valid)

Both keep the DESIGN.md §5 sampling rule and counter RNG, so samples are
bitwise identical to the plain kernel's. ``sample_positions`` additionally
returns CSR *positions* so edge weights (stored per CSR slot) can be
gathered for the sampled edges.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import rng, tiling
from .sampling import masked_mean


def sample_positions(rowptr, col, nodes, k, base, hop):
    """Like sampling.sample_neighbors but returns (ids, csr_positions);
    positions are -1 padded exactly where ids are."""
    if col.shape[0] == 0:
        pad = jnp.full(nodes.shape + (k,), -1, jnp.int32)
        return pad, pad
    valid_node = nodes >= 0
    u = jnp.maximum(nodes, 0).astype(jnp.int32)
    start = rowptr[u]
    deg = rowptr[u + jnp.int32(1)] - start

    slots_u = jnp.arange(k, dtype=jnp.uint64)
    slots_i = jnp.arange(k, dtype=jnp.int32)
    r = rng.rand_counter(base, u[..., None], hop, slots_u)
    deg_u = jnp.maximum(deg, 1).astype(jnp.uint64)
    idx_rand = (r % deg_u[..., None]).astype(jnp.int32)

    take_all = deg <= k
    pos_seq = start[..., None] + jnp.minimum(
        slots_i, jnp.maximum(deg - 1, 0)[..., None])
    pos = jnp.where(take_all[..., None], pos_seq,
                    start[..., None] + idx_rand)
    v = col[jnp.maximum(pos, 0)]
    invalid = (~valid_node[..., None]) | (deg[..., None] == 0) \
        | (take_all[..., None] & (slots_i >= deg[..., None]))
    ids = jnp.where(invalid, jnp.int32(-1), v.astype(jnp.int32))
    positions = jnp.where(invalid, jnp.int32(-1), pos.astype(jnp.int32))
    return ids, positions


def _kernel(rowptr_ref, col_ref, ew_ref, x_ref, seeds_ref, base_ref,
            out_ref, s2_ref, p2_ref, *, k1, k2, aggregator, weighted):
    seeds = seeds_ref[...]
    base = base_ref[0]
    rowptr = rowptr_ref[...]
    col = col_ref[...]

    s1, _ = sample_positions(rowptr, col, seeds, k1, base, hop=0)
    s2, p2 = sample_positions(rowptr, col, s1, k2, base, hop=1)

    valid1 = s1 >= 0                                     # [TB,k1]
    valid2 = s2 >= 0                                     # [TB,k1,k2]
    gathered = x_ref[jnp.maximum(s2.reshape(-1), 0), :]
    gathered = gathered.reshape(s2.shape + (x_ref.shape[-1],))

    if aggregator == "max":
        neg = jnp.float32(-3.0e38)
        masked = jnp.where(valid2[..., None],
                           gathered.astype(jnp.float32), neg)
        flat = masked.reshape(masked.shape[0], -1, masked.shape[-1])
        mx = flat.max(axis=1)                            # [TB,D]
        any_valid = valid2.reshape(valid2.shape[0], -1).any(axis=1)
        out = jnp.where(any_valid[:, None], mx, 0.0)
    elif weighted:
        w = ew_ref[jnp.maximum(p2.reshape(-1), 0)]
        w = w.reshape(p2.shape) * valid2.astype(jnp.float32)  # [TB,k1,k2]
        num = (gathered.astype(jnp.float32) * w[..., None]).sum(axis=2)
        den = jnp.maximum(w.sum(axis=2), 1e-12)
        inner = num / den[..., None]                     # [TB,k1,D]
        out = masked_mean(inner, valid1, axis=1)
    else:
        inner = masked_mean(gathered, valid2, axis=2)
        out = masked_mean(inner, valid1, axis=1)
    out_ref[...] = out.astype(out_ref.dtype)
    s2_ref[...] = s2
    p2_ref[...] = p2


@functools.partial(jax.jit,
                   static_argnames=("k1", "k2", "aggregator", "tile"))
def fused_sample_agg_2hop_ext(rowptr, col, edge_weights, x, seeds, base_seed,
                              *, k1, k2, aggregator="mean", tile=None):
    """Extended fused 2-hop forward.

    Args:
      edge_weights: [E] float32 per-CSR-slot weights, or None (uniform).
      aggregator: "mean" (optionally weighted) or "max".

    Returns:
      (agg [B,D], s2 [B,k1,k2] sampled ids, p2 [B,k1,k2] CSR positions).
    """
    if aggregator not in ("mean", "max"):
        raise ValueError(f"unknown aggregator {aggregator!r}")
    weighted = edge_weights is not None
    if not weighted:
        edge_weights = jnp.ones((max(col.shape[0], 1),), jnp.float32)
    b = seeds.shape[0]
    n, d = x.shape
    tb = tile or tiling.seed_tile(b, k1 * k2, d,
                                  dtype_bytes=x.dtype.itemsize)
    if b % tb != 0:
        raise ValueError(f"batch {b} not divisible by seed tile {tb}")
    grid = b // tb

    kernel = functools.partial(_kernel, k1=k1, k2=k2, aggregator=aggregator,
                               weighted=weighted)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(rowptr.shape, lambda i: (0,)),
            pl.BlockSpec(col.shape, lambda i: (0,)),
            pl.BlockSpec(edge_weights.shape, lambda i: (0,)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec(base_seed.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb, k1, k2), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, k1, k2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), x.dtype),
            jax.ShapeDtypeStruct((b, k1, k2), jnp.int32),
            jax.ShapeDtypeStruct((b, k1, k2), jnp.int32),
        ],
        interpret=True,
    )(rowptr, col, edge_weights, x, seeds, base_seed)


def make_fsa2_weighted_op(k1, k2, tile=None):
    """Weighted-mean fused op with saved-index replay backward.

    The backward reuses the replay path with the per-edge contribution
    w/(Σw · k1_eff) — exactly the paper's future-work recipe.
    """

    @jax.custom_vjp
    def op(rowptr, col, edge_weights, x, seeds, base_seed):
        out, _, _ = fused_sample_agg_2hop_ext(
            rowptr, col, edge_weights, x, seeds, base_seed,
            k1=k1, k2=k2, aggregator="mean", tile=tile)
        return out

    def fwd(rowptr, col, edge_weights, x, seeds, base_seed):
        out, s2, p2 = fused_sample_agg_2hop_ext(
            rowptr, col, edge_weights, x, seeds, base_seed,
            k1=k1, k2=k2, aggregator="mean", tile=tile)
        # replay needs hop-1 validity for the paper's k1_eff rule (a valid
        # u with an empty neighborhood still counts in the denominator)
        from .sampling import sample_neighbors
        s1 = sample_neighbors(rowptr, col, seeds, k1, base_seed[0], hop=0)
        return out, (s1, s2, p2, edge_weights, x.shape[0])

    def bwd(res, g):
        s1, s2, p2, ew, n = res
        g = g.astype(jnp.float32)
        valid2 = (s2 >= 0).astype(jnp.float32)
        w = ew[jnp.maximum(p2, 0)] * valid2                 # [B,k1,k2]
        den = jnp.maximum(w.sum(-1), 1e-12)                 # [B,k1]
        valid1 = (s1 >= 0).astype(jnp.float32)              # [B,k1]
        k1_eff = jnp.maximum(valid1.sum(-1), 1.0)           # [B]
        coef = w / (den[..., None] * k1_eff[:, None, None])
        contrib = g[:, None, None, :] * coef[..., None]
        flat = jnp.maximum(s2.reshape(-1), 0)
        dx = jnp.zeros((n, g.shape[1]), jnp.float32).at[flat].add(
            contrib.reshape(-1, g.shape[1]))
        return None, None, None, dx, None, None

    op.defvjp(fwd, bwd)
    return op


def make_fsa2_max_op(k1, k2, tile=None):
    """Max-aggregator fused op; backward routes the gradient to the argmax
    element per (root, feature) — the standard max subgradient, replayed
    from the saved indices."""

    @jax.custom_vjp
    def op(rowptr, col, x, seeds, base_seed):
        out, _, _ = fused_sample_agg_2hop_ext(
            rowptr, col, None, x, seeds, base_seed,
            k1=k1, k2=k2, aggregator="max", tile=tile)
        return out

    def fwd(rowptr, col, x, seeds, base_seed):
        out, s2, _ = fused_sample_agg_2hop_ext(
            rowptr, col, None, x, seeds, base_seed,
            k1=k1, k2=k2, aggregator="max", tile=tile)
        return out, (s2, x, out)

    def bwd(res, g):
        s2, x, out = res
        g = g.astype(jnp.float32)
        b, d = g.shape
        valid2 = s2 >= 0                                    # [B,k1,k2]
        flat_ids = jnp.maximum(s2.reshape(b, -1), 0)        # [B,K]
        feats = x[flat_ids].astype(jnp.float32)             # [B,K,D]
        neg = jnp.float32(-3.0e38)
        masked = jnp.where(valid2.reshape(b, -1)[..., None], feats, neg)
        arg = masked.argmax(axis=1)                         # [B,D]
        any_valid = valid2.reshape(b, -1).any(axis=1)       # [B]
        winner = jnp.take_along_axis(flat_ids, arg, axis=1) # [B,D] node ids
        gsel = jnp.where(any_valid[:, None], g, 0.0)
        n = x.shape[0]
        dx = jnp.zeros((n, d), jnp.float32)
        rows = winner.reshape(-1)
        cols = jnp.tile(jnp.arange(d), b)
        dx = dx.at[rows, cols].add(gsel.reshape(-1))
        return None, None, dx.astype(x.dtype), None, None

    op.defvjp(fwd, bwd)
    return op
