"""Deterministic counter RNG — the cross-language contract (DESIGN.md §5).

One pure function of (base_seed, node, hop, slot); no RNG state, no ordering
dependence. Implemented identically in:
  * here (jnp uint64) — used inside the Pallas kernels,
  * ``rust/src/rng/mod.rs`` — used by the host-side baseline sampler,
  * ``python/compile/kernels/ref.py`` — independent numpy oracle.
Golden-vector tests on both sides pin the bit patterns.

The finalizer is splitmix64's (Vigna); the paper derives its xorshift stream
from a splitmix seed the same way (§3.1, [1][15] in the paper).
"""
import jax.numpy as jnp
import numpy as np

# splitmix64 constants
GAMMA = np.uint64(0x9E3779B97F4A7C15)
M2 = np.uint64(0xBF58476D1CE4E5B9)
M3 = np.uint64(0x94D049BB133111EB)
# 32-bit golden ratio used to decorrelate node ids from hop/slot counters
GOLDEN32 = np.uint64(0x9E3779B1)


def mix(z):
    """splitmix64 finalizer over uint64 arrays (elementwise, wrap-around)."""
    z = (z + GAMMA).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(30))) * M2).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(27))) * M3).astype(jnp.uint64)
    return (z ^ (z >> jnp.uint64(31))).astype(jnp.uint64)


def node_key(node, hop):
    """Per-(node,hop) stream key. ``node`` int32/int64 array (>=0), hop scalar."""
    n = node.astype(jnp.uint64)
    return mix(n * GOLDEN32 + jnp.uint64(hop))


def rand_counter(base, node, hop, slot):
    """u64 random word for (base_seed, node, hop, slot). All broadcastable."""
    return mix(base + node_key(node, hop) + slot.astype(jnp.uint64))
