"""Fused 2-hop sample + mean-aggregate Pallas kernel (paper Alg. 2).

CUDA original: block-per-root with shared-memory buffers U[k1], W[k1,k2].
TPU re-expression (DESIGN.md §4): seed-tile per grid step; both hops'
index tiles ([TB,k1] and [TB,k1,k2]) are computed vectorized, and the
gathered [TB,k1,k2,D] feature tile exists only in VMEM for one grid step.
The nested mean uses the paper's k_eff rule exactly:

    X̂_r[d] = (1/k1_eff) Σ_{u∈U valid} (1/k2_eff(u)) Σ_{w∈W[u] valid} X_w[d]

Dtype dispatch matches the paper (§4): features may be f32 / bf16 / f16;
accumulation is always f32; the output is cast back to the feature dtype.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling
from .sampling import masked_mean, sample_neighbors

SUPPORTED_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _kernel(rowptr_ref, col_ref, x_ref, seeds_ref, base_ref,
            out_ref, s1_ref, s2_ref, *, k1, k2, save_indices):
    seeds = seeds_ref[...]                       # [TB] i32 root tile
    base = base_ref[0]
    rowptr = rowptr_ref[...]
    col = col_ref[...]

    s1 = sample_neighbors(rowptr, col, seeds, k1, base, hop=0)   # [TB,k1]
    s2 = sample_neighbors(rowptr, col, s1, k2, base, hop=1)      # [TB,k1,k2]

    valid1 = s1 >= 0
    valid2 = s2 >= 0
    gathered = x_ref[jnp.maximum(s2.reshape(-1), 0), :]
    gathered = gathered.reshape(s2.shape + (x_ref.shape[-1],))   # [TB,k1,k2,D]

    inner = masked_mean(gathered, valid2, axis=2)                # [TB,k1,D] f32
    # A valid u whose own neighborhood is empty contributes 0 but still
    # counts toward k1_eff (paper Alg. 2 lines 7-15).
    outer = masked_mean(inner, valid1, axis=1)                   # [TB,D] f32
    out_ref[...] = outer.astype(out_ref.dtype)
    if save_indices:
        s1_ref[...] = s1
        s2_ref[...] = s2


@functools.partial(jax.jit, static_argnames=("k1", "k2", "save_indices", "tile"))
def fused_sample_agg_2hop(rowptr, col, x, seeds, base_seed, *, k1, k2,
                          save_indices=True, tile=None):
    """Fused 2-hop GraphSAGE-mean forward.

    Args:
      rowptr: [N+1] int32 CSR row pointers.
      col:    [E] int32 CSR column indices (E_cap-padded allowed).
      x:      [N, D] features; f32 / bf16 / f16 (paper §4 dtype dispatch).
      seeds:  [B] int32 roots.
      base_seed: [1] uint64.
      k1, k2: per-hop fanouts (static).
      save_indices: also emit s1 [B,k1], s2 [B,k1,k2] for backward replay.
      tile:   seed-tile override.

    Returns:
      (agg [B,D] x.dtype, s1, s2) when save_indices, else agg only.
    """
    if x.dtype not in [jnp.dtype(t) for t in SUPPORTED_DTYPES]:
        raise TypeError(f"2-hop kernel supports f32/bf16/f16, got {x.dtype}")
    b = seeds.shape[0]
    n, d = x.shape
    tb = tile or tiling.seed_tile(b, k1 * k2, d, dtype_bytes=x.dtype.itemsize)
    if b % tb != 0:
        raise ValueError(f"batch {b} not divisible by seed tile {tb}")
    grid = b // tb

    out_shapes = [jax.ShapeDtypeStruct((b, d), x.dtype)]
    out_specs = [pl.BlockSpec((tb, d), lambda i: (i, 0))]
    if save_indices:
        out_shapes += [
            jax.ShapeDtypeStruct((b, k1), jnp.int32),
            jax.ShapeDtypeStruct((b, k1, k2), jnp.int32),
        ]
        out_specs += [
            pl.BlockSpec((tb, k1), lambda i: (i, 0)),
            pl.BlockSpec((tb, k1, k2), lambda i: (i, 0, 0)),
        ]

    kernel = functools.partial(_kernel, k1=k1, k2=k2, save_indices=save_indices)
    if not save_indices:
        def kernel(rp, c, xr, s, bs, o, *, _inner=_kernel):  # noqa: F811
            return _inner(rp, c, xr, s, bs, o, None, None,
                          k1=k1, k2=k2, save_indices=False)

    res = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(rowptr.shape, lambda i: (0,)),
            pl.BlockSpec(col.shape, lambda i: (0,)),
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec(base_seed.shape, lambda i: (0,)),
        ],
        out_specs=out_specs if save_indices else out_specs[0],
        out_shape=out_shapes if save_indices else out_shapes[0],
        interpret=True,  # CPU-PJRT execution; real-TPU lowering is Mosaic-only
    )(rowptr, col, x, seeds, base_seed)
    return tuple(res) if save_indices else res
