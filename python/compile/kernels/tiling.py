"""Seed-tile selection: the TPU adaptation of the paper's CUDA launch shape.

The CUDA operator maps one warp per seed (1-hop) / one block per root (2-hop)
and stages U[k1], W[k1,k2] in shared memory. On TPU the analogous resource is
VMEM: each Pallas grid step processes a *tile* of TB seeds, and the gathered
feature tile [TB, k1, k2, D] must fit a VMEM budget so that it streams
HBM -> VMEM -> reduce without ever being materialized in HBM
(DESIGN.md §4 Hardware-Adaptation).

interpret=True gives no TPU wallclock, so alongside the tile size we compute
*structural* estimates (VMEM footprint, MXU-relevant flop balance) that are
reported in EXPERIMENTS.md §Perf.
"""
from dataclasses import dataclass

# Default budget: a conservative quarter of the ~16 MiB TPU v4 VMEM, leaving
# room for double buffering and the output tile.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024
VMEM_TOTAL_BYTES = 16 * 1024 * 1024

# Budget for CPU-PJRT execution (this repo's benchmark target): the gathered
# tile should stay L2-resident. Measured on the flagship config
# (products_sim 15-10 B=1024): tile 8 (300 KiB) = 10.8 ms/step vs tile 64
# (2.3 MiB, the VMEM default) = 18.0 ms/step — see EXPERIMENTS.md §Perf and
# `cargo bench --bench tile_sweep`. On a real TPU the VMEM budget binds
# instead; both are just the "fit the fast memory" rule of DESIGN.md §4.
CPU_L2_BUDGET_BYTES = 320 * 1024


def seed_tile(batch, fanout_product, feat_dim, dtype_bytes=4,
              budget=VMEM_BUDGET_BYTES, min_tile=8):
    """Largest power-of-two tile TB dividing ``batch`` whose gathered feature
    tile TB*fanout_product*feat_dim*dtype_bytes fits ``budget``.

    Falls back to min(min_tile, batch) when even the minimum tile overflows
    (the tile then simply spills — interpret mode doesn't care, and on real
    hardware the kernel would switch to feature tiling, see DESIGN.md §4).
    """
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    tb = 1
    while tb * 2 <= batch and batch % (tb * 2) == 0:
        tb *= 2
    # shrink until the tile fits
    while tb > min_tile and tile_bytes(tb, fanout_product, feat_dim, dtype_bytes) > budget:
        tb //= 2
    return max(1, min(tb, batch))


def tile_bytes(tb, fanout_product, feat_dim, dtype_bytes=4):
    """Bytes of the gathered feature tile plus index/output tiles."""
    gather = tb * fanout_product * feat_dim * dtype_bytes
    indices = tb * fanout_product * 4
    out = tb * feat_dim * 4
    return gather + indices + out


@dataclass
class KernelEstimate:
    """Structural perf estimate for one kernel configuration (DESIGN.md §4)."""

    tile: int
    grid: int
    vmem_tile_bytes: int
    vmem_utilization: float       # tile bytes / VMEM budget
    hbm_bytes_per_step: int       # feature words actually read from HBM
    flops_per_step: int           # adds for the mean reduction
    arithmetic_intensity: float   # flops / HBM byte (VPU-bound reduction)


def estimate(batch, k1, k2, feat_dim, dtype_bytes=4, budget=VMEM_BUDGET_BYTES):
    """Estimate for the fused 2-hop kernel (k2=0 means 1-hop)."""
    fp = k1 * max(k2, 1)
    tb = seed_tile(batch, fp, feat_dim, dtype_bytes, budget)
    tbytes = tile_bytes(tb, fp, feat_dim, dtype_bytes)
    hbm = batch * fp * feat_dim * dtype_bytes  # each sampled feature read once
    flops = batch * fp * feat_dim              # one add per gathered element
    return KernelEstimate(
        tile=tb,
        grid=(batch + tb - 1) // tb,
        vmem_tile_bytes=tbytes,
        vmem_utilization=tbytes / budget,
        hbm_bytes_per_step=hbm,
        flops_per_step=flops,
        arithmetic_intensity=flops / max(hbm, 1),
    )
