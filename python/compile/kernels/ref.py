"""Independent numpy oracle for the fused kernels (tests only).

Deliberately written as scalar python-int arithmetic + numpy loops, sharing
NO code with the jnp kernels: the u64 wrap-around semantics are emulated
with explicit ``& MASK64`` on python ints, and sampling/aggregation follow
the paper's Algorithms 1-2 line by line. pytest compares the Pallas kernels
against this oracle bit-for-bit on indices and to fp tolerance on features.

Also provides the paper's *reservoir* sampler (uniform WITHOUT replacement,
Alg. 1 line 6) used to validate the Rust reservoir implementation and to
quantify the with-replacement substitution documented in DESIGN.md §3.
"""
import numpy as np

MASK64 = (1 << 64) - 1
GAMMA = 0x9E3779B97F4A7C15
M2 = 0xBF58476D1CE4E5B9
M3 = 0x94D049BB133111EB
GOLDEN32 = 0x9E3779B1


def mix(z: int) -> int:
    """splitmix64 finalizer on a python int (wraps at 64 bits)."""
    z = (z + GAMMA) & MASK64
    z = ((z ^ (z >> 30)) * M2) & MASK64
    z = ((z ^ (z >> 27)) * M3) & MASK64
    return (z ^ (z >> 31)) & MASK64


def rand_counter(base: int, node: int, hop: int, slot: int) -> int:
    """u64 random word for (base, node, hop, slot) — DESIGN.md §5 contract."""
    key = mix((node * GOLDEN32 + hop) & MASK64)
    return mix((base + key + slot) & MASK64)


def sample_neighbors(rowptr, col, node: int, k: int, base: int, hop: int):
    """Sampling rule of DESIGN.md §5 for one node; returns list of len k."""
    if node < 0:
        return [-1] * k
    start, end = int(rowptr[node]), int(rowptr[node + 1])
    deg = end - start
    if deg == 0:
        return [-1] * k
    if deg <= k:
        return [int(col[start + i]) if i < deg else -1 for i in range(k)]
    out = []
    for i in range(k):
        r = rand_counter(base, node, hop, i)
        out.append(int(col[start + (r % deg)]))
    return out


def reservoir_sample(rowptr, col, node: int, k: int, base: int, hop: int):
    """Paper's Alg. 1 reservoir sampler (uniform WITHOUT replacement).

    Vitter's Algorithm R driven by the same counter RNG: slot i>=k draws
    j = rand(base,node,hop,i) % (i+1) and replaces reservoir[j] if j<k.
    Matches rust/src/sampler/reservoir.rs exactly.
    """
    if node < 0:
        return [-1] * k
    start, end = int(rowptr[node]), int(rowptr[node + 1])
    deg = end - start
    if deg == 0:
        return [-1] * k
    if deg <= k:
        return [int(col[start + i]) if i < deg else -1 for i in range(k)]
    res = [int(col[start + i]) for i in range(k)]
    for i in range(k, deg):
        j = rand_counter(base, node, hop, i) % (i + 1)
        if j < k:
            res[j] = int(col[start + i])
    return res


def fused_1hop(rowptr, col, x, seeds, base: int, k: int):
    """Oracle for Alg. 1: returns (agg [B,D] f64, samples [B,k], takes [B])."""
    b = len(seeds)
    d = x.shape[1]
    agg = np.zeros((b, d), np.float64)
    samples = np.full((b, k), -1, np.int32)
    takes = np.zeros(b, np.int32)
    for bi, u in enumerate(seeds):
        s = sample_neighbors(rowptr, col, int(u), k, base, hop=0)
        samples[bi] = s
        valid = [v for v in s if v >= 0]
        takes[bi] = len(valid)
        if valid:
            agg[bi] = x[valid].astype(np.float64).mean(axis=0)
    return agg, samples, takes


def fused_2hop(rowptr, col, x, seeds, base: int, k1: int, k2: int):
    """Oracle for Alg. 2: returns (agg [B,D] f64, s1 [B,k1], s2 [B,k1,k2])."""
    b = len(seeds)
    d = x.shape[1]
    agg = np.zeros((b, d), np.float64)
    s1_all = np.full((b, k1), -1, np.int32)
    s2_all = np.full((b, k1, k2), -1, np.int32)
    for bi, r in enumerate(seeds):
        s1 = sample_neighbors(rowptr, col, int(r), k1, base, hop=0)
        s1_all[bi] = s1
        acc = np.zeros(d, np.float64)
        k1_eff = 0
        for ui, u in enumerate(s1):
            s2 = sample_neighbors(rowptr, col, u, k2, base, hop=1)
            s2_all[bi, ui] = s2
            if u < 0:
                continue
            k1_eff += 1
            valid = [w for w in s2 if w >= 0]
            if valid:
                acc += x[valid].astype(np.float64).mean(axis=0)
        agg[bi] = acc / max(1, k1_eff)
    return agg, s1_all, s2_all


def backward_2hop_sized(s1, s2, g, n):
    """dX [n,D] from saved indices and upstream grad g [B,D] (paper §3.2)."""
    b, k1, k2 = s2.shape
    d = g.shape[1]
    dx = np.zeros((n, d), np.float64)
    for bi in range(b):
        k1_eff = max(1, int((s1[bi] >= 0).sum()))
        for ui in range(k1):
            if s1[bi, ui] < 0:
                continue
            valid = s2[bi, ui][s2[bi, ui] >= 0]
            k2_eff = max(1, len(valid))
            wgt = 1.0 / (k1_eff * k2_eff)
            for w in valid:
                dx[w] += wgt * g[bi]
    return dx


def backward_1hop_sized(samples, takes, g, n):
    """dX [n,D] for the 1-hop op: dX[v] += g[u]/max(1,take(u)) (paper §3.1)."""
    b, k = samples.shape
    d = g.shape[1]
    dx = np.zeros((n, d), np.float64)
    for bi in range(b):
        t = max(1, int(takes[bi]))
        for v in samples[bi]:
            if v >= 0:
                dx[v] += g[bi] / t
    return dx
