"""L1: fused sample+aggregate Pallas kernels and their support code.

Public surface:
  rng.mix / rng.rand_counter      -- the cross-language deterministic RNG
  tiling.seed_tile                -- VMEM-budget tile-size selection
  fused_1hop.fused_sample_agg_1hop
  fused_2hop.fused_sample_agg_2hop
  ref                             -- independent numpy oracle (tests only)
"""
import jax

jax.config.update("jax_enable_x64", True)

from . import rng, tiling, fused_1hop, fused_2hop  # noqa: E402,F401
from .fused_1hop import fused_sample_agg_1hop  # noqa: E402,F401
from .fused_2hop import fused_sample_agg_2hop  # noqa: E402,F401
