"""L2: the DGL-like baseline — host-sampled blocks, materialized gathers,
two SAGEConv(mean) layers (paper §5 "for the DGL baseline we use two
SAGEConv (mean) layers").

Pipeline shape (the sampler→materialize→aggregate gap the paper attacks):
  1. the Rust host sampler (rust/src/sampler) draws the frontier
     f1 = [seed | s1] and second-hop samples s2 — DGL's NeighborSampler role;
  2. index tensors are uploaded to the device;
  3. this model *materializes* the gathered feature block [B, 1+k1, k2, D]
     (and the frontier features [B, 1+k1, D]) — ``optimization_barrier``
     pins the materialization so XLA cannot fuse it away, because DGL
     genuinely allocates these tensors;
  4. two SAGEConv layers aggregate over the blocks.

-1 entries in f1/s2 are padding (static shapes instead of DGL's dedup'd
dynamic blocks — DESIGN.md §10).
"""
import jax
import jax.numpy as jnp

from .model import cross_entropy, _mm
from .optim import adamw_update


def _materialize(t):
    """Force a real buffer for the gathered block (DGL materializes)."""
    return jax.lax.optimization_barrier(t)


def gather_blocks(x, f1, s2):
    """The materialization stage: frontier features + second-hop block."""
    xf1 = x[jnp.maximum(f1, 0)]                       # [B, 1+k1, D]
    xf1 = _materialize(xf1 * (f1 >= 0)[..., None].astype(x.dtype))
    block = x[jnp.maximum(s2, 0)]                     # [B, 1+k1, k2, D]
    block = _materialize(block)
    return xf1, block


def masked_mean_np(feats, valid, axis):
    """Mean over ``axis`` counting valid slots (f32 accumulation)."""
    vf = valid.astype(jnp.float32)
    num = (feats.astype(jnp.float32) * vf[..., None]).sum(axis=axis)
    den = jnp.maximum(vf.sum(axis=axis), 1.0)
    return num / den[..., None]


def sage_layer1(xf1, block, s2, w_self, w_neigh, b, amp):
    """SAGEConv over the innermost block: h1 for every frontier node."""
    mean2 = masked_mean_np(block, s2 >= 0, axis=2)    # [B, 1+k1, D]
    h = jax.nn.relu(_mm(xf1, w_self, amp) + _mm(mean2, w_neigh, amp) + b)
    return h                                          # [B, 1+k1, H]


def sage_layer2(h1, f1, w_self, w_neigh, b, amp):
    """SAGEConv seeds <- frontier: logits for the B seed nodes."""
    h_self = h1[:, 0]                                 # [B, H] (f1[:,0] = seed)
    neigh_valid = f1[:, 1:] >= 0                      # [B, k1]
    h_neigh = masked_mean_np(h1[:, 1:], neigh_valid, axis=1)
    return _mm(h_self, w_self, amp) + _mm(h_neigh, w_neigh, amp) + b


def dgl2_forward(params, x, f1, s2, amp):
    """2-layer SAGE over host-sampled blocks; returns logits [B, C]."""
    w1s, w1n, b1, w2s, w2n, b2 = params
    xf1, block = gather_blocks(x, f1, s2)
    h1 = sage_layer1(xf1, block, s2, w1s, w1n, b1, amp)
    # zero out padded frontier rows so layer 2's mean sees true zeros
    h1 = h1 * (f1 >= 0)[..., None].astype(h1.dtype)
    return sage_layer2(h1, f1, w2s, w2n, b2, amp)


def dgl1_forward(params, x, f1, amp):
    """1-layer SAGE baseline (f1 = [seed | s1]); w2_neigh is unused."""
    w1s, w1n, b1, w2s, _w2n, b2 = params
    xf1 = _materialize(x[jnp.maximum(f1, 0)]
                       * (f1 >= 0)[..., None].astype(x.dtype))
    h_self = xf1[:, 0]
    h_neigh = masked_mean_np(xf1[:, 1:], f1[:, 1:] >= 0, axis=1)
    h = jax.nn.relu(_mm(h_self, w1s, amp) + _mm(h_neigh, w1n, amp) + b1)
    return _mm(h, w2s, amp) + b2


def make_dgl_eval(*, amp=False):
    """Eval pass over host-sampled blocks: (params, x, f1, s2) -> (logits,)."""

    def eval_fn(params, x, f1, s2):
        return (dgl2_forward(params, x, f1, s2, amp),)

    return eval_fn


def make_dgl_train_step(*, hops, amp):
    """Train step over materialized blocks:
    2-hop: (params, m, v, step, x, f1, s2, labels) -> (new..., loss)
    1-hop: (params, m, v, step, x, f1, labels)     -> (new..., loss)
    """

    if hops == 2:
        def loss_fn(params, x, f1, s2, labels):
            return cross_entropy(dgl2_forward(params, x, f1, s2, amp), labels)

        def train_step(params, m, v, step, x, f1, s2, labels):
            loss, grads = jax.value_and_grad(loss_fn)(params, x, f1, s2, labels)
            new_p, new_m, new_v = adamw_update(params, grads, m, v, step)
            return new_p + new_m + new_v + (loss,)
    else:
        def loss_fn(params, x, f1, labels):
            return cross_entropy(dgl1_forward(params, x, f1, amp), labels)

        def train_step(params, m, v, step, x, f1, labels):
            loss, grads = jax.value_and_grad(loss_fn)(params, x, f1, labels)
            new_p, new_m, new_v = adamw_update(params, grads, m, v, step)
            return new_p + new_m + new_v + (loss,)

    return train_step
