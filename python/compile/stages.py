"""Stage-split baseline executables for the Table 3 profiler reproduction.

The paper profiles one steady-state DGL step with the PyTorch profiler and
reports exclusive CUDA time per operator class (AdamW update, copies,
index/gather, GEMM, GSpMM, loss). Our analogue (DESIGN.md §3): split the
baseline step into separate PJRT executables, one per pipeline stage, and
time each dispatch individually. Stage <-> paper-row mapping:

  host sample + uploads      <-> sampler + aten::copy_
  stage_gather               <-> aten::index (block materialization)
  stage_layer1, stage_layer2 <-> aten::mm + GSpMM (GEMM + mean-reduce)
  stage_loss                 <-> nll_loss_forward
  stage_bwd_layer2/bwd_layer1<-> autograd mm/reduce kernels
  stage_adamw                <-> Optimizer.step#AdamW.step

A pytest verifies that chaining the stages reproduces the monolithic
baseline train step bit-for-bit (same loss, same updated params).
"""
import jax
import jax.numpy as jnp

from .baseline import gather_blocks, masked_mean_np, sage_layer1, sage_layer2
from .optim import adamw_update

AMP = True  # Table 3 is measured with AMP on (paper §7)


def stage_gather(x, f1, s2):
    """Materialize frontier features + second-hop block (aten::index)."""
    return gather_blocks(x, f1, s2)


def stage_layer1(xf1, block, s2, w1_self, w1_neigh, b1):
    h1 = sage_layer1(xf1, block, s2, w1_self, w1_neigh, b1, AMP)
    return (h1,)


def stage_layer2(h1, f1, w2_self, w2_neigh, b2):
    h1 = h1 * (f1 >= 0)[..., None].astype(h1.dtype)
    return (sage_layer2(h1, f1, w2_self, w2_neigh, b2, AMP),)


def stage_loss(logits, labels):
    """Loss value plus dloss/dlogits (nll_loss fwd + the start of bwd)."""

    def ce(lg):
        lp = jax.nn.log_softmax(lg.astype(jnp.float32))
        return -jnp.take_along_axis(lp, labels[:, None].astype(jnp.int32),
                                    axis=1).mean()

    loss, glogits = jax.value_and_grad(ce)(logits)
    return loss, glogits


def stage_bwd_layer2(h1, f1, glogits, w2_self, w2_neigh):
    """Grads of layer 2 wrt (w2_self, w2_neigh, b2-as-sum, h1)."""
    h1m = h1 * (f1 >= 0)[..., None].astype(h1.dtype)

    def f(h1_in, ws, wn):
        return sage_layer2(h1_in, f1, ws, wn,
                           jnp.zeros(w2_self.shape[1], jnp.float32), AMP)

    _, vjp = jax.vjp(f, h1m, w2_self, w2_neigh)
    gh1, gw2s, gw2n = vjp(glogits)
    gb2 = glogits.sum(0)
    gh1 = gh1 * (f1 >= 0)[..., None].astype(gh1.dtype)
    return gw2s, gw2n, gb2, gh1


def stage_bwd_layer1(xf1, block, s2, h1, gh1, w1_self, w1_neigh, b1):
    """Grads of layer 1 wrt (w1_self, w1_neigh, b1). Features are frozen
    inputs in the paper's benchmark, so no gX is produced here."""

    def f(ws, wn, b):
        return sage_layer1(xf1, block, s2, ws, wn, b, AMP)

    _, vjp = jax.vjp(f, w1_self, w1_neigh, b1)
    gw1s, gw1n, gb1 = vjp(gh1)
    return gw1s, gw1n, gb1


def make_stage_adamw(n_params):
    """AdamW update stage over ``n_params`` flat tensors."""

    def stage(*args):
        params = args[:n_params]
        grads = args[n_params:2 * n_params]
        m = args[2 * n_params:3 * n_params]
        v = args[3 * n_params:4 * n_params]
        step = args[4 * n_params]
        new_p, new_m, new_v = adamw_update(params, grads, m, v, step)
        return new_p + new_m + new_v

    return stage


STAGE_FNS = {
    "gather": stage_gather,
    "layer1": stage_layer1,
    "layer2": stage_layer2,
    "loss": stage_loss,
    "bwd_layer2": stage_bwd_layer2,
    "bwd_layer1": stage_bwd_layer1,
}
