"""AOT driver: lower every configured executable to HLO text + manifest.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the published ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (normally via ``make artifacts``):
    cd python && python -m compile.aot --out-dir ../artifacts [--only SUBSTR]

Incremental: a fingerprint of python/compile/**.py is stored next to the
artifacts; when unchanged, existing files are skipped.
"""
import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import baseline, configs, model, stages


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec_to_aval(spec):
    return jax.ShapeDtypeStruct(tuple(spec.shape), np.dtype(spec.dtype))


def _n_params(variant):
    return 5 if variant.startswith("fsa") else 6


def build_fn(cfg):
    """Positional wrapper matching the manifest input order exactly."""
    np_ = _n_params(cfg.variant)

    if cfg.kind == "train" and cfg.variant in ("fsa1", "fsa2"):
        hops = 2 if cfg.variant == "fsa2" else 1
        ts = model.make_fsa_train_step(
            hops=hops, k1=cfg.k1, k2=cfg.k2, amp=cfg.amp,
            save_indices=cfg.save_indices, tile=cfg.tile or None)

        def fn(*args):
            p = tuple(args[:np_])
            m = tuple(args[np_:2 * np_])
            v = tuple(args[2 * np_:3 * np_])
            step = args[3 * np_]
            rowptr, col, x, seeds, labels, base_seed = args[3 * np_ + 1:]
            return ts(p, m, v, step, rowptr, col, x, seeds, labels, base_seed)

        return fn

    if cfg.kind == "train" and cfg.variant in ("dgl1", "dgl2"):
        hops = 2 if cfg.variant == "dgl2" else 1
        ts = baseline.make_dgl_train_step(hops=hops, amp=cfg.amp)

        def fn(*args):
            p = tuple(args[:np_])
            m = tuple(args[np_:2 * np_])
            v = tuple(args[2 * np_:3 * np_])
            step = args[3 * np_]
            rest = args[3 * np_ + 1:]
            return ts(p, m, v, step, *rest)

        return fn

    if cfg.kind == "eval" and cfg.variant.startswith("fsa"):
        hops = 2 if cfg.variant == "fsa2" else 1
        ev = model.make_fsa_eval(hops=hops, k1=cfg.k1, k2=cfg.k2,
                                 tile=cfg.tile or None)

        def fn(*args):
            p = tuple(args[:np_])
            rowptr, col, x, seeds, base_seed = args[np_:]
            return ev(p, rowptr, col, x, seeds, base_seed)

        return fn

    if cfg.kind == "eval" and cfg.variant.startswith("dgl"):
        ev = baseline.make_dgl_eval(amp=False)

        def fn(*args):
            p = tuple(args[:np_])
            x, f1, s2 = args[np_:]
            return ev(p, x, f1, s2)

        return fn

    if cfg.kind == "stage":
        if cfg.variant == "adamw":
            inner = stages.make_stage_adamw(6)
        else:
            inner = stages.STAGE_FNS[cfg.variant]

        def fn(*args):
            out = inner(*args)
            return out if isinstance(out, tuple) else (out,)

        return fn

    raise ValueError(f"unknown config kind/variant: {cfg.kind}/{cfg.variant}")


def lower_config(cfg, out_dir):
    fn = build_fn(cfg)
    avals = [spec_to_aval(s) for s in cfg.inputs]
    # keep_unused: the manifest's input list is a fixed ABI — XLA must not
    # drop parameters that a particular stage happens not to read (e.g.
    # bwd_layer1 receives h1 for interface symmetry only).
    lowered = jax.jit(fn, keep_unused=True).lower(*avals)
    text = to_hlo_text(lowered)

    # sanity: output arity must match the manifest contract
    out_avals = lowered.out_info
    n_out = len(jax.tree_util.tree_leaves(out_avals))
    if n_out != len(cfg.outputs):
        raise RuntimeError(
            f"{cfg.name}: lowered {n_out} outputs, manifest says "
            f"{len(cfg.outputs)}")

    (out_dir / cfg.file).write_text(text)
    return len(text)


def source_fingerprint():
    """Hash of every .py under compile/ — the incremental-build key."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.name.encode())
        h.update(p.read_bytes())
    return h.hexdigest()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    fp_file = out_dir / ".fingerprint"
    fp = source_fingerprint()
    fresh = fp_file.exists() and fp_file.read_text().strip() == fp

    cfgs = configs.all_configs()
    if args.only:
        cfgs = [c for c in cfgs if args.only in c.name]

    t0 = time.time()
    built = skipped = 0
    for i, cfg in enumerate(cfgs):
        path = out_dir / cfg.file
        if fresh and path.exists() and not args.force:
            skipped += 1
            continue
        t = time.time()
        size = lower_config(cfg, out_dir)
        built += 1
        print(f"[{i + 1}/{len(cfgs)}] {cfg.name}: {size / 1024:.0f} KiB "
              f"({time.time() - t:.1f}s)", flush=True)

    manifest = configs.manifest_dict()
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    fp_file.write_text(fp)
    print(f"artifacts: {built} built, {skipped} up-to-date, "
          f"manifest with {len(manifest['artifacts'])} entries "
          f"({time.time() - t0:.1f}s total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
