"""In-graph AdamW (paper §5: lr 3e-3, weight decay 5e-4, AMP-safe f32 states).

The optimizer lives inside the train-step executable so one PJRT dispatch
covers forward + backward + update, matching the paper's "per-step timings
include forward, backward, and optimizer step". Decoupled weight decay per
Loshchilov & Hutter (paper ref [11]).
"""
import jax.numpy as jnp

from .configs import ADAMW


def adamw_update(params, grads, m, v, step, *, lr=ADAMW["lr"], b1=ADAMW["b1"],
                 b2=ADAMW["b2"], eps=ADAMW["eps"], wd=ADAMW["wd"]):
    """One AdamW step over flat tuples. ``step`` is the 0-based step count.

    Returns (new_params, new_m, new_v), all flat tuples in input order.
    """
    t = step + 1.0
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        g = g.astype(jnp.float32)
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        mhat = mi / (1.0 - b1 ** t)
        vhat = vi / (1.0 - b2 ** t)
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return tuple(new_p), tuple(new_m), tuple(new_v)
