"""Single source of truth for every AOT artifact configuration.

Everything the Rust coordinator needs to know about an executable — input
order, shapes, dtypes, dataset dimensions, tile sizes — is derived here and
serialized into ``artifacts/manifest.json``. Rust never re-derives shapes;
it reads the manifest (rust/src/runtime/manifest.rs).

Paper protocol (§5): fanouts {10-10, 15-10, 25-10}, batches {512, 1024},
AMP on, hidden 256, AdamW(3e-3, wd 5e-4). CPU-scale substitutions
(DESIGN.md §6): hidden 64, feature width 64, scaled synthetic datasets.
"""
from dataclasses import dataclass, field

from .kernels import tiling

# ---------------------------------------------------------------------------
# datasets (DESIGN.md §6)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DatasetSpec:
    """Scaled synthetic stand-in for one of the paper's datasets."""

    name: str
    stands_for: str
    n: int            # node count
    e_cap: int        # static CSR edge capacity (undirected, both directions)
    avg_deg: int      # generator target average degree
    degree_law: str   # "powerlaw" | "hubs" | "uniform"
    d: int            # feature width
    c: int            # classes
    gen_seed: int     # generator base seed


DATASETS = {
    s.name: s
    for s in [
        DatasetSpec("arxiv_sim", "ogbn-arxiv", 20_000, 640_000, 14,
                    "powerlaw", 64, 40, 1001),
        DatasetSpec("reddit_sim", "Reddit", 12_000, 2_600_000, 100,
                    "hubs", 64, 41, 1002),
        DatasetSpec("products_sim", "ogbn-products", 32_000, 3_400_000, 50,
                    "powerlaw", 64, 47, 1003),
        DatasetSpec("tiny", "unit tests", 512, 8_192, 6,
                    "uniform", 16, 8, 1000),
    ]
}

HIDDEN = 64
ADAMW = dict(lr=3e-3, b1=0.9, b2=0.999, eps=1e-8, wd=5e-4)  # paper §5

MAIN_FANOUTS = [(10, 10), (15, 10), (25, 10)]
MAIN_BATCHES = [512, 1024]
MAIN_DATASETS = ["arxiv_sim", "reddit_sim", "products_sim"]
FIG2_BATCHES = [128, 256, 512, 1024, 2048]
PROFILE_CONFIG = ("products_sim", 15, 10, 1024)  # paper Table 3 setting

# ---------------------------------------------------------------------------
# tensor + artifact specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple
    dtype: str  # numpy dtype name: "float32", "int32", "uint64", "bfloat16"


@dataclass
class ArtifactConfig:
    """One AOT-compiled executable."""

    name: str
    kind: str          # "train" | "eval" | "stage"
    variant: str       # fsa1|fsa2|dgl1|dgl2|gather|layer1|layer2|loss|bwd2|bwd1|adamw
    dataset: str
    k1: int = 0
    k2: int = 0
    batch: int = 0
    amp: bool = True
    save_indices: bool = True
    hidden: int = HIDDEN
    feat_dtype: str = "float32"  # fused 2-hop dispatches on this (paper §4)
    inputs: list = field(default_factory=list)    # [TensorSpec]
    outputs: list = field(default_factory=list)   # [TensorSpec]
    tile: int = 0
    vmem_tile_bytes: int = 0

    @property
    def file(self):
        return f"{self.name}.hlo.txt"


def _amp_tag(amp):
    return "ampOn" if amp else "ampOff"


# parameter layouts (flat, ordered — the rust<->HLO arg order contract)

def fsa_param_specs(ds, hidden):
    d, c = DATASETS[ds].d, DATASETS[ds].c
    return [
        TensorSpec("w_self", (d, hidden), "float32"),
        TensorSpec("w_neigh", (d, hidden), "float32"),
        TensorSpec("b_hidden", (hidden,), "float32"),
        TensorSpec("w_out", (hidden, c), "float32"),
        TensorSpec("b_out", (c,), "float32"),
    ]


def dgl_param_specs(ds, hidden):
    d, c = DATASETS[ds].d, DATASETS[ds].c
    return [
        TensorSpec("w1_self", (d, hidden), "float32"),
        TensorSpec("w1_neigh", (d, hidden), "float32"),
        TensorSpec("b1", (hidden,), "float32"),
        TensorSpec("w2_self", (hidden, c), "float32"),
        TensorSpec("w2_neigh", (hidden, c), "float32"),
        TensorSpec("b2", (c,), "float32"),
    ]


def param_specs(variant, ds, hidden=HIDDEN):
    return fsa_param_specs(ds, hidden) if variant.startswith("fsa") \
        else dgl_param_specs(ds, hidden)


def graph_input_specs(ds, feat_dtype="float32"):
    s = DATASETS[ds]
    return [
        TensorSpec("rowptr", (s.n + 1,), "int32"),
        TensorSpec("col", (s.e_cap,), "int32"),
        TensorSpec("x", (s.n, s.d), feat_dtype),
    ]


def _opt_state(params):
    return ([TensorSpec(f"m_{p.name}", p.shape, p.dtype) for p in params]
            + [TensorSpec(f"v_{p.name}", p.shape, p.dtype) for p in params])


def train_input_specs(cfg):
    """Input order contract for train artifacts:
    params..., m..., v..., step, <data inputs per variant>."""
    s = DATASETS[cfg.dataset]
    params = param_specs(cfg.variant, cfg.dataset, cfg.hidden)
    common = params + _opt_state(params) + [TensorSpec("step", (), "float32")]
    b = cfg.batch
    if cfg.variant in ("fsa1", "fsa2"):
        data = graph_input_specs(cfg.dataset, cfg.feat_dtype) + [
            TensorSpec("seeds", (b,), "int32"),
            TensorSpec("labels", (b,), "int32"),
            TensorSpec("base_seed", (1,), "uint64"),
        ]
    elif cfg.variant == "dgl2":
        # host-sampled frontier f1 = [seeds | s1] and second-hop s2
        data = [
            TensorSpec("x", (s.n, s.d), "float32"),
            TensorSpec("f1", (b, 1 + cfg.k1), "int32"),
            TensorSpec("s2", (b, 1 + cfg.k1, cfg.k2), "int32"),
            TensorSpec("labels", (b,), "int32"),
        ]
    elif cfg.variant == "dgl1":
        # f1 = [seed | its k1 samples], like dgl2's first-layer frontier
        data = [
            TensorSpec("x", (s.n, s.d), "float32"),
            TensorSpec("f1", (b, 1 + cfg.k1), "int32"),
            TensorSpec("labels", (b,), "int32"),
        ]
    else:
        raise ValueError(cfg.variant)
    return common + data


def train_output_specs(cfg):
    params = param_specs(cfg.variant, cfg.dataset, cfg.hidden)
    outs = ([TensorSpec(f"new_{p.name}", p.shape, p.dtype) for p in params]
            + [TensorSpec(f"new_m_{p.name}", p.shape, p.dtype) for p in params]
            + [TensorSpec(f"new_v_{p.name}", p.shape, p.dtype) for p in params]
            + [TensorSpec("loss", (), "float32")])
    return outs


def eval_input_specs(cfg):
    b = cfg.batch
    s = DATASETS[cfg.dataset]
    params = param_specs(cfg.variant, cfg.dataset, cfg.hidden)
    if cfg.variant.startswith("dgl"):
        # baseline eval consumes host-sampled blocks, like its train step
        return params + [
            TensorSpec("x", (s.n, s.d), "float32"),
            TensorSpec("f1", (b, 1 + cfg.k1), "int32"),
            TensorSpec("s2", (b, 1 + cfg.k1, cfg.k2), "int32"),
        ]
    return params + graph_input_specs(cfg.dataset) + [
        TensorSpec("seeds", (b,), "int32"),
        TensorSpec("base_seed", (1,), "uint64"),
    ]


def eval_output_specs(cfg):
    c = DATASETS[cfg.dataset].c
    return [TensorSpec("logits", (cfg.batch, c), "float32")]


# ---------------------------------------------------------------------------
# the artifact grid
# ---------------------------------------------------------------------------


def _mk(name, kind, variant, dataset, k1=0, k2=0, batch=0, amp=True,
        save_indices=True, tile=None, feat_dtype="float32"):
    cfg = ArtifactConfig(name=name, kind=kind, variant=variant,
                         dataset=dataset, k1=k1, k2=k2, batch=batch, amp=amp,
                         save_indices=save_indices, feat_dtype=feat_dtype)
    s = DATASETS[dataset]
    if variant.startswith("fsa") and batch:
        fp = k1 * max(k2, 1)
        # artifacts in this repo execute on CPU-PJRT: the L2 budget binds
        # (tile_sweep bench, EXPERIMENTS.md §Perf); TPU would use
        # VMEM_BUDGET_BYTES via the same rule.
        nbytes = 2 if feat_dtype in ("bfloat16", "float16") else 4
        cfg.tile = tile or tiling.seed_tile(
            batch, fp, s.d, dtype_bytes=nbytes,
            budget=tiling.CPU_L2_BUDGET_BYTES)
        cfg.vmem_tile_bytes = tiling.tile_bytes(cfg.tile, fp, s.d, nbytes)
    if kind == "train":
        cfg.inputs = train_input_specs(cfg)
        cfg.outputs = train_output_specs(cfg)
    elif kind == "eval":
        cfg.inputs = eval_input_specs(cfg)
        cfg.outputs = eval_output_specs(cfg)
    return cfg


def _train_name(variant, ds, k1, k2, batch, amp, save_indices=True):
    si = "" if save_indices else "_nosave"
    k = f"f{k1}x{k2}" if k2 else f"f{k1}"
    return f"{variant}_train_{ds}_{k}_b{batch}_{_amp_tag(amp)}{si}"


def all_configs():
    """Every artifact to compile — the per-experiment index of DESIGN.md §8."""
    cfgs = []
    seen = set()

    def add(cfg):
        if cfg.name not in seen:
            seen.add(cfg.name)
            cfgs.append(cfg)

    # Main grid: Table 1 / Fig 1 / Table 2 / Figs 4,5 (and Fig 3 subset)
    for ds in MAIN_DATASETS:
        for (k1, k2) in MAIN_FANOUTS:
            for b in MAIN_BATCHES:
                for variant in ("fsa2", "dgl2"):
                    add(_mk(_train_name(variant, ds, k1, k2, b, True),
                            "train", variant, ds, k1, k2, b, amp=True))

    # Fig 2: batch scaling on products_sim, fanout 15-10
    for b in FIG2_BATCHES:
        for variant in ("fsa2", "dgl2"):
            add(_mk(_train_name(variant, "products_sim", 15, 10, b, True),
                    "train", variant, "products_sim", 15, 10, b, amp=True))

    # Ablation: AMP off (arxiv_sim 15-10 b1024)
    for variant in ("fsa2", "dgl2"):
        add(_mk(_train_name(variant, "arxiv_sim", 15, 10, 1024, False),
                "train", variant, "arxiv_sim", 15, 10, 1024, amp=False))

    # Ablation: 1-hop vs 2-hop (k=10, b1024, all datasets)
    for ds in MAIN_DATASETS:
        for variant in ("fsa1", "dgl1"):
            add(_mk(_train_name(variant, ds, 10, 0, 1024, True),
                    "train", variant, ds, 10, 0, 1024, amp=True))

    # Ablation: save_indices off (forward-profiling mode, paper §3.2)
    add(_mk(_train_name("fsa2", "products_sim", 15, 10, 1024, True, False),
            "train", "fsa2", "products_sim", 15, 10, 1024, amp=True,
            save_indices=False))

    # Eval (validation accuracy for the e2e / time-to-accuracy examples)
    for ds in MAIN_DATASETS + ["tiny"]:
        add(_mk(f"fsa2_eval_{ds}_f15x10_b512", "eval", "fsa2", ds,
                15, 10, 512, amp=False))
        add(_mk(f"dgl2_eval_{ds}_f15x10_b512", "eval", "dgl2", ds,
                15, 10, 512, amp=False))

    # Tiny configs for rust integration tests + quickstart
    for variant in ("fsa2", "dgl2"):
        add(_mk(_train_name(variant, "tiny", 5, 3, 64, True),
                "train", variant, "tiny", 5, 3, 64, amp=True))
    add(_mk(_train_name("fsa1", "tiny", 5, 0, 64, True),
            "train", "fsa1", "tiny", 5, 0, 64, amp=True))
    add(_mk(_train_name("dgl1", "tiny", 5, 0, 64, True),
            "train", "dgl1", "tiny", 5, 0, 64, amp=True))

    # §Perf seed-tile sweep (the paper's "autotuning over block sizes"
    # future-work knob): same config, different HBM<->VMEM schedules.
    for tile in (8, 16, 32, 64, 256, 1024):
        add(_mk(f"fsa2_train_products_sim_f15x10_b1024_ampOn_t{tile}",
                "train", "fsa2", "products_sim", 15, 10, 1024, amp=True,
                tile=tile))

    # §Perf feature-dtype dispatch (paper §4: the fused 2-hop runs in the
    # native tensor dtype): bf16 features halve the gather traffic.
    add(_mk("fsa2_train_products_sim_f15x10_b1024_ampOn_xbf16",
            "train", "fsa2", "products_sim", 15, 10, 1024, amp=True,
            feat_dtype="bfloat16"))

    # Table 3 profile stages (baseline decomposition, products 15-10 b1024)
    ds, k1, k2, b = PROFILE_CONFIG
    for stage in ("gather", "layer1", "layer2", "loss",
                  "bwd_layer2", "bwd_layer1", "adamw"):
        add(_stage_config(stage, ds, k1, k2, b))

    return cfgs


def _stage_config(stage, ds, k1, k2, b):
    """Stage-split baseline executables for Table 3 (DESIGN.md §8)."""
    s = DATASETS[ds]
    h = HIDDEN
    f1 = 1 + k1
    cfg = ArtifactConfig(
        name=f"stage_{stage}_{ds}_f{k1}x{k2}_b{b}",
        kind="stage", variant=stage, dataset=ds, k1=k1, k2=k2, batch=b)
    t = TensorSpec
    if stage == "gather":
        cfg.inputs = [t("x", (s.n, s.d), "float32"),
                      t("f1", (b, f1), "int32"),
                      t("s2", (b, f1, k2), "int32")]
        cfg.outputs = [t("xf1", (b, f1, s.d), "float32"),
                       t("block", (b, f1, k2, s.d), "float32")]
    elif stage == "layer1":
        cfg.inputs = [t("xf1", (b, f1, s.d), "float32"),
                      t("block", (b, f1, k2, s.d), "float32"),
                      t("s2", (b, f1, k2), "int32"),
                      t("w1_self", (s.d, h), "float32"),
                      t("w1_neigh", (s.d, h), "float32"),
                      t("b1", (h,), "float32")]
        cfg.outputs = [t("h1", (b, f1, h), "float32")]
    elif stage == "layer2":
        cfg.inputs = [t("h1", (b, f1, h), "float32"),
                      t("f1", (b, f1), "int32"),
                      t("w2_self", (h, s.c), "float32"),
                      t("w2_neigh", (h, s.c), "float32"),
                      t("b2", (s.c,), "float32")]
        cfg.outputs = [t("logits", (b, s.c), "float32")]
    elif stage == "loss":
        cfg.inputs = [t("logits", (b, s.c), "float32"),
                      t("labels", (b,), "int32")]
        cfg.outputs = [t("loss", (), "float32"),
                       t("glogits", (b, s.c), "float32")]
    elif stage == "bwd_layer2":
        cfg.inputs = [t("h1", (b, f1, h), "float32"),
                      t("f1", (b, f1), "int32"),
                      t("glogits", (b, s.c), "float32"),
                      t("w2_self", (h, s.c), "float32"),
                      t("w2_neigh", (h, s.c), "float32")]
        cfg.outputs = [t("gw2_self", (h, s.c), "float32"),
                       t("gw2_neigh", (h, s.c), "float32"),
                       t("gb2", (s.c,), "float32"),
                       t("gh1", (b, f1, h), "float32")]
    elif stage == "bwd_layer1":
        cfg.inputs = [t("xf1", (b, f1, s.d), "float32"),
                      t("block", (b, f1, k2, s.d), "float32"),
                      t("s2", (b, f1, k2), "int32"),
                      t("h1", (b, f1, h), "float32"),
                      t("gh1", (b, f1, h), "float32"),
                      t("w1_self", (s.d, h), "float32"),
                      t("w1_neigh", (s.d, h), "float32"),
                      t("b1", (h,), "float32")]
        cfg.outputs = [t("gw1_self", (s.d, h), "float32"),
                       t("gw1_neigh", (s.d, h), "float32"),
                       t("gb1", (h,), "float32")]
    elif stage == "adamw":
        params = dgl_param_specs(ds, h)
        cfg.inputs = (params
                      + [t(f"g_{p.name}", p.shape, p.dtype) for p in params]
                      + [t(f"m_{p.name}", p.shape, p.dtype) for p in params]
                      + [t(f"v_{p.name}", p.shape, p.dtype) for p in params]
                      + [t("step", (), "float32")])
        cfg.outputs = ([t(f"new_{p.name}", p.shape, p.dtype) for p in params]
                       + [t(f"new_m_{p.name}", p.shape, p.dtype) for p in params]
                       + [t(f"new_v_{p.name}", p.shape, p.dtype) for p in params])
    else:
        raise ValueError(stage)
    return cfg


def manifest_dict():
    """The structure serialized to artifacts/manifest.json."""
    return {
        "version": 1,
        "hidden": HIDDEN,
        "adamw": ADAMW,
        "datasets": {
            name: {
                "stands_for": s.stands_for, "n": s.n, "e_cap": s.e_cap,
                "avg_deg": s.avg_deg, "degree_law": s.degree_law,
                "d": s.d, "c": s.c, "gen_seed": s.gen_seed,
            }
            for name, s in DATASETS.items()
        },
        "artifacts": [
            {
                "name": c.name, "file": c.file, "kind": c.kind,
                "variant": c.variant, "dataset": c.dataset,
                "k1": c.k1, "k2": c.k2, "batch": c.batch,
                "amp": c.amp, "save_indices": c.save_indices,
                "hidden": c.hidden, "tile": c.tile,
                "feat_dtype": c.feat_dtype,
                "vmem_tile_bytes": c.vmem_tile_bytes,
                "inputs": [
                    {"name": i.name, "shape": list(i.shape), "dtype": i.dtype}
                    for i in c.inputs
                ],
                "outputs": [
                    {"name": o.name, "shape": list(o.shape), "dtype": o.dtype}
                    for o in c.outputs
                ],
            }
            for c in all_configs()
        ],
    }
