//! Memory explorer: how the sample→materialize→aggregate gap inflates
//! transient memory, and what fusion removes (paper §6.5, Table 2).
//!
//! Sweeps fanout × batch on one dataset, printing the analytic transient
//! model side by side with a short *measured* run of both variants.
//!
//! ```sh
//! cargo run --release --example memory_explorer [-- dataset=arxiv_sim]
//! ```

use anyhow::Result;
use fusesampleagg::bench::run_config;
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::builtin_spec;
use fusesampleagg::memory::{baseline_transient, fused_transient, StepDims};
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util::bytes_to_mb;

fn main() -> Result<()> {
    let mut dataset = "arxiv_sim".to_string();
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("dataset=") {
            dataset = v.to_string();
        }
    }
    let spec = builtin_spec(&dataset)?;
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();

    println!("transient memory on {dataset} — analytic model vs measured \
              (5 timed steps)\n");
    println!("{:<10} {:<7} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>7}",
             "fanout", "batch", "model DGL", "model FSA", "ratio",
             "meas DGL", "meas FSA", "ratio");
    println!("{:-<92}", "");

    // width sweep at depth 2, plus a 3-hop row at the 15·10 leaf budget
    for fanouts in [Fanouts::of(&[10, 10]), Fanouts::of(&[15, 10]),
                    Fanouts::of(&[25, 10]), Fanouts::of(&[15, 5, 2])] {
        for batch in [512usize, 1024] {
            let dims = StepDims {
                batch,
                fanouts: fanouts.clone(),
                d: spec.d,
                hidden: rt.manifest.hidden,
                classes: spec.c,
                tile: 64,
            };
            let model_dgl = baseline_transient(&dims).peak_hbm();
            let model_fsa = fused_transient(&dims, true).peak_hbm();

            let mut measure = |variant| -> Result<u64> {
                let cfg = TrainConfig {
                    variant,
                    dataset: dataset.clone(),
                    fanouts: fanouts.clone(),
                    batch,
                    amp: true,
                    save_indices: true,
                    seed: 42,
                    threads: 1,
                    prefetch: false,
                    backend: Default::default(),
                    planner: Default::default(),
                    planner_state: None,
                    simd: Default::default(),
                    layout: Default::default(),
                    faults: fusesampleagg::runtime::faults::none(),
                    hub_cache: None,
                };
                Ok(run_config(&rt, &mut cache, cfg, 1, 5)?
                    .peak_transient_bytes)
            };
            let meas_dgl = measure(Variant::Dgl)?;
            let meas_fsa = measure(Variant::Fsa)?;

            println!("{:<10} {:<7} | {:>9.1}M {:>9.2}M {:>6.1}x | {:>9.1}M \
                      {:>9.2}M {:>6.1}x",
                     fanouts.label(), batch,
                     bytes_to_mb(model_dgl), bytes_to_mb(model_fsa),
                     model_dgl as f64 / model_fsa as f64,
                     bytes_to_mb(meas_dgl), bytes_to_mb(meas_fsa),
                     meas_dgl as f64 / meas_fsa as f64);
        }
    }
    println!("\nThe materialized block Θ(B·Π(1+k_j)·k_L·D) dominates the \
              baseline and multiplies with depth; the fused path's \
              transients stay Θ(B·D) + saved indices (paper §4 complexity \
              summary).");
    Ok(())
}
