//! Quickstart: train GraphSAGE with the fused sample+aggregate operator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface on the `tiny` dataset: generate a
//! dataset, train with the FuseSampleAgg variant for a few steps, compare
//! against the DGL-like baseline, and evaluate. No artifacts needed — the
//! default `auto` backend runs the native CPU engine when the AOT/PJRT
//! path is unavailable (`make artifacts` + real bindings switch it over).

use anyhow::Result;
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::runtime::Runtime;

fn main() -> Result<()> {
    // 1. the runtime loads artifacts/manifest.json and compiles HLO on use
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();

    // 2. a training configuration = one cell of the paper's grid
    let cfg = TrainConfig {
        variant: Variant::Fsa,      // the fused operator
        dataset: "tiny".into(),
        fanouts: Fanouts::of(&[5, 3]), // any depth: &[5], &[5,3], &[5,3,2]…
        batch: 64,
        amp: true,
        save_indices: true,         // exact backward replay (paper §3.3)
        seed: 42,
        threads: 1,                 // host sampler workers (0 = auto)
        prefetch: false,            // overlap sampling with dispatch
        backend: Default::default(),    // auto: PJRT, else native engine
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };

    // 3. train for 40 steps
    let mut trainer = Trainer::new(&rt, &mut cache, cfg.clone())?;
    println!("training FuseSampleAgg on `tiny` ({} nodes, {} edges)",
             trainer.ds.spec.n, trainer.ds.graph.num_edges());
    let mut first_loss = None;
    let mut last = Default::default();
    for step in 0..40 {
        let t = trainer.step()?;
        first_loss.get_or_insert(t.loss);
        last = t;
        if step % 10 == 0 {
            println!("  step {step:>3}: loss {:.4}  ({:.2} ms)", t.loss,
                     t.total_ms());
        }
    }
    println!("loss: {:.4} -> {:.4}", first_loss.unwrap(), last.loss);
    println!("validation accuracy: {:.3}", trainer.evaluate(512)?);

    // 4. the baseline pipeline, same seeds, same neighborhoods
    let mut baseline = Trainer::new(&rt, &mut cache, TrainConfig {
        variant: Variant::Dgl,
        hub_cache: None,
        ..cfg
    })?;
    let mut base_ms = Vec::new();
    for _ in 0..40 {
        base_ms.push(baseline.step()?.total_ms());
    }
    let fsa_ms = last.total_ms();
    let dgl_ms = fusesampleagg::metrics::median(&base_ms);
    println!("step time: DGL-like {dgl_ms:.2} ms vs FSA {fsa_ms:.2} ms \
              ({:.2}x)", dgl_ms / fsa_ms);
    Ok(())
}
