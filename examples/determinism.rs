//! Determinism & replay demo (paper §3.3).
//!
//! Shows the three determinism properties the paper claims:
//!  1. bitwise-identical runs: same (seed, data) → identical loss sequence;
//!  2. paired sampling: the host sampler (baseline path) and the fused
//!     kernel (inside the artifact) draw the *same* neighborhoods from the
//!     same base_seed — verified here by replaying the host sampler against
//!     the counter-RNG contract;
//!  3. seed sensitivity: changing base_seed changes the samples.
//!
//! ```sh
//! cargo run --release --example determinism
//! ```

use anyhow::Result;
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::rng::rand_counter;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::sampler;

fn losses(rt: &Runtime, cache: &mut DatasetCache, seed: u64,
          steps: usize) -> Result<Vec<f64>> {
    let cfg = TrainConfig {
        variant: Variant::Fsa,
        dataset: "tiny".into(),
        fanouts: Fanouts::of(&[5, 3]),
        batch: 64,
        amp: true,
        save_indices: true,
        seed,
        threads: 1,
        prefetch: false,
        backend: Default::default(),
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let mut trainer = Trainer::new(rt, cache, cfg)?;
    (0..steps).map(|_| Ok(trainer.step()?.loss)).collect()
}

fn main() -> Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();

    // 1. bitwise repeatability of the full training loop
    let a = losses(&rt, &mut cache, 42, 20)?;
    let b = losses(&rt, &mut cache, 42, 20)?;
    assert_eq!(a, b, "identical seeds must give identical loss sequences");
    println!("1. replay: 20-step loss sequences bitwise identical ✓");

    let c = losses(&rt, &mut cache, 43, 20)?;
    assert_ne!(a, c, "different seeds should differ");
    println!("   (seed 43 differs from seed 42, as expected ✓)");

    // 2. the sampling rule is a pure counter function — replay one draw
    let ds = Dataset::generate(builtin_spec("tiny")?)?;
    let base = 0xFEED;
    let node = (0..ds.spec.n as i32)
        .find(|&u| ds.graph.degree(u) > 4)
        .expect("a node with degree > 4");
    let mut out = vec![0i32; 4];
    sampler::sample_neighbors(&ds.graph, node, 4, base, 0, &mut out);
    let deg = ds.graph.degree(node) as u64;
    let ns = ds.graph.neighbors(node);
    for (slot, &v) in out.iter().enumerate() {
        let expect = ns[(rand_counter(base, node as u64, 0, slot as u64)
            % deg) as usize];
        assert_eq!(v, expect);
    }
    println!("2. saved-index replay: host sampler reproduces the counter-RNG \
              contract (node {node}, samples {out:?}) ✓");

    // 3. seed sensitivity of raw sampling
    let mut other = vec![0i32; 4];
    sampler::sample_neighbors(&ds.graph, node, 4, base + 1, 0, &mut other);
    assert_ne!(out, other);
    println!("3. base_seed sensitivity: {out:?} vs {other:?} ✓");

    println!("\ndeterminism demo OK");
    Ok(())
}
