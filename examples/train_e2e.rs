//! End-to-end driver: full-system training run on a realistic workload.
//!
//! Trains 2-layer GraphSAGE via the fused operator on `products_sim`
//! (32k nodes, ~2.4M undirected edges — the ogbn-products stand-in) for a
//! few hundred steps, evaluating on the validation split along the way and
//! writing the loss curve to `results/e2e_loss.csv`. This proves all three
//! layers compose: Pallas fused kernel (L1) inside the jitted train step
//! (L2) dispatched by the Rust coordinator (L3) — with Python nowhere on
//! the path. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! cargo run --release --example train_e2e \
//!     [-- steps=300 dataset=products_sim threads=4 prefetch=on \
//!      fanout=15x10x5]
//! ```

use std::fmt::Write as _;

use anyhow::Result;
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::metrics::{summarize, Timer};
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

fn main() -> Result<()> {
    let mut steps = 300usize;
    let mut dataset = "products_sim".to_string();
    let mut threads = 1usize;
    let mut prefetch = false;
    let mut fanouts = Fanouts::of(&[15, 10]);
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("steps=") {
            steps = v.parse()?;
        } else if let Some(v) = arg.strip_prefix("dataset=") {
            dataset = v.to_string();
        } else if let Some(v) = arg.strip_prefix("threads=") {
            threads = v.parse()?;
        } else if let Some(v) = arg.strip_prefix("prefetch=") {
            prefetch = v == "on" || v == "true";
        } else if let Some(v) = arg.strip_prefix("fanout=") {
            fanouts = Fanouts::parse(v)?;
        }
    }

    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let hops = fanouts.depth();
    let cfg = TrainConfig {
        variant: Variant::Fsa,
        dataset: dataset.clone(),
        fanouts,
        batch: 1024,
        amp: true,
        save_indices: true,
        seed: 42,
        threads,
        prefetch,
        backend: Default::default(),
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let total = Timer::start();
    let mut trainer = Trainer::new(&rt, &mut cache, cfg)?;
    println!("e2e: training fsa{hops} on {dataset} ({} nodes, {} edges, {} \
              classes) for {steps} steps",
             trainer.ds.spec.n, trainer.ds.graph.num_edges(),
             trainer.ds.spec.c);

    let mut csv = String::from("step,loss,step_ms,val_acc\n");
    let mut step_times = Vec::new();
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for step in 0..steps {
        let t = trainer.step()?;
        if step == 0 {
            first = t.loss;
        }
        last = t.loss;
        step_times.push(t.total_ms());
        let eval_now = step % 50 == 0 || step == steps - 1;
        let acc = if eval_now { trainer.evaluate(2048)? } else { f64::NAN };
        let _ = writeln!(csv, "{},{:.5},{:.3},{:.4}", step, t.loss,
                         t.total_ms(), acc);
        if eval_now {
            println!("  step {step:>4}: loss {:.4}  val_acc {:.3}  \
                      ({:.2} ms/step)", t.loss, acc, t.total_ms());
        }
    }
    let path = util::results_dir().join("e2e_loss.csv");
    std::fs::write(&path, csv)?;

    let s = summarize(&step_times);
    let final_acc = trainer.evaluate(4096)?;
    let chance = 1.0 / trainer.ds.spec.c as f64;
    println!("\n== e2e summary ==");
    println!("loss {first:.4} -> {last:.4} over {steps} steps");
    println!("final val accuracy {final_acc:.3} (chance {chance:.3})");
    println!("median step {:.2} ms (p90 {:.2}); total wall {:.1}s",
             s.median, s.p90, total.ms() / 1e3);
    println!("loss curve written to {}", path.display());

    anyhow::ensure!(last < first * 0.7,
                    "loss did not decrease enough ({first:.3} -> {last:.3})");
    anyhow::ensure!(final_acc > 3.0 * chance,
                    "accuracy {final_acc:.3} not above chance {chance:.3}");
    println!("e2e OK: loss decreased and accuracy beats chance");
    Ok(())
}
