//! Time-to-accuracy: the paper's bottom-line claim ("for graph-centric,
//! training-bound workloads these gains translate into … faster
//! iteration", §10) measured directly — wall-clock to reach a target
//! validation accuracy, fused vs baseline, same seeds, same sampling
//! schedule.
//!
//! ```sh
//! cargo run --release --example time_to_accuracy [-- target=0.95 dataset=arxiv_sim]
//! ```

use anyhow::Result;
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::metrics::Timer;
use fusesampleagg::runtime::Runtime;

fn run(rt: &Runtime, cache: &mut DatasetCache, variant: Variant,
       dataset: &str, target: f64, max_steps: usize)
       -> Result<(f64, usize, f64)> {
    let cfg = TrainConfig {
        variant,
        dataset: dataset.into(),
        fanouts: Fanouts::of(&[15, 10]),
        batch: 1024,
        amp: true,
        save_indices: true,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend: Default::default(),
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let mut tr = Trainer::new(rt, cache, cfg)?;
    let timer = Timer::start();
    let mut train_ms = 0.0;
    for step in 1..=max_steps {
        let t = tr.step()?;
        train_ms += t.total_ms();
        if step % 10 == 0 {
            // eval time is excluded from the clock (both variants share it)
            let acc = tr.evaluate(1024)?;
            if acc >= target {
                return Ok((train_ms, step, acc));
            }
        }
    }
    let acc = tr.evaluate(1024)?;
    let _ = timer; // total wall includes eval; train_ms is the fair clock
    Ok((train_ms, max_steps, acc))
}

fn main() -> Result<()> {
    let mut target = 0.95f64;
    let mut dataset = "arxiv_sim".to_string();
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("target=") {
            target = v.parse()?;
        } else if let Some(v) = arg.strip_prefix("dataset=") {
            dataset = v.to_string();
        }
    }
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();

    println!("time-to-accuracy on {dataset} (target val acc {target}, \
              fanout 15-10, B=1024, AMP on)\n");
    let (dgl_ms, dgl_steps, dgl_acc) =
        run(&rt, &mut cache, Variant::Dgl, &dataset, target, 500)?;
    println!("DGL-like: {:>8.1} ms training time, {dgl_steps} steps, \
              acc {dgl_acc:.3}", dgl_ms);
    let (fsa_ms, fsa_steps, fsa_acc) =
        run(&rt, &mut cache, Variant::Fsa, &dataset, target, 500)?;
    println!("FSA:      {:>8.1} ms training time, {fsa_steps} steps, \
              acc {fsa_acc:.3}", fsa_ms);
    if fsa_acc >= target && dgl_acc >= target {
        println!("\nspeedup to target: {:.2}x (same seeds, same sampling \
                  schedule — steps should be comparable; the win is per-step \
                  time)", dgl_ms / fsa_ms);
    } else {
        println!("\ntarget not reached within 500 steps on at least one \
                  variant — lower `target=`");
    }
    Ok(())
}
