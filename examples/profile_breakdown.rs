//! Profiler breakdown example (paper §7, Table 3).
//!
//! Runs the baseline training step as a stage-split pipeline and prints the
//! exclusive-time table — the PJRT analogue of the paper's PyTorch profiler
//! run, which attributed ~50% of baseline GPU time to the AdamW update and
//! ~19% to copies/gathers.
//!
//! ```sh
//! cargo run --release --example profile_breakdown [-- steps=10]
//! ```

use anyhow::Result;
use fusesampleagg::bench::render;
use fusesampleagg::coordinator::{profile, DatasetCache};
use fusesampleagg::runtime::Runtime;

fn main() -> Result<()> {
    let mut steps = 10usize;
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("steps=") {
            steps = v.parse()?;
        }
    }
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let report = profile::profile_baseline(&rt, &mut cache, 2, steps, 42)?;
    println!("{}", render::table3(&report));
    println!("Reading guide (stage ↔ paper Table 3 rows):");
    println!("  sample(host)+copy ↔ sampler + aten::copy_");
    println!("  gather            ↔ aten::index (block materialization)");
    println!("  layer1/layer2     ↔ aten::mm + GSpMM");
    println!("  loss              ↔ nll_loss_forward");
    println!("  bwd_*             ↔ autograd mm/reduce kernels");
    println!("  adamw             ↔ Optimizer.step#AdamW.step");
    Ok(())
}
