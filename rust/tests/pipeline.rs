//! Host-pipeline invariants (no artifacts needed — pure host path):
//!
//! 1. the parallel sampler's [`Block`] output is **bitwise equal** to the
//!    serial sampler for thread counts {1, 2, 8} at depths 1, 2, and 3;
//! 2. the prefetch pipeline leaves the paired **seed order** and
//!    **base-seed schedule** unchanged — batches stream in the exact
//!    order and with the exact base seeds the synchronous path produces,
//!    across epoch reshuffle boundaries;
//! 3. the `throughput` bench mode reports the knobs faithfully.

use std::sync::Arc;

use fusesampleagg::bench::throughput::{run_throughput, ThroughputConfig};
use fusesampleagg::coordinator::pipeline::{prepare_batch, BatchPrefetcher,
                                           BatchScheduler, HostWork};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::rng::SplitMix64;
use fusesampleagg::sampler::{self, ParallelSampler};

fn tiny() -> Arc<Dataset> {
    Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap())
}

fn random_nodes(ds: &Dataset, n: usize, seed: u64) -> Vec<i32> {
    let mut r = SplitMix64::new(seed);
    (0..n).map(|_| r.next_below(ds.spec.n as u64) as i32).collect()
}

#[test]
fn blocks_bitwise_identical_for_1_2_8_threads_at_depths_1_2_3() {
    let ds = tiny();
    let seeds = random_nodes(&ds, 512, 1);
    for fo in [Fanouts::of(&[10]), Fanouts::of(&[15, 10]),
               Fanouts::of(&[10, 5, 5])] {
        let serial = sampler::build_block(&ds.graph, &seeds, &fo, 42);
        for threads in [1usize, 2, 8] {
            let par = ParallelSampler::new(threads)
                .build_block(&ds.graph, &seeds, &fo, 42);
            assert_eq!(par.frontiers, serial.frontiers,
                       "{fo}: frontiers mismatch at {threads} threads");
            assert_eq!(par.leaf, serial.leaf,
                       "{fo}: leaf mismatch at {threads} threads");
        }
    }
}

/// The prefetch pipeline must stream batches in the synchronous path's
/// exact (step, seeds, base) order — including across the epoch-boundary
/// reshuffle — and its sampled blocks must match bitwise.
#[test]
fn prefetch_preserves_seed_order_and_base_seed_schedule() {
    let ds = tiny();
    let (batch, seed) = (64usize, 42u64);
    let fo = Fanouts::of(&[5, 3]);
    // tiny has ~410 train nodes; 30 steps cross several epoch reshuffles
    let steps = 30usize;

    // reference: the synchronous schedule
    let sampler = ParallelSampler::serial();
    let mut sync_sched = BatchScheduler::new(&ds, batch, seed).unwrap();
    let reference: Vec<_> = (0..steps)
        .map(|s| {
            let seeds = sync_sched.next_seeds();
            let base = sync_sched.base_seed(s);
            prepare_batch(&ds, HostWork::Block, &fo, &sampler, s, seeds,
                          base)
        })
        .collect();

    // pipelined: double-buffered prefetch with a multi-threaded sampler
    let mut sched = BatchScheduler::new(&ds, batch, seed).unwrap();
    let mut pf = BatchPrefetcher::spawn(ds.clone(), HostWork::Block,
                                        fo.clone(), ParallelSampler::new(8));
    for (s, want) in reference.iter().enumerate() {
        let got = pf.next_batch(&mut sched).unwrap();
        assert_eq!(got.step, s, "batches out of order");
        assert_eq!(got.seeds, want.seeds, "seed order changed at step {s}");
        assert_eq!(got.base, want.base, "base-seed schedule changed at {s}");
        assert_eq!(got.labels, want.labels, "labels diverged at step {s}");
        let (gb, wb) = (got.block.as_ref().unwrap(),
                        want.block.as_ref().unwrap());
        assert_eq!(gb.frontiers, wb.frontiers,
                   "prefetched frontiers diverged at step {s}");
        assert_eq!(gb.leaf, wb.leaf, "prefetched leaf diverged at step {s}");
    }
}

/// Both variants' schedulers produce the same base-seed schedule — the
/// paired-comparison contract the paper's benchmarks rely on.
#[test]
fn schedulers_share_the_paired_base_seed_schedule() {
    let ds = tiny();
    let a = BatchScheduler::new(&ds, 64, 42).unwrap();
    let b = BatchScheduler::new(&ds, 128, 42).unwrap(); // batch-independent
    for s in 0..50 {
        assert_eq!(a.base_seed(s), b.base_seed(s));
    }
}

#[test]
fn throughput_mode_improves_with_threads_and_prefetch() {
    let ds = tiny();
    let cfg = ThroughputConfig {
        batch: 256,
        fanouts: Fanouts::of(&[10, 10]),
        steps: 6,
        warmup: 1,
        dispatch_ms: 1.0,
        ..ThroughputConfig::new("tiny")
    };
    let serial = run_throughput(ds.clone(), &cfg).unwrap();
    let piped = run_throughput(
        ds.clone(),
        &ThroughputConfig { threads: 4, prefetch: true, ..cfg.clone() })
        .unwrap();
    assert_eq!(serial.threads, 1);
    assert_eq!(piped.threads, 4);
    assert!(piped.prefetch && !serial.prefetch);
    // both report sane, positive throughput; the CI box may be too noisy
    // to assert a strict ordering on a tiny workload, but the pipelined
    // run must not pay more critical-path sampling than the serial run's
    // full sampling cost
    assert!(serial.steps_per_s > 0.0 && piped.steps_per_s > 0.0);
    assert!(piped.sample_ms <= serial.sample_ms.max(0.05) * 20.0,
            "prefetch critical path blew up: {} vs {}", piped.sample_ms,
            serial.sample_ms);
}
