//! Depth-generalization suite.
//!
//! Two jobs:
//!
//! 1. **Golden depth-1/2 regression** — the depth-generic kernel and
//!    block builder must reproduce the *pre-refactor* `fused_1hop` /
//!    `fused_2hop` / `build_block1` / `build_block2` outputs exactly.
//!    The legacy serial loops are inlined below verbatim (same scratch
//!    layout, same D-tiling, same op order), so equality is asserted with
//!    `==` — bit-for-bit up to f32 `PartialEq` (which only forgives the
//!    sign of zero).
//! 2. **Depth-3 coverage** — fused-vs-baseline aggregation parity, the
//!    FD gradient check on the 3-layer SAGE stack (engine level), bitwise
//!    determinism across thread counts {1, 4, 8}, and an end-to-end 3-hop
//!    native training run with decreasing loss.

use std::sync::Arc;

use fusesampleagg::coordinator::{measure, DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::graph::Csr;
use fusesampleagg::kernel::{fused, Features, D_TILE};
use fusesampleagg::rng::SplitMix64;
use fusesampleagg::runtime::BackendChoice;
use fusesampleagg::sampler::{self, sample_neighbors};

fn tiny() -> Dataset {
    Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
}

fn random_seeds(n_nodes: usize, n: usize, seed: u64) -> Vec<i32> {
    let mut r = SplitMix64::new(seed);
    (0..n).map(|_| r.next_below(n_nodes as u64) as i32).collect()
}

// ---------------------------------------------------------------------------
// legacy (pre-refactor) serial kernels, inlined as the golden reference
// ---------------------------------------------------------------------------

fn legacy_accumulate_mean(feat: &Features, valid: &[u32], tile: &mut [f32],
                          agg_row: &mut [f32]) {
    if valid.is_empty() {
        return;
    }
    let inv = 1.0 / valid.len() as f32;
    let d = feat.d;
    let mut t0 = 0;
    while t0 < d {
        let t1 = (t0 + D_TILE).min(d);
        let acc = &mut tile[..t1 - t0];
        acc.fill(0.0);
        for &w in valid {
            feat.add_row_slice(w as usize, t0, t1, acc);
        }
        for (a, &v) in agg_row[t0..t1].iter_mut().zip(acc.iter()) {
            *a += v * inv;
        }
        t0 = t1;
    }
}

fn legacy_collect_valid(row: &[i32], out: &mut Vec<u32>) {
    out.clear();
    for &v in row {
        if v >= 0 {
            out.push(v as u32);
        }
    }
}

/// The pre-refactor serial `fused_2hop` body (agg, s1, s2, pairs).
fn legacy_fused_2hop(csr: &Csr, feat: &Features, seeds: &[i32], k1: usize,
                     k2: usize, base: u64)
                     -> (Vec<f32>, Vec<i32>, Vec<i32>, u64) {
    let b = seeds.len();
    let d = feat.d;
    let mut agg = vec![0.0f32; b * d];
    let mut s1_out = vec![-1i32; b * k1];
    let mut s2_out = vec![-1i32; b * k1 * k2];
    let mut s1row = vec![-1i32; k1];
    let mut s2row = vec![-1i32; k2.max(1)];
    let mut valid: Vec<u32> = Vec::with_capacity(k2.max(k1));
    let mut tile = vec![0.0f32; D_TILE];
    let mut total_pairs = 0u64;
    for (bi, &r) in seeds.iter().enumerate() {
        let agg_row = &mut agg[bi * d..(bi + 1) * d];
        sample_neighbors(csr, r, k1, base, 0, &mut s1row);
        s1_out[bi * k1..(bi + 1) * k1].copy_from_slice(&s1row);
        let mut k1_eff = 0u64;
        let mut npairs = 0u64;
        for ui in 0..k1 {
            let u = s1row[ui];
            sample_neighbors(csr, u, k2, base, 1, &mut s2row);
            s2_out[(bi * k1 + ui) * k2..(bi * k1 + ui + 1) * k2]
                .copy_from_slice(&s2row);
            if u < 0 {
                continue;
            }
            k1_eff += 1;
            npairs += 1;
            legacy_collect_valid(&s2row, &mut valid);
            npairs += valid.len() as u64;
            legacy_accumulate_mean(feat, &valid, &mut tile, agg_row);
        }
        let inv = 1.0 / k1_eff.max(1) as f32;
        for v in agg_row.iter_mut() {
            *v *= inv;
        }
        total_pairs += npairs;
    }
    (agg, s1_out, s2_out, total_pairs)
}

/// The pre-refactor serial `fused_1hop` body (agg, samples, pairs).
fn legacy_fused_1hop(csr: &Csr, feat: &Features, seeds: &[i32], k: usize,
                     base: u64) -> (Vec<f32>, Vec<i32>, u64) {
    let b = seeds.len();
    let d = feat.d;
    let mut agg = vec![0.0f32; b * d];
    let mut samples = vec![-1i32; b * k];
    let mut s1row = vec![-1i32; k];
    let mut valid: Vec<u32> = Vec::with_capacity(k);
    let mut tile = vec![0.0f32; D_TILE];
    let mut pairs = 0u64;
    for (bi, &r) in seeds.iter().enumerate() {
        sample_neighbors(csr, r, k, base, 0, &mut s1row);
        samples[bi * k..(bi + 1) * k].copy_from_slice(&s1row);
        legacy_collect_valid(&s1row, &mut valid);
        pairs += valid.len() as u64;
        legacy_accumulate_mean(feat, &valid, &mut tile,
                               &mut agg[bi * d..(bi + 1) * d]);
    }
    (agg, samples, pairs)
}

/// The pre-refactor `build_block2` (f1, s2).
fn legacy_build_block2(csr: &Csr, seeds: &[i32], k1: usize, k2: usize,
                       base: u64) -> (Vec<i32>, Vec<i32>) {
    let b = seeds.len();
    let f1w = 1 + k1;
    let mut f1 = vec![-1i32; b * f1w];
    for (bi, &r) in seeds.iter().enumerate() {
        f1[bi * f1w] = r;
        sample_neighbors(csr, r, k1, base, 0,
                         &mut f1[bi * f1w + 1..(bi + 1) * f1w]);
    }
    let s2 = sampler::sample_frontier(csr, &f1, k2, base, 1);
    (f1, s2)
}

// ---------------------------------------------------------------------------
// golden depth-1/2 regression
// ---------------------------------------------------------------------------

#[test]
fn fused_khop_depth2_is_bitwise_identical_to_legacy_fused_2hop() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    for (nseeds, k1, k2, base) in
        [(96usize, 5usize, 3usize, 42u64), (64, 4, 4, 7), (33, 7, 2, 991)]
    {
        let seeds = random_seeds(ds.spec.n, nseeds, base ^ 0xA5);
        let (agg, s1, s2, pairs) =
            legacy_fused_2hop(&ds.graph, &feat, &seeds, k1, k2, base);
        let out = fused::fused_khop(&ds.graph, &feat, &seeds,
                                    &Fanouts::of(&[k1, k2]), base, true, 1);
        assert_eq!(out.agg, agg, "agg diverged (k1={k1} k2={k2})");
        let saved = out.saved.unwrap();
        assert_eq!(saved[0], s1, "hop-0 indices diverged");
        assert_eq!(saved[1], s2, "hop-1 indices diverged");
        assert_eq!(out.pairs, pairs, "pair count diverged");
    }
}

#[test]
fn fused_khop_depth1_is_bitwise_identical_to_legacy_fused_1hop() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    for (nseeds, k, base) in [(96usize, 5usize, 42u64), (50, 9, 123)] {
        let seeds = random_seeds(ds.spec.n, nseeds, base ^ 0x5A);
        let (agg, samples, pairs) =
            legacy_fused_1hop(&ds.graph, &feat, &seeds, k, base);
        let out = fused::fused_khop(&ds.graph, &feat, &seeds,
                                    &Fanouts::of(&[k]), base, true, 1);
        assert_eq!(out.agg, agg, "agg diverged (k={k})");
        assert_eq!(out.saved.unwrap()[0], samples, "indices diverged");
        assert_eq!(out.pairs, pairs, "pair count diverged");
    }
}

/// bf16 (AMP) storage goes through the same fold — golden at depth 2 too.
#[test]
fn fused_khop_depth2_bf16_matches_legacy() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, true);
    let seeds = random_seeds(ds.spec.n, 64, 3);
    let (agg, ..) = legacy_fused_2hop(&ds.graph, &feat, &seeds, 5, 3, 17);
    let out = fused::fused_khop(&ds.graph, &feat, &seeds,
                                &Fanouts::of(&[5, 3]), 17, false, 1);
    assert_eq!(out.agg, agg);
}

#[test]
fn build_block_depth2_matches_legacy_build_block2() {
    let ds = tiny();
    let seeds = random_seeds(ds.spec.n, 128, 9);
    for (k1, k2, base) in [(5usize, 3usize, 42u64), (15, 10, 7)] {
        let (f1, s2) = legacy_build_block2(&ds.graph, &seeds, k1, k2, base);
        let blk = sampler::build_block(&ds.graph, &seeds,
                                       &Fanouts::of(&[k1, k2]), base);
        assert_eq!(blk.frontiers[0], seeds);
        assert_eq!(blk.frontiers[1], f1, "f1 diverged (k1={k1})");
        assert_eq!(blk.leaf, s2, "s2 diverged (k2={k2})");
    }
    // depth 1: the leaf must equal the legacy Block1 sample columns
    let mut want = vec![-1i32; 128 * 6];
    for (bi, &r) in seeds.iter().enumerate() {
        sample_neighbors(&ds.graph, r, 6, 11, 0, &mut want[bi * 6..(bi + 1) * 6]);
    }
    let blk1 = sampler::build_block(&ds.graph, &seeds, &Fanouts::of(&[6]), 11);
    assert_eq!(blk1.frontiers.len(), 1);
    assert_eq!(blk1.frontiers[0], seeds);
    assert_eq!(blk1.leaf, want);
}

// ---------------------------------------------------------------------------
// depth-3 coverage
// ---------------------------------------------------------------------------

/// Fused-vs-baseline aggregation parity at depth 3: the fused kernel's
/// `[B, d]` aggregate must equal the nested masked mean computed from the
/// *materialized* baseline block tensors (sampled-neighborhood pairing at
/// the feature level, one depth deeper than the paper's setting).
#[test]
fn depth3_fused_agg_matches_baseline_block_aggregate() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    let seeds = random_seeds(ds.spec.n, 48, 13);
    let fo = Fanouts::of(&[4, 3, 2]);
    let (k1, k2, k3, base) = (4usize, 3usize, 2usize, 77u64);
    let d = ds.spec.d;
    let out = fused::fused_khop(&ds.graph, &feat, &seeds, &fo, base, false, 1);

    // baseline-side reference from the materialized block: the fused
    // kernel's hop tensors are the sampled sub-lattice of the block
    // (samples-only slots), addressed through the nested group layout.
    let blk = sampler::build_block(&ds.graph, &seeds, &fo, base);
    let (w1, w2) = (1 + k1, 1 + k2);
    for bi in 0..seeds.len() {
        let mut outer = vec![0.0f64; d];
        let mut eff1 = 0usize;
        for ui in 0..k1 {
            // frontier group bi, sample slot 1+ui
            let p1 = bi * w1 + 1 + ui;
            let u = blk.frontiers[1][p1];
            if u < 0 {
                continue;
            }
            eff1 += 1;
            let mut mid = vec![0.0f64; d];
            let mut eff2 = 0usize;
            for vi in 0..k2 {
                let p2 = p1 * w2 + 1 + vi;
                let v = blk.frontiers[2][p2];
                if v < 0 {
                    continue;
                }
                eff2 += 1;
                let leaf_row = &blk.leaf[p2 * k3..(p2 + 1) * k3];
                let valid: Vec<i32> =
                    leaf_row.iter().copied().filter(|&w| w >= 0).collect();
                for &w in &valid {
                    for j in 0..d {
                        mid[j] += ds.features[w as usize * d + j] as f64
                            / valid.len() as f64;
                    }
                }
            }
            if eff2 > 0 {
                for j in 0..d {
                    outer[j] += mid[j] / eff2 as f64;
                }
            }
        }
        for j in 0..d {
            let want = (outer[j] / eff1.max(1) as f64) as f32;
            let got = out.agg[bi * d + j];
            assert!((got - want).abs() < 1e-4,
                    "seed {bi} dim {j}: fused {got} vs block {want}");
        }
    }
}

/// Bitwise determinism at depth 3 across thread counts {1, 4, 8} — the
/// kernel outputs and the full training trajectory.
#[test]
fn depth3_bitwise_deterministic_across_threads_1_4_8() {
    let ds = Arc::new(tiny());
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    let seeds = random_seeds(ds.spec.n, 192, 21);
    let fo = Fanouts::of(&[4, 3, 2]);
    let serial = fused::fused_khop(&ds.graph, &feat, &seeds, &fo, 5, true, 1);
    for threads in [4usize, 8] {
        let par =
            fused::fused_khop(&ds.graph, &feat, &seeds, &fo, 5, true, threads);
        assert_eq!(par.agg, serial.agg, "agg differs at {threads} threads");
        assert_eq!(par.saved, serial.saved,
                   "saved indices differ at {threads} threads");
        assert_eq!(par.pairs, serial.pairs);
    }

    // trainer-level: loss trajectories identical across --threads 1/4/8
    let rt = fusesampleagg::runtime::Runtime::from_env().unwrap();
    let mut cache = DatasetCache::new();
    let losses = |threads: usize, cache: &mut DatasetCache| -> Vec<f64> {
        let cfg = TrainConfig {
            variant: Variant::Fsa,
            dataset: "tiny".into(),
            fanouts: fo.clone(),
            batch: 64,
            amp: false,
            save_indices: true,
            seed: 42,
            threads,
            prefetch: false,
            backend: BackendChoice::Native,
            planner: Default::default(),
            planner_state: None,
            simd: Default::default(),
            layout: Default::default(),
            faults: fusesampleagg::runtime::faults::none(),
            hub_cache: None,
        };
        let mut tr = Trainer::new(&rt, cache, cfg).unwrap();
        (0..8).map(|_| tr.step().unwrap().loss).collect()
    };
    let t1 = losses(1, &mut cache);
    assert_eq!(t1, losses(4, &mut cache), "threads=4 changed the trajectory");
    assert_eq!(t1, losses(8, &mut cache), "threads=8 changed the trajectory");
}

/// End-to-end 3-hop training on the native backend, both variants:
/// decreasing loss, positive pair counts, eval above chance for fsa.
#[test]
fn depth3_native_training_end_to_end() {
    let rt = fusesampleagg::runtime::Runtime::from_env().unwrap();
    let mut cache = DatasetCache::new();
    for variant in [Variant::Fsa, Variant::Dgl] {
        let cfg = TrainConfig {
            variant,
            dataset: "tiny".into(),
            fanouts: Fanouts::of(&[4, 3, 2]),
            batch: 64,
            amp: false,
            save_indices: true,
            seed: 42,
            threads: 1,
            prefetch: false,
            backend: BackendChoice::Native,
            planner: Default::default(),
            planner_state: None,
            simd: Default::default(),
            layout: Default::default(),
            faults: fusesampleagg::runtime::faults::none(),
            hub_cache: None,
        };
        let mut tr = Trainer::new(&rt, &mut cache, cfg).unwrap();
        let timings = measure(&mut tr, 2, 30).unwrap();
        let first = timings.first().unwrap().loss;
        let last = timings.last().unwrap().loss;
        assert!(last < first * 0.9,
                "{variant:?} 3-hop: loss {first} -> {last}");
        assert!(timings.iter().all(|t| t.loss.is_finite() && t.pairs > 0));
        if variant == Variant::Fsa {
            let acc = tr.evaluate(512).unwrap();
            let chance = 1.0 / tr.ds.spec.c as f64;
            assert!(acc > 1.5 * chance,
                    "3-hop accuracy {acc} vs chance {chance}");
        }
    }
}

/// Measured transient ratio grows with depth at a matched leaf budget
/// (the depth-axis acceptance claim, CPU-scaled: 24 = 4·6 = 2·3·4).
#[test]
fn depth_axis_transient_ratio_grows() {
    let rt = fusesampleagg::runtime::Runtime::from_env().unwrap();
    let mut cache = DatasetCache::new();
    let ratio = |ks: &[usize], cache: &mut DatasetCache| -> f64 {
        let mut peaks = [0u64; 2];
        for (i, variant) in [Variant::Fsa, Variant::Dgl].iter().enumerate() {
            let cfg = TrainConfig {
                variant: *variant,
                dataset: "tiny".into(),
                fanouts: Fanouts::of(ks),
                batch: 256,
                amp: false,
                save_indices: true,
                seed: 42,
                threads: 1,
                prefetch: false,
                backend: BackendChoice::Native,
                planner: Default::default(),
                planner_state: None,
                simd: Default::default(),
                layout: Default::default(),
                faults: fusesampleagg::runtime::faults::none(),
                hub_cache: None,
            };
            let mut tr = Trainer::new(&rt, cache, cfg).unwrap();
            peaks[i] = tr.step().unwrap().transient_bytes;
        }
        peaks[1] as f64 / peaks[0].max(1) as f64
    };
    let r1 = ratio(&[24], &mut cache);
    let r2 = ratio(&[4, 6], &mut cache);
    let r3 = ratio(&[2, 3, 4], &mut cache);
    assert!(r1 > 1.0, "depth-1 ratio {r1:.2}");
    assert!(r2 > r1, "depth-2 ratio {r2:.2} <= depth-1 {r1:.2}");
    assert!(r3 > r2, "depth-3 ratio {r3:.2} <= depth-2 {r2:.2}");
}
