//! Shard-planner suite — pins the expected-subtree cost model.
//!
//! 1. **Plan invariants**: every planner flavor produces contiguous,
//!    ordered, exactly-covering shard plans at random depths, fanouts,
//!    and part counts (including adaptive plans under arbitrary measured
//!    feedback).
//! 2. **Bitwise determinism**: sampler blocks, fused-kernel outputs, and
//!    whole loss trajectories are bitwise identical under
//!    `nominal`/`quantile`/`adaptive` planning at threads 1/4/8 — the
//!    plan may only change *where* cuts land, never *what* is computed.
//! 3. **Power-law regression**: on a sparse Zipf-ish graph generated via
//!    `gen::DatasetSpec`, the quantile planner's depth-3 cost-imbalance
//!    ratio beats the nominal planner's by a pinned margin (the nominal
//!    model charges every hop-0 draw the full-fanout subtree, which is
//!    exactly wrong on hub-heavy graphs).
//! 4. **Edge cases**: the old `subtree_weight` panic path (empty/1-hop
//!    fanouts), fuzzed `Fanouts` parsing round-tripped through the
//!    planner, and `plan_shards` corner cases (`parts > n`, a giant cost
//!    at the end of the range, u64-overflow-adjacent totals).

use std::ops::Range;

use fusesampleagg::cli::parse_fanout;
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset, DatasetSpec, DegreeLaw};
use fusesampleagg::graph::{cost::nominal_subtree_weight, plan_shards,
                           plan_shards_weighted, CostModel, Csr,
                           PlannerChoice, ShardStats};
use fusesampleagg::kernel::{fused, Features};
use fusesampleagg::rng::{mix, SplitMix64};
use fusesampleagg::runtime::{BackendChoice, Runtime};
use fusesampleagg::sampler::{self, sample_neighbors, ParallelSampler};

const CHOICES: [PlannerChoice; 3] = [PlannerChoice::Nominal,
                                     PlannerChoice::Quantile,
                                     PlannerChoice::Adaptive];

fn tiny() -> Dataset {
    Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
}

fn assert_covering(plan: &[Range<usize>], n: usize) {
    let mut pos = 0;
    for r in plan {
        assert_eq!(r.start, pos, "shards not contiguous: {plan:?}");
        assert!(r.end >= r.start, "shard reversed: {plan:?}");
        pos = r.end;
    }
    assert_eq!(pos, n, "shards do not cover 0..{n}: {plan:?}");
}

/// Property: every flavor's plans are contiguous, ordered, and covering
/// for random depths, fanouts, frontier sizes (with invalid rows), and
/// part counts — adaptive included, under arbitrary observed feedback.
#[test]
fn prop_cost_model_plans_always_cover() {
    let ds = tiny();
    let csr = &ds.graph;
    let mut r = SplitMix64::new(2024);
    for trial in 0..150 {
        let depth = 1 + r.next_below(4) as usize;
        let ks: Vec<usize> =
            (0..depth).map(|_| 1 + r.next_below(12) as usize).collect();
        let fo = Fanouts::new(ks).unwrap();
        let n = r.next_below(300) as usize;
        let mut frontier: Vec<i32> = (0..n)
            .map(|_| r.next_below(csr.n as u64) as i32)
            .collect();
        if n > 3 {
            frontier[0] = -1; // padded/invalid rows must plan too
            frontier[n / 2] = -1;
        }
        let parts = 1 + r.next_below(12) as usize;
        for choice in CHOICES {
            let mut model = CostModel::new(csr, &fo, choice);
            if choice == PlannerChoice::Adaptive && trial % 2 == 0 {
                // arbitrary measured feedback, including degenerate values
                model.observe(&ShardStats::new(
                    (0..parts).map(|j| j as f64 * 0.37).collect(),
                    (0..parts).map(|j| (j as u64 % 5) * 10).collect(),
                ));
            }
            let costs: Vec<u64> =
                frontier.iter().map(|&u| model.seed_cost(csr, u)).collect();
            assert!(costs.iter().all(|&c| c >= 1),
                    "zero cost from {choice:?}");
            let plan = model.plan(&costs, parts);
            assert_covering(&plan, n);
            assert!(plan.len() <= parts.max(1),
                    "{choice:?}: {} shards for {parts} parts", plan.len());
            // per-level frontier costs are guarded at any hop index
            for hop in 0..depth + 2 {
                for &u in frontier.iter().take(8) {
                    assert!(model.frontier_cost(csr, u, hop) >= 1);
                }
            }
        }
    }
}

/// The plan may only move cut positions: fused kernel outputs (aggregate,
/// saved indices, pair count) are bitwise identical across planner
/// flavors and thread counts 1/4/8 — including adaptive mid-training,
/// after feedback has skewed its cut targets.
#[test]
fn fused_outputs_bitwise_identical_across_planners_and_threads() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    let mut r = SplitMix64::new(9);
    let seeds: Vec<i32> =
        (0..256).map(|_| r.next_below(ds.spec.n as u64) as i32).collect();
    for fo in [Fanouts::of(&[6]), Fanouts::of(&[5, 3]),
               Fanouts::of(&[4, 3, 2])] {
        let reference =
            fused::fused_khop(&ds.graph, &feat, &seeds, &fo, 77, true, 1);
        for choice in CHOICES {
            let mut model = CostModel::new(&ds.graph, &fo, choice);
            for threads in [1usize, 4, 8] {
                let out = fused::fused_khop_planned(
                    &ds.graph, &feat, &seeds, &fo, 77, true, threads, &model);
                assert_eq!(out.agg, reference.agg,
                           "{fo} {choice:?} t={threads}: agg diverged");
                assert_eq!(out.saved, reference.saved,
                           "{fo} {choice:?} t={threads}: saved diverged");
                assert_eq!(out.pairs, reference.pairs);
                // feed the measured stats back (only adaptive uses them)
                model.observe(&out.stats);
            }
            // after feedback: still bitwise identical
            let out = fused::fused_khop_planned(
                &ds.graph, &feat, &seeds, &fo, 77, true, 8, &model);
            assert_eq!(out.agg, reference.agg,
                       "{fo} {choice:?}: post-feedback agg diverged");
            assert_eq!(out.saved, reference.saved);
        }
    }
}

/// Sampler blocks are bitwise identical to the serial sampler under
/// every planner flavor and thread count.
#[test]
fn sampler_blocks_bitwise_identical_across_planners_and_threads() {
    let ds = tiny();
    let mut r = SplitMix64::new(13);
    let seeds: Vec<i32> =
        (0..256).map(|_| r.next_below(ds.spec.n as u64) as i32).collect();
    for fo in [Fanouts::of(&[6]), Fanouts::of(&[4, 3]),
               Fanouts::of(&[4, 3, 2])] {
        let serial = sampler::build_block(&ds.graph, &seeds, &fo, 31);
        for choice in CHOICES {
            for threads in [1usize, 4, 8] {
                let s = ParallelSampler::with_planner(threads, choice);
                let par = s.build_block(&ds.graph, &seeds, &fo, 31);
                assert_eq!(par.frontiers, serial.frontiers,
                           "{fo} {choice:?} t={threads}: frontiers diverged");
                assert_eq!(par.leaf, serial.leaf,
                           "{fo} {choice:?} t={threads}: leaf diverged");
                // sharded runs must report their measured imbalance
                let imb = s.take_imbalance();
                if threads > 1 {
                    let v = imb.expect("sharded pass recorded no imbalance");
                    assert!(v.is_finite() && v >= 1.0 - 1e-9, "{v}");
                    assert!(s.take_imbalance().is_none(), "drain must clear");
                }
            }
        }
    }
}

/// Whole-trainer determinism: fsa and dgl loss trajectories on the
/// native backend are bitwise identical across planner flavors at
/// threads 1/4/8.
#[test]
fn training_trajectories_identical_across_planners() {
    let rt = Runtime::from_env().unwrap();
    let mut cache = DatasetCache::new();
    for variant in [Variant::Fsa, Variant::Dgl] {
        let run = |planner: PlannerChoice, threads: usize,
                   cache: &mut DatasetCache| -> Vec<f64> {
            let cfg = TrainConfig {
                variant,
                dataset: "tiny".into(),
                fanouts: Fanouts::of(&[4, 3, 2]),
                batch: 64,
                amp: false,
                save_indices: true,
                seed: 42,
                threads,
                prefetch: false,
                backend: BackendChoice::Native,
                planner,
                planner_state: None,
                simd: Default::default(),
                layout: Default::default(),
                faults: fusesampleagg::runtime::faults::none(),
                hub_cache: None,
            };
            let mut tr = Trainer::new(&rt, cache, cfg).unwrap();
            (0..6).map(|_| tr.step().unwrap().loss).collect()
        };
        let reference = run(PlannerChoice::Nominal, 1, &mut cache);
        for choice in CHOICES {
            for threads in [1usize, 4, 8] {
                assert_eq!(run(choice, threads, &mut cache), reference,
                           "{variant:?} {choice:?} t={threads}: \
                            trajectory diverged");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// power-law regression
// ---------------------------------------------------------------------------

/// Actual row-adds of the fused kernel's subtree under `seed` — the same
/// draws the kernel would make (sampler and kernel are bitwise-identical),
/// counted instead of aggregated.
fn true_subtree_cost(csr: &Csr, seed: i32, ks: &[usize], base: u64) -> u64 {
    fn rec(csr: &Csr, v: i32, ks: &[usize], base: u64, hop: u64) -> u64 {
        if hop as usize == ks.len() {
            return 1;
        }
        let k = ks[hop as usize];
        let mut row = vec![-1i32; k];
        sample_neighbors(csr, v, k, base, hop, &mut row);
        let mut total = 1;
        for &w in &row {
            if w >= 0 {
                total += rec(csr, w, ks, base, hop + 1);
            }
        }
        total
    }
    let k = ks[0];
    let mut row = vec![-1i32; k];
    sample_neighbors(csr, seed, k, base, 0, &mut row);
    let mut total = 1;
    for &v in &row {
        if v >= 0 {
            total += rec(csr, v, ks, base, 1);
        }
    }
    total
}

/// Max-shard true cost over the ideal per-shard share.
fn imbalance_on(plan: &[Range<usize>], true_costs: &[u64],
                parts: usize) -> f64 {
    let total: u64 = true_costs.iter().sum();
    let max: u64 = plan
        .iter()
        .map(|r| true_costs[r.clone()].iter().sum())
        .max()
        .unwrap_or(0);
    max as f64 / (total as f64 / parts as f64)
}

/// On a sparse Zipf-ish power-law graph at depth 3, the quantile
/// planner's cost-imbalance ratio must beat the nominal planner's by a
/// pinned margin. Seeds run in id order — the order `split_nodes` (and
/// with it eval batching) produces — where the generator's local-window
/// homophily clusters hub-adjacent seeds, which is exactly where the
/// full-fanout assumption misplaces cuts. Everything is deterministic:
/// graph, draws, costs, and plans.
#[test]
fn quantile_beats_nominal_on_power_law_depth3() {
    let spec = DatasetSpec {
        name: "zipf_sim".into(),
        stands_for: "planner regression fixture".into(),
        n: 1500,
        e_cap: 60_000,
        avg_deg: 4,
        degree_law: DegreeLaw::PowerLaw,
        d: 8,
        c: 4,
        gen_seed: 77,
    };
    let ds = Dataset::generate(spec).unwrap();
    let csr = &ds.graph;
    let stats = csr.degree_stats();
    assert!(stats.max as f64 > 4.0 * stats.mean,
            "fixture lost its heavy tail: {stats:?}");

    let fo = Fanouts::of(&[10, 10, 10]);
    let (base, parts) = (mix(1234), 8usize);
    let seeds: Vec<i32> = (0..csr.n as i32).collect();
    let true_costs: Vec<u64> = seeds
        .iter()
        .map(|&s| true_subtree_cost(csr, s, fo.as_slice(), base))
        .collect();

    let plan_for = |choice: PlannerChoice| -> Vec<Range<usize>> {
        let model = CostModel::new(csr, &fo, choice);
        let costs: Vec<u64> =
            seeds.iter().map(|&s| model.seed_cost(csr, s)).collect();
        model.plan(&costs, parts)
    };
    let im_nominal =
        imbalance_on(&plan_for(PlannerChoice::Nominal), &true_costs, parts);
    let im_quantile =
        imbalance_on(&plan_for(PlannerChoice::Quantile), &true_costs, parts);

    // pinned margin (measured ~1.10 vs ~1.04 on this fixture): quantile
    // must win by ≥ 0.03 absolute and carry ≤ 1/1.4 of the excess
    assert!(im_quantile + 0.03 <= im_nominal,
            "quantile {im_quantile:.4} did not beat nominal \
             {im_nominal:.4} by the pinned margin");
    assert!(im_nominal - 1.0 >= 1.4 * (im_quantile - 1.0),
            "excess imbalance ratio regressed: nominal {im_nominal:.4} \
             vs quantile {im_quantile:.4}");
    // and the model is a genuinely better predictor, not just lucky cuts:
    // an oracle plan from the true costs can't be much better than the
    // quantile plan's balance on this fixture
    let oracle = plan_shards(&true_costs, parts);
    let im_oracle = imbalance_on(&oracle, &true_costs, parts);
    assert!(im_quantile <= im_oracle + 0.10,
            "quantile {im_quantile:.4} far from oracle {im_oracle:.4}");
}

// ---------------------------------------------------------------------------
// guards, fuzzing, plan_shards edge cases
// ---------------------------------------------------------------------------

/// The old `kernel::fused::subtree_weight` indexed `ks[1..]`
/// unconditionally; the planner's version is guarded for depth 0/1 and
/// every model handles 1-hop fanouts.
#[test]
fn subtree_weight_guards_depth_0_and_1() {
    assert_eq!(nominal_subtree_weight(&[]), 1);
    assert_eq!(nominal_subtree_weight(&[9]), 1);
    assert_eq!(nominal_subtree_weight(&[15, 10]), 11);
    assert_eq!(nominal_subtree_weight(&[15, 10, 5]), 61); // 1 + 10*(1+5)
    let ds = tiny();
    for choice in CHOICES {
        let model = CostModel::new(&ds.graph, &Fanouts::of(&[7]), choice);
        for u in [-1i32, 0, 3, 511, 9999] {
            assert!(model.seed_cost(&ds.graph, u) >= 1, "{choice:?} {u}");
        }
    }
}

/// Fuzz: random fanout lists round-trip `label → parse → planner`
/// without panicking, and malformed strings error instead of panicking.
#[test]
fn fuzz_fanout_parse_round_trips_through_planner() {
    let ds = tiny();
    let mut r = SplitMix64::new(404);
    for _ in 0..100 {
        let depth = 1 + r.next_below(5) as usize;
        let ks: Vec<usize> =
            (0..depth).map(|_| 1 + r.next_below(20) as usize).collect();
        let fo = Fanouts::new(ks.clone()).unwrap();
        let parsed = parse_fanout(&fo.label()).unwrap();
        assert_eq!(parsed, fo, "label round-trip broke for {ks:?}");
        let model = CostModel::new(&ds.graph, &parsed,
                                   PlannerChoice::Quantile);
        let costs: Vec<u64> = (0..64)
            .map(|i| model.seed_cost(&ds.graph, (i * 7) % ds.spec.n as i32))
            .collect();
        assert_covering(&model.plan(&costs, 1 + r.next_below(9) as usize),
                        costs.len());
    }
    // malformed inputs: clean errors, never a panic
    for bad in ["", "x", "15x", "x10", "0", "15x0x5", "1e3", "-4", "4x-1",
                "nope", "10,,5", "  ", "10x5x"] {
        assert!(parse_fanout(bad).is_err(), "{bad:?} should not parse");
    }
}

#[test]
fn plan_shards_handles_more_parts_than_rows() {
    let costs = [3u64, 1, 2];
    let plan = plan_shards(&costs, 10);
    assert_covering(&plan, 3);
    assert!(plan.len() <= 10);
    // every row still lands in exactly one shard
    let live: usize = plan.iter().map(|r| r.len()).sum();
    assert_eq!(live, 3);
    // degenerate inputs
    assert_covering(&plan_shards(&[], 7), 0);
    assert_covering(&plan_shards(&[5], 7), 1);
}

#[test]
fn plan_shards_isolates_giant_cost_at_end_of_range() {
    let mut costs = vec![1u64; 64];
    costs[63] = 1_000; // one giant row at the *end* of the range
    let plan = plan_shards(&costs, 4);
    assert_covering(&plan, 64);
    // the giant row's shard must not drag a meaningful prefix with it
    let last_live = plan.iter().rev().find(|r| !r.is_empty()).unwrap();
    assert!(last_live.contains(&63));
    assert!(last_live.len() <= 2,
            "giant tail row not isolated: {plan:?}");
}

#[test]
fn plan_shards_survives_u64_overflow_adjacent_totals() {
    // total ≈ 2.67 * u64::MAX — u64 prefix sums would wrap/panic
    let costs = vec![u64::MAX / 3; 8];
    let plan = plan_shards(&costs, 4);
    assert_covering(&plan, 8);
    for r in &plan {
        assert_eq!(r.len(), 2, "unbalanced under huge costs: {plan:?}");
    }
    // a single near-max cost plus small ones
    let mut costs = vec![1u64; 16];
    costs[0] = u64::MAX - 7;
    let plan = plan_shards(&costs, 3);
    assert_covering(&plan, 16);
    let first_live = plan.iter().find(|r| !r.is_empty()).unwrap();
    assert!(first_live.len() <= 1 + 8,
            "near-max head not isolated: {plan:?}");
}

#[test]
fn weighted_plans_cover_and_degrade_safely() {
    let costs = vec![2u64; 90];
    // matching, valid weights: faster worker 0 takes a bigger range
    let plan = plan_shards_weighted(&costs, 3, &[2.0, 1.0, 1.0]);
    assert_covering(&plan, 90);
    assert!(plan[0].len() > plan[1].len(), "{plan:?}");
    // mismatched or invalid weights degrade to the unweighted plan
    for bad in [vec![1.0, 2.0], vec![0.0, 1.0, 1.0],
                vec![f64::NAN, 1.0, 1.0]] {
        assert_eq!(plan_shards_weighted(&costs, 3, &bad),
                   plan_shards(&costs, 3));
    }
}
