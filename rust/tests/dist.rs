//! Distributed-training integration tests: the localhost data-parallel
//! coordinator (`dist::train`), its worker protocol, and the `fsa train
//! --workers` process path.
//!
//! The contracts pinned here:
//!
//! 1. **Worker-count invariance** — the loss trajectory and final
//!    parameters are bitwise identical at 1, 2 and 4 workers for a
//!    matched config: the micro decomposition, fold order and fold
//!    weights never depend on N.
//! 2. **Single-process identity** — with `--micro-batch >= batch` a
//!    distributed run is additionally bitwise identical to plain
//!    `fsa train` (the `Trainer` loop).
//! 3. **Failure transparency** — a worker lost mid-run (scripted socket
//!    drop, dropped result frame, or a real SIGKILL of a child process)
//!    gets its shard reassigned and the run completes with the *same*
//!    bitwise trajectory: the coordinator owns every floating-point
//!    decision, so recomputing a micro elsewhere cannot perturb it, and
//!    gradient acceptance is first-wins so a re-dispatched micro is
//!    never double-counted.

use std::path::PathBuf;
use std::sync::Arc;

use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::dist::{self, DistOptions, WorkerMode};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::metrics::read_dist_csv;
use fusesampleagg::runtime::faults::ChaosPlane;
use fusesampleagg::runtime::manifest::{AdamwConfig, Manifest};
use fusesampleagg::runtime::{BackendChoice, Runtime};

fn tiny_cfg(seed: u64) -> TrainConfig {
    TrainConfig {
        variant: Variant::Fsa,
        dataset: "tiny".into(),
        fanouts: Fanouts::of(&[5, 3]),
        batch: 64,
        amp: false,
        save_indices: false,
        seed,
        threads: 1,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    }
}

fn tiny_ds() -> Arc<Dataset> {
    Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap())
}

fn adamw() -> AdamwConfig {
    Manifest::builtin().adamw
}

/// Thread-mode options: real sockets, deterministic to drive from tests.
fn thread_opts(workers: usize, micro_batch: usize) -> DistOptions {
    DistOptions {
        workers,
        micro_batch,
        heartbeat_ms: 50,
        mode: WorkerMode::Thread,
        steps: 3,
        warmup: 1,
        ..DistOptions::default()
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fsa_dist_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Contract 1: the trajectory is a function of the config, not of N.
/// Four micros per step are split across 1, 2 and 4 workers; losses and
/// final params must agree bitwise, the cut must stay edge-balanced,
/// and the per-worker stats must account for every seed.
#[test]
fn worker_counts_share_one_bitwise_trajectory() {
    let ds = tiny_ds();
    let cfg = tiny_cfg(42);
    let out = tmp("trajectory_dist.csv");
    let mut reference: Option<(Vec<f64>, Vec<Vec<f32>>)> = None;
    for workers in [1usize, 2, 4] {
        let mut opts = thread_opts(workers, 16); // 64/16 = 4 micros/step
        opts.dist_out = Some(out.clone());
        let report = dist::train(ds.clone(), &cfg, 32, adamw(), &opts)
            .unwrap();
        assert_eq!(report.losses.len(), 4, "warmup 1 + 3 timed steps");
        assert_eq!(report.reassigned, 0, "no failures were injected");
        assert!(report.edge_load_dev < 0.05,
                "{workers}-way cut is {:.1}% off the ideal edge share",
                report.edge_load_dev * 100.0);
        assert_eq!(report.rows.len(), workers);
        let seeds: u64 = report.rows.iter().map(|r| r.seeds).sum();
        assert_eq!(seeds, 4 * 64, "every step's 64 seeds must be computed \
                                   exactly once across the fleet");
        assert!(report.rows.iter().all(|r| r.completed),
                "all workers survive a clean run");
        let csv = read_dist_csv(&out).unwrap();
        assert_eq!(csv.len(), workers, "one dist.csv row per rank");
        match &reference {
            None => reference = Some((report.losses, report.params)),
            Some((losses, params)) => {
                assert_eq!(&report.losses, losses,
                           "workers={workers} changed the loss trajectory");
                assert_eq!(&report.params, params,
                           "workers={workers} changed the final params");
            }
        }
    }
}

/// Contract 2: `--micro-batch >= batch` makes the fold weight exactly
/// 1.0, so a 2-worker distributed session replays plain `fsa train`
/// (the `Trainer` loop) bitwise — losses and parameters.
#[test]
fn single_micro_run_matches_plain_trainer_bitwise() {
    let rt = Runtime::from_env().unwrap();
    let cfg = tiny_cfg(42);
    let hidden = rt.manifest.hidden;

    let mut cache = DatasetCache::new();
    let mut tr = Trainer::new(&rt, &mut cache, cfg.clone()).unwrap();
    let want: Vec<f64> = (0..4).map(|_| tr.step().unwrap().loss).collect();
    let want_params = tr.params_f32().unwrap();
    drop(tr);

    let report = dist::train(tiny_ds(), &cfg, hidden, rt.manifest.adamw,
                             &thread_opts(2, cfg.batch))
        .unwrap();
    assert_eq!(report.losses, want,
               "distributed losses diverged from plain fsa train");
    assert_eq!(report.params, want_params,
               "distributed params diverged from plain fsa train");
}

/// Contract 3a: a scripted socket drop (chaos `dist-send`) on the step-1
/// dispatch buries worker 0 mid-run; its shard moves to worker 1, the
/// orphaned micros are recomputed there, and the trajectory still
/// matches a clean run bitwise.
#[test]
fn scripted_send_drop_reassigns_shard_and_preserves_trajectory() {
    let ds = tiny_ds();
    let clean = dist::train(ds.clone(), &tiny_cfg(42), 32, adamw(),
                            &thread_opts(2, 16))
        .unwrap();

    let mut cfg = tiny_cfg(42);
    // ops 0,1 are step 0's two per-rank sends; op 2 is step 1, rank 0
    cfg.faults = Arc::new(ChaosPlane::parse("dist-send@2=err", 42).unwrap());
    let report =
        dist::train(ds, &cfg, 32, adamw(), &thread_opts(2, 16)).unwrap();

    assert_eq!(report.reassigned, 1, "the dropped worker's shard must be \
                                      reassigned exactly once");
    assert!(!report.rows[0].completed, "rank 0 was buried");
    assert!(report.rows[1].completed, "rank 1 survived");
    assert_eq!(report.rows[1].reassigned, 1,
               "rank 1 absorbed the dead shard");
    assert!((report.rows[1].edge_share - 1.0).abs() < 1e-9,
            "the survivor owns every edge after the reassignment");
    assert_eq!(report.losses, clean.losses,
               "losing a worker must not perturb the loss trajectory");
    assert_eq!(report.params, clean.params,
               "losing a worker must not perturb the final params");
}

/// Contract 3b (never double-count): a result frame lost in flight
/// (chaos `dist-recv` discards the first `Grads`) is recovered by the
/// stalled-micro re-dispatch — the micro is recomputed and accepted
/// exactly once. Any double fold (or a dropped one) would shift the
/// trajectory; bitwise equality with the clean run proves neither
/// happened.
#[test]
fn dropped_result_frame_recovers_without_double_count() {
    let ds = tiny_ds();
    let mut opts = thread_opts(2, 16);
    opts.steps = 1; // the ~200 ms recovery window runs once, keep it short
    let clean =
        dist::train(ds.clone(), &tiny_cfg(42), 32, adamw(), &opts).unwrap();

    let mut cfg = tiny_cfg(42);
    cfg.faults = Arc::new(ChaosPlane::parse("dist-recv@0=err", 42).unwrap());
    let report = dist::train(ds, &cfg, 32, adamw(), &opts).unwrap();

    assert_eq!(report.reassigned, 0,
               "a lost frame is not a lost worker — no reassignment");
    assert_eq!(report.losses, clean.losses,
               "the recovered micro must fold exactly once");
    assert_eq!(report.params, clean.params,
               "the recovered micro must fold exactly once (params)");
}

/// Losing the *last* worker is a hard error naming the step — the
/// coordinator must fail loudly, not hang waiting for gradients no one
/// will send.
#[test]
fn losing_every_worker_is_an_error_not_a_hang() {
    let mut cfg = tiny_cfg(42);
    cfg.faults = Arc::new(ChaosPlane::parse("dist-send@0=err", 42).unwrap());
    let err = dist::train(tiny_ds(), &cfg, 32, adamw(), &thread_opts(1, 16))
        .unwrap_err()
        .to_string();
    assert!(err.contains("every worker died"), "{err}");
}

/// The coordinator's checkpoint is `Engine`-compatible train state:
/// stopping a distributed run and resuming it from the saved params +
/// AdamW moments replays the uninterrupted run's remaining steps
/// bitwise.
#[test]
fn checkpoint_resume_continues_bitwise() {
    let ds = tiny_ds();
    let cfg = tiny_cfg(42);
    let path = tmp("resume_ckpt.json");

    // the uninterrupted control: warmup 1 + 5 timed steps
    let mut full_opts = thread_opts(2, 16);
    full_opts.steps = 5;
    let full =
        dist::train(ds.clone(), &cfg, 32, adamw(), &full_opts).unwrap();
    assert_eq!(full.losses.len(), 6);

    // first half: stop after 3 optimizer steps, snapshotting at exit
    let mut first = thread_opts(2, 16);
    first.steps = 2;
    first.ckpt_path = Some(path.clone());
    let a = dist::train(ds.clone(), &cfg, 32, adamw(), &first).unwrap();
    assert_eq!(a.losses, full.losses[..3],
               "the first half must already match the control");

    // second half: resume at step 3, run to the control's 6
    let mut second = full_opts;
    second.ckpt_path = Some(path);
    second.resume = true;
    let b = dist::train(ds, &cfg, 32, adamw(), &second).unwrap();
    assert_eq!(b.losses, full.losses[3..],
               "the resumed half must replay the control's tail bitwise");
    assert_eq!(b.params, full.params,
               "resume must land on the control's exact final params");
}

/// The real thing, end to end: `fsa train --workers 2` child processes,
/// one of them SIGKILLed mid-run. The coordinator must detect the loss,
/// reassign the shard, finish all steps with exit code 0, and print the
/// same final loss as an unharmed control run.
#[cfg(target_os = "linux")]
#[test]
fn sigkilled_child_worker_is_survived_with_identical_loss() {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    fn spawn_train() -> std::process::Child {
        Command::new(env!("CARGO_BIN_EXE_fsa"))
            .args(["train", "--dataset", "tiny", "--fanout", "5x3",
                   "--batch", "64", "--backend", "native", "--threads", "1",
                   "--workers", "2", "--micro-batch", "16",
                   "--heartbeat-ms", "50", "--steps", "400", "--warmup",
                   "5"])
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn fsa train --workers 2")
    }

    /// Direct children of `parent` whose cmdline names the hidden
    /// `dist-worker` entrypoint (ppid is field 4 of /proc/PID/stat,
    /// read after the parenthesized comm to survive spaces in it).
    fn dist_worker_children(parent: u32) -> Vec<u32> {
        let mut pids = Vec::new();
        let Ok(entries) = std::fs::read_dir("/proc") else { return pids };
        for e in entries.flatten() {
            let name = e.file_name();
            let Some(pid) =
                name.to_str().and_then(|s| s.parse::<u32>().ok())
            else {
                continue;
            };
            let Ok(stat) =
                std::fs::read_to_string(format!("/proc/{pid}/stat"))
            else {
                continue;
            };
            let Some(rest) = stat.rsplit(')').next() else { continue };
            if rest.split_whitespace().nth(1)
                != Some(parent.to_string().as_str())
            {
                continue;
            }
            let Ok(cmd) =
                std::fs::read_to_string(format!("/proc/{pid}/cmdline"))
            else {
                continue;
            };
            if cmd.contains("dist-worker") {
                pids.push(pid);
            }
        }
        pids
    }

    /// The `loss X` token of the last printed step line.
    fn final_loss(stdout: &str) -> String {
        stdout.lines()
            .filter(|l| l.trim_start().starts_with("step "))
            .filter_map(|l| l.rsplit_once("loss ").map(|(_, t)| t.trim()))
            .last()
            .unwrap_or_else(|| panic!("no step lines in:\n{stdout}"))
            .to_string()
    }

    // control: both workers live end to end
    let control = spawn_train().wait_with_output().unwrap();
    assert!(control.status.success(), "control run failed:\n{}",
            String::from_utf8_lossy(&control.stderr));
    let want = final_loss(&String::from_utf8_lossy(&control.stdout));

    // victim run: wait for the first timed step, then SIGKILL a worker
    let mut child = spawn_train();
    let pid = child.id();
    let mut reader = BufReader::new(child.stdout.take().unwrap());
    let mut stdout = String::new();
    let mut killed = false;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap() > 0 {
        stdout.push_str(&line);
        if !killed && line.contains("step ") && line.contains("loss") {
            // training is underway; bury one of the two workers
            let mut victims = Vec::new();
            for _ in 0..200 {
                victims = dist_worker_children(pid);
                if !victims.is_empty() {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            let victim = victims.first().expect("no dist-worker children \
                                                 to kill");
            let ok = Command::new("kill")
                .args(["-9", &victim.to_string()])
                .status()
                .unwrap()
                .success();
            assert!(ok, "kill -9 {victim} failed");
            killed = true;
        }
        line.clear();
    }
    assert!(killed, "the run finished before any step line appeared:\n\
                     {stdout}");
    let out = child.wait_with_output().unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "run with a SIGKILLed worker must still exit 0; stderr:\n\
             {stderr}");
    assert!(stderr.contains("shard reassigned"),
            "coordinator must report the reassignment; stderr:\n{stderr}");
    assert_eq!(final_loss(&stdout), want,
               "killing a worker mid-run must not change the final loss");
}
