//! SIMD-tier suite — pins the vector fold's bitwise contract.
//!
//! The native fused kernel carries lanes across the *feature* dimension,
//! so the per-element sequence of f32 operations is identical in the
//! scalar and vector tiers: outputs must be **bitwise equal**, not just
//! close. This suite pins that contract along every axis that could
//! break it:
//!
//! 1. **Scalar vs vector parity** at depths 1/2/3, threads 1/4/8, and
//!    both dtypes (f32 and bf16/AMP).
//! 2. **Remainder widths**: d = 7 / 63 / 65 exercise the sub-lane head
//!    (d < LANES), the full-chunks-minus-one tail, and the
//!    one-past-a-chunk tail of the 8-lane fold.
//! 3. **Feature-layout invariance**: the degree-descending physical
//!    permutation is an index-map change only — agg/saved/pairs are
//!    bitwise identical to the natural layout.
//! 4. **Feature-tile invariance**: `set_d_tile` only re-chunks the
//!    feature dimension; any width gives bitwise-identical outputs.
//! 5. **Engine-level layout invariance**: a `NativeBackend` configured
//!    with `--layout degree` reproduces the natural layout's losses and
//!    eval logits bitwise, f32 and bf16.

use std::sync::Arc;

use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset, DatasetSpec, DegreeLaw};
use fusesampleagg::graph::{CostModel, PlannerChoice};
use fusesampleagg::kernel::{fused, set_d_tile, FeatureLayout, Features,
                            NativeBackend, NativeConfig, SimdChoice};
use fusesampleagg::memory::MemoryMeter;
use fusesampleagg::rng::{mix, SplitMix64};
use fusesampleagg::runtime::{Backend, Manifest, StepInputs};

fn tiny() -> Dataset {
    Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
}

fn seeds_for(ds: &Dataset, count: usize, rng_seed: u64) -> Vec<i32> {
    let mut r = SplitMix64::new(rng_seed);
    (0..count).map(|_| r.next_below(ds.spec.n as u64) as i32).collect()
}

/// Scalar and vector tiers are bitwise identical at depths 1/2/3,
/// threads 1/4/8, both dtypes.
#[test]
fn scalar_and_vector_tiers_bitwise_identical() {
    let ds = tiny();
    let seeds = seeds_for(&ds, 256, 9);
    for amp in [false, true] {
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, amp);
        for fo in [Fanouts::of(&[5]), Fanouts::of(&[5, 3]),
                   Fanouts::of(&[4, 3, 2])] {
            let model = CostModel::new(&ds.graph, &fo, PlannerChoice::default());
            let scalar = fused::fused_khop_simd(
                &ds.graph, &feat, &seeds, &fo, 77, true, 1, &model, false);
            for threads in [1usize, 4, 8] {
                for simd_on in [false, true] {
                    let out = fused::fused_khop_simd(
                        &ds.graph, &feat, &seeds, &fo, 77, true, threads,
                        &model, simd_on);
                    assert_eq!(out.agg, scalar.agg,
                               "{fo} amp={amp} t={threads} simd={simd_on}: \
                                agg diverged from scalar tier");
                    assert_eq!(out.saved, scalar.saved,
                               "{fo} amp={amp} t={threads} simd={simd_on}: \
                                saved indices diverged");
                    assert_eq!(out.pairs, scalar.pairs);
                }
            }
        }
    }
}

/// Remainder feature widths (d = 7, 63, 65) hit the head/tail paths of
/// the 8-lane fold; parity must hold there too, both dtypes.
#[test]
fn remainder_feature_widths_stay_bitwise() {
    for (i, d) in [7usize, 63, 65].into_iter().enumerate() {
        let spec = DatasetSpec {
            name: format!("simd_rem_d{d}"),
            stands_for: "SIMD remainder-width fixture".into(),
            n: 256,
            e_cap: 4096,
            avg_deg: 6,
            degree_law: DegreeLaw::Uniform,
            d,
            c: 4,
            gen_seed: 2000 + i as u64,
        };
        let ds = Dataset::generate(spec).unwrap();
        let seeds = seeds_for(&ds, 128, 31);
        let fo = Fanouts::of(&[5, 3]);
        let model = CostModel::new(&ds.graph, &fo, PlannerChoice::default());
        for amp in [false, true] {
            let feat = Features::from_f32(&ds.features, ds.spec.n, d, amp);
            let scalar = fused::fused_khop_simd(
                &ds.graph, &feat, &seeds, &fo, 5, true, 1, &model, false);
            for threads in [1usize, 4] {
                let vect = fused::fused_khop_simd(
                    &ds.graph, &feat, &seeds, &fo, 5, true, threads, &model,
                    true);
                assert_eq!(vect.agg, scalar.agg,
                           "d={d} amp={amp} t={threads}: remainder fold \
                            diverged");
                assert_eq!(vect.saved, scalar.saved);
                assert_eq!(vect.pairs, scalar.pairs);
            }
        }
    }
}

/// The degree-descending storage permutation changes only where rows
/// live; kernel outputs stay bitwise identical in both tiers.
#[test]
fn feature_permutation_is_output_invariant() {
    let ds = tiny();
    let seeds = seeds_for(&ds, 200, 17);
    let fo = Fanouts::of(&[5, 3]);
    let model = CostModel::new(&ds.graph, &fo, PlannerChoice::default());
    for amp in [false, true] {
        let natural = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d,
                                         amp);
        let mut permuted =
            Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, amp);
        permuted.permute_by_degree(&ds.graph);
        let reference = fused::fused_khop_simd(
            &ds.graph, &natural, &seeds, &fo, 11, true, 1, &model, false);
        for threads in [1usize, 8] {
            for simd_on in [false, true] {
                let out = fused::fused_khop_simd(
                    &ds.graph, &permuted, &seeds, &fo, 11, true, threads,
                    &model, simd_on);
                assert_eq!(out.agg, reference.agg,
                           "amp={amp} t={threads} simd={simd_on}: layout \
                            pass changed the aggregate");
                assert_eq!(out.saved, reference.saved,
                           "amp={amp} t={threads} simd={simd_on}: layout \
                            pass leaked into saved node IDs");
                assert_eq!(out.pairs, reference.pairs);
            }
        }
    }
}

/// Any feature-tile width gives bitwise-identical outputs — the tile
/// only chunks the feature dimension, never reorders accumulation.
#[test]
fn feature_tile_width_is_output_invariant() {
    let ds = tiny();
    let seeds = seeds_for(&ds, 128, 23);
    let fo = Fanouts::of(&[4, 3, 2]);
    let model = CostModel::new(&ds.graph, &fo, PlannerChoice::default());
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, true);
    set_d_tile(0); // auto-detected width
    let reference = fused::fused_khop_simd(
        &ds.graph, &feat, &seeds, &fo, 3, true, 1, &model, true);
    for tile in [8usize, 64, 1024] {
        set_d_tile(tile);
        for simd_on in [false, true] {
            let out = fused::fused_khop_simd(
                &ds.graph, &feat, &seeds, &fo, 3, true, 4, &model, simd_on);
            assert_eq!(out.agg, reference.agg,
                       "d_tile={tile} simd={simd_on}: tile width changed \
                        the output");
            assert_eq!(out.saved, reference.saved);
        }
    }
    set_d_tile(0); // restore auto for the rest of the binary
}

/// A `NativeBackend` running the degree layout reproduces the natural
/// layout's training losses and eval logits bitwise.
#[test]
fn engine_degree_layout_is_loss_and_eval_invariant() {
    let ds = Arc::new(tiny());
    let cfg = |amp: bool, layout: FeatureLayout| NativeConfig {
        fused: true,
        fanouts: Fanouts::of(&[5, 3]),
        amp,
        save_indices: true,
        seed: 42,
        threads: 2,
        planner: Default::default(),
        hidden: 32,
        simd: SimdChoice::Auto,
        layout,
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let adamw = Manifest::builtin().adamw;
    for amp in [false, true] {
        let mut nat = NativeBackend::new(ds.clone(),
                                         cfg(amp, FeatureLayout::Natural),
                                         adamw).unwrap();
        let mut deg = NativeBackend::new(ds.clone(),
                                         cfg(amp, FeatureLayout::DegreeDesc),
                                         adamw).unwrap();
        for step in 0..4usize {
            let mut r = SplitMix64::new(mix(step as u64));
            let seeds: Vec<i32> = (0..64)
                .map(|_| r.next_below(ds.spec.n as u64) as i32).collect();
            let labels: Vec<i32> =
                seeds.iter().map(|&u| ds.labels[u as usize]).collect();
            let inp = StepInputs { seeds: &seeds, labels: &labels,
                                   base: mix(1000 + step as u64),
                                   block: None };
            let mut m1 = MemoryMeter::new();
            let mut m2 = MemoryMeter::new();
            let a = nat.train_step(step, &inp, &mut m1).unwrap();
            let b = deg.train_step(step, &inp, &mut m2).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(),
                       "amp={amp} step {step}: degree layout changed the \
                        loss ({} vs {})", a.loss, b.loss);
        }
        let eval_seeds: Vec<i32> = (0..64).collect();
        let ln = nat.eval_logits(&eval_seeds, 99).unwrap().unwrap();
        let ld = deg.eval_logits(&eval_seeds, 99).unwrap().unwrap();
        assert_eq!(ln, ld, "amp={amp}: degree layout changed eval logits");
    }
}
