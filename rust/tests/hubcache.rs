//! Hub-aggregate cache determinism tests (the `--hub-cache` knob).
//!
//! The cache's whole contract is *bitwise invisibility*: a cached hit
//! replays exactly the leaf-hop draw and fold the counter RNG would
//! have produced, so every observable output — train loss trajectories,
//! serve logits, saved indices, gradients — must be identical to the
//! cache-off engine at every thread count, depth, feature dtype, and
//! planner flavor. Only step time (and the hit/miss/refresh counters)
//! may move. These tests run on `zipf_serve`, the skewed fixture where
//! the cache actually fires; the structural hub-selection properties
//! are unit-tested next to the cache itself.

use std::sync::Arc;

use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::engine::Engine;
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::graph::PlannerChoice;
use fusesampleagg::kernel::{NativeBackend, NativeConfig};
use fusesampleagg::memory::MemoryMeter;
use fusesampleagg::rng::{mix, SplitMix64};
use fusesampleagg::runtime::{Backend, BackendChoice, Manifest, Runtime,
                             StepInputs};

fn runtime() -> Runtime {
    // manifest-less: Runtime::from_env falls back to the builtin manifest
    Runtime::from_env().expect("manifest-less runtime")
}

fn zipf_cfg(ks: &[usize], hub_cache: Option<usize>) -> TrainConfig {
    TrainConfig {
        variant: Variant::Fsa,
        dataset: "zipf_serve".into(),
        fanouts: Fanouts::of(ks),
        batch: 128,
        amp: false,
        save_indices: true,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache,
    }
}

/// Run `steps` training steps and return (losses, hits, misses,
/// refreshes) summed over the run.
fn trajectory(rt: &Runtime, cache: &mut DatasetCache, cfg: TrainConfig,
              steps: usize) -> (Vec<f64>, u64, u64, u64) {
    let mut tr = Trainer::new(rt, cache, cfg).unwrap();
    let mut losses = Vec::new();
    let (mut hits, mut misses, mut refreshes) = (0u64, 0u64, 0u64);
    for _ in 0..steps {
        let t = tr.step().unwrap();
        losses.push(t.loss);
        hits += t.hub_hits;
        misses += t.hub_misses;
        refreshes += t.hub_refreshes;
    }
    (losses, hits, misses, refreshes)
}

/// The headline invariant: the loss trajectory with the cache on is
/// bitwise the trajectory with it off, across the thread / depth /
/// dtype / planner grid — and the on-runs really did exercise the cache
/// (refreshes > 0 everywhere, hits > 0 wherever the leaf hop samples
/// neighbors).
#[test]
fn train_trajectory_is_bitwise_invariant_under_hub_cache() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    // (threads, fanouts, amp, planner) cells; depths 1/2/3 covered
    let ks213: &[usize] = &[6, 4, 2];
    let cells: &[(usize, &[usize], bool, PlannerChoice)] = &[
        (1, &[6, 4], false, PlannerChoice::Quantile),
        (4, &[6, 4], false, PlannerChoice::Quantile),
        (8, &[6, 4], false, PlannerChoice::Quantile),
        (1, &[6], false, PlannerChoice::Quantile),
        (1, ks213, false, PlannerChoice::Quantile),
        (1, &[6, 4], true, PlannerChoice::Quantile),
        (4, &[6, 4], false, PlannerChoice::Nominal),
        (4, &[6, 4], true, PlannerChoice::Adaptive),
    ];
    for &(threads, ks, amp, planner) in cells {
        let mut base = zipf_cfg(ks, None);
        base.threads = threads;
        base.amp = amp;
        base.planner = planner;
        let mut cached = base.clone();
        cached.hub_cache = Some(64);
        let (off, _, _, _) = trajectory(&rt, &mut cache, base, 6);
        let (on, hits, misses, refreshes) =
            trajectory(&rt, &mut cache, cached, 6);
        assert_eq!(off, on,
                   "t{threads} f{ks:?} amp={amp} {planner:?}: the cache \
                    changed the loss trajectory");
        assert!(refreshes > 0,
                "t{threads} f{ks:?}: cache never refreshed an entry");
        assert!(hits + misses > 0,
                "t{threads} f{ks:?}: kernel never consulted the cache");
        if ks.len() >= 2 {
            // leaf lookups are degree-weighted neighbor draws, so on a
            // Zipf graph the hottest cached hubs are hit essentially
            // surely across 6 steps of hundreds of lookups
            assert!(hits > 0,
                    "t{threads} f{ks:?}: no cached hit on a skewed graph");
        }
    }
}

/// Serve path: logits are bitwise identical on vs off, and because all
/// eval passes of a session share one seed epoch, a warm cache serves
/// repeat traffic without any further refreshes.
#[test]
fn serve_logits_match_and_warm_cache_reuses_across_requests() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut off = Engine::new(&rt, &mut cache, zipf_cfg(&[6, 4, 2], None))
        .unwrap();
    let mut on =
        Engine::new(&rt, &mut cache, zipf_cfg(&[6, 4, 2], Some(4096)))
            .unwrap();
    let n = off.ds.spec.n as u64;
    let mut rng = SplitMix64::new(mix(0x5EED));
    let requests: Vec<Vec<i32>> = (0..8)
        .map(|_| (0..32).map(|_| rng.next_below(n) as i32).collect())
        .collect();
    for req in &requests {
        let a = off.infer(req).unwrap();
        let b = on.infer(req).unwrap();
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "cached serve logits diverged");
    }
    assert!(off.hub_counters().is_none(), "off-engine grew a cache");
    let (h1, _, r1) = on.hub_counters().unwrap();
    assert!(r1 > 0, "serve pass refreshed nothing");
    // replay the same traffic: the budget (>= hub count) filled the
    // cache during the first pass, so the warm pass must re-hit it
    // without building a single new entry
    for req in &requests {
        on.infer(req).unwrap();
    }
    let (h2, _, r2) = on.hub_counters().unwrap();
    assert_eq!(r2, r1, "warm serve pass rebuilt entries in-epoch");
    assert!(h2 > h1, "warm serve pass never hit the cache");
}

/// `--hub-cache 0` must degenerate to cache-off bitwise: lookups are
/// counted but nothing is ever populated, hit, or refreshed.
#[test]
fn budget_zero_degenerates_to_cache_off() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let (off, _, _, _) =
        trajectory(&rt, &mut cache, zipf_cfg(&[6, 4], None), 5);
    let (zero, hits, misses, refreshes) =
        trajectory(&rt, &mut cache, zipf_cfg(&[6, 4], Some(0)), 5);
    assert_eq!(off, zero, "budget 0 changed the loss trajectory");
    assert_eq!((hits, refreshes), (0, 0),
               "budget 0 must never populate or hit");
    assert!(misses > 0, "budget 0 still counts (and misses) lookups");
}

/// Seed-epoch semantics end to end: every train step is its own epoch
/// (the per-step base seed rolls the generation, evicting all entries
/// and rebuilding under the same budget), eval/serve is one fixed epoch
/// per session (entries persist and re-hit), and stepping again after
/// an eval rolls back to the train epoch.
#[test]
fn seed_epoch_rollover_evicts_and_eval_epoch_reuses() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut tr =
        Trainer::new(&rt, &mut cache, zipf_cfg(&[6, 4], Some(4096)))
            .unwrap();
    // with an unbounded budget every step rebuilds exactly the full hub
    // set for its fresh epoch: the refresh count is the same every step
    let first = tr.step().unwrap().hub_refreshes;
    assert!(first > 0, "first step built no entries");
    for _ in 0..3 {
        assert_eq!(tr.step().unwrap().hub_refreshes, first,
                   "per-step epoch rollover must rebuild the full hub \
                    set every step");
    }
    // eval rolls to the session's fixed eval epoch: one full rebuild...
    let (_, _, r0) = tr.engine_mut().hub_counters().unwrap();
    tr.evaluate(512).unwrap();
    let (h1, _, r1) = tr.engine_mut().hub_counters().unwrap();
    assert_eq!(r1 - r0, first, "eval epoch must rebuild the hub set");
    // ...and a second eval in the same epoch reuses it wholesale
    tr.evaluate(512).unwrap();
    let (h2, _, r2) = tr.engine_mut().hub_counters().unwrap();
    assert_eq!(r2, r1, "second eval rebuilt entries in-epoch");
    assert!(h2 > h1, "second eval never hit the warm cache");
    // training again evicts the eval epoch and rebuilds the train one
    assert_eq!(tr.step().unwrap().hub_refreshes, first);
}

/// Backward through a cached hit: the analytic parameter gradients of a
/// pass that served leaf aggregates from the cache must match central
/// finite differences of the loss — the replayed saved indices and the
/// bit-exact cached means make backward indistinguishable from the
/// cache-off pass.
#[test]
fn backward_replay_through_cached_hits_matches_finite_difference() {
    let ds =
        Arc::new(Dataset::generate(builtin_spec("zipf_serve").unwrap())
            .unwrap());
    let h = 32usize;
    let cfg = NativeConfig {
        fused: true,
        fanouts: Fanouts::of(&[4, 3]),
        amp: false,
        save_indices: true,
        seed: 7,
        threads: 1,
        planner: Default::default(),
        hidden: h,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: Some(4096),
    };
    let adamw = Manifest::builtin().adamw;
    let mut eng = NativeBackend::new(ds.clone(), cfg, adamw).unwrap();
    let seeds: Vec<i32> = (0..32).collect();
    let labels: Vec<i32> =
        seeds.iter().map(|&u| ds.labels[u as usize]).collect();
    let base = mix(5);
    let params0 = eng.params().to_vec();

    // one backend step at `base` fills the cache for that epoch (the
    // prepare lives inside train_step); restore the pre-step params so
    // the gradient check runs at a known point *with a warm cache*
    let inp = StepInputs { seeds: &seeds, labels: &labels, base,
                           block: None };
    let mut meter = MemoryMeter::new();
    eng.train_step(0, &inp, &mut meter).unwrap();
    eng.set_params(params0.clone());

    let before = eng.hub_counters().unwrap();
    let mut m = MemoryMeter::new();
    let (_, grads, _, _) =
        eng.fsa_loss_grads(&seeds, &labels, base, &mut m).unwrap();
    let after = eng.hub_counters().unwrap();
    assert!(after.0 > before.0,
            "gradient pass took no cached hits — the check would be \
             vacuous");

    let mut r = SplitMix64::new(21);
    for ti in 0..grads.len() {
        let g = &grads[ti];
        let delta: Vec<f32> = (0..g.len())
            .map(|_| r.next_normal() as f32 / (g.len() as f32).sqrt())
            .collect();
        let eps = 1e-2f32;
        let loss_at = |sign: f32, eng: &mut NativeBackend| -> f64 {
            let mut p = params0.clone();
            for (pv, &dl) in p[ti].iter_mut().zip(&delta) {
                *pv += sign * eps * dl;
            }
            eng.set_params(p);
            let mut m = MemoryMeter::new();
            eng.fsa_loss_grads(&seeds, &labels, base, &mut m).unwrap().0
        };
        let fd = (loss_at(1.0, &mut eng) - loss_at(-1.0, &mut eng))
            / (2.0 * eps as f64);
        eng.set_params(params0.clone());
        let analytic: f64 =
            g.iter().zip(&delta).map(|(&gv, &dl)| (gv * dl) as f64).sum();
        assert!((fd - analytic).abs() < 2e-3 + 0.05 * analytic.abs(),
                "tensor {ti}: fd {fd} vs analytic {analytic}");
    }
}
