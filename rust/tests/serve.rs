//! Serving-stack integration tests: the `Engine` facade's forward-only
//! inference path, the micro-batching request loop built on it, and the
//! versioned params checkpoint that connects `fsa train` to `fsa serve`.
//!
//! The two contracts pinned here:
//!
//! 1. **Grouping invariance** — the logits a request receives through
//!    the serve path are bitwise identical to a direct [`Engine::infer`]
//!    call, no matter how requests are coalesced into micro-batches, in
//!    which order they arrived, or how many kernel threads run
//!    (counter RNG is keyed per node, head matmul rows are independent).
//! 2. **Refactor neutrality** — `Trainer` is now a thin loop over
//!    `Engine::step`; its loss trajectory must replay the pre-refactor
//!    recipe (scheduler → sampler → native backend → AdamW) bitwise.

use std::path::PathBuf;
use std::sync::Arc;

use fusesampleagg::coordinator::pipeline::{prepare_batch, BatchScheduler};
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer, Variant};
use fusesampleagg::engine::Engine;
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::kernel::NativeBackend;
use fusesampleagg::memory::MemoryMeter;
use fusesampleagg::rng::{mix, SplitMix64};
use fusesampleagg::runtime::{Backend, BackendChoice, Runtime, StepInputs};
use fusesampleagg::sampler::ParallelSampler;
use fusesampleagg::serve::{channel, run_server, Reply, ServeConfig, Submit};

fn runtime() -> Runtime {
    // manifest-less: Runtime::from_env falls back to the builtin manifest
    Runtime::from_env().expect("manifest-less runtime")
}

fn tiny_cfg(threads: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        variant: Variant::Fsa,
        dataset: "tiny".into(),
        fanouts: Fanouts::of(&[5, 3]),
        batch: 64,
        amp: false,
        save_indices: false,
        seed,
        threads,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fsa_serve_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Deterministic request mix: 12 requests of 1..=5 seeds each.
fn request_mix(n_nodes: usize) -> Vec<Vec<i32>> {
    let mut r = SplitMix64::new(7);
    (0..12)
        .map(|i| {
            (0..(i % 5) + 1)
                .map(|_| r.next_below(n_nodes as u64) as i32)
                .collect()
        })
        .collect()
}

/// The serve-path contract: per-request scores are bitwise identical to
/// direct `Engine::infer`, under three different micro-batch policies
/// (per-request, one giant batch, seed-budget groups with shuffled
/// arrival order), at 1, 4 and 8 kernel threads — and the logits
/// themselves are bitwise identical across thread counts.
#[test]
fn serve_logits_match_direct_infer_across_groupings_and_threads() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut reference_t1: Option<Vec<Vec<f32>>> = None;
    for threads in [1usize, 4, 8] {
        let mut engine =
            Engine::new(&rt, &mut cache, tiny_cfg(threads, 42)).unwrap();
        let reqs = request_mix(engine.ds.spec.n);
        let direct: Vec<Vec<f32>> = reqs
            .iter()
            .map(|seeds| engine.infer(seeds).unwrap())
            .collect();
        match &reference_t1 {
            None => reference_t1 = Some(direct.clone()),
            Some(want) => assert_eq!(&direct, want,
                                     "threads={threads} changed logits"),
        }

        // (window_ms, max_batch, shuffle arrival order?)
        let policies = [(0.0, 1usize, false),
                        (50.0, 4096, false),
                        (5.0, 7, true)];
        for (window, max_batch, shuffle) in policies {
            let scfg = ServeConfig { batch_window_ms: window,
                                     max_batch, queue_depth: 64,
                                     deadline_ms: 0.0 };
            let (handle, rx) = channel(&scfg, engine.ds.spec.n);
            let mut order: Vec<usize> = (0..reqs.len()).collect();
            if shuffle {
                let mut r = SplitMix64::new(99);
                for i in (1..order.len()).rev() {
                    let j = r.next_below(i as u64 + 1) as usize;
                    order.swap(i, j);
                }
            }
            let mut replies: Vec<Option<std::sync::mpsc::Receiver<Reply>>> =
                (0..reqs.len()).map(|_| None).collect();
            for &i in &order {
                match handle.submit(reqs[i].clone()).unwrap() {
                    Submit::Accepted(rx) => replies[i] = Some(rx),
                    Submit::Shed => panic!("queue_depth 64 shed 12 reqs"),
                }
            }
            drop(handle); // server drains the queue, then exits
            let stats = run_server(&mut engine, &scfg, &rx).unwrap();
            assert_eq!(stats.completed, reqs.len() as u64);
            assert!(stats.batches >= 1);
            for (i, rx) in replies.into_iter().enumerate() {
                let r = rx.unwrap().recv().unwrap();
                assert_eq!(r.scores().expect("scores reply"),
                           &direct[i][..],
                           "threads={threads} window={window} \
                            max_batch={max_batch} shuffle={shuffle}: \
                            request {i} logits diverged from direct \
                            inference");
                assert!(r.latency_ms >= 0.0);
            }
        }
    }
}

/// Backpressure: at queue depth 1 with no server draining, the second
/// and third submissions shed synchronously; once the server runs, the
/// one admitted request is still answered.
#[test]
fn tiny_queue_depth_sheds_then_serves_admitted_requests() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut engine =
        Engine::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    let scfg = ServeConfig { batch_window_ms: 0.0, max_batch: 512,
                             queue_depth: 1, deadline_ms: 0.0 };
    let (handle, rx) = channel(&scfg, engine.ds.spec.n);
    let accepted = match handle.submit(vec![3, 4]).unwrap() {
        Submit::Accepted(rx) => rx,
        Submit::Shed => panic!("empty queue shed the first request"),
    };
    assert!(matches!(handle.submit(vec![5]).unwrap(), Submit::Shed));
    assert!(matches!(handle.submit(vec![6]).unwrap(), Submit::Shed));
    drop(handle);
    let stats = run_server(&mut engine, &scfg, &rx).unwrap();
    assert_eq!((stats.completed, stats.batches, stats.seeds), (1, 1, 2));
    let reply = accepted.recv().unwrap();
    assert_eq!(reply.scores().expect("scores reply"),
               &engine.infer(&[3, 4]).unwrap()[..]);
}

/// Satellite of the fault-tolerance PR: 20 malformed stdin lines each
/// get a structured `ERR <reason>` reply on stdout, and a well-formed
/// request after all of them is still served — bad input never takes
/// the server down.
#[test]
fn malformed_stdin_lines_get_err_replies_and_serving_continues() {
    use std::io::Write as _;
    use std::process::{Command, Stdio};

    let malformed = [
        "abc", "1 2 x", "--", "1.5", "2 -x", ",", "!!", "9e9", "0x10",
        "1;2", "two", "NaN", "-", "+ +", "12345678901234567890",
        "seeds 1 2", "[1,2]", "\"3\"", "{", "1 2 3.0",
    ];
    assert_eq!(malformed.len(), 20);
    let mut child = Command::new(env!("CARGO_BIN_EXE_fsa"))
        .args(["serve", "--dataset", "tiny", "--fanout", "5x3",
               "--batch", "64", "--backend", "native"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn fsa serve");
    {
        let mut stdin = child.stdin.take().unwrap();
        for line in malformed {
            writeln!(stdin, "{line}").unwrap();
        }
        writeln!(stdin, "1 2 3").unwrap();
        // dropping stdin sends EOF: the server drains and exits cleanly
    }
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "serve exited {:?}", out.status);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let errs = stdout.lines().filter(|l| l.starts_with("ERR ")).count();
    assert_eq!(errs, 20,
               "every malformed line gets exactly one ERR reply:\n{stdout}");
    assert!(stdout.lines().any(|l| l.starts_with("seeds [1, 2, 3]")),
            "the good request after 20 bad ones must still be \
             served:\n{stdout}");
}

#[test]
fn infer_rejects_out_of_range_seeds() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut engine =
        Engine::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    let n = engine.ds.spec.n as i32;
    let err = engine.infer(&[-1]).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
    let err = engine.infer(&[n]).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
}

/// train --save-params → serve --params: the checkpoint restores the
/// trained tensors bitwise, and a restored engine reproduces the trained
/// engine's logits exactly.
#[test]
fn params_checkpoint_round_trips_bitwise_and_restores_into_engine() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let path = tmp("roundtrip_params.json");
    let mut tr = Trainer::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    for _ in 0..5 {
        tr.step().unwrap();
    }
    tr.save_params(&path).unwrap();
    let trained = tr.params_f32().unwrap();
    let seeds: Vec<i32> = (0..20).collect();
    let want = tr.infer(&seeds).unwrap();
    drop(tr);

    let mut fresh = Engine::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    assert_ne!(fresh.params_f32().unwrap(), trained,
               "training must have moved the parameters");
    fresh.load_params(&path).unwrap();
    assert_eq!(fresh.params_f32().unwrap(), trained,
               "checkpoint restore must be bitwise");
    assert_eq!(fresh.infer(&seeds).unwrap(), want,
               "restored engine must reproduce the trained logits");
}

/// Mismatched checkpoints are hard errors at `Engine::load_params` —
/// serving never silently falls back to fresh weights. (File-level
/// corruption — truncation, bad JSON, wrong version/kind — is pinned by
/// the unit battery in `engine::checkpoint`.)
#[test]
fn mismatched_checkpoints_are_hard_errors_at_load() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut tr = Trainer::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    tr.step().unwrap();
    let good = tr.params_checkpoint().unwrap();
    let engine = tr.engine_mut();

    fn check(engine: &mut Engine<'_>,
             good: &fusesampleagg::engine::ParamsCheckpoint, name: &str,
             mutate: &dyn Fn(&mut fusesampleagg::engine::ParamsCheckpoint),
             needle: &str) {
        let mut ck = good.clone();
        mutate(&mut ck);
        let p = tmp(&format!("bad_{name}.json"));
        ck.save(&p).unwrap();
        let err = engine.load_params(&p).unwrap_err().to_string();
        assert!(err.contains(needle), "{name}: {err}");
    }
    check(engine, &good, "variant", &|ck| ck.variant = "dgl".into(),
          "variant");
    check(engine, &good, "dataset", &|ck| ck.dataset = "arxiv_sim".into(),
          "dataset");
    check(engine, &good, "tensor_count", &|ck| { ck.params.pop(); },
          "tensors");
    check(engine, &good, "tensor_shape", &|ck| { ck.params[0].pop(); },
          "tensor 0");
    // after all those rejections the engine still serves
    assert!(engine.infer(&[1, 2, 3]).is_ok());
}

/// The tentpole's neutrality pin: `Trainer` (now a newtype over
/// `Engine`) must replay the pre-refactor training recipe bitwise —
/// same scheduler draws, same per-step base seeds, same native backend
/// stepping.
#[test]
fn trainer_loss_trajectory_matches_prerefactor_recipe_bitwise() {
    let rt = runtime();
    let cfg = tiny_cfg(1, 42);

    let mut cache = DatasetCache::new();
    let mut tr = Trainer::new(&rt, &mut cache, cfg.clone()).unwrap();
    let got: Vec<f64> = (0..12).map(|_| tr.step().unwrap().loss).collect();

    // the recipe as the pre-Engine Trainer hardcoded it
    let ds = Arc::new(Dataset::generate(builtin_spec("tiny").unwrap())
                          .unwrap());
    let mut sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed).unwrap();
    let sampler = ParallelSampler::with_planner(cfg.threads, cfg.planner);
    let mut eng = NativeBackend::new(
        ds.clone(), cfg.native_config(rt.manifest.hidden),
        rt.manifest.adamw).unwrap();
    let mut meter = MemoryMeter::new();
    let mut want = Vec::with_capacity(12);
    for step in 0..12usize {
        let seeds = sched.next_seeds();
        let base = mix(cfg.seed.wrapping_add(step as u64));
        let prepared = prepare_batch(&ds, cfg.host_work(), &cfg.fanouts,
                                     &sampler, step, seeds, base);
        let inp = StepInputs {
            seeds: &prepared.seeds,
            labels: &prepared.labels,
            base: prepared.base,
            block: prepared.block.as_ref(),
        };
        want.push(eng.train_step(step, &inp, &mut meter).unwrap().loss);
    }
    assert_eq!(got, want,
               "Engine refactor changed the training trajectory");
}

/// Pin of the reply-time deadline re-check (serve bugfix): a request
/// dispatched *within* its deadline whose micro-batch then stalls (the
/// chaos `serve` site scripts a 150 ms stall) must be answered
/// [`ReplyBody::Timeout`] — never the stale scores — and be counted in
/// `ServeStats::timeouts`. Before the fix the pre-dispatch check was
/// the only one, so a slow batch delivered expired scores uncounted.
#[test]
fn deadline_is_rechecked_at_reply_time_after_slow_batch() {
    use fusesampleagg::runtime::faults::ChaosPlane;
    use fusesampleagg::serve::ReplyBody;

    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut cfg = tiny_cfg(1, 42);
    // stall the first (and only) micro-batch well past the deadline
    cfg.faults = Arc::new(ChaosPlane::parse("serve@0=stall:150", 42)
                              .unwrap());
    let mut engine = Engine::new(&rt, &mut cache, cfg).unwrap();
    let scfg = ServeConfig { batch_window_ms: 0.0, max_batch: 512,
                             queue_depth: 8, deadline_ms: 20.0 };
    let (handle, rx) = channel(&scfg, engine.ds.spec.n);
    // submitted fresh: the pre-dispatch deadline check passes, only the
    // reply-time re-check can catch the stalled batch
    let accepted = match handle.submit(vec![1, 2]).unwrap() {
        Submit::Accepted(rx) => rx,
        Submit::Shed => panic!("empty queue shed the request"),
    };
    drop(handle);
    let stats = run_server(&mut engine, &scfg, &rx).unwrap();
    let reply = accepted.recv().unwrap();
    assert!(matches!(reply.body, ReplyBody::Timeout),
            "slow batch must time out at reply time, got {:?}",
            reply.body);
    assert!(reply.latency_ms > scfg.deadline_ms,
            "timeout reply carries the real latency ({} ms)",
            reply.latency_ms);
    assert_eq!((stats.completed, stats.timeouts, stats.batches), (1, 1, 1),
               "the expired request is answered, counted as a timeout, \
                and the batch still ran");
}

/// Satellite: duplicate seed ids — repeated *within* one request and
/// shared *across* two requests coalesced into the same micro-batch —
/// each get scores bitwise identical to a dedup'd direct
/// [`Engine::infer`] over the distinct seeds. The counter RNG is keyed
/// per node, so a seed's logits cannot depend on how often (or next to
/// what) it appears in a batch.
#[test]
fn duplicate_seeds_within_and_across_requests_match_dedup_infer() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut engine =
        Engine::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    let c = engine.ds.spec.c;

    // the dedup'd reference: one infer over the distinct seeds only
    let distinct = [5, 9, 17];
    let reference = engine.infer(&distinct).unwrap();
    let row = |s: i32| -> &[f32] {
        let i = distinct.iter().position(|&d| d == s).unwrap();
        &reference[i * c..(i + 1) * c]
    };

    // request 0 repeats seed 5 three times; request 1 shares seeds 9
    // and 5 with it; a wide window coalesces both into one batch
    let reqs = [vec![5, 5, 9, 5], vec![9, 17, 5]];
    let scfg = ServeConfig { batch_window_ms: 200.0, max_batch: 4096,
                             queue_depth: 64, deadline_ms: 0.0 };
    let (handle, rx) = channel(&scfg, engine.ds.spec.n);
    let replies: Vec<_> = reqs
        .iter()
        .map(|r| match handle.submit(r.clone()).unwrap() {
            Submit::Accepted(rx) => rx,
            Submit::Shed => panic!("queue_depth 64 shed 2 requests"),
        })
        .collect();
    drop(handle);
    let stats = run_server(&mut engine, &scfg, &rx).unwrap();
    assert_eq!((stats.completed, stats.batches), (2, 1),
               "both requests must coalesce into one micro-batch");
    assert_eq!(stats.seeds, 7, "the batch carries the raw (dup'd) seeds");
    for (req, rx) in reqs.iter().zip(replies) {
        let reply = rx.recv().unwrap();
        let scores = reply.scores().expect("scores reply");
        assert_eq!(scores.len(), req.len() * c);
        for (i, &s) in req.iter().enumerate() {
            assert_eq!(&scores[i * c..(i + 1) * c], row(s),
                       "seed {s} at slot {i} diverged from the dedup'd \
                        direct inference");
        }
    }
}

/// `evaluate` is now literally accuracy-over-`infer`: recompute it by
/// hand from the same logits and the two must agree exactly.
#[test]
fn evaluate_is_accuracy_over_infer() {
    use fusesampleagg::engine::argmax;
    use fusesampleagg::gen::Split;

    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut engine =
        Engine::new(&rt, &mut cache, tiny_cfg(1, 42)).unwrap();
    let acc = engine.evaluate(512).unwrap();
    let mut nodes = engine.ds.split_nodes(Split::Val);
    nodes.truncate(512); // evaluate(512) truncates to max_nodes.max(512)
    let logits = engine.infer(&nodes).unwrap();
    let c = engine.ds.spec.c;
    let correct = nodes
        .iter()
        .enumerate()
        .filter(|(i, &u)| {
            argmax(&logits[i * c..(i + 1) * c]) as i32
                == engine.ds.labels[u as usize]
        })
        .count();
    assert_eq!(acc, correct as f64 / nodes.len() as f64);
}
