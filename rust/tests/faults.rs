//! Fault-tolerance integration tests: the scripted chaos plane driven
//! through the real training, checkpoint, and serving stacks.
//!
//! The contracts pinned here:
//!
//! 1. **Worker panic recovery** — a kernel or sampler shard worker that
//!    panics is recomputed serially; the loss trajectory is bitwise
//!    identical to an undisturbed run, at 1/4/8 threads.
//! 2. **Crash-exact resume** — `save_params` at step `k` plus
//!    `restore_training` reproduces the uninterrupted trajectory
//!    bitwise, for `k` ∈ {first, mid, last}.
//! 3. **Bounded-retry persistence** — injected checkpoint-write failures
//!    retry with backoff, then hard-error naming the site; a transient
//!    failure heals with one retry.
//! 4. **Serve isolation** — a poisoned micro-batch answers its own
//!    requests with `Error` and every other request still gets bitwise
//!    `Engine::infer` scores, at 1/4/8 threads.
//! 5. **Crash-safe planner state** — a panic mid-session must not
//!    overwrite the previous `planner_state.json` (the `Engine::drop`
//!    `thread::panicking` guard), and injected state-write failures
//!    degrade to a warning, never an error.

use std::path::PathBuf;
use std::sync::Arc;

use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Variant};
use fusesampleagg::engine::Engine;
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::graph::PlannerChoice;
use fusesampleagg::runtime::faults::{self, ChaosPlane, FaultPlane};
use fusesampleagg::runtime::{BackendChoice, Runtime};
use fusesampleagg::serve::{channel, run_server, Reply, ReplyBody,
                           ServeConfig, Submit};

fn runtime() -> Runtime {
    // manifest-less: Runtime::from_env falls back to the builtin manifest
    Runtime::from_env().expect("manifest-less runtime")
}

fn chaos(spec: &str) -> Arc<dyn FaultPlane> {
    Arc::new(ChaosPlane::parse(spec, 42).unwrap())
}

fn tiny_cfg(variant: Variant, threads: usize,
            faults: Arc<dyn FaultPlane>) -> TrainConfig {
    TrainConfig {
        variant,
        dataset: "tiny".into(),
        fanouts: Fanouts::of(&[5, 3]),
        batch: 64,
        amp: false,
        save_indices: false,
        seed: 42,
        threads,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults,
        hub_cache: None,
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fsa_faults_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn losses(rt: &Runtime, cache: &mut DatasetCache, cfg: TrainConfig,
          steps: usize) -> Vec<f64> {
    let mut eng = Engine::new(rt, cache, cfg).unwrap();
    (0..steps).map(|_| eng.step().unwrap().loss).collect()
}

/// Contract 1: scripted worker panics (and stalls) in the fused kernel
/// and the parallel block sampler recover to a bitwise-identical loss
/// trajectory — the counter RNG is stateless, so the serial recompute
/// of a failed shard reproduces exactly what the worker would have
/// written. Probabilistic rules double as the replay-determinism check:
/// whatever subset of passes the seed poisons, values never move.
#[test]
fn scripted_worker_panics_recover_bitwise() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    for variant in [Variant::Fsa, Variant::Dgl] {
        let clean = losses(&rt, &mut cache,
                           tiny_cfg(variant, 1, faults::none()), 6);
        for threads in [1usize, 4, 8] {
            let plane =
                chaos("kernel@*~0.5=panic; sampler@*~0.5=panic; \
                       kernel@0=stall:1; sampler@0=stall:1");
            let got = losses(&rt, &mut cache,
                             tiny_cfg(variant, threads, plane), 6);
            assert_eq!(got, clean,
                       "{variant:?} threads={threads}: chaos changed \
                        the loss trajectory");
        }
    }
}

/// Contract 2: checkpoint at step `k`, restore into a fresh session,
/// continue — the concatenated trajectory must equal the uninterrupted
/// control bitwise, at the first, a middle, and the last checkpointable
/// step. This is the in-process half of the CI kill-and-resume smoke.
#[test]
fn resume_is_bitwise_at_first_mid_and_last_checkpoint() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    const STEPS: usize = 12;
    let control = losses(&rt, &mut cache,
                         tiny_cfg(Variant::Fsa, 1, faults::none()), STEPS);
    for k in [1usize, 6, STEPS - 1] {
        let path = tmp(&format!("resume_at_{k}.json"));
        {
            let mut eng = Engine::new(
                &rt, &mut cache,
                tiny_cfg(Variant::Fsa, 1, faults::none())).unwrap();
            for s in 0..k {
                assert_eq!(eng.step().unwrap().loss, control[s],
                           "pre-crash run diverged at step {s}");
            }
            eng.save_params(&path).unwrap();
            // the engine is dropped here: the "crash" loses everything
            // not in the checkpoint
        }
        let mut eng = Engine::new(
            &rt, &mut cache,
            tiny_cfg(Variant::Fsa, 1, faults::none())).unwrap();
        let done = eng.restore_training(&path).unwrap();
        assert_eq!(done, k, "checkpoint must remember its step cursor");
        let resumed: Vec<f64> =
            (k..STEPS).map(|_| eng.step().unwrap().loss).collect();
        assert_eq!(resumed, control[k..],
                   "resume at step {k} diverged from the uninterrupted \
                    trajectory");
    }
}

/// `--resume` guard rails: a params-only (train-less) checkpoint and a
/// session that already stepped are both hard errors with messages
/// naming the problem.
#[test]
fn resume_rejects_params_only_checkpoints_and_warm_sessions() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut eng = Engine::new(
        &rt, &mut cache, tiny_cfg(Variant::Fsa, 1, faults::none())).unwrap();
    eng.step().unwrap();

    // strip the v2 train block: --resume must refuse it
    let mut ck = eng.params_checkpoint().unwrap();
    assert!(ck.train.is_some(), "native checkpoints carry train state");
    ck.train = None;
    let p = tmp("params_only.json");
    ck.save(&p).unwrap();
    let mut fresh = Engine::new(
        &rt, &mut cache, tiny_cfg(Variant::Fsa, 1, faults::none())).unwrap();
    let err = fresh.restore_training(&p).unwrap_err().to_string();
    assert!(err.contains("no training state"), "{err}");

    // a full checkpoint must refuse to restore into a stepped session
    let p = tmp("full_for_warm.json");
    eng.save_params(&p).unwrap();
    let err = eng.restore_training(&p).unwrap_err().to_string();
    assert!(err.contains("fresh session"), "{err}");
}

/// Contract 3: every checkpoint write failing exhausts the retry budget
/// and hard-errors naming the site; a single transient failure costs
/// exactly one retry and still writes the file.
#[test]
fn checkpoint_write_failures_retry_then_hard_error_naming_the_site() {
    let rt = runtime();
    let mut cache = DatasetCache::new();

    let mut eng = Engine::new(
        &rt, &mut cache,
        tiny_cfg(Variant::Fsa, 1, chaos("ckpt-write@*=err"))).unwrap();
    eng.step().unwrap();
    let path = tmp("never_written.json");
    let err = format!("{:#}", eng.save_params(&path).unwrap_err());
    assert!(err.contains("ckpt-write failed after 3 attempts"), "{err}");
    assert!(!path.exists(),
            "an exhausted save must not leave a file behind");

    let mut eng = Engine::new(
        &rt, &mut cache,
        tiny_cfg(Variant::Fsa, 1, chaos("ckpt-write@0=err"))).unwrap();
    eng.step().unwrap();
    let path = tmp("healed_after_retry.json");
    eng.save_params(&path).unwrap();
    assert_eq!(eng.retries_total(), 1,
               "one transient failure = exactly one retry");
    assert!(path.exists());
}

/// Corrupt bytes on a checkpoint read (chaos `ckpt-read=corrupt`,
/// mangled between read and parse exactly where a torn disk would) are
/// a hard error — and only the scripted op is poisoned: the very next
/// load of the same file succeeds.
#[test]
fn corrupt_checkpoint_read_is_a_hard_error_then_heals() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let path = tmp("to_corrupt.json");
    {
        let mut eng = Engine::new(
            &rt, &mut cache,
            tiny_cfg(Variant::Fsa, 1, faults::none())).unwrap();
        eng.step().unwrap();
        eng.save_params(&path).unwrap();
    }
    let mut eng = Engine::new(
        &rt, &mut cache,
        tiny_cfg(Variant::Fsa, 1, chaos("ckpt-read@0=corrupt"))).unwrap();
    assert!(eng.load_params(&path).is_err(),
            "mangled checkpoint bytes must not parse");
    eng.load_params(&path)
        .expect("read op 1 is not scripted; the file itself is intact");
}

/// Contract 4: with one-request micro-batches, chaos `serve@1=panic`
/// poisons exactly the second batch — its request gets a typed `Error`
/// reply, every other request's scores stay bitwise equal to direct
/// `Engine::infer`, and the accounting (completed/faults/batches) adds
/// up. Identical behavior at 1/4/8 kernel threads.
#[test]
fn poisoned_serve_batch_is_isolated_and_others_serve_bitwise() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let reqs: Vec<Vec<i32>> =
        vec![vec![1], vec![2, 3], vec![4], vec![5, 6], vec![7]];
    for threads in [1usize, 4, 8] {
        let mut engine = Engine::new(
            &rt, &mut cache,
            tiny_cfg(Variant::Fsa, threads, chaos("serve@1=panic")))
            .unwrap();
        let direct: Vec<Vec<f32>> = reqs
            .iter()
            .map(|seeds| engine.infer(seeds).unwrap())
            .collect();
        // max_batch 1 ⇒ one micro-batch per request, in arrival order,
        // so the serve-site op counter indexes requests directly
        let scfg = ServeConfig { batch_window_ms: 0.0, max_batch: 1,
                                 queue_depth: 64, deadline_ms: 0.0 };
        let (handle, rx) = channel(&scfg, engine.ds.spec.n);
        let replies: Vec<std::sync::mpsc::Receiver<Reply>> = reqs
            .iter()
            .map(|seeds| match handle.submit(seeds.clone()).unwrap() {
                Submit::Accepted(rx) => rx,
                Submit::Shed => panic!("queue depth 64 shed 5 requests"),
            })
            .collect();
        drop(handle);
        let stats = run_server(&mut engine, &scfg, &rx).unwrap();
        assert_eq!(stats.completed, reqs.len() as u64,
                   "every admitted request gets exactly one reply");
        assert_eq!((stats.faults, stats.batches),
                   (1, reqs.len() as u64 - 1),
                   "threads={threads}: exactly the poisoned batch fails");
        for (i, rx) in replies.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            if i == 1 {
                match &r.body {
                    ReplyBody::Error(reason) => {
                        assert!(reason.contains("serve"), "{reason}")
                    }
                    other => panic!("poisoned request got {other:?}"),
                }
            } else {
                assert_eq!(r.scores().expect("scores reply"),
                           &direct[i][..],
                           "threads={threads}: request {i} diverged \
                            next to a poisoned batch");
            }
        }
    }
}

/// Contract 5a: a panic mid-session must leave the previous
/// `planner_state.json` byte-for-byte intact — `Engine::drop` skips the
/// shutdown save while unwinding (state measured up to an undefined
/// failure point must not clobber the last good file).
#[test]
fn mid_session_panic_leaves_previous_planner_state_intact() {
    let rt = runtime();
    let path = tmp("panic_guard_state.json");
    let _ = std::fs::remove_file(&path);
    let cfg = || TrainConfig {
        planner: PlannerChoice::Adaptive,
        planner_state: Some(path.clone()),
        hub_cache: None,
        ..tiny_cfg(Variant::Fsa, 4, faults::none())
    };
    {
        let mut cache = DatasetCache::new();
        let mut eng = Engine::new(&rt, &mut cache, cfg()).unwrap();
        for _ in 0..4 {
            eng.step().unwrap();
        }
        // clean drop: saves the adaptive weights
    }
    let before = std::fs::read(&path)
        .expect("a clean adaptive session must persist planner state");

    let crashed = std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| {
            let mut cache = DatasetCache::new();
            let mut eng = Engine::new(&rt, &mut cache, cfg()).unwrap();
            eng.step().unwrap();
            panic!("simulated crash mid-session");
        }));
    assert!(crashed.is_err());
    let after = std::fs::read(&path).unwrap();
    assert_eq!(before, after,
               "a panicking session must not rewrite planner state");
}

/// Contract 5b: injected planner-state write failures degrade to a
/// warning — the session completes, nothing is written, nothing panics.
#[test]
fn state_write_failures_degrade_to_a_warning() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let path = tmp("state_write_err.json");
    let _ = std::fs::remove_file(&path);
    let cfg = TrainConfig {
        planner: PlannerChoice::Adaptive,
        planner_state: Some(path.clone()),
        hub_cache: None,
        ..tiny_cfg(Variant::Fsa, 4, chaos("state-write@*=err"))
    };
    {
        let mut eng = Engine::new(&rt, &mut cache, cfg).unwrap();
        for _ in 0..3 {
            eng.step().unwrap();
        }
        // drop: the save fails, warns, and must not propagate
    }
    assert!(!path.exists(),
            "a failed state write must not leave a file behind");
}
