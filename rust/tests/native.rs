//! Native-backend integration tests: the full trainer loop (scheduler →
//! sampler → prefetch → native engine → AdamW) with **no** AOT artifacts
//! and no PJRT bindings. These are the non-skipping counterpart of
//! `integration.rs` — they must stay green in a fresh checkout and are run
//! in release mode by CI (parity + gradient checks are too slow in debug).
//!
//! Depth-3 coverage and the depth-1/2 golden regressions against the
//! pre-refactor kernels live in `depth.rs`.

use std::sync::Arc;

use fusesampleagg::coordinator::{measure, DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::kernel::{fsa_param_specs, NativeBackend, NativeConfig};
use fusesampleagg::memory::MemoryMeter;
use fusesampleagg::rng::{mix, SplitMix64};
use fusesampleagg::runtime::{Backend, BackendChoice, Manifest, Runtime};

fn runtime() -> Runtime {
    // manifest-less: Runtime::from_env falls back to the builtin manifest
    Runtime::from_env().expect("manifest-less runtime")
}

fn tiny_cfg(variant: Variant, ks: &[usize], seed: u64) -> TrainConfig {
    TrainConfig {
        variant,
        dataset: "tiny".into(),
        fanouts: Fanouts::of(ks),
        batch: 64,
        amp: false,
        save_indices: true,
        seed,
        threads: 1,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    }
}

#[test]
fn auto_backend_falls_back_to_native_without_artifacts() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut cfg = tiny_cfg(Variant::Fsa, &[5, 3], 42);
    cfg.backend = BackendChoice::Auto;
    let tr = Trainer::new(&rt, &mut cache, cfg).unwrap();
    assert_eq!(tr.backend_name(), "native");
}

#[test]
fn pjrt_backend_is_a_hard_error_without_artifacts() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut cfg = tiny_cfg(Variant::Fsa, &[5, 3], 42);
    cfg.backend = BackendChoice::Pjrt;
    assert!(Trainer::new(&rt, &mut cache, cfg).is_err());
}

/// PJRT cannot express depth > 2: explicit selection errors with a
/// message naming the manifest limitation, and `Auto` silently lands on
/// the native engine.
#[test]
fn pjrt_rejects_depth_3_and_auto_falls_back() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut cfg = tiny_cfg(Variant::Fsa, &[4, 3, 2], 42);
    cfg.backend = BackendChoice::Pjrt;
    let err = Trainer::new(&rt, &mut cache, cfg).unwrap_err().to_string();
    assert!(err.contains("depth"), "{err}");
    let mut cfg = tiny_cfg(Variant::Fsa, &[4, 3, 2], 42);
    cfg.backend = BackendChoice::Auto;
    let tr = Trainer::new(&rt, &mut cache, cfg).unwrap();
    assert_eq!(tr.backend_name(), "native");
}

#[test]
fn native_fsa2_trains_loss_decreases_and_beats_chance() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut tr =
        Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Fsa, &[5, 3], 42))
            .unwrap();
    let timings = measure(&mut tr, 2, 40).unwrap();
    let first = timings.first().unwrap().loss;
    let last = timings.last().unwrap().loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(timings.iter().all(|t| t.loss.is_finite()));
    assert!(timings.iter().all(|t| t.sample_ms == 0.0),
            "fsa must not pay host sampling");
    assert!(timings.iter().all(|t| t.pairs > 0));
    assert!(timings.iter().all(|t| t.execute_ms > 0.0));
    let acc = tr.evaluate(512).unwrap();
    let chance = 1.0 / tr.ds.spec.c as f64;
    assert!(acc > 2.0 * chance, "accuracy {acc} vs chance {chance}");
}

#[test]
fn native_dgl2_trains_and_pays_host_sampling() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut tr =
        Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Dgl, &[5, 3], 42))
            .unwrap();
    let timings = measure(&mut tr, 2, 30).unwrap();
    let first = timings.first().unwrap().loss;
    let last = timings.last().unwrap().loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(timings.iter().all(|t| t.sample_ms > 0.0),
            "baseline must pay host sampling");
    let acc = tr.evaluate(512).unwrap();
    let chance = 1.0 / tr.ds.spec.c as f64;
    assert!(acc > 1.5 * chance, "accuracy {acc} vs chance {chance}");
}

#[test]
fn one_hop_native_variants_train() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    for variant in [Variant::Fsa, Variant::Dgl] {
        let mut tr =
            Trainer::new(&rt, &mut cache, tiny_cfg(variant, &[5], 42))
                .unwrap();
        let timings = measure(&mut tr, 1, 25).unwrap();
        let first = timings.first().unwrap().loss;
        let last = timings.last().unwrap().loss;
        assert!(last < first, "{variant:?} 1-hop: loss {first} -> {last}");
    }
}

#[test]
fn native_training_is_bitwise_deterministic() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let losses = |seed: u64, cache: &mut DatasetCache| -> Vec<f64> {
        let mut tr =
            Trainer::new(&rt, cache, tiny_cfg(Variant::Fsa, &[5, 3], seed))
                .unwrap();
        (0..15).map(|_| tr.step().unwrap().loss).collect()
    };
    let a = losses(42, &mut cache);
    let b = losses(42, &mut cache);
    assert_eq!(a, b, "same seed must replay bitwise");
    let c = losses(43, &mut cache);
    assert_ne!(a, c, "different seed must differ");
}

/// The pipeline and kernel threading knobs must not change training:
/// 8 threads + prefetch must replay the serial loss sequence bitwise,
/// for both variants.
#[test]
fn parallel_prefetch_native_training_matches_serial() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let losses = |cfg: TrainConfig, cache: &mut DatasetCache| -> Vec<f64> {
        let mut tr = Trainer::new(&rt, cache, cfg).unwrap();
        (0..12).map(|_| tr.step().unwrap().loss).collect()
    };
    for variant in [Variant::Fsa, Variant::Dgl] {
        let serial = losses(tiny_cfg(variant, &[5, 3], 42), &mut cache);
        let mut fast = tiny_cfg(variant, &[5, 3], 42);
        fast.threads = 8;
        fast.prefetch = true;
        let pipelined = losses(fast, &mut cache);
        assert_eq!(serial, pipelined,
                   "{variant:?}: threads/prefetch changed the trajectory");
    }
}

#[test]
fn paired_native_variants_share_sampling_schedule() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let fsa =
        Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Fsa, &[5, 3], 42))
            .unwrap();
    let dgl =
        Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Dgl, &[5, 3], 42))
            .unwrap();
    assert_eq!(fsa.step_base_seed(), dgl.step_base_seed());
}

/// The acceptance-shaped memory claim, CPU-scaled: at a wider fanout the
/// measured transient bytes of the block-materializing baseline exceed the
/// fused path by well over 5x.
#[test]
fn measured_transient_ratio_exceeds_five() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut cfg = tiny_cfg(Variant::Fsa, &[10, 5], 42);
    cfg.batch = 256;
    let mut fsa = Trainer::new(&rt, &mut cache, cfg.clone()).unwrap();
    let f = fsa.step().unwrap();
    cfg.variant = Variant::Dgl;
    let mut dgl = Trainer::new(&rt, &mut cache, cfg).unwrap();
    let d = dgl.step().unwrap();
    assert!(f.transient_bytes > 0 && d.transient_bytes > 0);
    let ratio = d.transient_bytes as f64 / f.transient_bytes as f64;
    assert!(ratio > 5.0,
            "baseline {} vs fused {} ({ratio:.1}x)",
            d.transient_bytes, f.transient_bytes);
}

/// Golden parity at the model level: the fused forward of the engine must
/// match an independently-computed unfused forward (gather + masked means
/// + dense head) within 1e-5.
#[test]
fn native_fused_forward_matches_unfused_reference() {
    use fusesampleagg::kernel::linalg::{add_bias, matmul, relu};
    use fusesampleagg::sampler;

    let ds = Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap());
    let (d, h, c) = (ds.spec.d, 64usize, ds.spec.c);
    let cfg = NativeConfig {
        fused: true,
        fanouts: Fanouts::of(&[5, 3]),
        amp: false,
        save_indices: false,
        seed: 42,
        threads: 1,
        planner: Default::default(),
        hidden: h,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let adamw = Manifest::builtin().adamw;
    let mut eng = NativeBackend::new(ds.clone(), cfg, adamw).unwrap();
    let seeds: Vec<i32> = (100..164).collect();
    let base = mix(999);
    let got = eng.eval_logits(&seeds, base).unwrap().unwrap();

    // reference: materialized two-level masked means at the fixed eval
    // fanout (15x10 — eval_logits uses the depth-matched 15-10 protocol
    // for this 2-hop config, mirroring the AOT eval artifacts), then
    // the same head
    let (ek1, ek2) = (15usize, 10usize);
    let b = seeds.len();
    let params = eng.params().to_vec();
    let s1 = sampler::sample_frontier(&ds.graph, &seeds, ek1, base, 0);
    let s2 = sampler::sample_frontier(&ds.graph, &s1, ek2, base, 1);
    let mut agg = vec![0.0f32; b * d];
    for bi in 0..b {
        let mut outer = vec![0.0f64; d];
        let mut k1_eff = 0usize;
        for ui in 0..ek1 {
            let u = s1[bi * ek1 + ui];
            if u < 0 {
                continue;
            }
            k1_eff += 1;
            let row = &s2[(bi * ek1 + ui) * ek2..(bi * ek1 + ui + 1) * ek2];
            let valid: Vec<i32> =
                row.iter().copied().filter(|&w| w >= 0).collect();
            for &w in &valid {
                for j in 0..d {
                    outer[j] += ds.features[w as usize * d + j] as f64
                        / valid.len() as f64;
                }
            }
        }
        for j in 0..d {
            agg[bi * d + j] = (outer[j] / k1_eff.max(1) as f64) as f32;
        }
    }
    let mut x_self = vec![0.0f32; b * d];
    for (i, &s) in seeds.iter().enumerate() {
        x_self[i * d..(i + 1) * d]
            .copy_from_slice(&ds.features[s as usize * d..(s as usize + 1) * d]);
    }
    let mut pre = vec![0.0f32; b * h];
    matmul(&x_self, &params[0], &mut pre, b, d, h);
    matmul(&agg, &params[1], &mut pre, b, d, h);
    add_bias(&mut pre, &params[2], b, h);
    relu(&mut pre);
    let mut want = vec![0.0f32; b * c];
    matmul(&pre, &params[3], &mut want, b, h, c);
    add_bias(&mut want, &params[4], b, c);

    // the aggregate itself agrees to ~1e-7 (pinned at 1e-5 by the kernel
    // tests); two matmul layers amplify rounding, so logits get 1e-4
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-4 + w.abs() * 1e-4,
                "logit[{i}]: {g} vs {w}");
    }
}

/// The fused engine's parameter gradients must match central finite
/// differences of its loss (directional probes per tensor) on `tiny`.
#[test]
fn fused_grads_match_finite_difference() {
    let ds = Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap());
    let (d, h, c) = (ds.spec.d, 32usize, ds.spec.c);
    let cfg = NativeConfig {
        fused: true,
        fanouts: Fanouts::of(&[4, 3]),
        amp: false,
        save_indices: true,
        seed: 7,
        threads: 1,
        planner: Default::default(),
        hidden: h,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let adamw = Manifest::builtin().adamw;
    let mut eng = NativeBackend::new(ds.clone(), cfg, adamw).unwrap();
    let seeds: Vec<i32> = (0..32).collect();
    let labels: Vec<i32> =
        seeds.iter().map(|&u| ds.labels[u as usize]).collect();
    let base = mix(5);

    let params0 = eng.params().to_vec();
    let mut meter = MemoryMeter::new();
    let (_, grads, _, _) =
        eng.fsa_loss_grads(&seeds, &labels, base, &mut meter).unwrap();
    assert_eq!(grads.len(), fsa_param_specs(d, h, c).len());

    let mut r = SplitMix64::new(21);
    for ti in 0..grads.len() {
        let g = &grads[ti];
        let delta: Vec<f32> = (0..g.len())
            .map(|_| r.next_normal() as f32 / (g.len() as f32).sqrt())
            .collect();
        let eps = 1e-2f32;
        let loss_at = |sign: f32, eng: &mut NativeBackend| -> f64 {
            let mut p = params0.clone();
            for (pv, &dl) in p[ti].iter_mut().zip(&delta) {
                *pv += sign * eps * dl;
            }
            eng.set_params(p);
            let mut m = MemoryMeter::new();
            eng.fsa_loss_grads(&seeds, &labels, base, &mut m).unwrap().0
        };
        let fd = (loss_at(1.0, &mut eng) - loss_at(-1.0, &mut eng))
            / (2.0 * eps as f64);
        eng.set_params(params0.clone());
        let analytic: f64 =
            g.iter().zip(&delta).map(|(&gv, &dl)| (gv * dl) as f64).sum();
        assert!((fd - analytic).abs() < 2e-3 + 0.05 * analytic.abs(),
                "tensor {ti}: fd {fd} vs analytic {analytic}");
    }
}

/// bf16 feature storage (AMP) still trains: loss decreases and stays
/// within shouting distance of the f32 trajectory.
#[test]
fn amp_bf16_storage_trains() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut cfg = tiny_cfg(Variant::Fsa, &[5, 3], 42);
    cfg.amp = true;
    let mut tr = Trainer::new(&rt, &mut cache, cfg).unwrap();
    let timings = measure(&mut tr, 1, 30).unwrap();
    let first = timings.first().unwrap().loss;
    let last = timings.last().unwrap().loss;
    assert!(last < first * 0.9, "bf16 loss {first} -> {last}");
    assert!(timings.iter().all(|t| t.loss.is_finite()));
}

/// `step_with_seeds` (explicit-seed steps, as the e2e example uses) works
/// on the native backend and counts pairs.
#[test]
fn explicit_seed_steps_work() {
    let rt = runtime();
    let mut cache = DatasetCache::new();
    let mut tr =
        Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Fsa, &[5, 3], 42))
            .unwrap();
    let seeds: Vec<i32> = (0..64).collect();
    let t = tr.step_with_seeds(&seeds).unwrap();
    assert!(t.loss.is_finite() && t.pairs > 0);
}
