//! Integration tests over the full stack: manifest → PJRT compile →
//! trainer → loss curves → eval → bench rows → renderers.
//!
//! These need `make artifacts` to have been run; they skip (with a message)
//! when the artifacts are missing so that pure-rust unit tests stay green
//! in a fresh checkout.

use fusesampleagg::bench::{render, run_config};
use fusesampleagg::coordinator::{measure, DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::metrics::BenchRow;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

/// PJRT CPU buffer upload is not robust under concurrent clients in
/// xla_extension 0.5.1 (intermittent size-check aborts), so integration
/// tests serialize on a global lock. Each test still gets its own Runtime.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn runtime() -> Option<(std::sync::MutexGuard<'static, ()>, Runtime)> {
    let guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let dir = util::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration test: {dir:?} missing — run `make artifacts`");
        return None;
    }
    Some((guard, Runtime::new(&dir).expect("runtime")))
}

fn tiny_cfg(variant: Variant, hops: u32, seed: u64) -> TrainConfig {
    TrainConfig {
        variant,
        dataset: "tiny".into(),
        fanouts: if hops == 2 {
            Fanouts::of(&[5, 3])
        } else {
            Fanouts::of(&[5])
        },
        batch: 64,
        amp: true,
        save_indices: true,
        seed,
        threads: 1,
        prefetch: false,
        backend: Default::default(),
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    }
}

#[test]
fn fsa2_trains_and_loss_decreases() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let mut tr = Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Fsa, 2, 42))
        .unwrap();
    let timings = measure(&mut tr, 2, 30).unwrap();
    let first = timings.first().unwrap().loss;
    let last = timings.last().unwrap().loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(timings.iter().all(|t| t.loss.is_finite()));
    assert!(timings.iter().all(|t| t.sample_ms == 0.0),
            "fsa must not pay host sampling");
}

#[test]
fn dgl2_trains_and_loss_decreases() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let mut tr = Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Dgl, 2, 42))
        .unwrap();
    let timings = measure(&mut tr, 2, 30).unwrap();
    let first = timings.first().unwrap().loss;
    let last = timings.last().unwrap().loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(timings.iter().all(|t| t.sample_ms > 0.0),
            "baseline must pay host sampling");
}

#[test]
fn one_hop_variants_train() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    for variant in [Variant::Fsa, Variant::Dgl] {
        let mut tr =
            Trainer::new(&rt, &mut cache, tiny_cfg(variant, 1, 42)).unwrap();
        let timings = measure(&mut tr, 1, 20).unwrap();
        let first = timings.first().unwrap().loss;
        let last = timings.last().unwrap().loss;
        assert!(last < first, "{variant:?} 1-hop: loss {first} -> {last}");
    }
}

#[test]
fn training_is_bitwise_deterministic() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let losses = |seed: u64, cache: &mut DatasetCache| -> Vec<f64> {
        let mut tr =
            Trainer::new(&rt, cache, tiny_cfg(Variant::Fsa, 2, seed)).unwrap();
        (0..15).map(|_| tr.step().unwrap().loss).collect()
    };
    let a = losses(42, &mut cache);
    let b = losses(42, &mut cache);
    assert_eq!(a, b, "same seed must replay bitwise");
    let c = losses(43, &mut cache);
    assert_ne!(a, c, "different seed must differ");
}

/// The pipeline knobs must not change training: 8 sampler threads +
/// prefetch must replay the serial loss sequence bitwise.
#[test]
fn parallel_prefetch_training_matches_serial() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let losses = |cfg: TrainConfig, cache: &mut DatasetCache| -> Vec<f64> {
        let mut tr = Trainer::new(&rt, cache, cfg).unwrap();
        (0..12).map(|_| tr.step().unwrap().loss).collect()
    };
    let serial = losses(tiny_cfg(Variant::Dgl, 2, 42), &mut cache);
    let mut fast = tiny_cfg(Variant::Dgl, 2, 42);
    fast.threads = 8;
    fast.prefetch = true;
    let pipelined = losses(fast, &mut cache);
    assert_eq!(serial, pipelined,
               "threads/prefetch changed the training trajectory");
}

#[test]
fn paired_variants_share_sampling_schedule() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let fsa = Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Fsa, 2, 42))
        .unwrap();
    let dgl = Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Dgl, 2, 42))
        .unwrap();
    assert_eq!(fsa.step_base_seed(), dgl.step_base_seed());
}

#[test]
fn transient_memory_baseline_exceeds_fused() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let f = run_config(&rt, &mut cache, tiny_cfg(Variant::Fsa, 2, 42), 1, 5)
        .unwrap();
    let d = run_config(&rt, &mut cache, tiny_cfg(Variant::Dgl, 2, 42), 1, 5)
        .unwrap();
    assert!(d.peak_transient_bytes > f.peak_transient_bytes,
            "baseline {} <= fused {}", d.peak_transient_bytes,
            f.peak_transient_bytes);
}

#[test]
fn eval_accuracy_beats_chance_after_training() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let mut tr = Trainer::new(&rt, &mut cache, tiny_cfg(Variant::Fsa, 2, 42))
        .unwrap();
    for _ in 0..40 {
        tr.step().unwrap();
    }
    let acc = tr.evaluate(512).unwrap();
    let chance = 1.0 / tr.ds.spec.c as f64;
    assert!(acc > 2.0 * chance, "accuracy {acc} vs chance {chance}");
}

#[test]
fn bench_rows_render_all_exhibits() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    // fabricate a grid from the tiny dataset at two "fanouts" (re-using the
    // same artifact config; renderers only need paired rows)
    let mut rows: Vec<BenchRow> = Vec::new();
    for (variant, seed) in [(Variant::Fsa, 42), (Variant::Dgl, 42),
                            (Variant::Fsa, 43), (Variant::Dgl, 43)] {
        let mut r = run_config(&rt, &mut cache, tiny_cfg(variant, 2, seed),
                               1, 5).unwrap();
        r.batch = 1024; // renderers filter on the paper's B=1024 grid
        rows.push(r);
    }
    let t1 = render::table1(&rows);
    assert!(t1.contains("tiny") && t1.contains("x"), "{t1}");
    let t2 = render::table2(&rows);
    assert!(t2.contains("tiny"));
    for fig in [render::fig1(&rows), render::fig4(&rows),
                render::fig5(&rows)] {
        assert!(fig.contains("tiny"), "{fig}");
    }
}

#[test]
fn save_indices_off_artifact_runs() {
    let Some((_serial, rt)) = runtime() else { return };
    // forward-profiling mode exists only for products_sim in the manifest
    let spec = rt
        .manifest
        .find_train("fsa2", "products_sim", 15, 10, 1024, true, false);
    assert!(spec.is_ok(), "nosave artifact missing: {spec:?}");
}

#[test]
fn manifest_covers_every_grid_cell_and_files_exist() {
    let Some((_serial, rt)) = runtime() else { return };
    let dir = util::artifacts_dir();
    for a in rt.manifest.artifacts.values() {
        assert!(dir.join(&a.file).exists(), "missing {}", a.file);
        assert!(!a.inputs.is_empty());
        assert!(!a.outputs.is_empty());
    }
}

#[test]
fn bf16_feature_artifact_trains() {
    let Some((_serial, rt)) = runtime() else { return };
    let mut cache = DatasetCache::new();
    let cfg = TrainConfig {
        variant: Variant::Fsa,
        dataset: "products_sim".into(),
        fanouts: Fanouts::of(&[15, 10]),
        batch: 1024,
        amp: true,
        save_indices: true,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend: Default::default(),
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let mut tr = Trainer::new_named(
        &rt, &mut cache, cfg,
        "fsa2_train_products_sim_f15x10_b1024_ampOn_xbf16").unwrap();
    let timings = measure(&mut tr, 1, 5).unwrap();
    let first = timings.first().unwrap().loss;
    let last = timings.last().unwrap().loss;
    assert!(first.is_finite() && last < first, "bf16 loss {first} -> {last}");
}
