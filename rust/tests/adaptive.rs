//! Adaptive-planner feedback suite — deterministic by construction.
//!
//! Everything here runs on the [`VirtualClock`] seam: per-shard "wall
//! time" is a scripted function of the planned shard cost (a 2×-slow
//! worker is exactly 2× slower, every run), so the whole feedback loop —
//! plan → measure → observe → replan — is a pure function with no
//! wall-clock dependence. The suite proves:
//!
//! 1. **Convergence**: under a 2×-slow worker, adaptive cut targets pull
//!    work toward the fast workers until the measured imbalance drops
//!    below a pinned threshold within a pinned step budget.
//! 2. **Warm start**: a session seeded from persisted weights converges
//!    in strictly fewer steps than a cold session.
//! 3. **Sampler-side feedback**: the parallel block sampler's per-level
//!    stats feed the same shared [`CostModel`] as the fused kernel.
//! 4. **Persistence e2e**: a trainer writes `planner_state.json` at
//!    shutdown and a second trainer warm-starts from it — while loss
//!    trajectories stay bitwise identical (plans never change values).
//! 5. **No stat leaks**: the prefetch pipeline's stale-accumulation
//!    discard keeps one batch's sampler stats out of the next step's
//!    imbalance, at threads 1/4/8.
//! 6. **Output invariance**: nominal/quantile sampler, kernel, and
//!    trainer outputs are bitwise identical to the serial reference at
//!    threads 1/4/8, virtual clock or not.

use std::sync::{Arc, Mutex};

use fusesampleagg::coordinator::pipeline::{prepare_batch, BatchPrefetcher,
                                           BatchScheduler, HostWork};
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::graph::{lock_model, CostModel, PlannerChoice,
                           PlannerState, ShardClock, ShardStats,
                           SharedCostModel, StateEntry, StateKey,
                           VirtualClock};
use fusesampleagg::kernel::{fused, Features};
use fusesampleagg::runtime::{BackendChoice, Runtime};
use fusesampleagg::sampler::{self, ParallelSampler};

/// The pinned convergence contract: with one worker 2× slow among 4, a
/// uniform plan measures ≥ 1.5 imbalance; adaptive feedback must push it
/// below 1.15 within 12 observed steps.
const PARTS: usize = 4;
const SLOW: f64 = 2.0;
const THRESH: f64 = 1.15;
const BUDGET: usize = 12;

fn tiny() -> Dataset {
    Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
}

fn tmp_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("fsa_adaptive_suite");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Drive the pure feedback loop: plan `costs` into [`PARTS`] shards,
/// time them with `clock`, observe, repeat. Returns the imbalance
/// trajectory (one entry per step, measured *before* that step's
/// observation lands) and the first step whose plan was below
/// [`THRESH`] (`steps` if never).
fn simulate(model: &mut CostModel, clock: &VirtualClock, costs: &[u64],
            steps: usize) -> (Vec<f64>, usize) {
    let mut traj = Vec::with_capacity(steps);
    let mut converged = steps;
    for step in 0..steps {
        let plan = model.plan(costs, PARTS);
        let shard_cost: Vec<u64> = plan
            .iter()
            .map(|r| costs[r.clone()].iter().sum())
            .collect();
        let shard_ms: Vec<f64> = shard_cost
            .iter()
            .enumerate()
            .map(|(j, &c)| clock.shard_ms(j, c, 0.0))
            .collect();
        let stats = ShardStats::new(shard_ms, shard_cost);
        let imb = stats.imbalance();
        traj.push(imb);
        if imb < THRESH && converged == steps {
            converged = step;
        }
        model.observe(&stats);
    }
    (traj, converged)
}

#[test]
fn adaptive_converges_under_virtual_2x_slow_worker() {
    let ds = tiny();
    let fo = Fanouts::of(&[5, 3]);
    let clock = VirtualClock::with_slow_worker(PARTS, 0, SLOW);
    let mut model = CostModel::new(&ds.graph, &fo, PlannerChoice::Adaptive);
    let costs = vec![16u64; 512];
    let (traj, converged) = simulate(&mut model, &clock, &costs, BUDGET);
    // uniform first plan: slow worker is the critical path, ≈ 1.6
    assert!(traj[0] > 1.5, "cold start not imbalanced: {traj:?}");
    assert!(converged < BUDGET,
            "did not converge below {THRESH} within {BUDGET} steps: \
             {traj:?}");
    // and it *stays* converged: the last plan is at least as balanced
    assert!(*traj.last().unwrap() < THRESH, "{traj:?}");
    // weights moved the right way: the slow worker owns less cost share
    let w = model.worker_weights();
    assert_eq!(w.len(), PARTS);
    assert!(w[0] < 0.8 && w[1] > 1.0, "weights {w:?}");
    // the same loop under a uniform clock never drifts: imbalance stays
    // at 1.0 and weights stay (numerically) uniform
    let flat = VirtualClock::new(vec![1.0; PARTS]);
    let mut m2 = CostModel::new(&ds.graph, &fo, PlannerChoice::Adaptive);
    let (traj2, conv2) = simulate(&mut m2, &flat, &costs, 6);
    assert_eq!(conv2, 0, "{traj2:?}");
    assert!(traj2.iter().all(|&v| (v - 1.0).abs() < 1e-9), "{traj2:?}");
}

#[test]
fn warm_start_converges_strictly_faster_than_cold() {
    let ds = tiny();
    let fo = Fanouts::of(&[5, 3]);
    let clock = VirtualClock::with_slow_worker(PARTS, 0, SLOW);
    let costs = vec![16u64; 512];

    // cold session: converges, but needs at least one feedback step
    let mut cold = CostModel::new(&ds.graph, &fo, PlannerChoice::Adaptive);
    let (cold_traj, cold_steps) = simulate(&mut cold, &clock, &costs, 50);
    assert!(cold_steps >= 1, "cold start converged with no feedback?! \
                              {cold_traj:?}");
    assert!(cold_steps < 50);

    // persist the converged weights through the real state file machinery
    let path = tmp_dir().join("warm_start.json");
    let key = StateKey {
        host: "simhost".into(),
        threads: PARTS,
        planner: PlannerChoice::Adaptive,
    };
    let mut st = PlannerState::default();
    st.put(&key, StateEntry {
        weights: cold.worker_weights().to_vec(),
        steps_observed: cold.steps_observed(),
        saved_unix: 1,
    });
    st.save(&path).unwrap();

    // warm session: loads the file, seeds the model, converges faster
    let loaded = PlannerState::load(&path);
    let entry = loaded.get(&key).expect("saved entry must load back");
    let mut warm = CostModel::new(&ds.graph, &fo, PlannerChoice::Adaptive);
    assert!(warm.warm_start(&entry.weights, entry.steps_observed));
    assert_eq!(warm.steps_observed(), cold.steps_observed());
    let (warm_traj, warm_steps) = simulate(&mut warm, &clock, &costs, 50);
    assert!(warm_steps < cold_steps,
            "warm start ({warm_steps} steps, {warm_traj:?}) not strictly \
             faster than cold ({cold_steps} steps, {cold_traj:?})");
    // the very first warm plan is already balanced
    assert!(warm_traj[0] < THRESH, "{warm_traj:?}");
}

#[test]
fn fused_kernel_feeds_adaptive_weights_and_stays_bitwise() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    let seeds: Vec<i32> =
        (0..256i32).map(|i| (i * 3) % ds.spec.n as i32).collect();
    let fo = Fanouts::of(&[5, 3]);
    let reference = fused::fused_khop(&ds.graph, &feat, &seeds, &fo, 21,
                                      true, 1);
    let clock = Arc::new(VirtualClock::with_slow_worker(PARTS, 0, SLOW));
    let mut model = CostModel::new(&ds.graph, &fo, PlannerChoice::Adaptive)
        .with_clock(clock.clone());
    for step in 0..8 {
        let out = fused::fused_khop_planned(&ds.graph, &feat, &seeds, &fo,
                                            21, true, PARTS, &model);
        assert_eq!(out.agg, reference.agg, "step {step}: agg diverged");
        assert_eq!(out.saved, reference.saved, "step {step}");
        assert_eq!(out.pairs, reference.pairs, "step {step}");
        // the kernel's reported shard times are exactly the scripted
        // virtual values — no wall clock leaks through the seam
        assert_eq!(out.stats.shard_ms.len(), PARTS);
        for (j, (&ms, &c)) in out.stats.shard_ms.iter()
            .zip(&out.stats.shard_cost).enumerate()
        {
            let want = c as f64 * if j == 0 { SLOW } else { 1.0 };
            assert_eq!(ms, want, "step {step} shard {j}");
        }
        model.observe(&out.stats);
    }
    let w = model.worker_weights();
    assert_eq!(w.len(), PARTS);
    assert!(w[0] < 0.8, "slow worker not discounted: {w:?}");
    assert!(w[1] > 1.0 && w[2] > 1.0 && w[3] > 1.0, "{w:?}");
    assert_eq!(model.steps_observed(), 8);
}

#[test]
fn sampler_block_builds_feed_the_shared_model_and_stay_bitwise() {
    let ds = tiny();
    let fo = Fanouts::of(&[4, 3]);
    let seeds: Vec<i32> =
        (0..512i32).map(|i| (i * 7) % ds.spec.n as i32).collect();
    let serial = sampler::build_block(&ds.graph, &seeds, &fo, 33);

    let clock: Arc<dyn ShardClock> =
        Arc::new(VirtualClock::with_slow_worker(PARTS, 0, SLOW));
    let model = CostModel::new(&ds.graph, &fo, PlannerChoice::Adaptive)
        .with_clock(clock);
    let shared: SharedCostModel = Arc::new(Mutex::new(model));
    let s = ParallelSampler::with_planner(PARTS, PlannerChoice::Adaptive)
        .with_model(shared.clone());
    for round in 0..6 {
        let blk = s.build_block(&ds.graph, &seeds, &fo, 33);
        assert_eq!(blk.frontiers, serial.frontiers, "round {round}");
        assert_eq!(blk.leaf, serial.leaf, "round {round}");
        let imb = s.take_imbalance()
            .expect("sharded block build must record imbalance");
        assert!(imb >= 1.0 - 1e-9, "round {round}: {imb}");
    }
    // both levels of every build observed into the *shared* weights:
    // the sampler side of the feedback loop is closed
    let m = lock_model(&shared);
    let w = m.worker_weights();
    assert_eq!(w.len(), PARTS, "{w:?}");
    assert!(w[0] < 0.9 && w[0] < w[1], "sampler feedback missing: {w:?}");
    assert_eq!(m.steps_observed(), 12, "2 levels x 6 builds");
}

#[test]
fn prefetch_discard_never_leaks_stats_between_batches() {
    let ds = Arc::new(tiny());
    let fo = Fanouts::of(&[4, 3]);
    let batch = 256;
    for &threads in &[1usize, 4, 8] {
        let clock: Arc<dyn ShardClock> = Arc::new(VirtualClock::new(
            vec![2.0, 1.0, 1.0, 0.5, 1.0, 3.0, 1.0, 1.0]));
        // reference: a fresh sampler per batch is leak-free by
        // construction; the virtual clock makes each batch's imbalance
        // an exact, comparable value
        let mut ref_sched = BatchScheduler::new(&ds, batch, 42).unwrap();
        let mut want = Vec::new();
        for s in 0..6 {
            let seeds = ref_sched.next_seeds();
            let fresh = ParallelSampler::new(threads)
                .with_clock(clock.clone());
            let b = prepare_batch(&ds, HostWork::Block, &fo, &fresh, s,
                                  seeds, ref_sched.base_seed(s));
            want.push(b.sample_imbalance);
        }
        if threads == 1 {
            assert!(want.iter().all(Option::is_none),
                    "serial runs must not report imbalance");
        }
        // the same batches through one long-lived prefetch sampler:
        // the stale-accumulation discard must reproduce the fresh
        // values exactly — any leak shifts the f64 and fails
        let mut sched = BatchScheduler::new(&ds, batch, 42).unwrap();
        let worker = ParallelSampler::new(threads).with_clock(clock.clone());
        let mut pf = BatchPrefetcher::spawn(ds.clone(), HostWork::Block,
                                            fo.clone(), worker);
        for s in 0..6 {
            let got = pf.next_batch(&mut sched).unwrap();
            assert_eq!(got.step, s);
            assert_eq!(got.sample_imbalance, want[s],
                       "threads={threads} step {s}: stats leaked across \
                        batches");
        }
        // direct pollution: an unrelated sharded pass before
        // prepare_batch must be fully discarded
        if threads > 1 {
            let polluted = ParallelSampler::new(threads)
                .with_clock(clock.clone());
            let junk: Vec<i32> = (0..448).collect();
            polluted.sample_frontier(&ds.graph, &junk, 5, 99, 0);
            let mut sched = BatchScheduler::new(&ds, batch, 42).unwrap();
            let seeds = sched.next_seeds();
            let got = prepare_batch(&ds, HostWork::Block, &fo, &polluted,
                                    0, seeds, sched.base_seed(0));
            assert_eq!(got.sample_imbalance, want[0],
                       "threads={threads}: polluted accumulator leaked \
                        into the batch imbalance");
        }
    }
}

#[test]
fn trainer_persists_state_and_warm_starts_next_session() {
    let rt = Runtime::from_env().unwrap();
    let mut cache = DatasetCache::new();
    let path = tmp_dir().join("trainer_state.json");
    let _ = std::fs::remove_file(&path);
    let mk_cfg = |state: Option<std::path::PathBuf>| TrainConfig {
        variant: Variant::Fsa,
        dataset: "tiny".into(),
        fanouts: Fanouts::of(&[5, 3]),
        batch: 256,
        amp: false,
        save_indices: true,
        seed: 42,
        threads: 4,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: PlannerChoice::Adaptive,
        planner_state: state,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let cfg = mk_cfg(Some(path.clone()));
    // session 1: cold start, real (wall-clock) feedback, save on drop
    let losses_cold: Vec<f64> = {
        let mut tr = Trainer::new(&rt, &mut cache, cfg.clone()).unwrap();
        assert!(tr.planner_weights().is_none(),
                "cold session has no weights before feedback");
        (0..4).map(|_| tr.step().unwrap().loss).collect()
    };
    assert!(path.exists(), "session end must write the state file");
    let state = PlannerState::load(&path);
    let key = StateKey::for_session(4, PlannerChoice::Adaptive);
    let entry = state.get(&key)
        .expect("state file must hold this session's key");
    assert!(entry.steps_observed >= 1, "{entry:?}");
    assert_eq!(entry.weights.len(), 4, "{entry:?}");
    assert!(entry.saved_unix > 0);

    // session 2: warm-starts before its first step
    let bytes_before = std::fs::read(&path).unwrap();
    let tr2 = Trainer::new(&rt, &mut cache, cfg.clone()).unwrap();
    let w = tr2.planner_weights()
        .expect("second session must warm-start from the file");
    assert_eq!(w.len(), 4);
    assert!(w.iter().all(|v| v.is_finite() && *v > 0.0), "{w:?}");
    drop(tr2);
    // tr2 observed nothing beyond its warm-start baseline, so its drop
    // must not rewrite the file (no free staleness-stamp refreshes)
    assert_eq!(std::fs::read(&path).unwrap(), bytes_before,
               "measurement-free session rewrote the state file");

    // plans never change values: a warm-started session reproduces the
    // cold session's loss trajectory bitwise
    let mut tr3 = Trainer::new(&rt, &mut cache, cfg).unwrap();
    let losses_warm: Vec<f64> =
        (0..4).map(|_| tr3.step().unwrap().loss).collect();
    assert_eq!(losses_cold, losses_warm,
               "warm-started plans changed computed values");

    drop(tr3);
    // a corrupted state file degrades to uniform, never errors
    std::fs::write(&path, "{definitely not json").unwrap();
    let tr4 = Trainer::new(&rt, &mut cache, mk_cfg(Some(path.clone())))
        .unwrap();
    assert!(tr4.planner_weights().is_none(),
            "corrupt state must fall back to uniform");
}

/// Acceptance pin: nominal/quantile sampler, kernel, and trainer outputs
/// are bitwise identical to the serial reference at threads 1/4/8 — with
/// a virtual clock scripted onto every timing path, proving the clock
/// seam (and all the feedback plumbing behind it) cannot reach values.
#[test]
fn nominal_and_quantile_outputs_identical_at_threads_1_4_8() {
    let ds = tiny();
    let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
    let seeds: Vec<i32> =
        (0..256i32).map(|i| (i * 5) % ds.spec.n as i32).collect();
    let fo = Fanouts::of(&[4, 3]);
    let ref_block = sampler::build_block(&ds.graph, &seeds, &fo, 77);
    let ref_fused = fused::fused_khop(&ds.graph, &feat, &seeds, &fo, 77,
                                      true, 1);
    for choice in [PlannerChoice::Nominal, PlannerChoice::Quantile] {
        for threads in [1usize, 4, 8] {
            let clock: Arc<dyn ShardClock> =
                Arc::new(VirtualClock::with_slow_worker(threads, 0, 7.0));
            let s = ParallelSampler::with_planner(threads, choice)
                .with_clock(clock.clone());
            let blk = s.build_block(&ds.graph, &seeds, &fo, 77);
            assert_eq!(blk.frontiers, ref_block.frontiers,
                       "{choice:?} t={threads}: sampler diverged");
            assert_eq!(blk.leaf, ref_block.leaf, "{choice:?} t={threads}");
            let model = CostModel::new(&ds.graph, &fo, choice)
                .with_clock(clock);
            let out = fused::fused_khop_planned(&ds.graph, &feat, &seeds,
                                                &fo, 77, true, threads,
                                                &model);
            assert_eq!(out.agg, ref_fused.agg,
                       "{choice:?} t={threads}: kernel diverged");
            assert_eq!(out.saved, ref_fused.saved, "{choice:?} t={threads}");
            assert_eq!(out.pairs, ref_fused.pairs);
        }
    }

    // trainer level: whole loss trajectories across flavors × threads
    let rt = Runtime::from_env().unwrap();
    let mut cache = DatasetCache::new();
    let run = |choice: PlannerChoice, threads: usize,
               cache: &mut DatasetCache| -> Vec<f64> {
        let cfg = TrainConfig {
            variant: Variant::Fsa,
            dataset: "tiny".into(),
            fanouts: Fanouts::of(&[4, 3]),
            batch: 128,
            amp: false,
            save_indices: true,
            seed: 7,
            threads,
            prefetch: false,
            backend: BackendChoice::Native,
            planner: choice,
            planner_state: None,
            simd: Default::default(),
            layout: Default::default(),
            faults: fusesampleagg::runtime::faults::none(),
            hub_cache: None,
        };
        let mut tr = Trainer::new(&rt, cache, cfg).unwrap();
        (0..5).map(|_| tr.step().unwrap().loss).collect()
    };
    let reference = run(PlannerChoice::Nominal, 1, &mut cache);
    for choice in [PlannerChoice::Nominal, PlannerChoice::Quantile] {
        for threads in [1usize, 4, 8] {
            assert_eq!(run(choice, threads, &mut cache), reference,
                       "{choice:?} t={threads}: trajectory diverged");
        }
    }
}
