//! Bench: regenerate **Table 1** (and the Fig 1 speedup bars) — step time
//! and sampled-pairs/s, DGL→FSA, across the paper's main grid
//! (3 datasets × 3 fanouts × {512,1024} × 3 repeats, AMP on).
//!
//! Outputs: results/bench.csv, results/table1.txt, results/fig1.txt.
//! Scale down with FSA_BENCH_QUICK=1 or FSA_BENCH_STEPS/WARMUP/SEEDS.

use fusesampleagg::bench::{env_overrides, render, run_grid, save_exhibit, Grid};
use fusesampleagg::coordinator::DatasetCache;
use fusesampleagg::metrics;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let grid = env_overrides(Grid::default());
    eprintln!("table1: {} configs x {} repeats, {} timed steps each",
              grid.datasets.len() * grid.fanouts.len() * grid.batches.len()
                  * grid.variants.len(),
              grid.seeds.len(), grid.steps);
    let rows = run_grid(&rt, &mut cache, &grid, |r| {
        eprintln!("  {:<13} {:<4} f{:<8} b{:<4} s{}: {:>8.2} ms/step",
                  r.dataset, r.variant, r.fanout, r.batch, r.repeat_seed,
                  r.step_ms);
    })?;
    metrics::write_csv(&util::results_dir().join("bench.csv"), &rows)?;
    save_exhibit("table1", &render::table1(&rows));
    save_exhibit("fig1", &render::fig1(&rows));
    Ok(())
}
