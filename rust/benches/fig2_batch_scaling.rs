//! Bench: regenerate **Fig 2** — throughput scaling with batch size on
//! products_sim at fanout 15-10 (B ∈ {128,256,512,1024,2048}, AMP on).
//!
//! Outputs: results/fig2.csv, results/fig2.txt.

use fusesampleagg::bench::{env_overrides, render, run_grid, save_exhibit, Grid};
use fusesampleagg::coordinator::DatasetCache;
use fusesampleagg::metrics;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let grid = env_overrides(Grid::fig2());
    let rows = run_grid(&rt, &mut cache, &grid, |r| {
        eprintln!("  fig2 {:<4} b{:<5} s{}: {:>8.2} ms/step ({:.0} seeds/s)",
                  r.variant, r.batch, r.repeat_seed, r.step_ms, r.nodes_per_s);
    })?;
    metrics::write_csv(&util::results_dir().join("fig2.csv"), &rows)?;
    save_exhibit("fig2", &render::fig2(&rows));
    Ok(())
}
