//! Bench: fused sample+aggregate vs block-materializing baseline on the
//! **native CPU engine** — the repo's reproduction of the paper's headline
//! comparison, runnable with no artifacts and no PJRT bindings.
//!
//! Runs both variants over the three `*_sim` datasets at the paper's main
//! cell (fanout 15x10, batch 1024) **plus a depth axis**: fanouts of depth
//! 1/2/3 at a matched 150-leaves-per-seed budget (150, 15x10, 15x5x2), so
//! the transient-ratio-vs-depth trajectory is recorded at equal gather
//! volume. Reports per-step time, steps/sec, speedup, and *measured* peak
//! transient bytes per depth, and writes the cross-PR trajectory artifact
//! `BENCH_native.json` at the repo root. A final simd on/off A/B at the
//! paper's main cell records the native vector-tier speedup
//! (`simd_speedup` at the JSON root; outputs are bitwise identical, only
//! step time moves), and a hub-cache on/off A/B over the serve path on
//! the Zipf-skewed `zipf_serve` fixture (plus a uniform-law neutrality
//! cell on `tiny`) records `hub_cache_speedup` /
//! `hub_cache_uniform_ratio` the same way. Scale down with
//! FSA_BENCH_QUICK=1 / FSA_BENCH_STEPS / FSA_BENCH_SEEDS.

use fusesampleagg::bench::{self, env_overrides, save_exhibit, Grid};
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Variant};
use fusesampleagg::engine::Engine;
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::json::Value;
use fusesampleagg::kernel::SimdChoice;
use fusesampleagg::rng::{mix, SplitMix64};
use fusesampleagg::runtime::{BackendChoice, Runtime};
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let grid = env_overrides(Grid {
        datasets: vec!["arxiv_sim".into(), "reddit_sim".into(),
                       "products_sim".into()],
        // depth axis at a matched 150-leaf budget: 150 = 15·10 = 15·5·2
        fanouts: vec![Fanouts::of(&[150]), Fanouts::of(&[15, 10]),
                      Fanouts::of(&[15, 5, 2])],
        batches: vec![1024],
        steps: 20,
        warmup: 3,
        seeds: vec![42, 43, 44],
        backend: BackendChoice::Native,
        ..Grid::default()
    });

    let rows = bench::run_grid(&rt, &mut cache, &grid, |r| {
        eprintln!("  {:<14} {:<4} f{:<8} b{} seed {}: {:>8.2} ms/step \
                   ({:.1} MB transient)",
                  r.dataset, r.variant, r.fanout, r.batch, r.repeat_seed,
                  r.step_ms, util::bytes_to_mb(r.peak_transient_bytes));
    })?;

    // simd on/off A/B at the paper's main cell (products_sim, 15x10,
    // B=1024, fused, native): same seed and planner, so outputs are
    // bitwise identical and only the vector tier differs — the measured
    // step-time speedup lands at the JSON root for the CI smoke.
    let ab_cfg = |simd| TrainConfig {
        variant: Variant::Fsa,
        dataset: "products_sim".into(),
        fanouts: Fanouts::of(&[15, 10]),
        batch: 1024,
        amp: grid.amp,
        save_indices: true,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: grid.planner,
        planner_state: None,
        faults: fusesampleagg::runtime::faults::none(),
        simd,
        layout: Default::default(),
        hub_cache: None,
    };
    eprintln!("  simd A/B: products_sim f15x10 b1024 fused, scalar tier...");
    let off = bench::run_config(&rt, &mut cache, ab_cfg(SimdChoice::Off),
                                grid.warmup, grid.steps)?;
    eprintln!("  simd A/B: vector tier...");
    let on = bench::run_config(&rt, &mut cache, ab_cfg(SimdChoice::On),
                               grid.warmup, grid.steps)?;
    let simd_speedup = off.step_ms / on.step_ms.max(1e-9);
    eprintln!("  simd A/B: off {:.2} ms, on {:.2} ms ({simd_speedup:.2}x)",
              off.step_ms, on.step_ms);

    // hub-cache A/B on the serve/eval path: zipf_serve's degree law puts
    // roughly half of all leaf gather traffic on a few hundred hub
    // nodes, so caching their innermost-hop partial means should beat
    // the cache-off engine by a clear margin at depth 3; tiny's uniform
    // law selects zero hubs, so the same A/B there is the neutrality
    // guard (ratio ~1.0). Logits are asserted bitwise identical inside
    // hub_ab before any timing is recorded.
    let passes = if std::env::var("FSA_BENCH_QUICK").is_ok() { 2 } else { 6 };
    eprintln!("  hub-cache A/B: zipf_serve f15x10x5 serve path \
               (budget 512)...");
    let (z_off, z_on) = hub_ab(&rt, &mut cache, &grid, "zipf_serve", 512,
                               passes)?;
    let hub_speedup = z_off / z_on.max(1e-9);
    eprintln!("  hub-cache A/B: off {z_off:.1} ms, on {z_on:.1} ms \
               ({hub_speedup:.2}x)");
    eprintln!("  hub-cache A/B: tiny (uniform, no hubs) neutrality...");
    let (t_off, t_on) = hub_ab(&rt, &mut cache, &grid, "tiny", 512, passes)?;
    let hub_uniform = t_off / t_on.max(1e-9);
    eprintln!("  hub-cache A/B: tiny off {t_off:.1} ms, on {t_on:.1} ms \
               (ratio {hub_uniform:.2})");

    let mut json = bench::native_bench_json(&rows, grid.planner, grid.simd);
    if let Value::Obj(root) = &mut json {
        root.insert("simd_off_step_ms".into(), Value::Num(off.step_ms));
        root.insert("simd_on_step_ms".into(), Value::Num(on.step_ms));
        root.insert("simd_speedup".into(), Value::Num(simd_speedup));
        root.insert("hub_cache_off_ms".into(), Value::Num(z_off));
        root.insert("hub_cache_on_ms".into(), Value::Num(z_on));
        root.insert("hub_cache_speedup".into(), Value::Num(hub_speedup));
        root.insert("hub_cache_uniform_ratio".into(),
                    Value::Num(hub_uniform));
    }
    let repo = util::find_repo_root()
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    std::fs::write(repo.join("BENCH_native.json"), format!("{json}\n"))?;

    // human-readable exhibit with the acceptance-shaped summary
    let mut out = String::from(
        "fused vs baseline — native CPU engine, batch 1024, depths 1/2/3 \
         at a matched 150-leaf budget\n");
    let empty = Vec::new();
    let cells = json.get("cells").and_then(|c| c.as_arr()).unwrap_or(&empty);
    out.push_str(&format!(
        "{:<14} {:<9} {:>6} {:>11} {:>11} {:>9} {:>11} {:>11} {:>9}\n",
        "dataset", "fanout", "depth", "fused ms", "base ms", "speedup",
        "fused MB", "base MB", "mem x"));
    for cell in cells {
        let f = |k: &str| cell.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        out.push_str(&format!(
            "{:<14} {:<9} {:>6} {:>11.2} {:>11.2} {:>8.2}x {:>11.2} \
             {:>11.2} {:>8.1}x\n",
            cell.get("dataset").and_then(|v| v.as_str()).unwrap_or("?"),
            cell.get("fanout").and_then(|v| v.as_str()).unwrap_or("?"),
            f("depth") as u32,
            f("fused_step_ms"), f("baseline_step_ms"), f("speedup"),
            util::bytes_to_mb(f("fused_peak_transient_bytes") as u64),
            util::bytes_to_mb(f("baseline_peak_transient_bytes") as u64),
            f("transient_ratio")));
    }
    out.push_str("\n(the mem-x column should grow with depth: the baseline \
                  block multiplies by (1+k) per hop, the fused transients \
                  only add saved-index rows)\n");
    out.push_str(&format!(
        "\nsimd A/B (products_sim f15x10 b1024, fused, bitwise-identical \
         outputs):\n  scalar tier {:.2} ms/step, vector tier {:.2} ms/step \
         -> {:.2}x\n",
        off.step_ms, on.step_ms, simd_speedup));
    out.push_str(&format!(
        "\nhub-cache A/B (serve path, f15x10x5, budget 512, \
         bitwise-identical logits):\n  zipf_serve: off {z_off:.1} ms, \
         on {z_on:.1} ms -> {hub_speedup:.2}x\n  tiny (uniform, 0 hubs): \
         off {t_off:.1} ms, on {t_on:.1} ms -> ratio {hub_uniform:.2} \
         (neutrality)\n"));
    save_exhibit("fused_vs_baseline", &out);
    println!("wrote {}", repo.join("BENCH_native.json").display());
    Ok(())
}

/// Serve-path hub-cache A/B on `dataset`: the same deterministic request
/// stream (32 requests x 64 seeds, SplitMix64-drawn) through a cache-off
/// engine and a cache-on engine with the given refresh `budget`, fanout
/// 15x10x5. The first pass checks every logit bitwise (a hit must replay
/// the exact RNG draw) and pays the cache's refresh builds; the timed
/// `passes` that follow measure steady-state serving. Returns
/// `(off_ms, on_ms)` total forward wall time.
fn hub_ab(rt: &Runtime, cache: &mut DatasetCache, grid: &Grid,
          dataset: &str, budget: usize, passes: usize)
          -> anyhow::Result<(f64, f64)> {
    let cfg = |hub_cache| TrainConfig {
        variant: Variant::Fsa,
        dataset: dataset.into(),
        fanouts: Fanouts::of(&[15, 10, 5]),
        batch: 64,
        amp: grid.amp,
        save_indices: false,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: grid.planner,
        planner_state: None,
        faults: fusesampleagg::runtime::faults::none(),
        simd: grid.simd,
        layout: Default::default(),
        hub_cache,
    };
    let mut eng_off = Engine::new(rt, cache, cfg(None))?;
    let mut eng_on = Engine::new(rt, cache, cfg(Some(budget)))?;
    let n = eng_off.ds.spec.n as u64;
    let mut rng = SplitMix64::new(mix(42 ^ 0x4B5));
    let requests: Vec<Vec<i32>> = (0..32)
        .map(|_| (0..64).map(|_| rng.next_below(n) as i32).collect())
        .collect();
    for req in &requests {
        let a = eng_off.infer(req)?;
        let b = eng_on.infer(req)?;
        anyhow::ensure!(
            a.len() == b.len()
                && a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "hub-cache on/off logits diverged on {dataset} — the cache \
             must be bitwise-invisible");
    }
    let t0 = std::time::Instant::now();
    for _ in 0..passes {
        for req in &requests {
            eng_off.infer(req)?;
        }
    }
    let off_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = std::time::Instant::now();
    for _ in 0..passes {
        for req in &requests {
            eng_on.infer(req)?;
        }
    }
    let on_ms = t1.elapsed().as_secs_f64() * 1e3;
    Ok((off_ms, on_ms))
}
