//! Bench: fused sample+aggregate vs block-materializing baseline on the
//! **native CPU engine** — the repo's reproduction of the paper's headline
//! comparison, runnable with no artifacts and no PJRT bindings.
//!
//! Runs both variants over the three `*_sim` datasets at the paper's main
//! cell (fanout 15x10, batch 1024), reports per-step time, speedup, and
//! *measured* peak transient bytes, and writes the cross-PR trajectory
//! artifact `BENCH_native.json` at the repo root. Scale down with
//! FSA_BENCH_QUICK=1 / FSA_BENCH_STEPS / FSA_BENCH_SEEDS.

use fusesampleagg::bench::{self, env_overrides, save_exhibit, Grid};
use fusesampleagg::coordinator::DatasetCache;
use fusesampleagg::runtime::{BackendChoice, Runtime};
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let grid = env_overrides(Grid {
        datasets: vec!["arxiv_sim".into(), "reddit_sim".into(),
                       "products_sim".into()],
        fanouts: vec![(15, 10)],
        batches: vec![1024],
        steps: 20,
        warmup: 3,
        seeds: vec![42, 43, 44],
        backend: BackendChoice::Native,
        ..Grid::default()
    });

    let rows = bench::run_grid(&rt, &mut cache, &grid, |r| {
        eprintln!("  {:<14} {:<4} b{} seed {}: {:>8.2} ms/step \
                   ({:.1} MB transient)",
                  r.dataset, r.variant, r.batch, r.repeat_seed, r.step_ms,
                  util::bytes_to_mb(r.peak_transient_bytes));
    })?;

    let json = bench::native_bench_json(&rows);
    let repo = util::find_repo_root()
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    std::fs::write(repo.join("BENCH_native.json"), format!("{json}\n"))?;

    // human-readable exhibit with the acceptance-shaped summary
    let mut out = String::from(
        "fused vs baseline — native CPU engine, fanout 15x10, batch 1024\n");
    let empty = Vec::new();
    let cells = json.get("cells").and_then(|c| c.as_arr()).unwrap_or(&empty);
    out.push_str(&format!(
        "{:<14} {:>12} {:>12} {:>9} {:>12} {:>12} {:>9}\n",
        "dataset", "fused ms", "base ms", "speedup", "fused MB", "base MB",
        "mem x"));
    for cell in cells {
        let f = |k: &str| cell.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        out.push_str(&format!(
            "{:<14} {:>12.2} {:>12.2} {:>8.2}x {:>12.2} {:>12.2} {:>8.1}x\n",
            cell.get("dataset").and_then(|v| v.as_str()).unwrap_or("?"),
            f("fused_step_ms"), f("baseline_step_ms"), f("speedup"),
            util::bytes_to_mb(f("fused_peak_transient_bytes") as u64),
            util::bytes_to_mb(f("baseline_peak_transient_bytes") as u64),
            f("transient_ratio")));
    }
    save_exhibit("fused_vs_baseline", &out);
    println!("wrote {}", repo.join("BENCH_native.json").display());
    Ok(())
}
