//! Micro-benchmarks of the L3 substrates on the hot path: the host
//! neighbor sampler (the baseline's per-step cost), block building, graph
//! generation, counter-RNG throughput, and manifest JSON parsing.
//!
//! These locate L3 bottlenecks for the §Perf pass (EXPERIMENTS.md):
//! if the host sampler dominated the baseline step, the fused-vs-baseline
//! comparison would be measuring the sampler, not the materialization gap.

use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::metrics::Timer;
use fusesampleagg::rng::{rand_counter, SplitMix64};
use fusesampleagg::sampler::{self, ParallelSampler};
use fusesampleagg::util;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t = Timer::start();
    for _ in 0..iters {
        f();
    }
    let per = t.ms() / iters as f64;
    println!("{name:<44} {per:>10.3} ms/iter  ({iters} iters)");
    per
}

fn main() -> anyhow::Result<()> {
    println!("micro-benchmarks (hot-path substrates)\n");

    // counter RNG
    let mut acc = 0u64;
    bench("rng: 1M rand_counter words", 20, || {
        for i in 0..1_000_000u64 {
            acc = acc.wrapping_add(rand_counter(42, i, 0, i & 15));
        }
    });
    std::hint::black_box(acc);

    // graph generation
    let t = Timer::start();
    let ds = Dataset::generate(builtin_spec("products_sim")?)?;
    println!("{:<44} {:>10.1} ms  ({} edges)", "gen: products_sim generate",
             t.ms(), ds.graph.num_edges());

    // host sampler: the baseline's per-step stage at the paper's settings
    let mut rng = SplitMix64::new(7);
    let seeds: Vec<i32> = (0..1024)
        .map(|_| rng.next_below(ds.spec.n as u64) as i32)
        .collect();
    let fo = Fanouts::of(&[15, 10]);
    let ms = bench("sampler: build_block b1024 f15x10", 20, || {
        std::hint::black_box(sampler::build_block(&ds.graph, &seeds, &fo,
                                                  rng.next_u64()));
    });
    let pairs = 1024.0 * (16.0 * 10.0 + 15.0);
    println!("{:<44} {:>10.1} Mpairs/s", "  -> sampler throughput",
             pairs / ms / 1e3);

    bench("sampler: fused_sampled_pairs (untimed path)", 20, || {
        std::hint::black_box(sampler::fused_sampled_pairs(
            &ds.graph, &seeds, &fo, rng.next_u64()));
    });

    // depth scaling of the block builder (matched 150-leaf budget)
    for ks in [&[150usize][..], &[15, 10][..], &[15, 5, 2][..]] {
        let f = Fanouts::of(ks);
        bench(&format!("sampler: build_block b1024 f{f}"), 10, || {
            std::hint::black_box(sampler::build_block(&ds.graph, &seeds, &f,
                                                      rng.next_u64()));
        });
    }

    // parallel sampler thread scaling (the tentpole's sharded host path;
    // output is bitwise identical to the serial sampler at any count)
    let serial_ms = ms;
    for threads in [2usize, 4, 8] {
        let ps = ParallelSampler::new(threads);
        let pms = bench(
            &format!("sampler: parallel build_block t{threads}"), 20, || {
                std::hint::black_box(ps.build_block(&ds.graph, &seeds, &fo,
                                                    rng.next_u64()));
            });
        println!("{:<44} {:>10.2}x vs serial", "  -> speedup",
                 serial_ms / pms);
    }

    // shuffling (epoch boundary cost)
    let mut nodes: Vec<i32> = (0..ds.spec.n as i32).collect();
    bench("rng: shuffle 32k train nodes", 50, || {
        SplitMix64::new(rng.next_u64()).shuffle(&mut nodes);
    });

    // manifest parse
    let manifest_path = util::artifacts_dir().join("manifest.json");
    if manifest_path.exists() {
        let text = std::fs::read_to_string(&manifest_path)?;
        bench("json: parse manifest.json", 50, || {
            std::hint::black_box(fusesampleagg::json::parse(&text).unwrap());
        });
    }

    Ok(())
}
