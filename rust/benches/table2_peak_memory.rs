//! Bench: regenerate **Table 2** and **Figs 4–5** — peak transient memory
//! per training step, DGL→FSA, with reduction ratios (B=1024, AMP on).
//!
//! Memory is stable after the first steps, so the default run is shorter
//! than the timing grid. Outputs: results/table2.txt, fig4.txt, fig5.txt,
//! memory.csv.

use fusesampleagg::bench::{env_overrides, render, run_grid, save_exhibit, Grid};
use fusesampleagg::coordinator::DatasetCache;
use fusesampleagg::metrics;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let mut grid = Grid { steps: 5, warmup: 1, seeds: vec![42, 43, 44],
                          ..Grid::default() };
    grid = env_overrides(grid);
    let rows = run_grid(&rt, &mut cache, &grid, |r| {
        eprintln!("  mem {:<13} {:<4} f{:<8} b{:<4}: {:>9.1} MB transient",
                  r.dataset, r.variant, r.fanout, r.batch,
                  util::bytes_to_mb(r.peak_transient_bytes));
    })?;
    metrics::write_csv(&util::results_dir().join("memory.csv"), &rows)?;
    save_exhibit("table2", &render::table2(&rows));
    save_exhibit("fig4", &render::fig4(&rows));
    save_exhibit("fig5", &render::fig5(&rows));
    Ok(())
}
