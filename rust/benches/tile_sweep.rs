//! §Perf bench: the two tile axes of the kernel schedule.
//!
//! **Axis 1 — PJRT seed-tile sweep** (the HBM↔VMEM schedule knob, the
//! paper's "kernel autotuning over block sizes" future work): same
//! configuration (products_sim, 15-10, B=1024, AMP on), six seed-tile
//! sizes. On a real TPU only tiles whose gathered block fits VMEM are
//! legal; on CPU-PJRT all run, exposing the grid-iteration overhead the
//! tile size trades against. The gathered-block formula reads the feature
//! width from the dataset spec — it is d-dependent, not a constant 64.
//!
//! **Axis 2 — native feature-tile sweep** (the L1-blocking knob of the
//! native fused kernel): the same cell on the native CPU engine at a
//! range of `FSA_D_TILE`-equivalent widths via
//! [`fusesampleagg::kernel::set_d_tile`]. Every width is bitwise-output
//! identical (the tile only chunks the feature dimension), so the sweep
//! is purely a step-time measurement; the default is detected from L1d
//! cache geometry and reported alongside.
//!
//! Outputs: results/tile_sweep.txt.

use std::fmt::Write as _;

use fusesampleagg::bench::save_exhibit;
use fusesampleagg::coordinator::{measure, DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::builtin_spec;
use fusesampleagg::kernel::{d_tile, set_d_tile, SimdChoice};
use fusesampleagg::metrics::median;
use fusesampleagg::runtime::{BackendChoice, Runtime};
use fusesampleagg::util::fmt_bytes;

fn cell_cfg(backend: BackendChoice) -> TrainConfig {
    TrainConfig {
        variant: Variant::Fsa,
        dataset: "products_sim".into(),
        fanouts: Fanouts::of(&[15, 10]),
        batch: 1024,
        amp: true,
        save_indices: true,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend,
        planner: Default::default(),
        planner_state: None,
        simd: SimdChoice::Auto,
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let quick = std::env::var("FSA_BENCH_QUICK").is_ok();
    let steps = if quick { 5 } else { 20 };
    let warmup = if quick { 1 } else { 3 };
    let spec = builtin_spec("products_sim")?;
    let (k1, k2, d) = (15usize, 10usize, spec.d);

    let mut out = String::new();
    let _ = writeln!(out, "Tile sweep — products_sim (d={d}), fanout 15-10, \
                           B=1024, AMP on ({steps} timed steps).\n");

    // -- axis 1: PJRT seed tile (rows of the batch per grid step)
    let _ = writeln!(out, "PJRT seed-tile axis (HBM<->VMEM schedule):");
    let _ = writeln!(out, "{:<8} {:>6} {:>16} {:>14} {:>12}", "tile", "grid",
                     "gather tile", "VMEM-legal?", "step (ms)");
    for tile in [8usize, 16, 32, 64, 256, 1024] {
        let name = format!("fsa2_train_products_sim_f15x10_b1024_ampOn_t{tile}");
        let cfg = cell_cfg(Default::default());
        let mut tr = Trainer::new_named(&rt, &mut cache, cfg, &name)?;
        let timings = measure(&mut tr, warmup, steps)?;
        let ms = median(&timings.iter().map(|t| t.total_ms()).collect::<Vec<_>>());
        // gathered leaf block per grid step: tile seeds x k1*k2 leaves x
        // d features x 4 bytes (d from the dataset spec, NOT a constant)
        let tile_bytes = (tile * k1 * k2 * d * 4) as u64;
        let legal = tile_bytes <= 4 * 1024 * 1024;
        let _ = writeln!(out, "{:<8} {:>6} {:>16} {:>14} {:>12.2}", tile,
                         1024 / tile, fmt_bytes(tile_bytes),
                         if legal { "yes" } else { "no (CPU only)" }, ms);
        eprintln!("  seed tile {tile}: {ms:.2} ms/step");
    }
    let _ = writeln!(out, "Default = largest VMEM-legal tile \
                           (tiling.seed_tile); larger tiles trade VMEM \
                           footprint for fewer grid iterations.\n");

    // -- axis 2: native feature tile (elements of d per gather pass)
    let detected = {
        set_d_tile(0); // measure what auto resolves to on this host
        d_tile()
    };
    let _ = writeln!(out, "native feature-tile axis (L1 blocking of the \
                           fused gather/fold; detected default {detected}):");
    let _ = writeln!(out, "{:<8} {:>16} {:>12}", "d_tile", "tile bytes",
                     "step (ms)");
    for tile in [64usize, 128, 256, 512, 1024] {
        set_d_tile(tile);
        let cfg = cell_cfg(BackendChoice::Native);
        let mut tr = Trainer::new(&rt, &mut cache, cfg)?;
        let timings = measure(&mut tr, warmup, steps)?;
        let ms = median(&timings.iter().map(|t| t.total_ms()).collect::<Vec<_>>());
        let _ = writeln!(out, "{:<8} {:>16} {:>12.2}{}", tile,
                         fmt_bytes((tile * 4) as u64), ms,
                         if tile == detected { "  <- detected" } else { "" });
        eprintln!("  feature tile {tile}: {ms:.2} ms/step");
    }
    set_d_tile(0); // restore auto for anything running after us
    let _ = writeln!(out, "Default = detected from L1d cache geometry \
                           (FSA_D_TILE overrides); every width is \
                           bitwise-output identical.");

    save_exhibit("tile_sweep", &out);
    Ok(())
}
