//! §Perf bench: L1 seed-tile sweep — the HBM↔VMEM schedule knob
//! (the paper's "kernel autotuning over block sizes" future work).
//!
//! Same configuration (products_sim, 15-10, B=1024, AMP on), four tile
//! sizes: 16 / 64 (VMEM-budget default) / 256 / 1024 (whole batch, one grid
//! step). On a real TPU only tiles whose gathered block fits VMEM are
//! legal; on CPU-PJRT all four run, exposing the grid-iteration overhead
//! that the tile size trades against. Outputs: results/tile_sweep.txt.

use std::fmt::Write as _;

use fusesampleagg::bench::save_exhibit;
use fusesampleagg::coordinator::{measure, DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::metrics::median;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let quick = std::env::var("FSA_BENCH_QUICK").is_ok();
    let steps = if quick { 5 } else { 20 };
    let warmup = if quick { 1 } else { 3 };

    let mut out = String::new();
    let _ = writeln!(out, "L1 seed-tile sweep — products_sim, fanout 15-10, \
                           B=1024, AMP on ({steps} timed steps).\n");
    let _ = writeln!(out, "{:<8} {:>6} {:>16} {:>14} {:>12}", "tile", "grid",
                     "gather tile", "VMEM-legal?", "step (ms)");

    for tile in [8usize, 16, 32, 64, 256, 1024] {
        let name = format!("fsa2_train_products_sim_f15x10_b1024_ampOn_t{tile}");
        let cfg = TrainConfig {
            variant: Variant::Fsa,
            dataset: "products_sim".into(),
            fanouts: Fanouts::of(&[15, 10]),
            batch: 1024,
            amp: true,
            save_indices: true,
            seed: 42,
            threads: 1,
            prefetch: false,
            backend: Default::default(),
            planner: Default::default(),
            planner_state: None,
            faults: fusesampleagg::runtime::faults::none(),
        };
        let mut tr = Trainer::new_named(&rt, &mut cache, cfg, &name)?;
        let timings = measure(&mut tr, warmup, steps)?;
        let ms = median(&timings.iter().map(|t| t.total_ms()).collect::<Vec<_>>());
        let tile_bytes = (tile * 15 * 10 * 64 * 4) as u64;
        let legal = tile_bytes <= 4 * 1024 * 1024;
        let _ = writeln!(out, "{:<8} {:>6} {:>16} {:>14} {:>12.2}", tile,
                         1024 / tile, fmt_bytes(tile_bytes),
                         if legal { "yes" } else { "no (CPU only)" }, ms);
        eprintln!("  tile {tile}: {ms:.2} ms/step");
    }
    let _ = writeln!(out, "\nDefault = largest VMEM-legal tile \
                           (tiling.seed_tile); larger tiles trade VMEM \
                           footprint for fewer grid iterations.");
    save_exhibit("tile_sweep", &out);
    Ok(())
}
