//! §Perf bench: data-parallel scaling of the localhost coordinator.
//!
//! One training cell run at 1, 2 and 4 thread-mode workers (same
//! protocol and code path as `fsa train --workers`, minus the process
//! fork): median wall-clock per optimizer step, the implied speedup
//! over one worker, the realized edge-load deviation of the shard cut,
//! and the per-fleet compute/communication split from `dist.csv` rows.
//!
//! The sweep asserts the module's core contract while it measures: the
//! loss trajectory must be bitwise identical across worker counts, so
//! a scaling number can never come from silently different work. On
//! localhost the "network" is loopback TCP and every worker shares the
//! physical cores, so this measures coordination overhead (params
//! broadcast + gradient collection), not real multi-host scaling.
//!
//! Outputs: results/dist_scaling.txt.

use std::fmt::Write as _;
use std::sync::Arc;

use fusesampleagg::bench::save_exhibit;
use fusesampleagg::coordinator::{TrainConfig, Variant};
use fusesampleagg::dist::{self, DistOptions, WorkerMode};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::metrics::median;
use fusesampleagg::runtime::{BackendChoice, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let quick = std::env::var("FSA_BENCH_QUICK").is_ok();
    let dataset = if quick { "tiny" } else { "arxiv_sim" };
    let (batch, steps, warmup) =
        if quick { (64usize, 5usize, 1usize) } else { (1024, 20, 3) };
    let fanouts =
        if quick { Fanouts::of(&[5, 3]) } else { Fanouts::of(&[10, 5]) };
    let cfg = TrainConfig {
        variant: Variant::Fsa,
        dataset: dataset.into(),
        fanouts,
        batch,
        amp: false,
        save_indices: false,
        seed: 42,
        threads: 1,
        prefetch: false,
        backend: BackendChoice::Native,
        planner: Default::default(),
        planner_state: None,
        simd: Default::default(),
        layout: Default::default(),
        faults: fusesampleagg::runtime::faults::none(),
        hub_cache: None,
    };
    let ds = Arc::new(Dataset::generate(builtin_spec(dataset)?)?);

    let mut out = String::new();
    let _ = writeln!(out, "Distributed scaling — {dataset}, fanout {}, \
                           B={batch}, {steps} timed steps, thread-mode \
                           workers over loopback TCP.\n",
                     cfg.fanouts.label());
    let _ = writeln!(out, "{:<8} {:>12} {:>9} {:>10} {:>11} {:>11}",
                     "workers", "step (ms)", "speedup", "edge dev",
                     "compute ms", "comm ms");

    let mut baseline_ms = 0.0f64;
    let mut reference: Option<Vec<f64>> = None;
    for workers in [1usize, 2, 4] {
        let opts = DistOptions {
            workers,
            // four micros per step so every fleet size splits real work
            micro_batch: (batch / 4).max(1),
            heartbeat_ms: 200,
            mode: WorkerMode::Thread,
            steps,
            warmup,
            ..DistOptions::default()
        };
        let report = dist::train(ds.clone(), &cfg, rt.manifest.hidden,
                                 rt.manifest.adamw, &opts)?;
        match &reference {
            None => reference = Some(report.losses.clone()),
            Some(want) => assert_eq!(&report.losses, want,
                                     "workers={workers} changed the loss \
                                      trajectory — the sweep is measuring \
                                      different work"),
        }
        let ms = median(&report.step_ms);
        if workers == 1 {
            baseline_ms = ms;
        }
        let comp: f64 = report.rows.iter().map(|r| r.step_ms).sum();
        let comm: f64 = report.rows.iter().map(|r| r.comm_ms).sum();
        let _ = writeln!(out, "{:<8} {:>12.2} {:>8.2}x {:>9.1}% {:>11.1} \
                               {:>11.1}",
                         workers, ms, baseline_ms / ms.max(1e-9),
                         report.edge_load_dev * 100.0, comp, comm);
        eprintln!("  {workers} worker(s): {ms:.2} ms/step");
    }
    let _ = writeln!(out, "\nTrajectories bitwise identical across all \
                           worker counts (asserted). Speedup saturates \
                           when per-micro compute no longer dominates \
                           the params broadcast + gradient collection \
                           roundtrip.");

    save_exhibit("dist_scaling", &out);
    Ok(())
}
