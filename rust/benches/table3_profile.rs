//! Bench: regenerate **Table 3** — stage-split exclusive-time profile of
//! the DGL-like baseline step (products_sim, fanout 15-10, B=1024, AMP on).
//!
//! Outputs: results/table3.txt.

use fusesampleagg::bench::save_exhibit;
use fusesampleagg::coordinator::{profile, DatasetCache};
use fusesampleagg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let steps: usize = std::env::var("FSA_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let report = profile::profile_baseline(&rt, &mut cache, 2, steps, 42)?;
    save_exhibit("table3", &fusesampleagg::bench::render::table3(&report));
    Ok(())
}
