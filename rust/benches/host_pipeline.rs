//! Bench: host sampling/batch pipeline scaling — steps/sec vs sampler
//! thread count and prefetch on/off (the tentpole's two knobs).
//!
//! Needs **no artifacts**: the device dispatch that prefetch overlaps is
//! emulated by a fixed per-step sleep (see `bench::throughput`). Scale
//! down with FSA_BENCH_QUICK=1. Outputs: results/host_pipeline.txt,
//! results/host_pipeline.csv.

use std::sync::Arc;

use fusesampleagg::bench::{save_exhibit, throughput};
use fusesampleagg::gen::{builtin_spec, Dataset};
use fusesampleagg::metrics;
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FSA_BENCH_QUICK").is_ok();
    let steps: usize = std::env::var("FSA_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 8 } else { 30 });
    let warmup = if quick { 1 } else { 3 };

    let mut rows = Vec::new();
    for dataset in ["arxiv_sim", "products_sim"] {
        let ds = Arc::new(Dataset::generate(builtin_spec(dataset)?)?);
        eprintln!("host_pipeline: {dataset} ({} nodes, {} edges)",
                  ds.spec.n, ds.graph.num_edges());
        for threads in [1usize, 2, 4, 8] {
            for prefetch in [false, true] {
                let cfg = throughput::ThroughputConfig {
                    steps,
                    warmup,
                    threads,
                    prefetch,
                    ..throughput::ThroughputConfig::new(dataset)
                };
                let row = throughput::run_throughput(ds.clone(), &cfg)?;
                eprintln!("  t{threads} prefetch={}: {:>7.1} steps/s \
                           (sample {:.2} ms crit, {:.2} ms overlapped)",
                          if prefetch { "on " } else { "off" },
                          row.steps_per_s, row.sample_ms, row.overlap_ms);
                rows.push(row);
            }
        }
    }

    let mut out = String::new();
    for dataset in ["arxiv_sim", "products_sim"] {
        let subset: Vec<_> = rows
            .iter()
            .filter(|r| r.dataset == dataset)
            .cloned()
            .collect();
        out.push_str(&format!("[{dataset}]\n"));
        out.push_str(&throughput::render_table(&subset));
        out.push('\n');
    }
    metrics::write_throughput_csv(
        &util::results_dir().join("host_pipeline.csv"), &rows)?;
    save_exhibit("host_pipeline", &out);
    Ok(())
}
