//! Bench: the §6.4 ablations — AMP on/off, 1-hop vs 2-hop, and
//! save_indices on/off (the knobs the paper holds fixed in the main grid).
//!
//! Outputs: results/ablations.txt, results/ablations.csv.

use std::fmt::Write as _;

use fusesampleagg::bench::{run_config, save_exhibit};
use fusesampleagg::coordinator::{DatasetCache, TrainConfig, Variant};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::metrics::{self, BenchRow};
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let quick = std::env::var("FSA_BENCH_QUICK").is_ok();
    let steps: usize = std::env::var("FSA_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 5 } else { 20 });
    let warmup = if quick { 1 } else { 3 };

    let mut rows: Vec<BenchRow> = Vec::new();
    let mut out = String::new();
    let _ = writeln!(out, "Ablations (paper §6.4): knobs held fixed in the \
                           main grid.\n");

    let run = |cache: &mut DatasetCache, cfg: TrainConfig|
                   -> anyhow::Result<BenchRow> {
        let row = run_config(&rt, cache, cfg, warmup, steps)?;
        eprintln!("  abl {:<13} {:<4} hops{} f{:<8} amp={} save={}: \
                   {:>8.2} ms/step",
                  row.dataset, row.variant, row.hops, row.fanout, row.amp,
                  row.steps > 0, row.step_ms);
        Ok(row)
    };

    // --- AMP on/off (arxiv_sim 15-10 b1024, both variants)
    let _ = writeln!(out, "[A] AMP on/off — arxiv_sim, fanout 15-10, B=1024");
    for amp in [true, false] {
        for variant in [Variant::Dgl, Variant::Fsa] {
            let cfg = TrainConfig {
                variant, dataset: "arxiv_sim".into(),
                fanouts: Fanouts::of(&[15, 10]), batch: 1024, amp,
                save_indices: true, seed: 42, threads: 1, prefetch: false,
                backend: Default::default(),
                planner: Default::default(),
                planner_state: None,
                simd: Default::default(),
                layout: Default::default(),
                faults: fusesampleagg::runtime::faults::none(),
                hub_cache: None,
            };
            let r = run(&mut cache, cfg)?;
            let _ = writeln!(out, "  amp={:<5} {:<4}: {:>8.2} ms/step", amp,
                             r.variant, r.step_ms);
            rows.push(r);
        }
    }

    // --- depth 1/2/3 at k1=10 (b1024, all datasets)
    let _ = writeln!(out, "\n[B] sampling depth 1/2/3 — k1=10, B=1024, \
                           AMP on");
    for ds in ["arxiv_sim", "reddit_sim", "products_sim"] {
        for ks in [&[10usize][..], &[10, 10][..], &[10, 5, 5][..]] {
            for variant in [Variant::Dgl, Variant::Fsa] {
                let cfg = TrainConfig {
                    variant, dataset: ds.into(), fanouts: Fanouts::of(ks),
                    batch: 1024, amp: true, save_indices: true, seed: 42,
                    threads: 1, prefetch: false,
                    backend: Default::default(),
                    planner: Default::default(),
                    planner_state: None,
                    simd: Default::default(),
                    layout: Default::default(),
                    faults: fusesampleagg::runtime::faults::none(),
                    hub_cache: None,
                };
                let r = run(&mut cache, cfg)?;
                let _ = writeln!(out, "  {:<13} {}-hop {:<4}: {:>8.2} ms/step \
                                       ({:.1} MB transient)",
                                 ds, ks.len(), r.variant, r.step_ms,
                                 util::bytes_to_mb(r.peak_transient_bytes));
                rows.push(r);
            }
        }
    }

    // --- save_indices on/off (products_sim 15-10 b1024, fsa only)
    let _ = writeln!(out, "\n[C] save_indices on/off — products_sim, \
                           fanout 15-10, B=1024, fsa (off = the paper's \
                           forward-profiling mode, §3.2)");
    for save in [true, false] {
        let cfg = TrainConfig {
            variant: Variant::Fsa, dataset: "products_sim".into(),
            fanouts: Fanouts::of(&[15, 10]), batch: 1024, amp: true,
            save_indices: save, seed: 42, threads: 1, prefetch: false,
            backend: Default::default(),
            planner: Default::default(),
            planner_state: None,
            simd: Default::default(),
            layout: Default::default(),
            faults: fusesampleagg::runtime::faults::none(),
            hub_cache: None,
        };
        let r = run(&mut cache, cfg)?;
        let _ = writeln!(out, "  save_indices={:<5}: {:>8.2} ms/step \
                               ({:.1} MB transient)", save, r.step_ms,
                         util::bytes_to_mb(r.peak_transient_bytes));
        rows.push(r);
    }

    // --- feature dtype f32 vs bf16 (products_sim 15-10 b1024, fsa; the
    // paper's §4 dtype dispatch — bf16 halves the gather traffic)
    let _ = writeln!(out, "\n[D] feature dtype f32 vs bf16 — products_sim, \
                           fanout 15-10, B=1024, fsa (§Perf)");
    {
        use fusesampleagg::coordinator::{measure, Trainer};
        use fusesampleagg::metrics::median;
        let rt2 = &rt;
        for (label, artifact) in [
            ("f32 ", "fsa2_train_products_sim_f15x10_b1024_ampOn"),
            ("bf16", "fsa2_train_products_sim_f15x10_b1024_ampOn_xbf16"),
        ] {
            let cfg = TrainConfig {
                variant: Variant::Fsa,
                dataset: "products_sim".into(),
                fanouts: Fanouts::of(&[15, 10]), batch: 1024,
                amp: true, save_indices: true, seed: 42,
                threads: 1, prefetch: false,
                backend: Default::default(),
                planner: Default::default(),
                planner_state: None,
                simd: Default::default(),
                layout: Default::default(),
                faults: fusesampleagg::runtime::faults::none(),
                hub_cache: None,
            };
            let mut tr = Trainer::new_named(rt2, &mut cache, cfg, artifact)?;
            let timings = measure(&mut tr, warmup, steps)?;
            let ms = median(&timings.iter().map(|t| t.total_ms())
                .collect::<Vec<_>>());
            let loss = timings.last().unwrap().loss;
            let _ = writeln!(out, "  x={label}: {ms:>8.2} ms/step \
                                   (loss {loss:.3})");
            eprintln!("  abl feat dtype {label}: {ms:.2} ms/step");
        }
    }

    metrics::write_csv(&util::results_dir().join("ablations.csv"), &rows)?;
    save_exhibit("ablations", &out);
    Ok(())
}
