//! Bench: regenerate **Fig 3** — median step time vs fanout on arxiv_sim
//! at B=1024 (fanouts {10-10, 15-10, 25-10}, AMP on; lower is better).
//!
//! Outputs: results/fig3.csv, results/fig3.txt.

use fusesampleagg::bench::{env_overrides, render, run_grid, save_exhibit, Grid};
use fusesampleagg::coordinator::DatasetCache;
use fusesampleagg::metrics;
use fusesampleagg::runtime::Runtime;
use fusesampleagg::util;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let grid = env_overrides(Grid::fig3());
    let rows = run_grid(&rt, &mut cache, &grid, |r| {
        eprintln!("  fig3 {:<4} f{:<8} s{}: {:>8.2} ms/step", r.variant,
                  r.fanout, r.repeat_seed, r.step_ms);
    })?;
    metrics::write_csv(&util::results_dir().join("fig3.csv"), &rows)?;
    save_exhibit("fig3", &render::fig3(&rows));
    Ok(())
}
