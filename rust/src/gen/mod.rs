//! Synthetic dataset registry — scaled stand-ins for the paper's benchmarks.
//!
//! The paper evaluates on Reddit, ogbn-arxiv, and ogbn-products; those
//! downloads are unavailable here, so `arxiv_sim` / `reddit_sim` /
//! `products_sim` reproduce the *shape statistics* that drive sampling
//! pipelines — node count (scaled), average degree, degree-law (power law /
//! hub-heavy), feature width, class count — per DESIGN.md §3/§6. Everything
//! is deterministic in `gen_seed` via the counter RNG.
//!
//! Features are class-conditioned Gaussian clusters and labels are locality-
//! blocked, with generators biased toward intra-block edges, so GraphSAGE
//! training on these graphs has real signal: loss decreases and accuracy
//! beats chance (exercised by examples/train_e2e.rs).

use anyhow::{bail, Result};

use crate::graph::Csr;
use crate::rng::{mix, SplitMix64};

/// Generator parameters for one dataset (mirrors manifest `datasets`).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub stands_for: String,
    pub n: usize,
    pub e_cap: usize,
    pub avg_deg: usize,
    pub degree_law: DegreeLaw,
    pub d: usize,
    pub c: usize,
    pub gen_seed: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegreeLaw {
    Uniform,
    PowerLaw,
    Hubs,
    /// Zipf-skewed *target* popularity: out-degrees are uniform but edge
    /// targets concentrate on low node ids with an inverse-square draw,
    /// so a handful of hubs absorbs most of the traffic. Built for the
    /// `zipf_serve` fixture; unlike the legacy laws, self-loop draws are
    /// redrawn (not dropped), so realized directed edge counts equal the
    /// out-degree spec exactly.
    Zipf,
}

impl DegreeLaw {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "uniform" => DegreeLaw::Uniform,
            "powerlaw" => DegreeLaw::PowerLaw,
            "hubs" => DegreeLaw::Hubs,
            "zipf" => DegreeLaw::Zipf,
            other => bail!("unknown degree law {other:?}"),
        })
    }
}

/// A fully materialized dataset: graph + features + labels + split.
pub struct Dataset {
    pub spec: DatasetSpec,
    pub graph: Csr,
    /// Row-major `[n, d]` float32 features.
    pub features: Vec<f32>,
    /// `[n]` int32 labels in `[0, c)`.
    pub labels: Vec<i32>,
    /// `[n]` split assignment.
    pub split: Vec<Split>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// Fraction of edges drawn inside the local label block (homophily knob).
const LOCAL_EDGE_FRACTION: f64 = 0.7;
/// Window (in node ids) for local edges.
const LOCAL_WINDOW: usize = 256;
/// Hub parameters for the Reddit-like law.
const HUB_RATE: u64 = 100; // 1 in HUB_RATE nodes is a hub
const HUB_FACTOR: usize = 20;

impl Dataset {
    /// Generate deterministically from the spec.
    pub fn generate(spec: DatasetSpec) -> Result<Dataset> {
        let graph = generate_graph(&spec)?;
        let labels = assign_labels(&spec);
        let features = synth_features(&spec, &labels);
        let split = assign_split(&spec);
        Ok(Dataset { spec, graph, features, labels, split })
    }

    /// Node ids of one split, in id order.
    pub fn split_nodes(&self, s: Split) -> Vec<i32> {
        (0..self.spec.n as i32)
            .filter(|&u| self.split[u as usize] == s)
            .collect()
    }

    /// Feature row of node `u`.
    pub fn feature(&self, u: i32) -> &[f32] {
        let d = self.spec.d;
        &self.features[u as usize * d..(u as usize + 1) * d]
    }
}

/// Out-degree target per node under the spec's degree law. The directed
/// out-degree is ~avg_deg/2 so that symmetrization lands near avg_deg.
fn out_degree(spec: &DatasetSpec, rng: &mut SplitMix64, node: usize) -> usize {
    let half = (spec.avg_deg / 2).max(1);
    match spec.degree_law {
        DegreeLaw::Uniform | DegreeLaw::Zipf => half,
        DegreeLaw::PowerLaw => {
            // Pareto(alpha=2.5) weight, clamped; mean ~ alpha/(alpha-1) = 1.67
            let u = rng.next_f64().max(1e-12);
            let w = u.powf(-1.0 / 1.5) / 1.6667; // normalized Pareto draw
            ((half as f64 * w).round() as usize).clamp(1, spec.n / 4)
        }
        DegreeLaw::Hubs => {
            if mix(spec.gen_seed ^ node as u64) % HUB_RATE == 0 {
                half * HUB_FACTOR
            } else {
                half
            }
        }
    }
}

fn generate_graph(spec: &DatasetSpec) -> Result<Csr> {
    let edges = draw_edges(spec);
    Csr::from_edges(spec.n, &edges, spec.e_cap, /*symmetrize=*/ true)
}

/// The realized directed edge list before CSR construction (symmetrize +
/// dedup). Split out of [`generate_graph`] so tests can pin the realized
/// counts: legacy laws silently drop self-loop draws (so counts drift
/// below the out-degree spec — frozen behavior, goldens depend on it);
/// the Zipf law redraws and its count equals the spec exactly.
fn draw_edges(spec: &DatasetSpec) -> Vec<(u32, u32)> {
    let mut rng = SplitMix64::new(spec.gen_seed);
    let n = spec.n;
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * spec.avg_deg / 2);
    // Preferential-attachment flavour: targets drawn from the running
    // endpoint list (Barabási–Albert style) for power-law graphs; uniform
    // otherwise. A LOCAL_EDGE_FRACTION of draws is confined to a nearby id
    // window for label homophily.
    let mut endpoints: Vec<u32> = Vec::with_capacity(n * spec.avg_deg);
    for u in 0..n {
        let du = out_degree(spec, &mut rng, u);
        for _ in 0..du {
            if spec.degree_law == DegreeLaw::Zipf {
                // inverse-square skewed target: P(v < x) = 1 - 1/sqrt(x),
                // concentrating traffic on the low-id hubs. Self-loop
                // draws are REDRAWN (bounded), not dropped, so the
                // realized directed edge count equals the out-degree
                // spec exactly — the legacy laws below keep their
                // drop-on-self-loop behavior (and their RNG streams)
                // untouched so existing goldens stay bitwise.
                let mut v = u as u32;
                for _ in 0..64 {
                    let r = rng.next_f64().min(1.0 - 1e-12);
                    let z = (1.0 / (1.0 - r)).powi(2) - 1.0;
                    v = (z as usize).min(n - 1) as u32;
                    if v as usize != u {
                        break;
                    }
                }
                if v as usize == u {
                    v = ((u + 1) % n) as u32;
                }
                edges.push((u as u32, v));
                endpoints.push(v);
                endpoints.push(u as u32);
                continue;
            }
            let local = rng.next_f64() < LOCAL_EDGE_FRACTION;
            let v = if local {
                let w = LOCAL_WINDOW.min(n - 1) as u64;
                let off = 1 + rng.next_below(w) as usize;
                ((u + off) % n) as u32
            } else if spec.degree_law == DegreeLaw::PowerLaw
                && !endpoints.is_empty()
            {
                endpoints[rng.next_below(endpoints.len() as u64) as usize]
            } else {
                rng.next_below(n as u64) as u32
            };
            if v as usize != u {
                edges.push((u as u32, v));
                endpoints.push(v);
                endpoints.push(u as u32);
            }
        }
    }
    edges
}

/// Labels by contiguous id blocks (communities); edges are locality-biased,
/// so neighborhoods are label-homophilous.
fn assign_labels(spec: &DatasetSpec) -> Vec<i32> {
    let block = (spec.n + spec.c - 1) / spec.c;
    (0..spec.n).map(|u| ((u / block) % spec.c) as i32).collect()
}

/// Class-conditioned Gaussian features: x_u = mu[label_u] + 0.8 * noise.
fn synth_features(spec: &DatasetSpec, labels: &[i32]) -> Vec<f32> {
    let mut rng = SplitMix64::new(mix(spec.gen_seed ^ 0xFEA7));
    let d = spec.d;
    let mut mu = vec![0f32; spec.c * d];
    for x in mu.iter_mut() {
        *x = rng.next_normal() as f32;
    }
    let mut feats = vec![0f32; spec.n * d];
    for u in 0..spec.n {
        let c = labels[u] as usize;
        for j in 0..d {
            feats[u * d + j] =
                mu[c * d + j] + 0.8 * rng.next_normal() as f32;
        }
    }
    feats
}

/// 80/10/10 split by node-id hash (deterministic, like OGB's fixed splits).
fn assign_split(spec: &DatasetSpec) -> Vec<Split> {
    (0..spec.n)
        .map(|u| match mix(spec.gen_seed ^ (u as u64) << 1) % 10 {
            0..=7 => Split::Train,
            8 => Split::Val,
            _ => Split::Test,
        })
        .collect()
}

/// Built-in registry mirroring `python/compile/configs.py::DATASETS`
/// (the manifest is the authoritative copy at runtime; this table lets
/// pure-rust tests run without artifacts).
pub fn builtin_spec(name: &str) -> Result<DatasetSpec> {
    let s = |name: &str, stands_for: &str, n, e_cap, avg_deg, law, d, c, seed| {
        DatasetSpec {
            name: name.into(),
            stands_for: stands_for.into(),
            n,
            e_cap,
            avg_deg,
            degree_law: law,
            d,
            c,
            gen_seed: seed,
        }
    };
    Ok(match name {
        "arxiv_sim" => s("arxiv_sim", "ogbn-arxiv", 20_000, 640_000, 14,
                         DegreeLaw::PowerLaw, 64, 40, 1001),
        "reddit_sim" => s("reddit_sim", "Reddit", 12_000, 2_600_000, 100,
                          DegreeLaw::Hubs, 64, 41, 1002),
        "products_sim" => s("products_sim", "ogbn-products", 32_000,
                            3_400_000, 50, DegreeLaw::PowerLaw, 64, 47, 1003),
        "tiny" => s("tiny", "unit tests", 512, 8_192, 6,
                    DegreeLaw::Uniform, 16, 8, 1000),
        // serving fixture with Zipf-skewed target popularity: a small
        // hub set dominates gather traffic, the regime the hub-aggregate
        // cache (`--hub-cache`) is built for
        "zipf_serve" => s("zipf_serve", "zipf serving fixture", 16_384,
                          320_000, 16, DegreeLaw::Zipf, 128, 32, 1009),
        other => bail!("unknown dataset {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dataset_generates_and_validates() {
        let ds = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        ds.graph.validate().unwrap();
        assert!(ds.graph.is_symmetric());
        assert_eq!(ds.features.len(), 512 * 16);
        assert_eq!(ds.labels.len(), 512);
        assert!(ds.labels.iter().all(|&l| (0..8).contains(&l)));
        let s = ds.graph.degree_stats();
        assert!(s.mean > 3.0 && s.mean < 12.0, "avg degree {}", s.mean);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        let b = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        assert_eq!(a.graph.rowptr, b.graph.rowptr);
        assert_eq!(a.graph.col, b.graph.col);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let mut spec = builtin_spec("tiny").unwrap();
        spec.gen_seed += 1;
        let a = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        let b = Dataset::generate(spec).unwrap();
        assert_ne!(a.graph.col, b.graph.col);
    }

    #[test]
    fn splits_cover_and_are_disjoint() {
        let ds = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        let tr = ds.split_nodes(Split::Train).len();
        let va = ds.split_nodes(Split::Val).len();
        let te = ds.split_nodes(Split::Test).len();
        assert_eq!(tr + va + te, 512);
        assert!(tr > 300, "train too small: {tr}");
        assert!(va > 20 && te > 20);
    }

    #[test]
    fn features_carry_class_signal() {
        // nearest-centroid on the raw features must beat chance easily
        let ds = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        let (d, c) = (ds.spec.d, ds.spec.c);
        let mut centroids = vec![0f64; c * d];
        let mut counts = vec![0usize; c];
        for u in 0..ds.spec.n {
            let l = ds.labels[u] as usize;
            counts[l] += 1;
            for j in 0..d {
                centroids[l * d + j] += ds.features[u * d + j] as f64;
            }
        }
        for l in 0..c {
            for j in 0..d {
                centroids[l * d + j] /= counts[l].max(1) as f64;
            }
        }
        let mut correct = 0;
        for u in 0..ds.spec.n {
            let best = (0..c)
                .min_by(|&a, &b| {
                    let da = dist(ds.feature(u as i32), &centroids[a * d..][..d]);
                    let db = dist(ds.feature(u as i32), &centroids[b * d..][..d]);
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.labels[u] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.spec.n as f64;
        assert!(acc > 0.5, "nearest-centroid accuracy only {acc}");
    }

    fn dist(x: &[f32], c: &[f64]) -> f64 {
        x.iter().zip(c).map(|(a, b)| (*a as f64 - b).powi(2)).sum()
    }

    /// Pin the realized drawn-edge counts of the legacy laws. Their
    /// generators silently DROP self-loop draws (gen drift below the
    /// out-degree spec) — frozen behavior: goldens and every seeded
    /// artifact depend on these exact streams, so a future "fix" that
    /// redraws instead must show up here, not as a silent golden shift.
    /// (Laws that use `powf` are excluded: their draw counts depend on
    /// libm rounding, so the pins would not be portable.)
    #[test]
    fn legacy_laws_pin_realized_edge_counts() {
        // tiny (Uniform): 512 nodes x 3 targets = 1536 draws, 2 dropped
        let spec = builtin_spec("tiny").unwrap();
        let drawn = draw_edges(&spec);
        assert_eq!(drawn.len(), 1534, "tiny realized edge count moved");
        let targets: usize = 512 * 3;
        assert_eq!(targets - drawn.len(), 2, "tiny self-loop drops moved");
        // and the CSR that everything downstream sees is pinned too
        let g = generate_graph(&spec).unwrap();
        assert_eq!(g.num_edges(), 3064, "tiny CSR edge count moved");
        // reddit_sim (Hubs): 714950 targets, 19 dropped
        let spec = builtin_spec("reddit_sim").unwrap();
        let drawn = draw_edges(&spec);
        assert_eq!(drawn.len(), 714_931,
                   "reddit_sim realized edge count moved");
        assert_eq!(generate_graph(&spec).unwrap().num_edges(), 1_259_998,
                   "reddit_sim CSR edge count moved");
    }

    /// The Zipf law redraws self-loop draws instead of dropping them, so
    /// its realized directed edge count equals the out-degree spec
    /// exactly — no drift, by construction.
    #[test]
    fn zipf_law_realizes_the_out_degree_spec_exactly() {
        let spec = builtin_spec("zipf_serve").unwrap();
        let half = (spec.avg_deg / 2).max(1);
        let drawn = draw_edges(&spec);
        assert_eq!(drawn.len(), spec.n * half,
                   "zipf must redraw, never drop");
        assert!(drawn.iter().all(|&(u, v)| u != v), "zipf self-loop");
        // pinned CSR count (post symmetrize + dedup), well under cap
        let g = generate_graph(&spec).unwrap();
        assert_eq!(g.num_edges(), 192_546, "zipf CSR edge count moved");
        assert!(g.num_edges() <= spec.e_cap);
        // the skew the fixture exists for: the max-degree node absorbs
        // a macroscopic slice of all edges
        let stats = g.degree_stats();
        assert!(stats.max as f64 > 0.05 * g.num_edges() as f64,
                "zipf skew collapsed: max degree {}", stats.max);
    }

    #[test]
    fn zipf_dataset_generates_and_validates() {
        let ds = Dataset::generate(builtin_spec("zipf_serve").unwrap())
            .unwrap();
        ds.graph.validate().unwrap();
        assert!(ds.graph.is_symmetric());
        assert_eq!(ds.spec.n, 16_384);
        assert_eq!(ds.features.len(), 16_384 * 128);
        assert!(ds.labels.iter().all(|&l| (0..32).contains(&l)));
    }

    /// Shape statistics of the three main datasets respect their caps and
    /// rough degree targets (slow-ish; still < 1s in release).
    #[test]
    fn main_datasets_fit_caps() {
        for name in ["arxiv_sim", "reddit_sim", "products_sim"] {
            let spec = builtin_spec(name).unwrap();
            let ds = Dataset::generate(spec.clone()).unwrap();
            let e = ds.graph.num_edges();
            assert!(e <= spec.e_cap, "{name}: {e} > cap {}", spec.e_cap);
            assert!(e >= spec.e_cap / 8, "{name}: suspiciously few edges {e}");
            let stats = ds.graph.degree_stats();
            assert!(stats.mean >= spec.avg_deg as f64 * 0.4,
                    "{name}: mean degree {} vs target {}",
                    stats.mean, spec.avg_deg);
            if spec.degree_law == DegreeLaw::PowerLaw
                || spec.degree_law == DegreeLaw::Hubs
            {
                assert!(stats.max as f64 > stats.mean * 4.0,
                        "{name}: no heavy tail (max {} mean {})",
                        stats.max, stats.mean);
            }
        }
    }
}
