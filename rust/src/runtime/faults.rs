//! Injectable fault plane — deterministic chaos for fault-tolerance
//! testing, modeled on the [`crate::graph::ShardClock`] seam: production
//! code threads an `Arc<dyn FaultPlane>` through every fault site, the
//! default [`NoFaults`] implementation is a zero-cost no-op (every hook
//! returns a constant, nothing is counted, outputs are bitwise identical
//! to a build without the seam), and tests / `--chaos` runs install a
//! [`ChaosPlane`] that scripts faults by site and operation index.
//!
//! Replayability: a chaos run is a pure function of (spec, seed, call
//! order). Each site keeps its own operation counter, advanced once per
//! operation by the *coordinating* thread ([`FaultPlane::begin`]) before
//! any worker fans out, so shard workers query faults with a stable
//! `(site, op, worker)` key no matter how threads interleave.
//! Probabilistic rules (`~p`) draw from the counter RNG
//! ([`crate::rng::rand_counter`]) keyed by that same triple — rerunning
//! the same spec+seed reproduces exactly the same fault schedule.
//!
//! The `--chaos` spec grammar (train/serve):
//!
//! ```text
//! spec  := rule (';' rule)*
//! rule  := site '@' ops [ '/w' N ] [ '~' P ] '=' kind
//! site  := kernel | sampler | state-write | ckpt-write | ckpt-read
//!          | csv-write | serve | dist-send | dist-recv
//! ops   := N | N '-' M (inclusive) | '*'        site-local op counter
//! kind  := panic | err | corrupt | stall:MS
//! ```
//!
//! Examples: `kernel@3/w1=panic` (worker 1 of the 4th parallel kernel
//! pass panics, the pass recovers by serial recompute),
//! `ckpt-write@*=err` (every checkpoint write fails — retries exhaust,
//! the save hard-errors naming the site), `serve@2=panic` (the 3rd
//! micro-batch is poisoned; the server isolates it and keeps draining),
//! `state-write@0-4~0.5=err` (each of the first 5 planner-state saves
//! fails with probability 0.5, drawn deterministically).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::rng::rand_counter;

/// Everywhere a fault can be injected, named as in the `--chaos` grammar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// A parallel pass of the fused kernel (`fused_khop_planned`); one op
    /// per sharded pass, faults keyed per worker.
    KernelWorker,
    /// A sharded pass of the parallel host sampler (`run_plan`).
    SamplerWorker,
    /// A planner-state save (`results/planner_state.json`).
    StateWrite,
    /// A params/train checkpoint save.
    CheckpointWrite,
    /// A params/train checkpoint load (supports `corrupt`).
    CheckpointRead,
    /// A results CSV write (bench/throughput/serving).
    CsvWrite,
    /// One serve micro-batch (the fused forward inside `run_server`).
    ServeBatch,
    /// One coordinator→worker send on the distributed training socket
    /// (`dist::coordinator`); `err` drops the connection as if the
    /// worker's socket died, `stall:MS` delays the dispatch, faults
    /// keyed per worker rank.
    DistSend,
    /// One coordinator-side receive/processing of a worker frame;
    /// `err` discards the frame as if the bytes were lost in flight.
    DistRecv,
}

pub const ALL_SITES: [FaultSite; 9] = [
    FaultSite::KernelWorker,
    FaultSite::SamplerWorker,
    FaultSite::StateWrite,
    FaultSite::CheckpointWrite,
    FaultSite::CheckpointRead,
    FaultSite::CsvWrite,
    FaultSite::ServeBatch,
    FaultSite::DistSend,
    FaultSite::DistRecv,
];

impl FaultSite {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultSite::KernelWorker => "kernel",
            FaultSite::SamplerWorker => "sampler",
            FaultSite::StateWrite => "state-write",
            FaultSite::CheckpointWrite => "ckpt-write",
            FaultSite::CheckpointRead => "ckpt-read",
            FaultSite::CsvWrite => "csv-write",
            FaultSite::ServeBatch => "serve",
            FaultSite::DistSend => "dist-send",
            FaultSite::DistRecv => "dist-recv",
        }
    }

    pub fn parse(s: &str) -> Result<FaultSite> {
        ALL_SITES
            .iter()
            .copied()
            .find(|site| site.as_str() == s)
            .ok_or_else(|| {
                anyhow!("unknown fault site {s:?}; sites are {}",
                        ALL_SITES.map(|s| s.as_str()).join("|"))
            })
    }

    fn index(&self) -> usize {
        ALL_SITES.iter().position(|s| s == self).unwrap()
    }
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What a site is scripted to do for one `(op, worker)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Proceed normally (the only answer [`NoFaults`] ever gives).
    None,
    /// Fail the operation with an injected error (retried where the call
    /// site has a retry budget; hard error once it is exhausted).
    Error,
    /// Panic inside the operation (exercises `catch_unwind` isolation and
    /// shard-recompute recovery).
    Panic,
    /// Sleep this many milliseconds, then proceed — moves timing (and the
    /// adaptive planner's measurements) without ever touching values.
    Stall(u64),
    /// Corrupt the bytes of a read (checkpoint loads) deterministically.
    Corrupt,
}

/// The injectable fault seam. Prod is [`NoFaults`]; chaos runs and the
/// fault-tolerance tests install a scripted [`ChaosPlane`]. Same shape as
/// `ShardClock`: `Debug + Send + Sync` behind an `Arc`, threaded through
/// the cost model, the sampler, the engine, and serve.
pub trait FaultPlane: std::fmt::Debug + Send + Sync {
    /// Advance and return `site`'s 0-based operation counter. Called once
    /// per operation by the coordinating thread, *before* workers fan
    /// out, so `(site, op, worker)` keys are interleaving-independent.
    fn begin(&self, site: FaultSite) -> u64 {
        let _ = site;
        0
    }

    /// The scripted fault for operation `op` at `site` as seen by shard
    /// `worker` (0 outside sharded passes). Pure: the same key always
    /// answers the same fault.
    fn fault(&self, site: FaultSite, op: u64, worker: usize) -> Fault {
        let _ = (site, op, worker);
        Fault::None
    }

    /// Deterministically corrupt `bytes` when operation `op` at `site` is
    /// scripted [`Fault::Corrupt`]; no-op otherwise.
    fn mangle(&self, site: FaultSite, op: u64, bytes: &mut [u8]) {
        let _ = (site, op, bytes);
    }
}

/// The production plane: never faults, counts nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoFaults;

impl FaultPlane for NoFaults {}

/// Shared handle to the production no-op plane.
pub fn none() -> Arc<dyn FaultPlane> {
    Arc::new(NoFaults)
}

/// One parsed `--chaos` rule.
#[derive(Clone, Debug)]
struct Rule {
    site: FaultSite,
    /// Inclusive op range; `*` parses to `(0, u64::MAX)`.
    ops: (u64, u64),
    /// `/wN`: only this worker index (sharded sites); None = every worker.
    worker: Option<usize>,
    /// `~p`: fire with probability `p`, drawn from the counter RNG keyed
    /// by `(seed, site, op, worker)`; None = always.
    prob: Option<f64>,
    kind: Fault,
}

impl Rule {
    fn matches(&self, seed: u64, rule_idx: usize, site: FaultSite, op: u64,
               worker: usize) -> bool {
        if site != self.site || op < self.ops.0 || op > self.ops.1 {
            return false;
        }
        if self.worker.is_some_and(|w| w != worker) {
            return false;
        }
        match self.prob {
            None => true,
            Some(p) => {
                // decorrelate rules sharing a key via the slot counter
                let word = rand_counter(seed, site.index() as u64 ^ (op << 3),
                                        worker as u64, rule_idx as u64);
                (word >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < p
            }
        }
    }
}

/// A scripted fault schedule: deterministic, replayable, thread-count
/// independent (see module docs).
#[derive(Debug)]
pub struct ChaosPlane {
    seed: u64,
    rules: Vec<Rule>,
    counters: [AtomicU64; ALL_SITES.len()],
}

impl ChaosPlane {
    /// Parse a `--chaos` spec (grammar in the module docs). `seed` drives
    /// the probabilistic rules and read corruption.
    pub fn parse(spec: &str, seed: u64) -> Result<ChaosPlane> {
        let mut rules = Vec::new();
        for raw in spec.split(';').map(str::trim).filter(|r| !r.is_empty()) {
            rules.push(Self::parse_rule(raw)?);
        }
        if rules.is_empty() {
            bail!("--chaos spec {spec:?} contains no rules");
        }
        Ok(ChaosPlane {
            seed: crate::rng::mix(seed ^ 0xC4A0),
            rules,
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    fn parse_rule(raw: &str) -> Result<Rule> {
        let err = || {
            anyhow!("bad chaos rule {raw:?}; expected \
                     site@ops[/wN][~P]=kind (e.g. kernel@3/w1=panic)")
        };
        let (lhs, kind) = raw.split_once('=').ok_or_else(err)?;
        let (site, mut sel) = lhs.split_once('@').ok_or_else(err)?;
        let site = FaultSite::parse(site.trim())?;
        let mut prob = None;
        if let Some((rest, p)) = sel.split_once('~') {
            let p: f64 = p.trim().parse().map_err(|_| err())?;
            if !(0.0..=1.0).contains(&p) {
                bail!("chaos probability {p} not in [0, 1] in {raw:?}");
            }
            prob = Some(p);
            sel = rest;
        }
        let mut worker = None;
        if let Some((rest, w)) = sel.split_once("/w") {
            worker = Some(w.trim().parse().map_err(|_| err())?);
            sel = rest;
        }
        let sel = sel.trim();
        let ops = if sel == "*" {
            (0, u64::MAX)
        } else if let Some((a, b)) = sel.split_once('-') {
            let lo: u64 = a.trim().parse().map_err(|_| err())?;
            let hi: u64 = b.trim().parse().map_err(|_| err())?;
            if hi < lo {
                bail!("empty op range {sel:?} in {raw:?}");
            }
            (lo, hi)
        } else {
            let n: u64 = sel.parse().map_err(|_| err())?;
            (n, n)
        };
        let kind = match kind.trim() {
            "panic" => Fault::Panic,
            "err" => Fault::Error,
            "corrupt" => Fault::Corrupt,
            other => match other.strip_prefix("stall:") {
                Some(ms) => Fault::Stall(ms.trim().parse().map_err(|_| {
                    anyhow!("bad stall duration in chaos rule {raw:?}")
                })?),
                None => bail!("unknown chaos kind {other:?} in {raw:?}; \
                               kinds are panic|err|corrupt|stall:MS"),
            },
        };
        Ok(Rule { site, ops, worker, prob, kind })
    }
}

impl FaultPlane for ChaosPlane {
    fn begin(&self, site: FaultSite) -> u64 {
        self.counters[site.index()].fetch_add(1, Ordering::Relaxed)
    }

    fn fault(&self, site: FaultSite, op: u64, worker: usize) -> Fault {
        for (i, r) in self.rules.iter().enumerate() {
            if r.matches(self.seed, i, site, op, worker) {
                return r.kind;
            }
        }
        Fault::None
    }

    fn mangle(&self, site: FaultSite, op: u64, bytes: &mut [u8]) {
        if self.fault(site, op, 0) != Fault::Corrupt || bytes.is_empty() {
            return;
        }
        // flip a handful of deterministically chosen bytes
        let n = bytes.len();
        for slot in 0..4u64.min(n as u64) {
            let word = rand_counter(self.seed, site.index() as u64, op, slot);
            bytes[(word % n as u64) as usize] ^= 0xA5;
        }
    }
}

/// Apply the scripted fault for one coordinated (non-sharded) operation:
/// stalls sleep, errors return `Err`, panics panic. `Corrupt` is a no-op
/// here — read sites apply it to their bytes via [`FaultPlane::mangle`].
pub fn inject(plane: &dyn FaultPlane, site: FaultSite, op: u64) -> Result<()> {
    match plane.fault(site, op, 0) {
        Fault::None | Fault::Corrupt => Ok(()),
        Fault::Stall(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        Fault::Error => bail!("chaos: injected {site} error (op {op})"),
        Fault::Panic => panic!("chaos: injected {site} panic (op {op})"),
    }
}

/// Run `op` with bounded retries and deterministic jittered exponential
/// backoff (for transient persistence failures). Returns the result and
/// the number of retries consumed; on exhaustion the error names the
/// site and attempt count. Backoff after attempt `i` (0-based) is
/// `2^i` ms plus up to `2^i` ms of counter-RNG jitter keyed by
/// `(jitter_seed, site, invocation, attempt)`.
pub fn with_retries<T>(site: FaultSite, max_attempts: u32, jitter_seed: u64,
                       invocation: u64,
                       mut op: impl FnMut() -> Result<T>)
                       -> (Result<T>, u32) {
    debug_assert!(max_attempts >= 1);
    let mut retries = 0;
    loop {
        match op() {
            Ok(v) => return (Ok(v), retries),
            Err(e) if retries + 1 >= max_attempts => {
                return (Err(e.context(format!(
                    "{site} failed after {max_attempts} attempts"))),
                        retries);
            }
            Err(_) => {
                let base = 1u64 << retries.min(6);
                let jitter = rand_counter(crate::rng::mix(jitter_seed),
                                          site.index() as u64, invocation,
                                          retries as u64) % base;
                std::thread::sleep(std::time::Duration::from_millis(
                    base + jitter));
                retries += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_plane_answers_constants() {
        let p = NoFaults;
        assert_eq!(p.begin(FaultSite::KernelWorker), 0);
        assert_eq!(p.begin(FaultSite::KernelWorker), 0);
        assert_eq!(p.fault(FaultSite::ServeBatch, 7, 3), Fault::None);
        let mut bytes = vec![1u8, 2, 3];
        p.mangle(FaultSite::CheckpointRead, 0, &mut bytes);
        assert_eq!(bytes, vec![1, 2, 3]);
    }

    #[test]
    fn spec_parses_sites_ops_workers_kinds() {
        let p = ChaosPlane::parse(
            "kernel@3/w1=panic; ckpt-write@0-2=err; serve@*=stall:5; \
             ckpt-read@0=corrupt",
            42).unwrap();
        assert_eq!(p.fault(FaultSite::KernelWorker, 3, 1), Fault::Panic);
        assert_eq!(p.fault(FaultSite::KernelWorker, 3, 0), Fault::None);
        assert_eq!(p.fault(FaultSite::KernelWorker, 2, 1), Fault::None);
        assert_eq!(p.fault(FaultSite::CheckpointWrite, 0, 0), Fault::Error);
        assert_eq!(p.fault(FaultSite::CheckpointWrite, 2, 0), Fault::Error);
        assert_eq!(p.fault(FaultSite::CheckpointWrite, 3, 0), Fault::None);
        assert_eq!(p.fault(FaultSite::ServeBatch, 999, 0), Fault::Stall(5));
        assert_eq!(p.fault(FaultSite::CheckpointRead, 0, 0), Fault::Corrupt);
    }

    #[test]
    fn bad_specs_error_clearly() {
        for (spec, needle) in [
            ("", "no rules"),
            ("kernel=panic", "expected"),
            ("bogus@0=panic", "unknown fault site"),
            ("kernel@0=explode", "unknown chaos kind"),
            ("kernel@5-2=panic", "empty op range"),
            ("kernel@0~1.5=err", "not in [0, 1]"),
            ("kernel@0=stall:abc", "stall duration"),
        ] {
            let err = ChaosPlane::parse(spec, 1).unwrap_err().to_string();
            assert!(err.contains(needle), "{spec:?}: {err}");
        }
    }

    #[test]
    fn dist_sites_parse_and_script() {
        for (name, site) in [("dist-send", FaultSite::DistSend),
                             ("dist-recv", FaultSite::DistRecv)] {
            assert_eq!(FaultSite::parse(name).unwrap(), site);
            assert_eq!(site.as_str(), name);
        }
        let p = ChaosPlane::parse(
            "dist-send@1/w0=err; dist-recv@*=stall:3", 42).unwrap();
        assert_eq!(p.fault(FaultSite::DistSend, 1, 0), Fault::Error);
        assert_eq!(p.fault(FaultSite::DistSend, 1, 1), Fault::None);
        assert_eq!(p.fault(FaultSite::DistSend, 0, 0), Fault::None);
        assert_eq!(p.fault(FaultSite::DistRecv, 17, 0), Fault::Stall(3));
    }

    #[test]
    fn counters_are_per_site_and_monotonic() {
        let p = ChaosPlane::parse("kernel@*=panic", 1).unwrap();
        assert_eq!(p.begin(FaultSite::KernelWorker), 0);
        assert_eq!(p.begin(FaultSite::KernelWorker), 1);
        assert_eq!(p.begin(FaultSite::ServeBatch), 0);
        assert_eq!(p.begin(FaultSite::KernelWorker), 2);
    }

    #[test]
    fn probabilistic_rules_replay_exactly() {
        let fire = |seed: u64| -> Vec<bool> {
            let p = ChaosPlane::parse("serve@*~0.5=err", seed).unwrap();
            (0..64)
                .map(|op| p.fault(FaultSite::ServeBatch, op, 0) == Fault::Error)
                .collect()
        };
        let a = fire(7);
        assert_eq!(a, fire(7), "same seed must replay the same schedule");
        assert_ne!(a, fire(8), "different seed should move the schedule");
        let hits = a.iter().filter(|&&b| b).count();
        assert!((16..=48).contains(&hits), "p=0.5 wildly off: {hits}/64");
    }

    #[test]
    fn mangle_corrupts_deterministically_and_only_when_scripted() {
        let p = ChaosPlane::parse("ckpt-read@1=corrupt", 3).unwrap();
        let clean = b"{\"version\": 2}".to_vec();
        let mut a = clean.clone();
        p.mangle(FaultSite::CheckpointRead, 0, &mut a);
        assert_eq!(a, clean, "op 0 is not scripted");
        p.mangle(FaultSite::CheckpointRead, 1, &mut a);
        assert_ne!(a, clean, "op 1 must corrupt");
        let mut b = clean.clone();
        let q = ChaosPlane::parse("ckpt-read@1=corrupt", 3).unwrap();
        q.mangle(FaultSite::CheckpointRead, 1, &mut b);
        assert_eq!(a, b, "corruption must be deterministic");
    }

    #[test]
    fn inject_maps_kinds() {
        let p = ChaosPlane::parse("state-write@0=err", 1).unwrap();
        let err = inject(&p, FaultSite::StateWrite, 0).unwrap_err()
            .to_string();
        assert!(err.contains("state-write"), "{err}");
        assert!(err.contains("op 0"), "{err}");
        inject(&p, FaultSite::StateWrite, 1).unwrap();
        let panicking = ChaosPlane::parse("serve@0=panic", 1).unwrap();
        let r = std::panic::catch_unwind(|| {
            inject(&panicking, FaultSite::ServeBatch, 0)
        });
        assert!(r.is_err(), "panic kind must panic");
    }

    #[test]
    fn retries_back_off_then_hard_error_naming_site() {
        // always-failing op: exhausts the budget
        let mut calls = 0;
        let (res, retries) = with_retries(
            FaultSite::CheckpointWrite, 3, 42, 0, || {
                calls += 1;
                bail!("transient")
            });
        assert_eq!((calls, retries), (3, 2));
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("ckpt-write failed after 3 attempts"), "{err}");
        // heals on the second attempt: one retry, success
        let mut calls = 0;
        let (res, retries) = with_retries(
            FaultSite::StateWrite, 3, 42, 1, || {
                calls += 1;
                if calls == 1 {
                    bail!("transient")
                }
                Ok(7)
            });
        assert_eq!((res.unwrap(), retries), (7, 1));
    }
}
