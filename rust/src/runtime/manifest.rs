//! Artifact manifest loader — the Rust half of the AOT contract.
//!
//! `python/compile/configs.py` is the single source of truth; it serializes
//! every executable's input/output order, shapes, and dtypes into
//! `artifacts/manifest.json`, which this module parses (via the in-house
//! [`crate::json`] parser — no serde offline). Rust never re-derives shapes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::gen::{DatasetSpec, DegreeLaw};
use crate::json::{self, Value};

/// Element type of a tensor in the AOT contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U64,
    Bf16,
    F16,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "float32" => Dtype::F32,
            "int32" => Dtype::I32,
            "uint64" => Dtype::U64,
            "bfloat16" => Dtype::Bf16,
            "float16" => Dtype::F16,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn bytes(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U64 => 8,
            Dtype::Bf16 | Dtype::F16 => 2,
        }
    }
}

/// Shape + dtype + name of one executable input/output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> u64 {
        (self.elements() * self.dtype.bytes()) as u64
    }
}

/// One AOT-compiled executable as described by the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,    // train | eval | stage
    pub variant: String, // fsa1 | fsa2 | dgl1 | dgl2 | stage names
    pub dataset: String,
    pub k1: usize,
    pub k2: usize,
    pub batch: usize,
    pub amp: bool,
    pub save_indices: bool,
    pub hidden: usize,
    pub tile: usize,
    pub vmem_tile_bytes: u64,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Number of model parameter tensors (leading inputs are params, then
    /// m, then v — the train-step contract).
    pub fn n_params(&self) -> usize {
        if self.variant.starts_with("fsa") { 5 } else { 6 }
    }

    pub fn input_bytes(&self) -> u64 {
        self.inputs.iter().map(|t| t.bytes()).sum()
    }

    pub fn output_bytes(&self) -> u64 {
        self.outputs.iter().map(|t| t.bytes()).sum()
    }
}

/// AdamW hyper-parameters recorded in the manifest (paper §5).
#[derive(Clone, Copy, Debug)]
pub struct AdamwConfig {
    pub lr: f64,
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
    pub wd: f64,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub hidden: usize,
    pub adamw: AdamwConfig,
    pub datasets: BTreeMap<String, DatasetSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Manifest-less default: the paper's hyper-parameters (§5, CPU-scale
    /// hidden width) and no artifacts. Datasets resolve through
    /// [`crate::gen::builtin_spec`]; every artifact lookup fails, steering
    /// `BackendChoice::Auto` onto the native engine.
    pub fn builtin() -> Manifest {
        Manifest {
            hidden: 64,
            adamw: AdamwConfig {
                lr: 3e-3,
                b1: 0.9,
                b2: 0.999,
                eps: 1e-8,
                wd: 5e-4,
            },
            datasets: BTreeMap::new(),
            artifacts: BTreeMap::new(),
        }
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} — run `make artifacts` first"))?;
        Self::parse(&text).with_context(|| format!("parsing manifest {path:?}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text)?;
        let version = v.get("version").and_then(Value::as_i64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let hidden = req_usize(&v, "hidden")?;
        let aw = v.get("adamw").ok_or_else(|| anyhow!("missing adamw"))?;
        let adamw = AdamwConfig {
            lr: req_f64(aw, "lr")?,
            b1: req_f64(aw, "b1")?,
            b2: req_f64(aw, "b2")?,
            eps: req_f64(aw, "eps")?,
            wd: req_f64(aw, "wd")?,
        };

        let mut datasets = BTreeMap::new();
        for (name, d) in v
            .get("datasets")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow!("missing datasets"))?
        {
            datasets.insert(
                name.clone(),
                DatasetSpec {
                    name: name.clone(),
                    stands_for: req_str(d, "stands_for")?,
                    n: req_usize(d, "n")?,
                    e_cap: req_usize(d, "e_cap")?,
                    avg_deg: req_usize(d, "avg_deg")?,
                    degree_law: DegreeLaw::parse(&req_str(d, "degree_law")?)?,
                    d: req_usize(d, "d")?,
                    c: req_usize(d, "c")?,
                    gen_seed: req_usize(d, "gen_seed")? as u64,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in v
            .get("artifacts")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let spec = ArtifactSpec {
                name: req_str(a, "name")?,
                file: req_str(a, "file")?,
                kind: req_str(a, "kind")?,
                variant: req_str(a, "variant")?,
                dataset: req_str(a, "dataset")?,
                k1: req_usize(a, "k1")?,
                k2: req_usize(a, "k2")?,
                batch: req_usize(a, "batch")?,
                amp: a.get("amp").and_then(Value::as_bool).unwrap_or(false),
                save_indices: a
                    .get("save_indices")
                    .and_then(Value::as_bool)
                    .unwrap_or(true),
                hidden: req_usize(a, "hidden")?,
                tile: req_usize(a, "tile")?,
                vmem_tile_bytes: req_usize(a, "vmem_tile_bytes")? as u64,
                inputs: parse_tensors(a.get("inputs"))?,
                outputs: parse_tensors(a.get("outputs"))?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { hidden, adamw, datasets, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetSpec> {
        self.datasets
            .get(name)
            .ok_or_else(|| anyhow!("dataset {name:?} not in manifest"))
    }

    /// Find the train artifact for a configuration.
    pub fn find_train(&self, variant: &str, dataset: &str, k1: usize,
                      k2: usize, batch: usize, amp: bool,
                      save_indices: bool) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| {
                a.kind == "train" && a.variant == variant
                    && a.dataset == dataset && a.k1 == k1 && a.k2 == k2
                    && a.batch == batch && a.amp == amp
                    && a.save_indices == save_indices
            })
            .ok_or_else(|| anyhow!(
                "no train artifact for {variant}/{dataset} f{k1}x{k2} \
                 b{batch} amp={amp} save={save_indices} — extend \
                 python/compile/configs.py and re-run `make artifacts`"))
    }

    /// All stage artifacts for the Table 3 profile config, pipeline order.
    pub fn profile_stages(&self) -> Vec<&ArtifactSpec> {
        let order = ["gather", "layer1", "layer2", "loss", "bwd_layer2",
                     "bwd_layer1", "adamw"];
        order
            .iter()
            .filter_map(|s| {
                self.artifacts.values().find(|a| a.kind == "stage" && a.variant == *s)
            })
            .collect()
    }
}

fn parse_tensors(v: Option<&Value>) -> Result<Vec<TensorSpec>> {
    let arr = v.and_then(Value::as_arr).ok_or_else(|| anyhow!("missing tensor list"))?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: req_str(t, "name")?,
                shape: t
                    .get("shape")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| anyhow!("missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: Dtype::parse(&req_str(t, "dtype")?)?,
            })
        })
        .collect()
}

fn req_str(v: &Value, k: &str) -> Result<String> {
    v.get(k)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing string field {k:?}"))
}

fn req_usize(v: &Value, k: &str) -> Result<usize> {
    v.get(k)
        .and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("missing int field {k:?}"))
}

fn req_f64(v: &Value, k: &str) -> Result<f64> {
    v.get(k)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("missing float field {k:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "hidden": 64,
      "adamw": {"lr": 0.003, "b1": 0.9, "b2": 0.999, "eps": 1e-8, "wd": 0.0005},
      "datasets": {"tiny": {"stands_for": "unit tests", "n": 512,
        "e_cap": 8192, "avg_deg": 6, "degree_law": "uniform", "d": 16,
        "c": 8, "gen_seed": 1000}},
      "artifacts": [{
        "name": "fsa2_train_tiny", "file": "fsa2_train_tiny.hlo.txt",
        "kind": "train", "variant": "fsa2", "dataset": "tiny",
        "k1": 5, "k2": 3, "batch": 64, "amp": true, "save_indices": true,
        "hidden": 64, "tile": 64, "vmem_tile_bytes": 123,
        "inputs": [{"name": "w", "shape": [16, 64], "dtype": "float32"}],
        "outputs": [{"name": "loss", "shape": [], "dtype": "float32"}]
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hidden, 64);
        assert!((m.adamw.lr - 3e-3).abs() < 1e-12);
        let ds = m.dataset("tiny").unwrap();
        assert_eq!(ds.n, 512);
        let a = m.artifact("fsa2_train_tiny").unwrap();
        assert_eq!(a.k1, 5);
        assert_eq!(a.inputs[0].elements(), 16 * 64);
        assert_eq!(a.inputs[0].bytes(), 16 * 64 * 4);
        assert_eq!(a.outputs[0].elements(), 1);
        assert_eq!(a.n_params(), 5);
    }

    #[test]
    fn find_train_matches_exactly() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find_train("fsa2", "tiny", 5, 3, 64, true, true).is_ok());
        assert!(m.find_train("fsa2", "tiny", 5, 3, 64, false, true).is_err());
        assert!(m.find_train("dgl2", "tiny", 5, 3, 64, true, true).is_err());
    }

    #[test]
    fn rejects_bad_version_and_dtype() {
        assert!(Manifest::parse(&SAMPLE.replace("\"version\": 1", "\"version\": 9")).is_err());
        assert!(Manifest::parse(&SAMPLE.replace("float32", "float8")).is_err());
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let path = crate::util::artifacts_dir().join("manifest.json");
        if !path.exists() {
            eprintln!("skipping: {path:?} missing (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&path).unwrap();
        assert!(m.artifacts.len() >= 60, "expected full grid, got {}", m.artifacts.len());
        // the paper's main grid must be present
        for ds in ["arxiv_sim", "reddit_sim", "products_sim"] {
            for (k1, k2) in [(10, 10), (15, 10), (25, 10)] {
                for b in [512, 1024] {
                    for v in ["fsa2", "dgl2"] {
                        m.find_train(v, ds, k1, k2, b, true, true)
                            .unwrap_or_else(|e| panic!("{e}"));
                    }
                }
            }
        }
        assert_eq!(m.profile_stages().len(), 7);
    }
}
