//! The execution-backend seam: one synchronized train-step dispatch.
//!
//! The coordinator's `Trainer` prepares batches (seed scheduling, host
//! sampling, prefetch) and hands one [`StepInputs`] per step to a
//! [`Backend`]; the backend owns the model/optimizer state and runs
//! forward + backward + AdamW. The step spec is depth-generic: the batch
//! carries one optional [`Block`] whose [`crate::fanout::Fanouts`] decide
//! everything shape-related. Two implementations:
//!
//! * [`PjrtBackend`] (here) — the AOT path: upload per-step tensors,
//!   dispatch one compiled artifact, read back state. The artifact
//!   manifest only defines 1- and 2-hop graphs, so this backend rejects
//!   deeper fanouts with a clear error (use the native engine). With the
//!   in-crate `xla` stub compilation also fails with a clear error; with
//!   real bindings it is the paper's measurement path.
//! * [`crate::kernel::NativeBackend`] — real host compute at any depth,
//!   no artifacts needed. `BackendChoice::Auto` (the default) tries PJRT
//!   and falls back to native, so `fsa train` works end-to-end in this
//!   offline build. See DESIGN_BACKEND.md for the re-vendoring contract.
//!
//! Transient accounting: backends record every per-step allocation into
//! the coordinator's [`MemoryMeter`]; the native backend's numbers are
//! fully measured, the PJRT backend adds the analytic model of the
//! executable-internal intermediates ([`crate::memory`]) on top of its
//! measured uploads/outputs.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::fanout::Fanouts;
use crate::gen::Dataset;
use crate::memory::{self, MemoryMeter, StepDims};
use crate::metrics::Timer;
use crate::sampler::Block;
use crate::xla;

use super::{init_params, Executable, Runtime};

/// Which execution backend a trainer should use.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendChoice {
    /// Try PJRT (artifact + compile), fall back to the native engine.
    #[default]
    Auto,
    /// Native CPU engine (no artifacts needed).
    Native,
    /// PJRT only; errors when the artifact or the bindings are missing.
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<BackendChoice> {
        Ok(match s {
            "auto" => BackendChoice::Auto,
            "native" => BackendChoice::Native,
            "pjrt" => BackendChoice::Pjrt,
            other => bail!("--backend must be auto|native|pjrt, got {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Native => "native",
            BackendChoice::Pjrt => "pjrt",
        }
    }
}

/// Everything the host pipeline prepared for one step, by reference.
pub struct StepInputs<'a> {
    pub seeds: &'a [i32],
    pub labels: &'a [i32],
    /// Per-step base seed (shared sampling schedule across variants).
    pub base: u64,
    /// Host-materialized L-hop index block (baseline variant only; its
    /// fanouts carry the depth).
    pub block: Option<&'a Block>,
}

/// What one dispatch reports back to the coordinator.
pub struct StepOutcome {
    pub loss: f64,
    /// Per-step uploads (params/opt state + batch tensors); 0 for native.
    pub upload_ms: f64,
    /// Synchronized dispatch (fwd + bwd + optimizer).
    pub execute_ms: f64,
    /// Output handling / state update; 0 for native (in-place update).
    pub post_ms: f64,
    /// Sampled (seed, neighbor) pairs counted inside the dispatch, when
    /// the backend knows them for free (fused native kernels).
    pub pairs: Option<u64>,
    /// Per-shard wall time/cost of the dispatch's batch sharding (native
    /// fused kernel only; None when the backend does not shard on the
    /// host). Feeds the measured-imbalance metrics and the adaptive
    /// planner's session-shared [`crate::graph::CostModel`] — whose
    /// weights the trainer persists across sessions via
    /// `results/planner_state.json` (`--planner-state`). Timing is
    /// measured through the [`crate::graph::ShardClock`] seam, so tests
    /// can script it deterministically.
    pub shard_stats: Option<crate::graph::ShardStats>,
    /// Hub-aggregate cache activity inside this dispatch (leaf-hop
    /// lookups served from / missed by the cache, entries refreshed by
    /// the pre-pass budget). All zero when the cache is off or the
    /// backend has none.
    pub hub_hits: u64,
    pub hub_misses: u64,
    pub hub_refreshes: u64,
}

/// One synchronized train-step executor. Implementations own the model and
/// optimizer state; the coordinator owns batching and measurement.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Run forward + backward + AdamW on one prepared batch, recording
    /// per-step transient allocations into `meter`.
    fn train_step(&mut self, step: usize, inp: &StepInputs<'_>,
                  meter: &mut MemoryMeter) -> Result<StepOutcome>;

    /// Forward-only logits `[seeds.len() * classes]` for evaluation.
    /// `None` means "not supported here" — the PJRT path evaluates through
    /// its dedicated AOT eval artifacts instead.
    fn eval_logits(&mut self, _seeds: &[i32], _base: u64)
                   -> Result<Option<Vec<f32>>> {
        Ok(None)
    }

    /// Current parameters as host f32 tensors, canonical spec order.
    fn params_f32(&self) -> Result<Vec<Vec<f32>>>;

    /// Replace the model parameters from host f32 tensors (checkpoint
    /// restore, `fsa serve --params`). Backends without an in-place
    /// parameter store must reject with a clear error instead of
    /// silently serving whatever weights they initialized with.
    fn set_params_f32(&mut self, _params: &[Vec<f32>]) -> Result<()> {
        bail!("the {} backend cannot load parameter checkpoints; \
               use --backend native", self.name())
    }

    /// Measured shard-imbalance ratio (max/mean per-shard wall time) of
    /// the most recent `eval_logits` pass — `None` when that pass ran
    /// serially or the backend does not shard on the host.
    fn eval_imbalance(&self) -> Option<f64> {
        None
    }

    /// Cumulative hub-cache `(hits, misses, refreshes)` counters since
    /// backend construction — `None` when the backend has no cache.
    /// Callers that want per-window activity (serve bench cells, the
    /// throughput harness) snapshot before/after and difference.
    fn hub_counters(&self) -> Option<(u64, u64, u64)> {
        None
    }

    /// AdamW (m, v) moment tensors as host f32, aligned with
    /// [`Backend::params_f32`] — the other half of a crash-exact
    /// checkpoint. `None` means the backend cannot export them (the
    /// resulting checkpoint is then serve-only, not resumable).
    fn opt_state_f32(&self) -> Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        None
    }

    /// Restore the AdamW moments (checkpoint resume). Backends without
    /// an in-place optimizer store must reject: resuming with zeroed
    /// moments would silently diverge from the uninterrupted trajectory.
    fn set_opt_state_f32(&mut self, _m: &[Vec<f32>], _v: &[Vec<f32>])
                         -> Result<()> {
        bail!("the {} backend cannot restore optimizer state; \
               use --backend native", self.name())
    }
}

/// Reject fanouts the AOT manifest cannot express. The manifest only
/// generates 1- and 2-hop train/eval graphs (`fsa1/fsa2/dgl1/dgl2`);
/// L-hop PJRT manifests are an open ROADMAP item.
pub fn ensure_pjrt_depth(fanouts: &Fanouts) -> Result<()> {
    ensure!(fanouts.depth() <= 2,
            "PJRT backend supports fanout depth <= 2, got depth {} ({}): \
             the AOT artifact manifest only defines 1- and 2-hop graphs — \
             use --backend native for deeper fanouts",
            fanouts.depth(), fanouts);
    Ok(())
}

/// The AOT/PJRT implementation of [`Backend`] (the paper's device path).
pub struct PjrtBackend<'rt> {
    rt: &'rt Runtime,
    pub exe: Rc<Executable>,
    fused: bool,
    save_indices: bool,
    dims: StepDims,
    /// Shared rowptr/col buffers — only fused artifacts consume them.
    graph: Option<Rc<super::GraphBufs>>,
    /// Shared f32 feature buffer (absent when the artifact wants bf16).
    x_f32: Option<Rc<xla::PjRtBuffer>>,
    /// Artifact-owned bf16 feature buffer (AMP storage).
    x_bf16: Option<xla::PjRtBuffer>,
    params: Vec<xla::Literal>,
    mstate: Vec<xla::Literal>,
    vstate: Vec<xla::Literal>,
}

impl<'rt> PjrtBackend<'rt> {
    /// Load + compile `artifact` and set up static buffers and state.
    /// Fails fast (before any training) when the bindings are stubbed or
    /// the fanout depth exceeds what the manifest expresses.
    #[allow(clippy::too_many_arguments)]
    pub fn new(rt: &'rt Runtime, ds: &Arc<Dataset>, artifact: &str,
               fused: bool, fanouts: &Fanouts, batch: usize,
               save_indices: bool, seed: u64) -> Result<PjrtBackend<'rt>> {
        ensure_pjrt_depth(fanouts)?;
        let exe = rt.load(artifact)?;
        // static uploads, shared per dataset across trainers and eval;
        // each variant only uploads what its artifact consumes
        let graph = if fused { Some(rt.graph_bufs(ds)?) } else { None };
        let x_dtype = exe
            .spec
            .inputs
            .iter()
            .find(|t| t.name == "x")
            .map(|t| t.dtype)
            .unwrap_or(super::Dtype::F32);
        let (x_f32, x_bf16) = match x_dtype {
            super::Dtype::Bf16 => (None, Some(rt.buf_bf16_from_f32(
                &ds.features, &[ds.spec.n, ds.spec.d])?)),
            _ => (Some(rt.features_f32(ds)?), None),
        };

        let np = exe.spec.n_params();
        let pspecs = &exe.spec.inputs[..np];
        let values = init_params(pspecs, seed);
        let mut params = Vec::with_capacity(np);
        let mut mstate = Vec::with_capacity(np);
        let mut vstate = Vec::with_capacity(np);
        for (s, vals) in pspecs.iter().zip(&values) {
            params.push(lit_f32(vals, &s.shape)?);
            mstate.push(lit_f32(&vec![0.0; vals.len()], &s.shape)?);
            vstate.push(lit_f32(&vec![0.0; vals.len()], &s.shape)?);
        }

        let dims = StepDims {
            batch,
            fanouts: fanouts.clone(),
            d: ds.spec.d,
            hidden: rt.manifest.hidden,
            classes: ds.spec.c,
            tile: exe.spec.tile,
        };
        Ok(PjrtBackend {
            rt,
            exe,
            fused,
            save_indices,
            dims,
            graph,
            x_f32,
            x_bf16,
            params,
            mstate,
            vstate,
        })
    }
}

impl Backend for PjrtBackend<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train_step(&mut self, step: usize, inp: &StepInputs<'_>,
                  meter: &mut MemoryMeter) -> Result<StepOutcome> {
        let b = self.dims.batch;
        ensure!(inp.seeds.len() == b,
                "expected {b} seeds, got {}", inp.seeds.len());
        let depth = self.dims.fanouts.depth();
        let k1 = self.dims.fanouts.k(0);

        // ---- per-step uploads (params/opt state + batch tensors); static
        // buffers (graph, features) are passed by reference.
        let timer = Timer::start();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(24);
        let mut upload_bytes = 0u64;
        for lit in self.params.iter().chain(&self.mstate).chain(&self.vstate) {
            owned.push(self.rt.buf_from_literal(lit)?);
            upload_bytes += lit.size_bytes() as u64;
        }
        owned.push(self.rt.buf_scalar_f32(step as f32)?);
        upload_bytes += 4;

        // (owned-index | static-ref) arg plan, in manifest input order
        enum Arg {
            Owned(usize),
            Rowptr,
            Col,
            X,
        }
        let mut plan: Vec<Arg> = (0..owned.len()).map(Arg::Owned).collect();
        match (self.fused, depth) {
            (true, _) => {
                plan.push(Arg::Rowptr);
                plan.push(Arg::Col);
                plan.push(Arg::X);
                owned.push(self.rt.buf_i32(inp.seeds, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(inp.labels, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_u64(&[inp.base], &[1])?);
                plan.push(Arg::Owned(owned.len() - 1));
                upload_bytes += (2 * b * 4 + 8) as u64;
            }
            (false, 2) => {
                let blk = inp.block
                    .context("pipeline prepared no 2-hop block")?;
                ensure!(blk.fanouts == self.dims.fanouts,
                        "block fanouts {} do not match artifact fanouts {}",
                        blk.fanouts, self.dims.fanouts);
                let f1w = 1 + k1;
                let k2 = self.dims.fanouts.k(1);
                let f1 = &blk.frontiers[1];
                let s2 = &blk.leaf;
                plan.push(Arg::X);
                owned.push(self.rt.buf_i32(f1, &[b, f1w])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(s2, &[b, f1w, k2])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(inp.labels, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                upload_bytes += (f1.len() * 4 + s2.len() * 4 + b * 4) as u64;
            }
            (false, _) => {
                let blk = inp.block
                    .context("pipeline prepared no 1-hop block")?;
                ensure!(blk.fanouts == self.dims.fanouts,
                        "block fanouts {} do not match artifact fanouts {}",
                        blk.fanouts, self.dims.fanouts);
                // the dgl1 artifact consumes the legacy combined
                // [B, 1+k] frontier (seed column + samples)
                let f1w = 1 + k1;
                let mut f1 = vec![-1i32; b * f1w];
                for bi in 0..b {
                    f1[bi * f1w] = blk.frontiers[0][bi];
                    f1[bi * f1w + 1..(bi + 1) * f1w]
                        .copy_from_slice(&blk.leaf[bi * k1..(bi + 1) * k1]);
                }
                plan.push(Arg::X);
                owned.push(self.rt.buf_i32(&f1, &[b, f1w])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(inp.labels, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                upload_bytes += (f1.len() * 4 + b * 4) as u64;
            }
        }
        let graph = self.graph.as_ref(); // present iff the variant is fused
        let args: Vec<&xla::PjRtBuffer> = plan
            .iter()
            .map(|a| match a {
                Arg::Owned(i) => &owned[*i],
                Arg::Rowptr => &graph.expect("fused needs graph").rowptr,
                Arg::Col => &graph.expect("fused needs graph").col,
                Arg::X => match &self.x_bf16 {
                    Some(b) => b,
                    None => self.x_f32.as_deref().expect("f32 features"),
                },
            })
            .collect();
        let upload_ms = timer.ms();
        meter.alloc(upload_bytes);

        // ---- synchronized dispatch (fwd + bwd + AdamW in one artifact)
        let timer = Timer::start();
        let outputs = self.exe.run(&args).context("train step dispatch")?;
        let execute_ms = timer.ms();

        // ---- state update + loss read-back
        let timer = Timer::start();
        let np = self.exe.spec.n_params();
        let mut outputs = outputs;
        let loss_lit = outputs.pop().unwrap();
        let loss = loss_lit.get_first_element::<f32>()? as f64;
        let vs = outputs.split_off(2 * np);
        let ms = outputs.split_off(np);
        self.params = outputs;
        self.mstate = ms;
        self.vstate = vs;
        let post_ms = timer.ms();

        // measured uploads/outputs + analytic executable intermediates
        let analytic = if self.fused {
            memory::fused_transient(&self.dims, self.save_indices)
        } else {
            memory::baseline_transient(&self.dims)
        };
        meter.alloc(analytic.intermediates + self.exe.spec.output_bytes());

        Ok(StepOutcome { loss, upload_ms, execute_ms, post_ms, pairs: None,
                         shard_stats: None, hub_hits: 0, hub_misses: 0,
                         hub_refreshes: 0 })
    }

    fn params_f32(&self) -> Result<Vec<Vec<f32>>> {
        self.params
            .iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect()
    }
}

fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_parses_and_defaults() {
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("native").unwrap(),
                   BackendChoice::Native);
        assert_eq!(BackendChoice::parse("pjrt").unwrap(), BackendChoice::Pjrt);
        assert!(BackendChoice::parse("gpu").is_err());
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
        assert_eq!(BackendChoice::Native.as_str(), "native");
    }

    #[test]
    fn pjrt_depth_gate_names_the_limitation() {
        assert!(ensure_pjrt_depth(&Fanouts::of(&[10])).is_ok());
        assert!(ensure_pjrt_depth(&Fanouts::of(&[15, 10])).is_ok());
        let err = ensure_pjrt_depth(&Fanouts::of(&[15, 10, 5]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("depth 3"), "{err}");
        assert!(err.contains("manifest"), "{err}");
        assert!(err.contains("--backend native"), "{err}");
    }
}
