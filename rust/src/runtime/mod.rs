//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute on
//! the hot path.
//!
//! Pattern follows the xla_extension load_hlo flow: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax≥0.5 serialized protos; see aot.py).
//!
//! In this offline build `xla` resolves to [`crate::xla`], a stand-in for
//! the native bindings: buffers/literals are fully functional, compilation
//! errors out with a clear message (see that module's docs for the swap
//! path back to the real PJRT).
//!
//! Static tensors (graph arrays, features) are uploaded once as device
//! buffers and reused across steps — mirroring DGL keeping graph+features
//! GPU-resident. Per-step tensors (seeds, labels, index blocks, params)
//! are uploaded each step and counted by the memory meter.

pub mod backend;
pub mod faults;
pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use crate::gen::Dataset;
use crate::xla;

pub use backend::{Backend, BackendChoice, PjrtBackend, StepInputs,
                  StepOutcome};
pub use manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};

/// A compiled artifact plus its manifest contract.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with device buffers in manifest input order; returns the
    /// output literals in manifest output order (host-synchronized — this
    /// is the paper's "explicit device synchronization" point).
    pub fn run<L: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self, args: &[L]) -> Result<Vec<xla::Literal>> {
        ensure!(args.len() == self.spec.inputs.len(),
                "{}: got {} args, manifest says {}",
                self.spec.name, args.len(), self.spec.inputs.len());
        let out = self.exe.execute_b(args)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        ensure!(parts.len() == self.spec.outputs.len(),
                "{}: got {} outputs, manifest says {}",
                self.spec.name, parts.len(), self.spec.outputs.len());
        Ok(parts)
    }

    /// Execute but keep results on device (no host sync) — used by the
    /// profiler to time pure dispatch+compute.
    pub fn run_device<L: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self, args: &[L]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        ensure!(args.len() == self.spec.inputs.len(),
                "{}: arg count mismatch", self.spec.name);
        Ok(self.exe.execute_b(args)?)
    }
}

/// Static graph-array buffers (rowptr + col) of one dataset, uploaded once
/// and shared by every fused-variant trainer/eval pass on that dataset —
/// see [`Runtime::graph_bufs`]. The f32 feature buffer is cached
/// separately ([`Runtime::features_f32`]) because baseline artifacts
/// consume only `x`, and bf16 artifacts none of the f32 copies.
pub struct GraphBufs {
    pub rowptr: xla::PjRtBuffer,
    pub col: xla::PjRtBuffer,
}

/// PJRT client + artifact cache. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, Rc<Executable>>>,
    graph_cache: std::cell::RefCell<HashMap<String, Rc<GraphBufs>>>,
    feat_cache: std::cell::RefCell<HashMap<String, Rc<xla::PjRtBuffer>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        Self::with_manifest(artifacts_dir, manifest)
    }

    fn with_manifest(artifacts_dir: &Path, manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: Default::default(),
            graph_cache: Default::default(),
            feat_cache: Default::default(),
        })
    }

    /// Default runtime: artifacts dir discovered from the repo root. When
    /// no `manifest.json` exists (no `make artifacts` run — the normal
    /// state of this offline build) the built-in manifest is used, which
    /// has hyper-parameters and datasets but no artifacts: every PJRT
    /// lookup fails cleanly and `BackendChoice::Auto` lands on the native
    /// engine.
    pub fn from_env() -> Result<Runtime> {
        let dir = crate::util::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Self::new(&dir)
        } else {
            Self::with_manifest(&dir, Manifest::builtin())
        }
    }

    /// Static per-dataset graph arrays (rowptr, col), uploaded on first
    /// use and cached for the process lifetime. Before this cache, every
    /// trainer and every `evaluate_params` call re-uploaded them —
    /// multiplying peak host memory whenever training and eval interleaved.
    pub fn graph_bufs(&self, ds: &Dataset) -> Result<Rc<GraphBufs>> {
        if let Some(b) = self.graph_cache.borrow().get(&ds.spec.name) {
            return Ok(b.clone());
        }
        let n = ds.spec.n;
        let bufs = Rc::new(GraphBufs {
            rowptr: self.buf_i32(&ds.graph.rowptr, &[n + 1])?,
            col: self.buf_i32(&ds.graph.col, &[ds.graph.e_cap()])?,
        });
        self.graph_cache
            .borrow_mut()
            .insert(ds.spec.name.clone(), bufs.clone());
        Ok(bufs)
    }

    /// Static per-dataset f32 feature buffer, cached like
    /// [`Runtime::graph_bufs`] (bf16 feature buffers are artifact-specific
    /// and owned by their backend instead).
    pub fn features_f32(&self, ds: &Dataset) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.feat_cache.borrow().get(&ds.spec.name) {
            return Ok(b.clone());
        }
        let buf = Rc::new(
            self.buf_f32(&ds.features, &[ds.spec.n, ds.spec.d])?);
        self.feat_cache
            .borrow_mut()
            .insert(ds.spec.name.clone(), buf.clone());
        Ok(buf)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact (cached after first use).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    // --- upload helpers (device buffers in manifest order) ---------------

    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn buf_u64(&self, data: &[u64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn buf_scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// Upload f32 host data as a bf16 device buffer (the fused 2-hop
    /// kernel dispatches on the feature dtype, paper §4). Goes through the
    /// XLA literal converter, which rounds to nearest-even like
    /// [`f32_to_bf16_bytes`].
    pub fn buf_bf16_from_f32(&self, data: &[f32], dims: &[usize])
                             -> Result<xla::PjRtBuffer> {
        let lit = xla::Literal::vec1(data);
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = if dims.len() > 1 { lit.reshape(&dims_i64)? } else { lit };
        let bf16 = lit.convert(xla::PrimitiveType::Bf16)?;
        Ok(self.client.buffer_from_host_literal(None, &bf16)?)
    }

    /// Re-upload a host literal (e.g. an updated parameter) as a buffer.
    pub fn buf_from_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

/// Round-to-nearest-even f32 → bf16 conversion (little-endian byte
/// pairs), delegating to the shared [`crate::util::f32_to_bf16`].
pub fn f32_to_bf16_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 2);
    for &x in data {
        out.extend_from_slice(&crate::util::f32_to_bf16(x).to_le_bytes());
    }
    out
}

/// Deterministic parameter initialization: Kaiming-scaled normals from the
/// counter RNG; identical across runs with the same seed. Biases start at 0.
pub fn init_params(specs: &[TensorSpec], seed: u64) -> Vec<Vec<f32>> {
    use crate::rng::SplitMix64;
    let mut rng = SplitMix64::new(crate::rng::mix(seed ^ 0x9A9A));
    specs
        .iter()
        .map(|s| {
            let fan_in = if s.shape.len() >= 2 { s.shape[0] } else { s.elements() };
            let scale = if s.shape.len() >= 2 {
                (2.0 / fan_in as f64).sqrt()
            } else {
                0.0 // biases start at zero
            };
            (0..s.elements())
                .map(|_| (rng.next_normal() * scale) as f32)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Dtype;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: Dtype::F32 }
    }

    #[test]
    fn bf16_conversion_rounds_to_nearest_even() {
        // 1.0f32 = 0x3F800000 -> bf16 0x3F80
        assert_eq!(f32_to_bf16_bytes(&[1.0]), vec![0x80, 0x3F]);
        // value exactly halfway rounds to even mantissa
        let halfway = f32::from_bits(0x3F80_8000); // 1.00390625
        assert_eq!(f32_to_bf16_bytes(&[halfway]), vec![0x80, 0x3F]);
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(f32_to_bf16_bytes(&[above]), vec![0x81, 0x3F]);
        // NaN stays NaN
        assert_eq!(f32_to_bf16_bytes(&[f32::NAN]), vec![0xC0, 0x7F]);
        // round trip error bounded by 2^-8 relative
        for x in [0.1f32, -3.5, 123.456, 1e-3] {
            let b = f32_to_bf16_bytes(&[x]);
            let back = f32::from_bits(
                (u16::from_le_bytes([b[0], b[1]]) as u32) << 16);
            assert!((back - x).abs() <= x.abs() / 128.0, "{x} -> {back}");
        }
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        let specs = vec![spec("w", &[64, 32]), spec("b", &[32])];
        let a = init_params(&specs, 42);
        let b = init_params(&specs, 42);
        assert_eq!(a, b);
        let c = init_params(&specs, 43);
        assert_ne!(a[0], c[0]);
        // biases zero
        assert!(a[1].iter().all(|&x| x == 0.0));
        // weight std ~ sqrt(2/64) = 0.177
        let std = (a[0].iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / a[0].len() as f64)
            .sqrt();
        assert!((std - 0.177).abs() < 0.03, "std {std}");
    }
}
