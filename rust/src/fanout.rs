//! [`Fanouts`] — the ordered per-hop fanout list that parameterizes every
//! layer of the stack (CLI → coordinator → sampler → kernels → runtime).
//!
//! Before this type the repo hardcoded the `{1, 2}`-hop pair everywhere
//! (`(k1, k2)` tuples with `k2 == 0` meaning "1-hop"); `Fanouts` makes
//! depth a value, so `15x10x5` (SALIENT-style 3-hop) is one configuration
//! away instead of a third copy-pasted code path. Hop 0 is the hop drawn
//! from the seed nodes; the last hop's samples are the leaves whose
//! features the fused operator aggregates.
//!
//! Accepted string forms (all equivalent separators): `15x10x5`,
//! `15_10_5`, `15,10,5`; a single integer (`10`) is a 1-hop fanout. The
//! legacy `15x10` / `10` forms parse to exactly the same configurations
//! as before the depth generalization.

use std::fmt;

use anyhow::{bail, Result};

/// Ordered per-hop neighbor fanouts `[k1, k2, …, kL]`; depth = `L ≥ 1`,
/// every `k > 0`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Fanouts(Vec<usize>);

impl Fanouts {
    /// Validated constructor: at least one hop, all fanouts positive.
    pub fn new(ks: Vec<usize>) -> Result<Fanouts> {
        if ks.is_empty() {
            bail!("fanout must have at least one hop");
        }
        if let Some(pos) = ks.iter().position(|&k| k == 0) {
            bail!("fanout segment {} is zero (every hop must sample at \
                   least one neighbor)", pos + 1);
        }
        Ok(Fanouts(ks))
    }

    /// Literal constructor for tests/benches; panics on invalid input.
    pub fn of(ks: &[usize]) -> Fanouts {
        Fanouts::new(ks.to_vec()).expect("invalid fanout literal")
    }

    /// Parse `15x10x5` / `15_10_5` / `15,10,5` / `10`. Empty or zero
    /// segments are errors with the offending segment named.
    pub fn parse(s: &str) -> Result<Fanouts> {
        let trimmed = s.trim();
        if trimmed.is_empty() {
            bail!("empty fanout string");
        }
        let mut ks = Vec::new();
        for (i, seg) in trimmed.split(['x', '_', ',']).enumerate() {
            let seg = seg.trim();
            if seg.is_empty() {
                bail!("fanout {trimmed:?}: segment {} is empty", i + 1);
            }
            let k: usize = seg.parse().map_err(|_| {
                anyhow::anyhow!("fanout {trimmed:?}: segment {:?} is not an \
                                 integer", seg)
            })?;
            if k == 0 {
                bail!("fanout {trimmed:?}: segment {:?} is zero (every hop \
                       must sample at least one neighbor)", seg);
            }
            ks.push(k);
        }
        Fanouts::new(ks)
    }

    /// Number of hops `L`.
    pub fn depth(&self) -> usize {
        self.0.len()
    }

    /// Fanout of hop `hop` (0-based; hop 0 is drawn from the seeds).
    pub fn k(&self, hop: usize) -> usize {
        self.0[hop]
    }

    /// All fanouts, outermost (seed) hop first.
    pub fn as_slice(&self) -> &[usize] {
        &self.0
    }

    /// Canonical display label, e.g. `"15x10x5"` (also the CSV/JSON form).
    pub fn label(&self) -> String {
        self.0
            .iter()
            .map(|k| k.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }

    /// Leaves per seed: `k1·k2·…·kL` (the fused kernel's gather budget).
    pub fn leaf_count(&self) -> usize {
        self.0.iter().product()
    }

    /// Cumulative sample counts per hop: `[k1, k1·k2, …, k1·…·kL]` — the
    /// per-seed row widths of the fused kernel's saved-index tensors.
    pub fn cumulative(&self) -> Vec<usize> {
        self.0
            .iter()
            .scan(1usize, |w, &k| {
                *w *= k;
                Some(*w)
            })
            .collect()
    }

    /// Self-inclusive frontier width after `hops` hops:
    /// `(1+k1)·(1+k2)·…·(1+k_hops)` — the baseline's materialized row
    /// width at that depth (`hops = 0` → 1, the seed itself).
    pub fn frontier_width(&self, hops: usize) -> usize {
        self.0[..hops].iter().map(|&k| 1 + k).product()
    }
}

impl fmt::Display for Fanouts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_separators_and_depths() {
        assert_eq!(Fanouts::parse("15x10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(Fanouts::parse("15_10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(Fanouts::parse("15,10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(Fanouts::parse("10").unwrap(), Fanouts::of(&[10]));
        assert_eq!(Fanouts::parse("15x10x5").unwrap(),
                   Fanouts::of(&[15, 10, 5]));
        assert_eq!(Fanouts::parse(" 15, 10 , 5 ").unwrap(),
                   Fanouts::of(&[15, 10, 5]));
        assert_eq!(Fanouts::parse("2x2x2x2").unwrap().depth(), 4);
    }

    #[test]
    fn rejects_empty_zero_and_garbage_segments() {
        assert!(Fanouts::parse("").is_err());
        assert!(Fanouts::parse("x").is_err());
        assert!(Fanouts::parse("15x").is_err());
        assert!(Fanouts::parse("x10").is_err());
        assert!(Fanouts::parse("15x0x5").is_err());
        assert!(Fanouts::parse("15xabc").is_err());
        assert!(Fanouts::new(vec![]).is_err());
        assert!(Fanouts::new(vec![5, 0]).is_err());
        let err = Fanouts::parse("15x0").unwrap_err().to_string();
        assert!(err.contains("zero"), "{err}");
    }

    #[test]
    fn derived_quantities() {
        let f = Fanouts::of(&[15, 10, 5]);
        assert_eq!(f.depth(), 3);
        assert_eq!(f.k(0), 15);
        assert_eq!(f.k(2), 5);
        assert_eq!(f.label(), "15x10x5");
        assert_eq!(format!("{f}"), "15x10x5");
        assert_eq!(f.leaf_count(), 750);
        assert_eq!(f.cumulative(), vec![15, 150, 750]);
        assert_eq!(f.frontier_width(0), 1);
        assert_eq!(f.frontier_width(1), 16);
        assert_eq!(f.frontier_width(2), 176);
    }
}
