//! Small shared utilities: human-readable formatting, path discovery, and
//! the one shared bf16 conversion (previously duplicated between
//! `kernel` and `runtime`).

use std::path::{Path, PathBuf};

/// f32 → bf16 with round-to-nearest-even — the exact conversion the XLA
/// literal converter applies, shared by the native engine's bf16 feature
/// storage and the runtime's upload path. NaN maps to the canonical
/// quiet-NaN pattern.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        0x7FC0
    } else {
        let round = 0x7FFF + ((bits >> 16) & 1);
        (bits.wrapping_add(round) >> 16) as u16
    }
}

/// bf16 → f32 (exact: bf16 is a truncated f32).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Format a byte count as a human-readable string (MiB precision like the
/// paper's tables, which report MB).
pub fn fmt_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Bytes -> MB (10^6, matching the paper's "Peak MB" unit).
pub fn bytes_to_mb(bytes: u64) -> f64 {
    bytes as f64 / 1.0e6
}

/// Format milliseconds with the precision the paper's tables use.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.1}")
    } else {
        format!("{ms:.2}")
    }
}

/// Locate the repository root by walking up from the current directory until
/// `artifacts/manifest.json` (or `Cargo.toml`) is found. Tests, examples and
/// benches all run from different working directories; this makes artifact
/// discovery uniform.
pub fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("artifacts/manifest.json").exists()
            || dir.join("Cargo.toml").exists()
        {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// `artifacts/` directory: `$FSA_ARTIFACTS` override or repo-root discovery.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FSA_ARTIFACTS") {
        return PathBuf::from(p);
    }
    find_repo_root()
        .map(|r| r.join("artifacts"))
        .unwrap_or_else(|| Path::new("artifacts").to_path_buf())
}

/// `results/` directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = find_repo_root()
        .map(|r| r.join("results"))
        .unwrap_or_else(|| Path::new("results").to_path_buf());
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Phases of [`atomic_write`], in order — the unit tests inject a failure
/// at each one and assert the destination file survives untouched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WritePhase {
    Create,
    Write,
    Sync,
    Rename,
}

/// Atomic durable write: the bytes land in `<path>.tmp` first, are
/// fsync'd, and only then renamed over `path` — so a crash or I/O error
/// at any point leaves either the complete old file or the complete new
/// file, never a torn one. Every results file (planner state, params
/// checkpoints, CSVs) goes through here.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    atomic_write_hooked(path, bytes, &|_| Ok(()))
}

/// [`atomic_write`] with a per-phase failure hook (tests only; prod
/// callers use the no-op hook). On failure the temp file is removed.
fn atomic_write_hooked(path: &Path, bytes: &[u8],
                       hook: &dyn Fn(WritePhase) -> std::io::Result<()>)
                       -> std::io::Result<()> {
    use std::io::Write as _;
    let tmp = match path.file_name() {
        Some(name) => {
            let mut n = name.to_os_string();
            n.push(".tmp");
            path.with_file_name(n)
        }
        None => {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidInput,
                                           format!("{path:?} has no file \
                                                    name")));
        }
    };
    let attempt = || -> std::io::Result<()> {
        hook(WritePhase::Create)?;
        let mut f = std::fs::File::create(&tmp)?;
        hook(WritePhase::Write)?;
        f.write_all(bytes)?;
        hook(WritePhase::Sync)?;
        f.sync_all()?;
        drop(f);
        hook(WritePhase::Rename)?;
        std::fs::rename(&tmp, path)
    };
    let res = attempt();
    if res.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    res
}

/// A coarse cross-process mutex over one state file, held for the
/// duration of a load-merge-save cycle. Implemented as an `O_EXCL`
/// sibling lock file (`<path>.lock`) — the only primitive that is both
/// atomic on every local filesystem and dependency-free.
///
/// Acquisition retries with a short sleep for up to ~2s; a lock file
/// older than [`FileLock::STALE_SECS`] is presumed leaked by a crashed
/// process and is removed. If the lock still cannot be taken, `acquire`
/// returns `None` and the caller proceeds *unlocked* — planner state is
/// a warm-start cache, so losing mutual exclusion once must never turn
/// into losing the save entirely.
pub struct FileLock {
    lock_path: PathBuf,
}

impl FileLock {
    /// A leftover lock this old belongs to a crashed process, not a
    /// concurrent one: the guarded window is a single JSON
    /// load-merge-save, which completes in milliseconds.
    pub const STALE_SECS: u64 = 10;

    /// Try to take the lock guarding `path` (the state file itself, not
    /// the lock file). Blocks with bounded retries; `None` on timeout.
    pub fn acquire(path: &Path) -> Option<FileLock> {
        let mut name = path.file_name()?.to_os_string();
        name.push(".lock");
        let lock_path = path.with_file_name(name);
        if let Some(dir) = lock_path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).ok()?;
            }
        }
        for _ in 0..200 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&lock_path)
            {
                Ok(_) => return Some(FileLock { lock_path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    Self::reap_stale(&lock_path);
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => return None,
            }
        }
        None
    }

    /// Remove the lock file if its mtime says the holder is long gone.
    fn reap_stale(lock_path: &Path) {
        let stale = std::fs::metadata(lock_path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok())
            .is_some_and(|age| age.as_secs() > Self::STALE_SECS);
        if stale {
            let _ = std::fs::remove_file(lock_path);
        }
    }
}

impl Drop for FileLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.lock_path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trip_error_is_bounded() {
        for x in [0.0f32, 1.0, -3.5, 0.1, 123.456, -1e-3, 65504.0, 1e-8] {
            let back = bf16_to_f32(f32_to_bf16(x));
            assert!((back - x).abs() <= x.abs() / 128.0 + 1e-38,
                    "{x} -> {back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(f32_to_bf16(f32::NAN), 0x7FC0);
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0f32 = 0x3F800000 -> bf16 0x3F80
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        // exactly-halfway rounds to the even mantissa
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        // bf16 values decode and re-encode bit-exactly
        for b in [0x0000u16, 0x3F80, 0xC2F7, 0x7F7F] {
            assert_eq!(f32_to_bf16(bf16_to_f32(b)), b);
        }
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0 MiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn mb_matches_paper_unit() {
        assert!((bytes_to_mb(5_052_000_000) - 5052.0).abs() < 1e-9);
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(86.88), "86.88");
        assert_eq!(fmt_ms(166.0), "166.0");
    }

    fn atomic_tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fsa_atomic_write_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn atomic_write_replaces_and_round_trips() {
        let p = atomic_tmp("roundtrip.txt");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer contents");
        assert!(!p.with_file_name("roundtrip.txt.tmp").exists(),
                "temp file must not linger");
    }

    #[test]
    fn injected_failure_at_every_phase_preserves_the_old_file() {
        for phase in [WritePhase::Create, WritePhase::Write,
                      WritePhase::Sync, WritePhase::Rename] {
            let p = atomic_tmp(&format!("fail_{phase:?}.txt"));
            atomic_write(&p, b"precious").unwrap();
            let hook = move |at: WritePhase| -> std::io::Result<()> {
                if at == phase {
                    Err(std::io::Error::other(format!("injected at \
                                                       {at:?}")))
                } else {
                    Ok(())
                }
            };
            let err = atomic_write_hooked(&p, b"torn", &hook).unwrap_err();
            assert!(err.to_string().contains("injected"), "{phase:?}: {err}");
            assert_eq!(std::fs::read(&p).unwrap(), b"precious",
                       "{phase:?} failure must leave the old file intact");
            assert!(!p.with_file_name(format!("fail_{phase:?}.txt.tmp"))
                        .exists(),
                    "{phase:?} failure must clean up the temp file");
        }
    }

    #[test]
    fn atomic_write_rejects_pathless_targets() {
        assert!(atomic_write(Path::new("/"), b"x").is_err());
    }

    #[test]
    fn file_lock_excludes_and_releases() {
        let p = atomic_tmp("locked_state.json");
        let lock_file = p.with_file_name("locked_state.json.lock");
        let _ = std::fs::remove_file(&lock_file);
        let guard = FileLock::acquire(&p).expect("first acquire");
        assert!(lock_file.exists(), "lock file must exist while held");
        // a second taker in another thread blocks until the guard drops
        let p2 = p.clone();
        let waiter = std::thread::spawn(move || {
            FileLock::acquire(&p2).is_some()
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(guard);
        assert!(waiter.join().unwrap(),
                "waiter must acquire after release");
        assert!(!lock_file.exists(), "drop must remove the lock file");
    }

    #[test]
    fn file_lock_reaps_stale_locks() {
        let p = atomic_tmp("stale_state.json");
        let lock_file = p.with_file_name("stale_state.json.lock");
        std::fs::write(&lock_file, b"").unwrap();
        // age the lock file past the staleness horizon
        let old = std::time::SystemTime::now()
            - std::time::Duration::from_secs(FileLock::STALE_SECS + 5);
        let f = std::fs::OpenOptions::new().write(true)
            .open(&lock_file).unwrap();
        f.set_modified(old).unwrap();
        drop(f);
        let guard = FileLock::acquire(&p);
        assert!(guard.is_some(), "stale lock must be reaped, not block");
    }
}
