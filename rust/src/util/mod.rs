//! Small shared utilities: human-readable formatting, path discovery, and
//! the one shared bf16 conversion (previously duplicated between
//! `kernel` and `runtime`).

use std::path::{Path, PathBuf};

/// f32 → bf16 with round-to-nearest-even — the exact conversion the XLA
/// literal converter applies, shared by the native engine's bf16 feature
/// storage and the runtime's upload path. NaN maps to the canonical
/// quiet-NaN pattern.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        0x7FC0
    } else {
        let round = 0x7FFF + ((bits >> 16) & 1);
        (bits.wrapping_add(round) >> 16) as u16
    }
}

/// bf16 → f32 (exact: bf16 is a truncated f32).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Format a byte count as a human-readable string (MiB precision like the
/// paper's tables, which report MB).
pub fn fmt_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Bytes -> MB (10^6, matching the paper's "Peak MB" unit).
pub fn bytes_to_mb(bytes: u64) -> f64 {
    bytes as f64 / 1.0e6
}

/// Format milliseconds with the precision the paper's tables use.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.1}")
    } else {
        format!("{ms:.2}")
    }
}

/// Locate the repository root by walking up from the current directory until
/// `artifacts/manifest.json` (or `Cargo.toml`) is found. Tests, examples and
/// benches all run from different working directories; this makes artifact
/// discovery uniform.
pub fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("artifacts/manifest.json").exists()
            || dir.join("Cargo.toml").exists()
        {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// `artifacts/` directory: `$FSA_ARTIFACTS` override or repo-root discovery.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FSA_ARTIFACTS") {
        return PathBuf::from(p);
    }
    find_repo_root()
        .map(|r| r.join("artifacts"))
        .unwrap_or_else(|| Path::new("artifacts").to_path_buf())
}

/// `results/` directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = find_repo_root()
        .map(|r| r.join("results"))
        .unwrap_or_else(|| Path::new("results").to_path_buf());
    std::fs::create_dir_all(&dir).ok();
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trip_error_is_bounded() {
        for x in [0.0f32, 1.0, -3.5, 0.1, 123.456, -1e-3, 65504.0, 1e-8] {
            let back = bf16_to_f32(f32_to_bf16(x));
            assert!((back - x).abs() <= x.abs() / 128.0 + 1e-38,
                    "{x} -> {back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(f32_to_bf16(f32::NAN), 0x7FC0);
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0f32 = 0x3F800000 -> bf16 0x3F80
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        // exactly-halfway rounds to the even mantissa
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        // bf16 values decode and re-encode bit-exactly
        for b in [0x0000u16, 0x3F80, 0xC2F7, 0x7F7F] {
            assert_eq!(f32_to_bf16(bf16_to_f32(b)), b);
        }
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0 MiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn mb_matches_paper_unit() {
        assert!((bytes_to_mb(5_052_000_000) - 5052.0).abs() < 1e-9);
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(86.88), "86.88");
        assert_eq!(fmt_ms(166.0), "166.0");
    }
}
