//! Stage-split profiler — the Table 3 reproduction.
//!
//! Runs the baseline training step as a pipeline of separate executables
//! (gather → layer1 → layer2 → loss → bwd_layer2 → bwd_layer1 → adamw),
//! timing every dispatch individually plus the host sampler and the
//! between-stage copies. This is the PJRT analogue of the paper's PyTorch
//! profiler breakdown (exclusive CUDA time per operator class); the
//! stage ↔ paper-row mapping is documented in python/compile/stages.py.
//!
//! The between-stage copies are real: each stage's outputs are synced to
//! host literals and re-uploaded for the next stage. The dominant copy is
//! the materialized feature block — that round trip is precisely the
//! "block materialization" cost the fused operator removes.

use anyhow::{Context, Result};

use crate::coordinator::DatasetCache;
use crate::fanout::Fanouts;
use crate::gen::Split;
use crate::metrics::{summarize, Timer};
use crate::rng::{mix, SplitMix64};
use crate::runtime::{init_params, Runtime};
use crate::sampler;
use crate::xla;

/// Exclusive time of one profiled row.
#[derive(Clone, Debug)]
pub struct ProfileRow {
    pub name: String,
    /// Median exclusive milliseconds per step.
    pub median_ms: f64,
    /// Share of the summed exclusive time, in percent.
    pub pct: f64,
    /// Dispatches per step.
    pub calls: u32,
}

/// Result of a profiling run.
#[derive(Debug)]
pub struct ProfileReport {
    pub rows: Vec<ProfileRow>,
    pub total_ms: f64,
    pub dataset: String,
    pub steps: usize,
}

/// Profile the baseline pipeline on the Table 3 configuration
/// (products_sim, fanout 15–10, batch 1024, AMP on).
pub fn profile_baseline(rt: &Runtime, cache: &mut DatasetCache,
                        warmup: usize, steps: usize, seed: u64)
                        -> Result<ProfileReport> {
    let stages = rt.manifest.profile_stages();
    anyhow::ensure!(stages.len() == 7, "expected 7 stage artifacts");
    let spec0 = stages[0].clone();
    let (ds_name, k1, k2, b) =
        (spec0.dataset.clone(), spec0.k1, spec0.k2, spec0.batch);
    let ds = cache.get(rt, &ds_name)?;
    anyhow::ensure!(k2 > 0, "profile stages are 2-hop artifacts");
    let fanouts = Fanouts::new(vec![k1, k2])?;
    let f1w = 1 + k1;

    // compile all stages up front
    let exes: Vec<_> = stages
        .iter()
        .map(|s| rt.load(&s.name))
        .collect::<Result<Vec<_>>>()?;
    let stage_names: Vec<String> =
        stages.iter().map(|s| s.variant.clone()).collect();

    // static upload
    let x_buf = rt.buf_f32(&ds.features, &[ds.spec.n, ds.spec.d])?;

    // params for the adamw stage (dgl2 layout) — reuse its input specs
    let adamw_spec = stages[6].clone();
    let np = 6usize;
    let pspecs = &adamw_spec.inputs[..np];
    let values = init_params(pspecs, seed);
    let mut params: Vec<xla::Literal> = Vec::new();
    let mut mstate: Vec<xla::Literal> = Vec::new();
    let mut vstate: Vec<xla::Literal> = Vec::new();
    for (s, vals) in pspecs.iter().zip(&values) {
        params.push(lit(vals, &s.shape)?);
        mstate.push(lit(&vec![0.0; vals.len()], &s.shape)?);
        vstate.push(lit(&vec![0.0; vals.len()], &s.shape)?);
    }

    let mut train_nodes = ds.split_nodes(Split::Train);
    SplitMix64::new(mix(seed)).shuffle(&mut train_nodes);

    // per-row samples across timed steps
    let row_names = ["sample(host)", "copy(h2d/d2h)", "gather", "layer1",
                     "layer2", "loss", "bwd_layer2", "bwd_layer1", "adamw"];
    let mut samples: Vec<Vec<f64>> =
        row_names.iter().map(|_| Vec::new()).collect();

    for step in 0..warmup + steps {
        let timed = step >= warmup;
        let base = mix(seed.wrapping_add(step as u64));
        let seeds = &train_nodes[(step * b) % (train_nodes.len() - b)..][..b];
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();

        let mut row_ms = [0f64; 9];

        // -- host sampling
        let t = Timer::start();
        let blk = sampler::build_block(&ds.graph, seeds, &fanouts, base);
        row_ms[0] = t.ms();

        // -- copies: index upload
        let t = Timer::start();
        let f1_buf = rt.buf_i32(&blk.frontiers[1], &[b, f1w])?;
        let s2_buf = rt.buf_i32(&blk.leaf, &[b, f1w, k2])?;
        let labels_buf = rt.buf_i32(&labels, &[b])?;
        let mut copy_ms = t.ms();

        // helper: run a stage synchronized, return output literals
        let mut run_stage = |idx: usize,
                             args: &[&xla::PjRtBuffer]|
                             -> Result<Vec<xla::Literal>> {
            let t = Timer::start();
            let out = exes[idx].run(args)
                .with_context(|| format!("stage {}", stage_names[idx]))?;
            row_ms[2 + idx] = t.ms();
            Ok(out)
        };

        // -- gather (materializes xf1 + block)
        let g_out = run_stage(0, &[&x_buf, &f1_buf, &s2_buf])?;

        let t = Timer::start();
        let xf1_buf = rt.buf_from_literal(&g_out[0])?;
        let block_buf = rt.buf_from_literal(&g_out[1])?;
        let pbufs: Vec<xla::PjRtBuffer> = params
            .iter()
            .map(|l| rt.buf_from_literal(l))
            .collect::<Result<Vec<_>>>()?;
        copy_ms += t.ms();

        // -- layer1
        let l1_out = run_stage(1, &[&xf1_buf, &block_buf, &s2_buf,
                                    &pbufs[0], &pbufs[1], &pbufs[2]])?;
        let t = Timer::start();
        let h1_buf = rt.buf_from_literal(&l1_out[0])?;
        copy_ms += t.ms();

        // -- layer2
        let l2_out = run_stage(2, &[&h1_buf, &f1_buf, &pbufs[3], &pbufs[4],
                                    &pbufs[5]])?;
        let t = Timer::start();
        let logits_buf = rt.buf_from_literal(&l2_out[0])?;
        copy_ms += t.ms();

        // -- loss (+ dloss/dlogits)
        let loss_out = run_stage(3, &[&logits_buf, &labels_buf])?;
        let t = Timer::start();
        let glogits_buf = rt.buf_from_literal(&loss_out[1])?;
        copy_ms += t.ms();

        // -- bwd layer2
        let b2_out = run_stage(4, &[&h1_buf, &f1_buf, &glogits_buf,
                                    &pbufs[3], &pbufs[4]])?;
        let t = Timer::start();
        let gh1_buf = rt.buf_from_literal(&b2_out[3])?;
        copy_ms += t.ms();

        // -- bwd layer1
        let b1_out = run_stage(5, &[&xf1_buf, &block_buf, &s2_buf, &h1_buf,
                                    &gh1_buf, &pbufs[0], &pbufs[1],
                                    &pbufs[2]])?;

        // -- adamw
        let t = Timer::start();
        let grads = [&b1_out[0], &b1_out[1], &b1_out[2], &b2_out[0],
                     &b2_out[1], &b2_out[2]];
        let mut abufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(25);
        for l in params.iter() {
            abufs.push(rt.buf_from_literal(l)?);
        }
        for g in grads {
            abufs.push(rt.buf_from_literal(g)?);
        }
        for l in mstate.iter().chain(vstate.iter()) {
            abufs.push(rt.buf_from_literal(l)?);
        }
        abufs.push(rt.buf_scalar_f32(step as f32)?);
        copy_ms += t.ms();
        let a_out = run_stage(6, &abufs.iter().collect::<Vec<_>>())?;

        // state update
        let mut a_out = a_out;
        let vs = a_out.split_off(2 * np);
        let ms_ = a_out.split_off(np);
        params = a_out;
        mstate = ms_;
        vstate = vs;

        row_ms[1] = copy_ms;
        if timed {
            for (i, v) in row_ms.iter().enumerate() {
                samples[i].push(*v);
            }
        }
    }

    // summarize
    let medians: Vec<f64> =
        samples.iter().map(|s| summarize(s).median).collect();
    let total: f64 = medians.iter().sum();
    let calls = [1u32, 9, 1, 1, 1, 1, 1, 1, 1];
    let mut rows: Vec<ProfileRow> = row_names
        .iter()
        .zip(&medians)
        .zip(&calls)
        .map(|((n, m), c)| ProfileRow {
            name: n.to_string(),
            median_ms: *m,
            pct: 100.0 * m / total.max(1e-12),
            calls: *c,
        })
        .collect();
    rows.sort_by(|a, b| b.median_ms.partial_cmp(&a.median_ms).unwrap());

    Ok(ProfileReport { rows, total_ms: total, dataset: ds_name, steps })
}

fn lit(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let l = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(l);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(l.reshape(&dims)?)
}
