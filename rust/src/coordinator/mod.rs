//! Training-loop coordinator — variant dispatch, batching, measurement.
//!
//! This is the L3 driver of the paper's benchmark protocol (§5): for each
//! configuration it runs `warmup` untimed steps then `steps` timed steps,
//! where one step = (host sampling for the baseline) + per-step uploads +
//! one synchronized train-step dispatch + parameter-state update. Both
//! variants share seed order, base-seed schedule, and dataset, so every
//! comparison is paired (DESIGN.md §5).
//!
//! The host half of the step runs through [`pipeline`]: batches are built
//! by a sharded multi-threaded sampler (`TrainConfig::threads`) and can be
//! prefetched on a background worker so sampling of step *t+1* overlaps
//! the dispatch of step *t* (`TrainConfig::prefetch`, SALIENT-style).
//! Seed order, base-seed schedule, and sampled neighborhoods are bitwise
//! unchanged by either knob.

pub mod pipeline;
pub mod profile;

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::gen::{builtin_spec, Dataset, Split};
use crate::memory::{self, MemoryMeter, StepDims};
use crate::metrics::Timer;
use crate::rng::mix;
use crate::runtime::{init_params, Executable, Runtime};
use crate::sampler::{self, ParallelSampler};
use crate::xla;

pub use pipeline::{BatchPrefetcher, BatchScheduler, HostWork, PreparedBatch};

/// Which pipeline a trainer drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// FuseSampleAgg: sampling happens inside the fused kernel.
    Fsa,
    /// DGL-like baseline: host sampling → materialized blocks → SAGEConv.
    Dgl,
}

impl Variant {
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Fsa => "fsa",
            Variant::Dgl => "dgl",
        }
    }
}

/// One training configuration (a row of the paper's grid).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub variant: Variant,
    pub hops: u32,
    pub dataset: String,
    pub k1: usize,
    pub k2: usize,
    pub batch: usize,
    pub amp: bool,
    pub save_indices: bool,
    /// Repeat seed (paper uses {42, 43, 44}).
    pub seed: u64,
    /// Host sampler worker threads (0 = auto-detect, 1 = serial legacy
    /// path). Output is bitwise identical at any value.
    pub threads: usize,
    /// Overlap host sampling of step t+1 with dispatch of step t on a
    /// background worker (double-buffered prefetch).
    pub prefetch: bool,
}

impl TrainConfig {
    pub fn artifact_variant(&self) -> String {
        let base = match self.variant {
            Variant::Fsa => "fsa",
            Variant::Dgl => "dgl",
        };
        format!("{base}{}", self.hops)
    }

    /// What the host pipeline must prepare per step for this variant.
    pub fn host_work(&self) -> HostWork {
        match (self.variant, self.hops) {
            (Variant::Dgl, 2) => HostWork::Block2,
            (Variant::Dgl, _) => HostWork::Block1,
            (Variant::Fsa, _) => HostWork::SeedsOnly,
        }
    }
}

/// Timing breakdown of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Host-side neighbor sampling on the critical path (baseline only).
    /// With prefetch on this is the time the step *blocked* waiting for
    /// its batch, not the full sampling cost — see `sample_overlap_ms`.
    pub sample_ms: f64,
    /// Host sampling wall-clock that ran overlapped with the previous
    /// step's dispatch (prefetch on; 0 otherwise). Not on the critical
    /// path and excluded from [`StepTiming::total_ms`].
    pub sample_overlap_ms: f64,
    /// Per-step uploads: params/opt-state re-upload + batch tensors.
    pub upload_ms: f64,
    /// Synchronized executable dispatch (fwd+bwd+optimizer).
    pub execute_ms: f64,
    /// Output literal handling (tuple decomposition, loss read-back).
    pub post_ms: f64,
    /// Training loss after this step.
    pub loss: f64,
    /// Raw sampled (seed, neighbor) pairs this step (counted untimed).
    pub pairs: u64,
    /// Peak transient bytes this step (measured uploads/outputs + analytic
    /// executable intermediates).
    pub transient_bytes: u64,
}

impl StepTiming {
    /// The paper's primary metric: full synchronized step wall-clock.
    pub fn total_ms(&self) -> f64 {
        self.sample_ms + self.upload_ms + self.execute_ms + self.post_ms
    }
}

/// Cache of generated datasets (generation is deterministic but costly).
/// Datasets are `Arc`-shared so the prefetch worker can sample them from
/// its own thread.
#[derive(Default)]
pub struct DatasetCache {
    map: HashMap<String, Arc<Dataset>>,
}

impl DatasetCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&mut self, rt: &Runtime, name: &str) -> Result<Arc<Dataset>> {
        if let Some(d) = self.map.get(name) {
            return Ok(d.clone());
        }
        // manifest spec is authoritative; fall back to the builtin table
        let spec = rt
            .manifest
            .datasets
            .get(name)
            .cloned()
            .map_or_else(|| builtin_spec(name), Ok)?;
        let ds = Arc::new(Dataset::generate(spec)?);
        self.map.insert(name.to_string(), ds.clone());
        Ok(ds)
    }
}

/// A live training session for one configuration.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub cfg: TrainConfig,
    exe: Rc<Executable>,
    pub ds: Arc<Dataset>,
    // static device buffers
    rowptr_buf: Option<xla::PjRtBuffer>,
    col_buf: Option<xla::PjRtBuffer>,
    x_buf: xla::PjRtBuffer,
    // host-side model state (re-uploaded each step; both variants pay this)
    params: Vec<xla::Literal>,
    mstate: Vec<xla::Literal>,
    vstate: Vec<xla::Literal>,
    pub step_count: usize,
    // host batch pipeline
    sched: BatchScheduler,
    sampler: ParallelSampler,
    prefetcher: Option<BatchPrefetcher>,
    pub meter: MemoryMeter,
    dims: StepDims,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cache: &mut DatasetCache,
               cfg: TrainConfig) -> Result<Trainer<'rt>> {
        let name = rt.manifest.find_train(
            &cfg.artifact_variant(), &cfg.dataset, cfg.k1, cfg.k2,
            cfg.batch, cfg.amp, cfg.save_indices)?.name.clone();
        Self::new_named(rt, cache, cfg, &name)
    }

    /// Build a trainer on an explicit artifact (e.g. a §Perf tile variant)
    /// whose dims must match `cfg`.
    pub fn new_named(rt: &'rt Runtime, cache: &mut DatasetCache,
                     cfg: TrainConfig, artifact: &str) -> Result<Trainer<'rt>> {
        let exe = rt.load(artifact)?;
        let ds = cache.get(rt, &cfg.dataset)?;

        // static uploads (graph + features live on device, like DGL)
        let n = ds.spec.n;
        let needs_graph = cfg.variant == Variant::Fsa;
        let rowptr_buf = if needs_graph {
            Some(rt.buf_i32(&ds.graph.rowptr, &[n + 1])?)
        } else {
            None
        };
        let col_buf = if needs_graph {
            Some(rt.buf_i32(&ds.graph.col, &[ds.graph.e_cap()])?)
        } else {
            None
        };
        // feature dtype follows the artifact contract (the fused 2-hop
        // kernel dispatches on it — paper §4; bf16 halves gather traffic)
        let x_dtype = exe
            .spec
            .inputs
            .iter()
            .find(|t| t.name == "x")
            .map(|t| t.dtype)
            .unwrap_or(crate::runtime::Dtype::F32);
        let x_buf = match x_dtype {
            crate::runtime::Dtype::Bf16 => {
                rt.buf_bf16_from_f32(&ds.features, &[n, ds.spec.d])?
            }
            _ => rt.buf_f32(&ds.features, &[n, ds.spec.d])?,
        };

        // deterministic parameter init (identical across variants' seeds)
        let np = exe.spec.n_params();
        let pspecs = &exe.spec.inputs[..np];
        let values = init_params(pspecs, cfg.seed);
        let mut params = Vec::with_capacity(np);
        let mut mstate = Vec::with_capacity(np);
        let mut vstate = Vec::with_capacity(np);
        for (s, vals) in pspecs.iter().zip(&values) {
            params.push(lit_f32(vals, &s.shape)?);
            mstate.push(lit_f32(&vec![0.0; vals.len()], &s.shape)?);
            vstate.push(lit_f32(&vec![0.0; vals.len()], &s.shape)?);
        }

        let sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed)?;
        let sampler = ParallelSampler::new(cfg.threads);
        let prefetcher = cfg.prefetch.then(|| {
            BatchPrefetcher::spawn(ds.clone(), cfg.host_work(), cfg.k1,
                                   cfg.k2, cfg.threads)
        });

        let dims = StepDims {
            batch: cfg.batch,
            k1: cfg.k1,
            k2: cfg.k2,
            d: ds.spec.d,
            hidden: rt.manifest.hidden,
            classes: ds.spec.c,
            tile: exe.spec.tile,
        };

        Ok(Trainer {
            rt,
            cfg,
            exe,
            ds,
            rowptr_buf,
            col_buf,
            x_buf,
            params,
            mstate,
            vstate,
            step_count: 0,
            sched,
            sampler,
            prefetcher,
            meter: MemoryMeter::new(),
            dims,
        })
    }

    /// Next batch of seed nodes (reshuffles at epoch boundaries; identical
    /// order across variants for the same seed). Draws from the shared
    /// scheduler — mixing manual draws with prefetching degrades the
    /// prefetcher to the synchronous path (see [`Trainer::acquire_batch`]).
    pub fn next_batch(&mut self) -> Vec<i32> {
        self.sched.next_seeds()
    }

    /// Per-step base seed: shared schedule across variants so both sample
    /// the same neighborhoods at the same step (paired comparisons).
    pub fn step_base_seed(&self) -> u64 {
        mix(self.cfg.seed.wrapping_add(self.step_count as u64))
    }

    /// Run one training step; returns the timing breakdown.
    pub fn step(&mut self) -> Result<StepTiming> {
        let prepared = self.acquire_batch()?;
        self.step_prepared(prepared)
    }

    /// Run one step on explicit seeds (used by tests and the e2e example).
    /// Always samples synchronously; does not consume the scheduler.
    pub fn step_with_seeds(&mut self, seeds: &[i32]) -> Result<StepTiming> {
        let prepared = pipeline::prepare_batch(
            &self.ds, self.cfg.host_work(), self.cfg.k1, self.cfg.k2,
            &self.sampler, self.step_count, seeds.to_vec(),
            self.step_base_seed());
        self.step_prepared(prepared)
    }

    /// Obtain the batch for the current step — synchronously, or from the
    /// double-buffered prefetch worker (keeping one batch in flight behind
    /// the one being consumed so sampling overlaps dispatch).
    fn acquire_batch(&mut self) -> Result<PreparedBatch> {
        if let Some(p) = &mut self.prefetcher {
            let prepared = p.next_batch(&mut self.sched)?;
            if prepared.step == self.step_count {
                return Ok(prepared);
            }
            // Schedule desync: explicit-seed steps advanced `step_count`
            // past the prefetched stream. Keep the seed order (the drawn
            // batch is still next) but resample synchronously with the
            // base seed the legacy schedule mandates for this step.
            return Ok(pipeline::prepare_batch(
                &self.ds, self.cfg.host_work(), self.cfg.k1, self.cfg.k2,
                &self.sampler, self.step_count, prepared.seeds,
                self.step_base_seed()));
        }
        let seeds = self.sched.next_seeds();
        Ok(pipeline::prepare_batch(
            &self.ds, self.cfg.host_work(), self.cfg.k1, self.cfg.k2,
            &self.sampler, self.step_count, seeds, self.step_base_seed()))
    }

    /// Upload, dispatch, and account one prepared batch.
    fn step_prepared(&mut self, prepared: PreparedBatch) -> Result<StepTiming> {
        let mut t = StepTiming::default();
        let base = prepared.base;
        let b = self.cfg.batch;
        let seeds: &[i32] = &prepared.seeds;
        if seeds.len() != b {
            bail!("expected {b} seeds, got {}", seeds.len());
        }
        let labels: &[i32] = &prepared.labels;
        let block1: Option<&sampler::Block1> = prepared.block1.as_ref();
        let block2: Option<&sampler::Block2> = prepared.block2.as_ref();
        match prepared.wait_ms {
            // synchronous build: sampling is the critical path
            None => t.sample_ms = prepared.sample_ms,
            // prefetched: only the wait is critical; the build overlapped
            Some(wait) => {
                t.sample_ms = wait;
                t.sample_overlap_ms = prepared.sample_ms;
            }
        }
        self.meter.reset_step();

        // ---- 2. per-step uploads (params/opt state + batch tensors);
        // static buffers (graph, features) are passed by reference.
        let timer = Timer::start();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(24);
        let mut upload_bytes = 0u64;
        for lit in self.params.iter().chain(&self.mstate).chain(&self.vstate) {
            owned.push(self.rt.buf_from_literal(lit)?);
            upload_bytes += lit.size_bytes() as u64;
        }
        owned.push(self.rt.buf_scalar_f32(self.step_count as f32)?);
        upload_bytes += 4;

        // (owned-index | static-ref) arg plan, in manifest input order
        enum Arg {
            Owned(usize),
            Rowptr,
            Col,
            X,
        }
        let mut plan: Vec<Arg> = (0..owned.len()).map(Arg::Owned).collect();
        match (self.cfg.variant, self.cfg.hops) {
            (Variant::Fsa, _) => {
                plan.push(Arg::Rowptr);
                plan.push(Arg::Col);
                plan.push(Arg::X);
                owned.push(self.rt.buf_i32(seeds, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(labels, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_u64(&[base], &[1])?);
                plan.push(Arg::Owned(owned.len() - 1));
                upload_bytes += (2 * b * 4 + 8) as u64;
            }
            (Variant::Dgl, 2) => {
                let blk = block2.expect("pipeline prepared no 2-hop block");
                let f1w = 1 + self.cfg.k1;
                plan.push(Arg::X);
                owned.push(self.rt.buf_i32(&blk.f1, &[b, f1w])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(&blk.s2, &[b, f1w, self.cfg.k2])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(labels, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                upload_bytes +=
                    (blk.f1.len() * 4 + blk.s2.len() * 4 + b * 4) as u64;
            }
            (Variant::Dgl, _) => {
                let blk = block1.expect("pipeline prepared no 1-hop block");
                let f1w = 1 + self.cfg.k1;
                plan.push(Arg::X);
                owned.push(self.rt.buf_i32(&blk.f1, &[b, f1w])?);
                plan.push(Arg::Owned(owned.len() - 1));
                owned.push(self.rt.buf_i32(labels, &[b])?);
                plan.push(Arg::Owned(owned.len() - 1));
                upload_bytes += (blk.f1.len() * 4 + b * 4) as u64;
            }
        }
        let args: Vec<&xla::PjRtBuffer> = plan
            .iter()
            .map(|a| match a {
                Arg::Owned(i) => &owned[*i],
                Arg::Rowptr => self.rowptr_buf.as_ref().unwrap(),
                Arg::Col => self.col_buf.as_ref().unwrap(),
                Arg::X => &self.x_buf,
            })
            .collect();
        t.upload_ms = timer.ms();
        self.meter.alloc(upload_bytes);

        // ---- 3. synchronized dispatch (fwd + bwd + AdamW in one artifact)
        let timer = Timer::start();
        let outputs = self.exe.run(&args).context("train step dispatch")?;
        t.execute_ms = timer.ms();

        // ---- 4. state update + loss read-back
        let timer = Timer::start();
        let np = self.exe.spec.n_params();
        let mut outputs = outputs;
        let loss_lit = outputs.pop().unwrap();
        t.loss = loss_lit.get_first_element::<f32>()? as f64;
        let vs = outputs.split_off(2 * np);
        let ms = outputs.split_off(np);
        self.params = outputs;
        self.mstate = ms;
        self.vstate = vs;
        t.post_ms = timer.ms();

        // transient accounting: measured uploads/outputs + analytic
        // executable intermediates (DESIGN.md §3 meter)
        let analytic = match (self.cfg.variant, self.cfg.hops) {
            (Variant::Dgl, 2) => memory::baseline2_transient(&self.dims),
            (Variant::Dgl, _) => memory::baseline1_transient(&self.dims),
            (Variant::Fsa, 2) => {
                memory::fused2_transient(&self.dims, self.cfg.save_indices)
            }
            (Variant::Fsa, _) => {
                memory::fused1_transient(&self.dims, self.cfg.save_indices)
            }
        };
        self.meter.alloc(analytic.intermediates + self.exe.spec.output_bytes());
        t.transient_bytes = self.meter.peak();
        self.meter.reset_peak();
        self.meter.reset_step();

        // untimed: raw sampled-pair count (paper's auxiliary metric)
        t.pairs = match (self.cfg.variant, self.cfg.hops) {
            (Variant::Dgl, 2) => {
                sampler::block2_sampled_pairs(block2.unwrap())
            }
            (Variant::Dgl, _) => {
                let blk = block1.unwrap();
                let f1w = 1 + self.cfg.k1;
                (0..b)
                    .map(|bi| sampler::valid_pairs(
                        &blk.f1[bi * f1w + 1..(bi + 1) * f1w]))
                    .sum()
            }
            (Variant::Fsa, 2) => sampler::fused2_sampled_pairs(
                &self.ds.graph, seeds, self.cfg.k1, self.cfg.k2, base),
            (Variant::Fsa, _) => {
                let s1 = sampler::sample_frontier(
                    &self.ds.graph, seeds, self.cfg.k1, base, 0);
                sampler::valid_pairs(&s1)
            }
        };

        self.step_count += 1;
        Ok(t)
    }

    /// Current parameter literals (for eval / checkpoint inspection).
    pub fn params(&self) -> &[xla::Literal] {
        &self.params
    }

    /// Validation accuracy via the dataset's eval artifact (matching the
    /// trainer's variant — fused forward for Fsa, block forward for Dgl).
    pub fn evaluate(&self, max_nodes: usize) -> Result<f64> {
        evaluate_params(self.rt, &self.ds, self.cfg.variant, &self.params,
                        self.cfg.seed, max_nodes)
    }
}

/// Validation accuracy of a parameter set using the dataset's
/// `{fsa2|dgl2}_eval_*` artifact.
pub fn evaluate_params(rt: &Runtime, ds: &Dataset, variant: Variant,
                       params: &[xla::Literal], seed: u64,
                       max_nodes: usize) -> Result<f64> {
    let name = format!("{}2_eval_{}_f15x10_b512", variant.as_str(),
                       ds.spec.name);
    let exe = rt.load(&name)?;
    let (b, k1, k2) = (exe.spec.batch, exe.spec.k1, exe.spec.k2);
    let mut nodes = ds.split_nodes(Split::Val);
    nodes.truncate(max_nodes.max(b));
    let eval_base = mix(seed ^ 0xEAE1);
    let rowptr = rt.buf_i32(&ds.graph.rowptr, &[ds.spec.n + 1])?;
    let col = rt.buf_i32(&ds.graph.col, &[ds.graph.e_cap()])?;
    let x = rt.buf_f32(&ds.features, &[ds.spec.n, ds.spec.d])?;

    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in nodes.chunks(b) {
        let mut seeds = chunk.to_vec();
        let real = seeds.len();
        seeds.resize(b, chunk[0]); // pad; padded rows ignored below
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(10);
        for lit in params {
            owned.push(rt.buf_from_literal(lit)?);
        }
        let np = owned.len();
        let out = match variant {
            Variant::Fsa => {
                owned.push(rt.buf_i32(&seeds, &[b])?);
                owned.push(rt.buf_u64(&[eval_base], &[1])?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    owned[..np].iter().collect();
                args.push(&rowptr);
                args.push(&col);
                args.push(&x);
                args.push(&owned[np]);
                args.push(&owned[np + 1]);
                exe.run(&args)?
            }
            Variant::Dgl => {
                let blk = sampler::build_block2(&ds.graph, &seeds, k1, k2,
                                                eval_base);
                owned.push(rt.buf_i32(&blk.f1, &[b, 1 + k1])?);
                owned.push(rt.buf_i32(&blk.s2, &[b, 1 + k1, k2])?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    owned[..np].iter().collect();
                args.push(&x);
                args.push(&owned[np]);
                args.push(&owned[np + 1]);
                exe.run(&args)?
            }
        };
        let logits = out[0].to_vec::<f32>()?;
        let c = ds.spec.c;
        for (i, &u) in chunk.iter().enumerate().take(real) {
            let row = &logits[i * c..(i + 1) * c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 == ds.labels[u as usize] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Warmup + timed measurement loop (the paper's protocol, §5).
pub fn measure(trainer: &mut Trainer, warmup: usize, steps: usize)
               -> Result<Vec<StepTiming>> {
    for _ in 0..warmup {
        trainer.step()?;
    }
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        out.push(trainer.step()?);
    }
    Ok(out)
}

fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}
