//! Training-loop coordinator — variant dispatch, batching, measurement.
//!
//! This is the L3 driver of the paper's benchmark protocol (§5): for each
//! configuration it runs `warmup` untimed steps then `steps` timed steps,
//! where one step = (host sampling for the baseline) + per-step uploads +
//! one synchronized train-step dispatch + parameter-state update. Both
//! variants share seed order, base-seed schedule, and dataset, so every
//! comparison is paired (DESIGN.md §5).
//!
//! Depth is configuration, not code: a [`TrainConfig`] carries an ordered
//! [`Fanouts`] list and the whole stack — host sampling, kernels, model
//! width, eval protocol — follows its depth.
//!
//! Session state — dataset handle, parameters, optimizer state, planner
//! model + persistence, backend dispatch, RNG schedule — lives in the
//! [`Engine`] facade ([`crate::engine`]); [`Trainer`] is the training
//! loop driving [`Engine::step`], and derefs to the engine so the whole
//! session API (`step`, `evaluate`, `infer`, `save_params`, …) is
//! available on it. The serving loop ([`crate::serve`]) drives the same
//! engine through [`Engine::infer`] instead.
//!
//! The host half of the step runs through [`pipeline`]: batches are built
//! by a sharded multi-threaded sampler (`TrainConfig::threads`) and can be
//! prefetched on a background worker so sampling of step *t+1* overlaps
//! the dispatch of step *t* (`TrainConfig::prefetch`, SALIENT-style).
//! Seed order, base-seed schedule, and sampled neighborhoods are bitwise
//! unchanged by either knob.
//!
//! The dispatch half goes through the [`Backend`] seam
//! (`TrainConfig::backend`): `Pjrt` runs the AOT artifact (depth ≤ 2 —
//! the manifest only defines 1- and 2-hop graphs), `Native` runs the
//! in-crate CPU engine ([`crate::kernel`]) at any depth, and `Auto`
//! (default) tries PJRT and falls back to native — so training works
//! end-to-end with no artifacts and no PJRT bindings.
//!
//! [`Backend`]: crate::runtime::backend::Backend

pub mod pipeline;
pub mod profile;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::fanout::Fanouts;
use crate::gen::{builtin_spec, Dataset};
use crate::graph::PlannerChoice;
use crate::kernel::{FeatureLayout, NativeConfig, SimdChoice};
use crate::runtime::backend::BackendChoice;
use crate::runtime::faults::FaultPlane;
use crate::runtime::Runtime;

pub use crate::engine::{evaluate_params, Engine};
pub use pipeline::{BatchPrefetcher, BatchScheduler, HostWork, PreparedBatch};

/// Which pipeline a trainer drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// FuseSampleAgg: sampling happens inside the fused kernel.
    Fsa,
    /// DGL-like baseline: host sampling → materialized blocks → SAGEConv.
    Dgl,
}

impl Variant {
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Fsa => "fsa",
            Variant::Dgl => "dgl",
        }
    }
}

/// One training configuration (a row of the paper's grid).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub variant: Variant,
    pub dataset: String,
    /// Ordered per-hop fanouts; `fanouts.depth()` is the number of hops
    /// (and, for the baseline, SAGE layers).
    pub fanouts: Fanouts,
    pub batch: usize,
    pub amp: bool,
    pub save_indices: bool,
    /// Repeat seed (paper uses {42, 43, 44}).
    pub seed: u64,
    /// Host sampler worker threads (0 = auto-detect, 1 = serial legacy
    /// path). Output is bitwise identical at any value.
    pub threads: usize,
    /// Overlap host sampling of step t+1 with dispatch of step t on a
    /// background worker (double-buffered prefetch).
    pub prefetch: bool,
    /// Execution backend (default [`BackendChoice::Auto`]: PJRT when an
    /// artifact compiles, native CPU engine otherwise).
    pub backend: BackendChoice,
    /// Shard-planner cost model (`--planner`; default quantile). Outputs
    /// are bitwise identical under every flavor — only shard balance,
    /// and with it step time, moves.
    pub planner: PlannerChoice,
    /// Planner-state persistence file (`--planner-state <path|off>`):
    /// the adaptive flavor warm-starts its per-worker weights from this
    /// file at startup and saves them back at shutdown. `None` = off;
    /// the other flavors have no learned state and ignore it. Cuts may
    /// differ across sessions because of it — sampled values never do.
    pub planner_state: Option<PathBuf>,
    /// Fault-injection plane (`--chaos <spec>`); [`crate::runtime::
    /// faults::none`] in production, where every hook is a no-op.
    /// Installed into the session cost model so kernel and sampler
    /// workers observe the same scripted schedule.
    pub faults: Arc<dyn FaultPlane>,
    /// Native-kernel vector tier (`--simd auto|on|off`). Outputs are
    /// bitwise identical either way (lanes run across the feature
    /// dimension, never across neighbors) — only step time moves.
    pub simd: SimdChoice,
    /// Feature-row storage order (`--layout natural|degree`). `degree`
    /// permutes rows into degree-descending order behind an index map;
    /// node IDs, RNG draws, saved indices, and planner costs are
    /// untouched, so outputs are bitwise identical.
    pub layout: FeatureLayout,
    /// Hub-aggregate cache refresh budget (`--hub-cache off|N`):
    /// `None` = off, `Some(n)` = cache leaf-hop hub aggregates and
    /// refresh at most `n` entries per seed epoch. Outputs are bitwise
    /// identical either way — only gather time moves.
    pub hub_cache: Option<usize>,
}

impl TrainConfig {
    /// Sampling depth (hops = baseline SAGE layers).
    pub fn hops(&self) -> u32 {
        self.fanouts.depth() as u32
    }

    pub fn artifact_variant(&self) -> String {
        let base = match self.variant {
            Variant::Fsa => "fsa",
            Variant::Dgl => "dgl",
        };
        format!("{base}{}", self.fanouts.depth())
    }

    /// What the host pipeline must prepare per step for this variant.
    pub fn host_work(&self) -> HostWork {
        match self.variant {
            Variant::Dgl => HostWork::Block,
            Variant::Fsa => HostWork::SeedsOnly,
        }
    }

    /// The native-engine view of this configuration.
    pub fn native_config(&self, hidden: usize) -> NativeConfig {
        NativeConfig {
            fused: self.variant == Variant::Fsa,
            fanouts: self.fanouts.clone(),
            amp: self.amp,
            save_indices: self.save_indices,
            seed: self.seed,
            threads: self.threads,
            planner: self.planner,
            faults: self.faults.clone(),
            hidden,
            simd: self.simd,
            layout: self.layout,
            hub_cache: self.hub_cache,
        }
    }
}

/// Timing breakdown of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Host-side neighbor sampling on the critical path (baseline only).
    /// With prefetch on this is the time the step *blocked* waiting for
    /// its batch, not the full sampling cost — see `sample_overlap_ms`.
    pub sample_ms: f64,
    /// Host sampling wall-clock that ran overlapped with the previous
    /// step's dispatch (prefetch on; 0 otherwise). Not on the critical
    /// path and excluded from [`StepTiming::total_ms`].
    pub sample_overlap_ms: f64,
    /// Per-step uploads: params/opt-state re-upload + batch tensors
    /// (0 on the native backend — nothing crosses a bus).
    pub upload_ms: f64,
    /// Synchronized dispatch (fwd+bwd+optimizer) — real compute on the
    /// native backend, executable dispatch on PJRT.
    pub execute_ms: f64,
    /// Output literal handling (tuple decomposition, loss read-back).
    pub post_ms: f64,
    /// Training loss after this step.
    pub loss: f64,
    /// Raw sampled (seed, neighbor) pairs this step (counted untimed).
    pub pairs: u64,
    /// Peak transient bytes this step — measured allocations on the
    /// native backend; measured uploads/outputs + analytic executable
    /// intermediates on PJRT.
    pub transient_bytes: u64,
    /// Measured shard-imbalance ratio of this step's sharded host pass
    /// (max/mean per-shard wall time): the fused kernel's batch shards
    /// when the native engine sharded, else the sampler's block shards.
    /// 1.0 = balanced or serial.
    pub imbalance: f64,
    /// Hub-cache leaf-hop lookups served from the cache this step
    /// (0 when `--hub-cache off`).
    pub hub_hits: u64,
    /// Leaf-hop lookups the cache could not serve (non-hub nodes,
    /// evicted or not-yet-refreshed entries; 0 when off).
    pub hub_misses: u64,
    /// Cache entries (re)built by this step's refresh budget pre-pass.
    pub hub_refreshes: u64,
}

impl StepTiming {
    /// The paper's primary metric: full synchronized step wall-clock.
    pub fn total_ms(&self) -> f64 {
        self.sample_ms + self.upload_ms + self.execute_ms + self.post_ms
    }
}

/// Cache of generated datasets (generation is deterministic but costly).
/// Datasets are `Arc`-shared so the prefetch worker can sample them from
/// its own thread.
#[derive(Default)]
pub struct DatasetCache {
    map: HashMap<String, Arc<Dataset>>,
}

impl DatasetCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&mut self, rt: &Runtime, name: &str) -> Result<Arc<Dataset>> {
        if let Some(d) = self.map.get(name) {
            return Ok(d.clone());
        }
        // manifest spec is authoritative; fall back to the builtin table
        let spec = rt
            .manifest
            .datasets
            .get(name)
            .cloned()
            .map_or_else(|| builtin_spec(name), Ok)?;
        let ds = Arc::new(Dataset::generate(spec)?);
        self.map.insert(name.to_string(), ds.clone());
        Ok(ds)
    }
}

/// A live training session: the training loop over an [`Engine`].
///
/// The trainer owns nothing but the engine — params, graph buffers,
/// planner state, and the RNG schedule all belong to the facade. It
/// derefs to [`Engine`], so `trainer.step()`, `trainer.evaluate(..)`,
/// `trainer.cfg`, `trainer.ds`, … all resolve to the engine's fields
/// and methods unchanged.
pub struct Trainer<'rt> {
    engine: Engine<'rt>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cache: &mut DatasetCache,
               cfg: TrainConfig) -> Result<Trainer<'rt>> {
        Ok(Trainer { engine: Engine::new(rt, cache, cfg)? })
    }

    /// Build a trainer on an explicit PJRT artifact (e.g. a §Perf tile
    /// variant) whose dims must match `cfg`.
    pub fn new_named(rt: &'rt Runtime, cache: &mut DatasetCache,
                     cfg: TrainConfig, artifact: &str) -> Result<Trainer<'rt>> {
        Ok(Trainer { engine: Engine::new_named(rt, cache, cfg, artifact)? })
    }

    /// The session engine this loop drives.
    pub fn engine(&self) -> &Engine<'rt> {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine<'rt> {
        &mut self.engine
    }

    /// Hand the session over (e.g. train, then serve the same weights
    /// in-process without a checkpoint round trip).
    pub fn into_engine(self) -> Engine<'rt> {
        self.engine
    }
}

impl<'rt> std::ops::Deref for Trainer<'rt> {
    type Target = Engine<'rt>;

    fn deref(&self) -> &Engine<'rt> {
        &self.engine
    }
}

impl std::ops::DerefMut for Trainer<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.engine
    }
}

/// Warmup + timed measurement loop (the paper's protocol, §5).
pub fn measure(trainer: &mut Trainer, warmup: usize, steps: usize)
               -> Result<Vec<StepTiming>> {
    for _ in 0..warmup {
        trainer.step()?;
    }
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        out.push(trainer.step()?);
    }
    Ok(out)
}
