//! Training-loop coordinator — variant dispatch, batching, measurement.
//!
//! This is the L3 driver of the paper's benchmark protocol (§5): for each
//! configuration it runs `warmup` untimed steps then `steps` timed steps,
//! where one step = (host sampling for the baseline) + per-step uploads +
//! one synchronized train-step dispatch + parameter-state update. Both
//! variants share seed order, base-seed schedule, and dataset, so every
//! comparison is paired (DESIGN.md §5).
//!
//! Depth is configuration, not code: a [`TrainConfig`] carries an ordered
//! [`Fanouts`] list and the whole stack — host sampling, kernels, model
//! width, eval protocol — follows its depth.
//!
//! The host half of the step runs through [`pipeline`]: batches are built
//! by a sharded multi-threaded sampler (`TrainConfig::threads`) and can be
//! prefetched on a background worker so sampling of step *t+1* overlaps
//! the dispatch of step *t* (`TrainConfig::prefetch`, SALIENT-style).
//! Seed order, base-seed schedule, and sampled neighborhoods are bitwise
//! unchanged by either knob.
//!
//! The dispatch half goes through the [`Backend`] seam
//! (`TrainConfig::backend`): `Pjrt` runs the AOT artifact (depth ≤ 2 —
//! the manifest only defines 1- and 2-hop graphs), `Native` runs the
//! in-crate CPU engine ([`crate::kernel`]) at any depth, and `Auto`
//! (default) tries PJRT and falls back to native — so training works
//! end-to-end with no artifacts and no PJRT bindings.

pub mod pipeline;
pub mod profile;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::fanout::Fanouts;
use crate::gen::{builtin_spec, Dataset, Split};
use crate::graph::cost::shared_session_model;
use crate::graph::state::{unix_now, PlannerState, StateEntry, StateKey};
use crate::graph::{lock_model, PlannerChoice, SharedCostModel};
use crate::kernel::{NativeBackend, NativeConfig};
use crate::memory::MemoryMeter;
use crate::rng::mix;
use crate::runtime::backend::{ensure_pjrt_depth, Backend, BackendChoice,
                              PjrtBackend, StepInputs};
use crate::runtime::Runtime;
use crate::sampler::{self, ParallelSampler};
use crate::xla;

pub use pipeline::{BatchPrefetcher, BatchScheduler, HostWork, PreparedBatch};

/// Which pipeline a trainer drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// FuseSampleAgg: sampling happens inside the fused kernel.
    Fsa,
    /// DGL-like baseline: host sampling → materialized blocks → SAGEConv.
    Dgl,
}

impl Variant {
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Fsa => "fsa",
            Variant::Dgl => "dgl",
        }
    }
}

/// One training configuration (a row of the paper's grid).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub variant: Variant,
    pub dataset: String,
    /// Ordered per-hop fanouts; `fanouts.depth()` is the number of hops
    /// (and, for the baseline, SAGE layers).
    pub fanouts: Fanouts,
    pub batch: usize,
    pub amp: bool,
    pub save_indices: bool,
    /// Repeat seed (paper uses {42, 43, 44}).
    pub seed: u64,
    /// Host sampler worker threads (0 = auto-detect, 1 = serial legacy
    /// path). Output is bitwise identical at any value.
    pub threads: usize,
    /// Overlap host sampling of step t+1 with dispatch of step t on a
    /// background worker (double-buffered prefetch).
    pub prefetch: bool,
    /// Execution backend (default [`BackendChoice::Auto`]: PJRT when an
    /// artifact compiles, native CPU engine otherwise).
    pub backend: BackendChoice,
    /// Shard-planner cost model (`--planner`; default quantile). Outputs
    /// are bitwise identical under every flavor — only shard balance,
    /// and with it step time, moves.
    pub planner: PlannerChoice,
    /// Planner-state persistence file (`--planner-state <path|off>`):
    /// the adaptive flavor warm-starts its per-worker weights from this
    /// file at startup and saves them back at shutdown. `None` = off;
    /// the other flavors have no learned state and ignore it. Cuts may
    /// differ across sessions because of it — sampled values never do.
    pub planner_state: Option<PathBuf>,
}

impl TrainConfig {
    /// Sampling depth (hops = baseline SAGE layers).
    pub fn hops(&self) -> u32 {
        self.fanouts.depth() as u32
    }

    pub fn artifact_variant(&self) -> String {
        let base = match self.variant {
            Variant::Fsa => "fsa",
            Variant::Dgl => "dgl",
        };
        format!("{base}{}", self.fanouts.depth())
    }

    /// What the host pipeline must prepare per step for this variant.
    pub fn host_work(&self) -> HostWork {
        match self.variant {
            Variant::Dgl => HostWork::Block,
            Variant::Fsa => HostWork::SeedsOnly,
        }
    }

    /// The native-engine view of this configuration.
    pub fn native_config(&self, hidden: usize) -> NativeConfig {
        NativeConfig {
            fused: self.variant == Variant::Fsa,
            fanouts: self.fanouts.clone(),
            amp: self.amp,
            save_indices: self.save_indices,
            seed: self.seed,
            threads: self.threads,
            planner: self.planner,
            hidden,
        }
    }
}

/// Timing breakdown of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Host-side neighbor sampling on the critical path (baseline only).
    /// With prefetch on this is the time the step *blocked* waiting for
    /// its batch, not the full sampling cost — see `sample_overlap_ms`.
    pub sample_ms: f64,
    /// Host sampling wall-clock that ran overlapped with the previous
    /// step's dispatch (prefetch on; 0 otherwise). Not on the critical
    /// path and excluded from [`StepTiming::total_ms`].
    pub sample_overlap_ms: f64,
    /// Per-step uploads: params/opt-state re-upload + batch tensors
    /// (0 on the native backend — nothing crosses a bus).
    pub upload_ms: f64,
    /// Synchronized dispatch (fwd+bwd+optimizer) — real compute on the
    /// native backend, executable dispatch on PJRT.
    pub execute_ms: f64,
    /// Output literal handling (tuple decomposition, loss read-back).
    pub post_ms: f64,
    /// Training loss after this step.
    pub loss: f64,
    /// Raw sampled (seed, neighbor) pairs this step (counted untimed).
    pub pairs: u64,
    /// Peak transient bytes this step — measured allocations on the
    /// native backend; measured uploads/outputs + analytic executable
    /// intermediates on PJRT.
    pub transient_bytes: u64,
    /// Measured shard-imbalance ratio of this step's sharded host pass
    /// (max/mean per-shard wall time): the fused kernel's batch shards
    /// when the native engine sharded, else the sampler's block shards.
    /// 1.0 = balanced or serial.
    pub imbalance: f64,
}

impl StepTiming {
    /// The paper's primary metric: full synchronized step wall-clock.
    pub fn total_ms(&self) -> f64 {
        self.sample_ms + self.upload_ms + self.execute_ms + self.post_ms
    }
}

/// Cache of generated datasets (generation is deterministic but costly).
/// Datasets are `Arc`-shared so the prefetch worker can sample them from
/// its own thread.
#[derive(Default)]
pub struct DatasetCache {
    map: HashMap<String, Arc<Dataset>>,
}

impl DatasetCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&mut self, rt: &Runtime, name: &str) -> Result<Arc<Dataset>> {
        if let Some(d) = self.map.get(name) {
            return Ok(d.clone());
        }
        // manifest spec is authoritative; fall back to the builtin table
        let spec = rt
            .manifest
            .datasets
            .get(name)
            .cloned()
            .map_or_else(|| builtin_spec(name), Ok)?;
        let ds = Arc::new(Dataset::generate(spec)?);
        self.map.insert(name.to_string(), ds.clone());
        Ok(ds)
    }
}

/// A live training session for one configuration.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub cfg: TrainConfig,
    backend: Box<dyn Backend + 'rt>,
    pub ds: Arc<Dataset>,
    pub step_count: usize,
    // host batch pipeline
    sched: BatchScheduler,
    sampler: ParallelSampler,
    prefetcher: Option<BatchPrefetcher>,
    pub meter: MemoryMeter,
    /// The session-shared planner model (adaptive flavor only): the
    /// fused kernel, the host sampler, and the prefetch thread all plan
    /// and observe through it.
    planner_model: Option<SharedCostModel>,
    /// Where (and under which key) to persist the adaptive weights at
    /// shutdown (`cfg.planner_state`, resolved), plus the
    /// `steps_observed` baseline inherited from the warm start — only
    /// sessions that observed *past* that baseline save, so re-running
    /// without new measurements never refreshes the staleness stamp.
    planner_persist: Option<(PathBuf, StateKey, u64)>,
}

/// One-time note when `Auto` falls back from PJRT to the native engine.
fn note_native_fallback(err: &anyhow::Error) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        eprintln!("note: PJRT backend unavailable ({err:#}); \
                   using the native CPU engine");
    });
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cache: &mut DatasetCache,
               cfg: TrainConfig) -> Result<Trainer<'rt>> {
        let ds = cache.get(rt, &cfg.dataset)?;
        let shared = Self::session_model(&ds, &cfg);
        let backend: Box<dyn Backend + 'rt> = match cfg.backend {
            BackendChoice::Native => Box::new(
                Self::native_backend(rt, &ds, &cfg, shared.clone())?),
            BackendChoice::Pjrt => Box::new(Self::pjrt_backend(rt, &ds,
                                                               &cfg)?),
            BackendChoice::Auto => match Self::pjrt_backend(rt, &ds, &cfg) {
                Ok(b) => Box::new(b),
                Err(e) => {
                    note_native_fallback(&e);
                    Box::new(Self::native_backend(rt, &ds, &cfg,
                                                  shared.clone())?)
                }
            },
        };
        Self::with_backend(rt, cfg, ds, backend, shared)
    }

    /// Build a trainer on an explicit PJRT artifact (e.g. a §Perf tile
    /// variant) whose dims must match `cfg`.
    pub fn new_named(rt: &'rt Runtime, cache: &mut DatasetCache,
                     cfg: TrainConfig, artifact: &str) -> Result<Trainer<'rt>> {
        let ds = cache.get(rt, &cfg.dataset)?;
        let shared = Self::session_model(&ds, &cfg);
        let backend = PjrtBackend::new(
            rt, &ds, artifact, cfg.variant == Variant::Fsa, &cfg.fanouts,
            cfg.batch, cfg.save_indices, cfg.seed)?;
        Self::with_backend(rt, cfg, ds, Box::new(backend), shared)
    }

    /// The session's shared planner model (`Some` for adaptive only —
    /// see [`crate::graph::cost::shared_session_model`]).
    fn session_model(ds: &Arc<Dataset>,
                     cfg: &TrainConfig) -> Option<SharedCostModel> {
        shared_session_model(&ds.graph, &cfg.fanouts, cfg.planner)
    }

    fn pjrt_backend(rt: &'rt Runtime, ds: &Arc<Dataset>,
                    cfg: &TrainConfig) -> Result<PjrtBackend<'rt>> {
        ensure_pjrt_depth(&cfg.fanouts)?;
        let k1 = cfg.fanouts.k(0);
        let k2 = if cfg.fanouts.depth() == 2 { cfg.fanouts.k(1) } else { 0 };
        let name = rt.manifest.find_train(
            &cfg.artifact_variant(), &cfg.dataset, k1, k2,
            cfg.batch, cfg.amp, cfg.save_indices)?.name.clone();
        PjrtBackend::new(rt, ds, &name, cfg.variant == Variant::Fsa,
                         &cfg.fanouts, cfg.batch, cfg.save_indices, cfg.seed)
    }

    fn native_backend(rt: &Runtime, ds: &Arc<Dataset>, cfg: &TrainConfig,
                      shared: Option<SharedCostModel>)
                      -> Result<NativeBackend> {
        let native_cfg = cfg.native_config(rt.manifest.hidden);
        match shared {
            Some(model) => NativeBackend::with_shared_model(
                ds.clone(), native_cfg, rt.manifest.adamw, model),
            None => NativeBackend::new(ds.clone(), native_cfg,
                                       rt.manifest.adamw),
        }
    }

    fn with_backend(rt: &'rt Runtime, cfg: TrainConfig, ds: Arc<Dataset>,
                    backend: Box<dyn Backend + 'rt>,
                    planner_model: Option<SharedCostModel>)
                    -> Result<Trainer<'rt>> {
        let sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed)?;
        let mut sampler =
            ParallelSampler::with_planner(cfg.threads, cfg.planner);
        if let Some(m) = &planner_model {
            sampler = sampler.with_model(m.clone());
        }
        // warm-start before any planning happens, so the very first
        // batch already cuts with the persisted weights
        let planner_persist = Self::load_planner_state(
            &cfg, &sampler, planner_model.as_ref());
        let prefetcher = cfg.prefetch.then(|| {
            // a dedicated sampler for the prefetch thread: same shared
            // model and clock, private imbalance accumulator
            BatchPrefetcher::spawn(ds.clone(), cfg.host_work(),
                                   cfg.fanouts.clone(),
                                   sampler.fresh_stats())
        });
        Ok(Trainer {
            rt,
            cfg,
            backend,
            ds,
            step_count: 0,
            sched,
            sampler,
            prefetcher,
            meter: MemoryMeter::new(),
            planner_model,
            planner_persist,
        })
    }

    /// Warm-start the shared model from `cfg.planner_state` (adaptive
    /// flavor only). Corrupt or mismatched files degrade to uniform
    /// weights with a warning; a found entry is logged so a second run
    /// can be seen to warm-start (the CI smoke greps for it). Returns
    /// the resolved (path, key) to save back to at shutdown.
    fn load_planner_state(cfg: &TrainConfig, sampler: &ParallelSampler,
                          model: Option<&SharedCostModel>)
                          -> Option<(PathBuf, StateKey, u64)> {
        let (path, model) = match (&cfg.planner_state, model) {
            (Some(p), Some(m)) => (p.clone(), m),
            _ => return None,
        };
        // key on the *resolved* worker count (0 = auto is a CLI detail)
        let key = StateKey::for_session(sampler.threads(), cfg.planner);
        let state = PlannerState::load(&path);
        let mut baseline = 0u64;
        if let Some(entry) = state.get(&key) {
            let mut m = lock_model(model);
            if m.warm_start(&entry.weights, entry.steps_observed) {
                baseline = entry.steps_observed;
                eprintln!("planner-state: warm-start from {} \
                           ({} steps observed, weights {:?})",
                          path.display(), entry.steps_observed,
                          entry.weights);
            } else {
                eprintln!("warning: planner-state entry for {} is \
                           unusable; starting from uniform weights",
                          key.as_string());
            }
        }
        Some((path, key, baseline))
    }

    /// Persist the adaptive weights (load-merge-save, preserving other
    /// keys' entries). Called at drop; callable explicitly by tests.
    /// Sessions that observed nothing beyond their warm-start baseline
    /// save nothing — a serial (or measurement-free) run must neither
    /// clobber measured state with uniform weights nor refresh the
    /// `saved_unix` staleness stamp without new evidence.
    pub fn save_planner_state(&self) {
        let (Some((path, key, baseline)), Some(model)) =
            (&self.planner_persist, &self.planner_model)
        else {
            return;
        };
        let (weights, steps) = {
            let m = lock_model(model);
            (m.worker_weights().to_vec(), m.steps_observed())
        };
        if weights.is_empty() || steps <= *baseline {
            return;
        }
        let mut state = PlannerState::load(path);
        state.put(key, StateEntry {
            weights,
            steps_observed: steps,
            saved_unix: unix_now(),
        });
        match state.save(path) {
            Ok(()) => eprintln!("planner-state: saved {} ({} steps \
                                 observed) to {}",
                                key.as_string(), steps, path.display()),
            Err(e) => eprintln!("warning: could not save planner-state \
                                 {}: {e}", path.display()),
        }
    }

    /// Current adaptive per-worker weights (None for other flavors or
    /// before any feedback/warm-start).
    pub fn planner_weights(&self) -> Option<Vec<f64>> {
        let m = self.planner_model.as_ref()?;
        let w = lock_model(m).worker_weights().to_vec();
        (!w.is_empty()).then_some(w)
    }

    /// The execution backend actually in use ("native" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Next batch of seed nodes (reshuffles at epoch boundaries; identical
    /// order across variants for the same seed). Draws from the shared
    /// scheduler — mixing manual draws with prefetching degrades the
    /// prefetcher to the synchronous path (see [`Trainer::acquire_batch`]).
    pub fn next_batch(&mut self) -> Vec<i32> {
        self.sched.next_seeds()
    }

    /// Per-step base seed: shared schedule across variants so both sample
    /// the same neighborhoods at the same step (paired comparisons).
    pub fn step_base_seed(&self) -> u64 {
        mix(self.cfg.seed.wrapping_add(self.step_count as u64))
    }

    /// Run one training step; returns the timing breakdown.
    pub fn step(&mut self) -> Result<StepTiming> {
        let prepared = self.acquire_batch()?;
        self.step_prepared(prepared)
    }

    /// Run one step on explicit seeds (used by tests and the e2e example).
    /// Always samples synchronously; does not consume the scheduler.
    pub fn step_with_seeds(&mut self, seeds: &[i32]) -> Result<StepTiming> {
        let prepared = pipeline::prepare_batch(
            &self.ds, self.cfg.host_work(), &self.cfg.fanouts,
            &self.sampler, self.step_count, seeds.to_vec(),
            self.step_base_seed());
        self.step_prepared(prepared)
    }

    /// Obtain the batch for the current step — synchronously, or from the
    /// double-buffered prefetch worker (keeping one batch in flight behind
    /// the one being consumed so sampling overlaps dispatch).
    fn acquire_batch(&mut self) -> Result<PreparedBatch> {
        if let Some(p) = &mut self.prefetcher {
            let prepared = p.next_batch(&mut self.sched)?;
            if prepared.step == self.step_count {
                return Ok(prepared);
            }
            // Schedule desync: explicit-seed steps advanced `step_count`
            // past the prefetched stream. Keep the seed order (the drawn
            // batch is still next) but resample synchronously with the
            // base seed the legacy schedule mandates for this step.
            return Ok(pipeline::prepare_batch(
                &self.ds, self.cfg.host_work(), &self.cfg.fanouts,
                &self.sampler, self.step_count, prepared.seeds,
                self.step_base_seed()));
        }
        let seeds = self.sched.next_seeds();
        Ok(pipeline::prepare_batch(
            &self.ds, self.cfg.host_work(), &self.cfg.fanouts, &self.sampler,
            self.step_count, seeds, self.step_base_seed()))
    }

    /// Dispatch one prepared batch through the backend and account it.
    fn step_prepared(&mut self, prepared: PreparedBatch) -> Result<StepTiming> {
        let mut t = StepTiming::default();
        let b = self.cfg.batch;
        if prepared.seeds.len() != b {
            bail!("expected {b} seeds, got {}", prepared.seeds.len());
        }
        match prepared.wait_ms {
            // synchronous build: sampling is the critical path
            None => t.sample_ms = prepared.sample_ms,
            // prefetched: only the wait is critical; the build overlapped
            Some(wait) => {
                t.sample_ms = wait;
                t.sample_overlap_ms = prepared.sample_ms;
            }
        }

        // ---- synchronized dispatch through the backend seam
        self.meter.reset_step();
        let inp = StepInputs {
            seeds: &prepared.seeds,
            labels: &prepared.labels,
            base: prepared.base,
            block: prepared.block.as_ref(),
        };
        let out = self.backend.train_step(self.step_count, &inp,
                                          &mut self.meter)?;
        t.upload_ms = out.upload_ms;
        t.execute_ms = out.execute_ms;
        t.post_ms = out.post_ms;
        t.loss = out.loss;
        // shard balance: the engine's batch shards when it sharded, else
        // the host sampler's block shards, else serial (1.0)
        t.imbalance = out
            .shard_stats
            .as_ref()
            .map(|s| s.imbalance())
            .or(prepared.sample_imbalance)
            .unwrap_or(1.0);
        t.transient_bytes = self.meter.peak();
        self.meter.reset_peak();
        self.meter.reset_step();

        // untimed: raw sampled-pair count (paper's auxiliary metric) —
        // fused native kernels count inline; other paths recount here
        t.pairs = match out.pairs {
            Some(p) => p,
            None => match self.cfg.variant {
                Variant::Dgl => sampler::block_sampled_pairs(
                    prepared.block.as_ref().unwrap()),
                Variant::Fsa => sampler::fused_sampled_pairs(
                    &self.ds.graph, &prepared.seeds, &self.cfg.fanouts,
                    prepared.base),
            },
        };

        self.step_count += 1;
        Ok(t)
    }

    /// Current parameters as host f32 tensors (canonical spec order).
    pub fn params_f32(&self) -> Result<Vec<Vec<f32>>> {
        self.backend.params_f32()
    }

    /// Validation accuracy: the depth-matched eval forward at the
    /// 15-10(-5…) fanout over at least 512 val nodes. Native runs it
    /// directly; PJRT goes through the dataset's `{fsa2|dgl2}_eval_*`
    /// artifact (matching the trainer's variant). At depth 2 the two
    /// protocols coincide, so numbers are comparable across the backend
    /// seam; at depth 1 the native baseline is a different (single-layer)
    /// model than the fixed two-layer dgl1 artifacts, and at depth ≥ 3
    /// only the native path exists — cross-seam comparisons are a
    /// depth-2 property until L-hop manifests land (ROADMAP).
    pub fn evaluate(&mut self, max_nodes: usize) -> Result<f64> {
        let mut nodes = self.ds.split_nodes(Split::Val);
        nodes.truncate(max_nodes.max(512));
        let eval_base = mix(self.cfg.seed ^ 0xEAE1);
        let c = self.ds.spec.c;
        let mut correct = 0usize;
        let mut total = 0usize;
        for chunk in nodes.chunks(512) {
            let Some(logits) = self.backend.eval_logits(chunk, eval_base)?
            else {
                // backend has no forward-only path: AOT eval artifact
                return evaluate_params(self.rt, &self.ds, self.cfg.variant,
                                       &self.backend.params_f32()?,
                                       self.cfg.seed, max_nodes);
            };
            for (i, &u) in chunk.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                if argmax(row) as i32 == self.ds.labels[u as usize] {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

impl Drop for Trainer<'_> {
    /// "Saved at shutdown": persist the adaptive weights when the
    /// session ends, however it ends. No-op unless `cfg.planner_state`
    /// is set, the flavor is adaptive, and feedback was observed.
    fn drop(&mut self) {
        self.save_planner_state();
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Validation accuracy of a parameter set using the dataset's
/// `{fsa2|dgl2}_eval_*` artifact. Static graph/feature buffers come from
/// the runtime's per-dataset cache ([`Runtime::graph_bufs`]) instead of
/// being re-uploaded per call.
pub fn evaluate_params(rt: &Runtime, ds: &Dataset, variant: Variant,
                       params: &[Vec<f32>], seed: u64,
                       max_nodes: usize) -> Result<f64> {
    let name = format!("{}2_eval_{}_f15x10_b512", variant.as_str(),
                       ds.spec.name);
    let exe = rt.load(&name)?;
    let (b, k1, k2) = (exe.spec.batch, exe.spec.k1, exe.spec.k2);
    let np = exe.spec.n_params();
    anyhow::ensure!(params.len() == np,
                    "eval artifact {name} wants {np} params, got {}",
                    params.len());
    let mut nodes = ds.split_nodes(Split::Val);
    nodes.truncate(max_nodes.max(b));
    let eval_base = mix(seed ^ 0xEAE1);
    let x = rt.features_f32(ds)?;

    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in nodes.chunks(b) {
        let mut seeds = chunk.to_vec();
        let real = seeds.len();
        seeds.resize(b, chunk[0]); // pad; padded rows ignored below
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(10);
        for (vals, spec) in params.iter().zip(&exe.spec.inputs[..np]) {
            owned.push(rt.buf_f32(vals, &spec.shape)?);
        }
        let out = match variant {
            Variant::Fsa => {
                let graph = rt.graph_bufs(ds)?;
                owned.push(rt.buf_i32(&seeds, &[b])?);
                owned.push(rt.buf_u64(&[eval_base], &[1])?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    owned[..np].iter().collect();
                args.push(&graph.rowptr);
                args.push(&graph.col);
                args.push(x.as_ref());
                args.push(&owned[np]);
                args.push(&owned[np + 1]);
                exe.run(&args)?
            }
            Variant::Dgl => {
                let fo = Fanouts::new(vec![k1, k2])?;
                let blk = sampler::build_block(&ds.graph, &seeds, &fo,
                                               eval_base);
                owned.push(rt.buf_i32(&blk.frontiers[1], &[b, 1 + k1])?);
                owned.push(rt.buf_i32(&blk.leaf, &[b, 1 + k1, k2])?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    owned[..np].iter().collect();
                args.push(x.as_ref());
                args.push(&owned[np]);
                args.push(&owned[np + 1]);
                exe.run(&args)?
            }
        };
        let logits = out[0].to_vec::<f32>()?;
        let c = ds.spec.c;
        for (i, &u) in chunk.iter().enumerate().take(real) {
            let row = &logits[i * c..(i + 1) * c];
            if argmax(row) as i32 == ds.labels[u as usize] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Warmup + timed measurement loop (the paper's protocol, §5).
pub fn measure(trainer: &mut Trainer, warmup: usize, steps: usize)
               -> Result<Vec<StepTiming>> {
    for _ in 0..warmup {
        trainer.step()?;
    }
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        out.push(trainer.step()?);
    }
    Ok(out)
}
