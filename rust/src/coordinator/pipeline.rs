//! Host batch pipeline — seed scheduling, batch preparation, and the
//! double-buffered prefetch stage (SALIENT-style pipelining, arXiv
//! 2110.08450: overlap host sampling of step *t+1* with dispatch of
//! step *t*).
//!
//! Invariants the benchmarks depend on (pinned by `rust/tests/pipeline.rs`):
//!
//! * **seed order** — [`BatchScheduler`] reproduces the trainer's legacy
//!   shuffle/epoch logic exactly, so batches arrive in the same order
//!   whether prefetching is on or off;
//! * **base-seed schedule** — step *t* always samples with
//!   `mix(seed + t)`, the paired-comparison contract shared by both
//!   variants;
//! * **bitwise sampling** — batches are built by [`ParallelSampler`],
//!   identical to the serial sampler at any thread count and any fanout
//!   depth.
//!
//! Accounting: [`PreparedBatch::sample_ms`] is the wall-clock the host
//! sampler actually spent (worker-side when prefetched), while the
//! consumer records the *critical-path* time it blocked waiting — the
//! split `StepTiming` reports as `sample_ms` vs `sample_overlap_ms`.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, bail, Result};

use crate::fanout::Fanouts;
use crate::gen::{Dataset, Split};
use crate::metrics::Timer;
use crate::rng::{mix, SplitMix64};
use crate::sampler::{Block, ParallelSampler};

/// What the host must prepare per step for a given variant (the fanout
/// list — and with it the depth — rides alongside in the trainer config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HostWork {
    /// Fused path: the kernel samples on device; host supplies seeds+labels.
    SeedsOnly,
    /// Baseline: materialize an L-hop [`Block`] at the config's fanouts.
    Block,
}

/// Deterministic seed-batch scheduler (the trainer's legacy epoch logic,
/// extracted so the prefetch stage can draw batches ahead of consumption).
pub struct BatchScheduler {
    seed: u64,
    batch: usize,
    train_nodes: Vec<i32>,
    cursor: usize,
    epoch: u64,
    drawn: usize,
}

impl BatchScheduler {
    pub fn new(ds: &Dataset, batch: usize, seed: u64) -> Result<BatchScheduler> {
        let mut train_nodes = ds.split_nodes(Split::Train);
        if train_nodes.len() < batch {
            bail!("dataset {} has {} train nodes < batch {}",
                  ds.spec.name, train_nodes.len(), batch);
        }
        SplitMix64::new(mix(seed ^ 0xE90C)).shuffle(&mut train_nodes);
        Ok(BatchScheduler { seed, batch, train_nodes, cursor: 0, epoch: 0,
                            drawn: 0 })
    }

    /// Number of batches drawn so far = the step index of the next draw.
    pub fn steps_drawn(&self) -> usize {
        self.drawn
    }

    /// Per-step base seed: shared schedule across variants so both sample
    /// the same neighborhoods at the same step (paired comparisons).
    pub fn base_seed(&self, step: usize) -> u64 {
        mix(self.seed.wrapping_add(step as u64))
    }

    /// Next batch of seed nodes (reshuffles at epoch boundaries; identical
    /// order across variants for the same seed).
    pub fn next_seeds(&mut self) -> Vec<i32> {
        if self.cursor + self.batch > self.train_nodes.len() {
            self.epoch += 1;
            SplitMix64::new(mix(self.seed ^ 0xE90C ^ self.epoch))
                .shuffle(&mut self.train_nodes);
            self.cursor = 0;
        }
        let out = self.train_nodes[self.cursor..self.cursor + self.batch]
            .to_vec();
        self.cursor += self.batch;
        self.drawn += 1;
        out
    }
}

/// Everything the host prepares for one training step.
pub struct PreparedBatch {
    /// Step index this batch was drawn for (consumption-order guard).
    pub step: usize,
    pub seeds: Vec<i32>,
    pub labels: Vec<i32>,
    pub base: u64,
    /// Host-materialized L-hop block (baseline variant only).
    pub block: Option<Block>,
    /// Host sampling wall-clock spent building the blocks (worker-side
    /// when prefetched — overlapped, not critical-path).
    pub sample_ms: f64,
    /// Critical-path wait the consumer paid to obtain this batch
    /// (`None` = built synchronously; `sample_ms` *is* the critical path).
    pub wait_ms: Option<f64>,
    /// Measured shard-imbalance ratio of the block build's sharded
    /// sampling passes (None when the sampler ran serially or no block
    /// was built) — the sampler half of the measured-imbalance feedback.
    pub sample_imbalance: Option<f64>,
}

/// Build one batch synchronously with the given sampler.
pub fn prepare_batch(ds: &Dataset, work: HostWork, fanouts: &Fanouts,
                     sampler: &ParallelSampler, step: usize, seeds: Vec<i32>,
                     base: u64) -> PreparedBatch {
    let labels: Vec<i32> =
        seeds.iter().map(|&u| ds.labels[u as usize]).collect();
    let mut block = None;
    let mut sample_ms = 0.0;
    let mut sample_imbalance = None;
    match work {
        HostWork::SeedsOnly => {}
        HostWork::Block => {
            let t = Timer::start();
            sampler.take_imbalance(); // discard any stale accumulation
            block = Some(sampler.build_block(&ds.graph, &seeds, fanouts,
                                             base));
            sample_ms = t.ms();
            sample_imbalance = sampler.take_imbalance();
        }
    }
    PreparedBatch { step, seeds, labels, base, block, sample_ms,
                    wait_ms: None, sample_imbalance }
}

struct Job {
    step: usize,
    seeds: Vec<i32>,
    base: u64,
}

/// Double-buffered batch prefetcher: a persistent worker thread builds
/// batches FIFO while the consumer dispatches the previous step. Keep two
/// jobs in flight (one being received, one overlapping) for full overlap.
pub struct BatchPrefetcher {
    jobs: Option<mpsc::Sender<Job>>,
    done: mpsc::Receiver<PreparedBatch>,
    worker: Option<thread::JoinHandle<()>>,
    in_flight: usize,
}

impl BatchPrefetcher {
    /// Spawn the worker around a fully configured [`ParallelSampler`]
    /// (thread count, planner flavor, clock, and — for the adaptive
    /// feedback loop — the session's [`crate::graph::SharedCostModel`],
    /// so the prefetch thread's measured shard stats feed the same
    /// per-worker weights as every other planning site). Callers should
    /// hand over a dedicated sampler (e.g. `sampler.fresh_stats()`):
    /// the imbalance accumulator is drained per batch and must not be
    /// shared with a sampler running on another thread.
    pub fn spawn(ds: Arc<Dataset>, work: HostWork, fanouts: Fanouts,
                 sampler: ParallelSampler) -> BatchPrefetcher {
        let (jtx, jrx) = mpsc::channel::<Job>();
        let (dtx, drx) = mpsc::channel::<PreparedBatch>();
        let worker = thread::spawn(move || {
            for job in jrx {
                let batch = prepare_batch(&ds, work, &fanouts, &sampler,
                                          job.step, job.seeds, job.base);
                if dtx.send(batch).is_err() {
                    break; // consumer gone
                }
            }
        });
        BatchPrefetcher {
            jobs: Some(jtx),
            done: drx,
            worker: Some(worker),
            in_flight: 0,
        }
    }

    /// Batches submitted but not yet received.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Queue one batch for background preparation. Errors when the worker
    /// thread is gone (died or already shut down).
    pub fn submit(&mut self, step: usize, seeds: Vec<i32>,
                  base: u64) -> Result<()> {
        let tx = self
            .jobs
            .as_ref()
            .ok_or_else(|| anyhow!("prefetch worker already shut down"))?;
        if tx.send(Job { step, seeds, base }).is_err() {
            bail!("prefetch worker terminated unexpectedly");
        }
        self.in_flight += 1;
        Ok(())
    }

    /// Drive the double buffer from `sched`: keep two batches in flight
    /// (one being consumed, one overlapping the caller's dispatch), block
    /// for the oldest, and stamp the critical-path wait into
    /// [`PreparedBatch::wait_ms`]. This is the one protocol all consumers
    /// share — trainer, throughput mode, and tests.
    pub fn next_batch(&mut self,
                      sched: &mut BatchScheduler) -> Result<PreparedBatch> {
        while self.in_flight < 2 {
            let step = sched.steps_drawn();
            let seeds = sched.next_seeds();
            let base = sched.base_seed(step);
            self.submit(step, seeds, base)?;
        }
        let timer = Timer::start();
        let mut batch = self.recv()?;
        batch.wait_ms = Some(timer.ms());
        Ok(batch)
    }

    /// Block until the oldest in-flight batch is ready. Prefer
    /// [`Self::next_batch`], which also keeps the buffer primed and
    /// stamps the critical-path wait.
    pub fn recv(&mut self) -> Result<PreparedBatch> {
        if self.in_flight == 0 {
            bail!("prefetcher: recv with no batch in flight");
        }
        let batch = self
            .done
            .recv()
            .map_err(|_| anyhow!("prefetch worker terminated unexpectedly"))?;
        self.in_flight -= 1;
        Ok(batch)
    }
}

impl Drop for BatchPrefetcher {
    fn drop(&mut self) {
        self.jobs.take(); // close the queue; worker loop exits
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::builtin_spec;

    fn tiny() -> Arc<Dataset> {
        Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap())
    }

    #[test]
    fn scheduler_is_deterministic_and_epoch_aware() {
        let ds = tiny();
        let mut a = BatchScheduler::new(&ds, 64, 42).unwrap();
        let mut b = BatchScheduler::new(&ds, 64, 42).unwrap();
        // tiny has ~410 train nodes -> epoch boundary inside 20 steps
        for step in 0..20 {
            assert_eq!(a.next_seeds(), b.next_seeds(), "step {step}");
            assert_eq!(a.base_seed(step), b.base_seed(step));
        }
        assert_eq!(a.steps_drawn(), 20);
        let mut c = BatchScheduler::new(&ds, 64, 43).unwrap();
        assert_ne!(a.base_seed(0), c.base_seed(0));
    }

    #[test]
    fn scheduler_rejects_oversized_batch() {
        let ds = tiny();
        assert!(BatchScheduler::new(&ds, 100_000, 42).is_err());
    }

    #[test]
    fn prepare_batch_builds_the_requested_block() {
        let ds = tiny();
        let sampler = ParallelSampler::serial();
        let seeds: Vec<i32> = (0..32).collect();
        for fo in [Fanouts::of(&[4]), Fanouts::of(&[4, 3]),
                   Fanouts::of(&[4, 3, 2])] {
            let b = prepare_batch(&ds, HostWork::Block, &fo, &sampler, 0,
                                  seeds.clone(), 7);
            let blk = b.block.as_ref().unwrap();
            assert_eq!(blk.fanouts, fo);
            assert_eq!(blk.frontiers.len(), fo.depth());
            assert_eq!(b.labels.len(), 32);
        }
        let s = prepare_batch(&ds, HostWork::SeedsOnly, &Fanouts::of(&[4, 3]),
                              &sampler, 0, seeds, 7);
        assert!(s.block.is_none());
        assert_eq!(s.sample_ms, 0.0);
    }

    #[test]
    fn prefetcher_returns_batches_in_submission_order() {
        let ds = tiny();
        let mut sched = BatchScheduler::new(&ds, 64, 42).unwrap();
        let mut pf = BatchPrefetcher::spawn(ds.clone(), HostWork::Block,
                                            Fanouts::of(&[4, 3]),
                                            ParallelSampler::new(2));
        for _ in 0..3 {
            let step = sched.steps_drawn();
            let seeds = sched.next_seeds();
            let base = sched.base_seed(step);
            pf.submit(step, seeds, base).unwrap();
        }
        assert_eq!(pf.in_flight(), 3);
        for want in 0..3 {
            let b = pf.recv().unwrap();
            assert_eq!(b.step, want);
            assert!(b.block.is_some());
        }
        assert_eq!(pf.in_flight(), 0);
        assert!(pf.recv().is_err(), "recv with empty queue must error");
    }

    #[test]
    fn prefetched_batches_match_synchronous_ones() {
        let ds = tiny();
        let fo = Fanouts::of(&[4, 3]);
        let sampler = ParallelSampler::serial();
        let mut sync_sched = BatchScheduler::new(&ds, 64, 42).unwrap();
        let mut pf_sched = BatchScheduler::new(&ds, 64, 42).unwrap();
        let mut pf = BatchPrefetcher::spawn(ds.clone(), HostWork::Block,
                                            fo.clone(),
                                            ParallelSampler::new(8));
        for _ in 0..10 {
            let step = pf_sched.steps_drawn();
            let seeds = pf_sched.next_seeds();
            pf.submit(step, seeds, pf_sched.base_seed(step)).unwrap();
        }
        for step in 0..10 {
            let seeds = sync_sched.next_seeds();
            let want = prepare_batch(&ds, HostWork::Block, &fo, &sampler,
                                     step, seeds, sync_sched.base_seed(step));
            let got = pf.recv().unwrap();
            assert_eq!(got.step, want.step);
            assert_eq!(got.seeds, want.seeds);
            assert_eq!(got.labels, want.labels);
            assert_eq!(got.base, want.base);
            assert_eq!(got.block.as_ref().unwrap().frontiers,
                       want.block.as_ref().unwrap().frontiers, "step {step}");
            assert_eq!(got.block.as_ref().unwrap().leaf,
                       want.block.as_ref().unwrap().leaf, "step {step}");
        }
    }
}
