//! Stable-Rust SIMD primitives for the native kernel's gather/fold.
//!
//! Lanes run across the **feature dimension**: element `j` of an
//! accumulator only ever combines with element `j` of a neighbor row, in
//! the same neighbor order as the scalar loop, so every output element
//! sees the identical floating-point operation sequence at any vector
//! width. That is what keeps `--simd on` bitwise identical to `--simd
//! off` at every depth, thread count and planner; lane-per-neighbor
//! folding would reassociate the sum and break it (DESIGN_BACKEND.md
//! §SIMD). No FMA is emitted anywhere: the vector fold rounds after the
//! multiply and after the add, exactly like the scalar code.
//!
//! Dispatch is two-tier and decided at runtime: on `x86_64` with AVX2
//! detected, 8-lane intrinsic loops (including the bf16→f32 decode —
//! a `u16` zero-extend plus 16-bit shift, the same bits as
//! [`crate::util::bf16_to_f32`]); everywhere else, portable
//! `chunks_exact` loops the optimizer can auto-vectorize. The scalar
//! reference kernel (`--simd off`) bypasses this module entirely — it is
//! the pre-vectorization per-row-dispatch loop, kept as the baseline the
//! bench speedup is measured against.

use std::sync::OnceLock;

use anyhow::{bail, Result};

/// Vector width of the fold loops (f32 lanes per step). The AVX2 tier
/// uses exactly this width; the portable tier chunks by it so both tiers
/// walk the remainder identically.
pub const LANES: usize = 8;

/// `--simd auto|on|off` — vector vs scalar gather/fold in the native
/// kernel. Outputs are bitwise identical under every setting; the knob
/// only moves step time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdChoice {
    /// The vector path unless an `FSA_SIMD=off` environment override
    /// says otherwise (the portable fallback makes the vector path safe
    /// on every target, so auto needs no capability gate; AVX2 is a
    /// runtime specialization *inside* the vector helpers).
    #[default]
    Auto,
    On,
    Off,
}

impl SimdChoice {
    pub fn parse(s: &str) -> Result<SimdChoice> {
        Ok(match s {
            "auto" => SimdChoice::Auto,
            "on" => SimdChoice::On,
            "off" => SimdChoice::Off,
            other => bail!("--simd must be auto|on|off, got {other:?}"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SimdChoice::Auto => "auto",
            SimdChoice::On => "on",
            SimdChoice::Off => "off",
        }
    }

    /// Resolve to the concrete path this process takes: `auto` honors an
    /// `FSA_SIMD=on|off` override (how CI flips whole suites without
    /// touching each invocation) and otherwise takes the vector path.
    pub fn enabled(self) -> bool {
        static AUTO: OnceLock<bool> = OnceLock::new();
        match self {
            SimdChoice::On => true,
            SimdChoice::Off => false,
            SimdChoice::Auto => *AUTO.get_or_init(|| {
                !matches!(std::env::var("FSA_SIMD").ok().as_deref(),
                          Some("off") | Some("0"))
            }),
        }
    }
}

/// True when the AVX2 specializations are in use (benches report it so a
/// recorded speedup names its tier); every other target serves the
/// portable loops.
pub fn avx2_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// `acc[i] += row[i]` — one add per element, same order as the scalar
/// gather.
#[inline]
pub(crate) fn add_assign_f32(acc: &mut [f32], row: &[f32]) {
    debug_assert_eq!(acc.len(), row.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: AVX2 presence was just checked.
        unsafe { add_assign_f32_avx2(acc, row) };
        return;
    }
    add_assign_f32_portable(acc, row);
}

/// `acc[i] += decode(row[i])` with the bf16→f32 widening in the same
/// pass (bit-exact with [`crate::util::bf16_to_f32`]).
#[inline]
pub(crate) fn add_assign_bf16(acc: &mut [f32], row: &[u16]) {
    debug_assert_eq!(acc.len(), row.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: AVX2 presence was just checked.
        unsafe { add_assign_bf16_avx2(acc, row) };
        return;
    }
    add_assign_bf16_portable(acc, row);
}

/// `out[i] += acc[i] * s` — multiply rounds, then add rounds (no FMA),
/// matching the scalar fold's two-rounding sequence bit for bit.
#[inline]
pub(crate) fn scale_add(out: &mut [f32], acc: &[f32], s: f32) {
    debug_assert_eq!(out.len(), acc.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_active() {
        // SAFETY: AVX2 presence was just checked.
        unsafe { scale_add_avx2(out, acc, s) };
        return;
    }
    scale_add_portable(out, acc, s);
}

/// Hint the cache hierarchy to start pulling `x[at..]` — the gather loop
/// issues this for the *next* valid neighbor row one iteration ahead.
/// Out-of-range indices and non-x86 targets degrade to a no-op.
#[inline]
pub(crate) fn prefetch_f32(x: &[f32], at: usize) {
    #[cfg(target_arch = "x86_64")]
    if at < x.len() {
        // SAFETY: in-bounds pointer; prefetch has no memory effects.
        unsafe {
            use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            _mm_prefetch::<_MM_HINT_T0>(x.as_ptr().add(at) as *const i8);
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (x, at);
}

/// [`prefetch_f32`] for bf16-compressed storage.
#[inline]
pub(crate) fn prefetch_u16(x: &[u16], at: usize) {
    #[cfg(target_arch = "x86_64")]
    if at < x.len() {
        // SAFETY: in-bounds pointer; prefetch has no memory effects.
        unsafe {
            use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            _mm_prefetch::<_MM_HINT_T0>(x.as_ptr().add(at) as *const i8);
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (x, at);
}

// ---------------------------------------------------------------------------
// portable tier — LANES-wide chunks the optimizer can auto-vectorize
// ---------------------------------------------------------------------------

fn add_assign_f32_portable(acc: &mut [f32], row: &[f32]) {
    let mut a = acc.chunks_exact_mut(LANES);
    let mut r = row.chunks_exact(LANES);
    for (ac, rc) in (&mut a).zip(&mut r) {
        for (x, &v) in ac.iter_mut().zip(rc) {
            *x += v;
        }
    }
    for (x, &v) in a.into_remainder().iter_mut().zip(r.remainder()) {
        *x += v;
    }
}

fn add_assign_bf16_portable(acc: &mut [f32], row: &[u16]) {
    let mut a = acc.chunks_exact_mut(LANES);
    let mut r = row.chunks_exact(LANES);
    for (ac, rc) in (&mut a).zip(&mut r) {
        for (x, &v) in ac.iter_mut().zip(rc) {
            *x += crate::util::bf16_to_f32(v);
        }
    }
    for (x, &v) in a.into_remainder().iter_mut().zip(r.remainder()) {
        *x += crate::util::bf16_to_f32(v);
    }
}

fn scale_add_portable(out: &mut [f32], acc: &[f32], s: f32) {
    let mut o = out.chunks_exact_mut(LANES);
    let mut a = acc.chunks_exact(LANES);
    for (oc, ac) in (&mut o).zip(&mut a) {
        for (x, &v) in oc.iter_mut().zip(ac) {
            *x += v * s;
        }
    }
    for (x, &v) in o.into_remainder().iter_mut().zip(a.remainder()) {
        *x += v * s;
    }
}

// ---------------------------------------------------------------------------
// AVX2 tier — explicit 8-lane intrinsics, runtime-selected
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_assign_f32_avx2(acc: &mut [f32], row: &[f32]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let mut i = 0;
    while i + LANES <= n {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let r = _mm256_loadu_ps(row.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, r));
        i += LANES;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += *row.get_unchecked(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_assign_bf16_avx2(acc: &mut [f32], row: &[u16]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let mut i = 0;
    while i + LANES <= n {
        // widen 8 bf16 halves to u32 lanes and shift them into the f32
        // high half — the same bits the scalar decoder produces
        let h = _mm_loadu_si128(row.as_ptr().add(i) as *const __m128i);
        let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let v = _mm256_castsi256_ps(w);
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, v));
        i += LANES;
    }
    while i < n {
        *acc.get_unchecked_mut(i) +=
            crate::util::bf16_to_f32(*row.get_unchecked(i));
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_add_avx2(out: &mut [f32], acc: &[f32], s: f32) {
    use std::arch::x86_64::*;
    let sv = _mm256_set1_ps(s);
    let n = out.len();
    let mut i = 0;
    while i + LANES <= n {
        let o = _mm256_loadu_ps(out.as_ptr().add(i));
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        // mul then add (two roundings) — deliberately not an FMA
        let r = _mm256_add_ps(o, _mm256_mul_ps(a, sv));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
        i += LANES;
    }
    while i < n {
        *out.get_unchecked_mut(i) += *acc.get_unchecked(i) * s;
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{bf16_to_f32, f32_to_bf16};

    fn pattern(n: usize, salt: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.37 + salt).collect()
    }

    #[test]
    fn choice_parses_and_round_trips() {
        for (s, want) in [("auto", SimdChoice::Auto), ("on", SimdChoice::On),
                          ("off", SimdChoice::Off)] {
            let c = SimdChoice::parse(s).unwrap();
            assert_eq!(c, want);
            assert_eq!(c.as_str(), s);
        }
        assert!(SimdChoice::parse("avx512").is_err());
        assert!(SimdChoice::On.enabled());
        assert!(!SimdChoice::Off.enabled());
        assert_eq!(SimdChoice::default(), SimdChoice::Auto);
    }

    #[test]
    fn add_assign_matches_scalar_at_remainder_lengths() {
        for n in [1usize, 7, 8, 63, 64, 65, 256] {
            let row = pattern(n, 0.25);
            let mut acc = pattern(n, -3.0);
            let mut want = acc.clone();
            for (a, &v) in want.iter_mut().zip(&row) {
                *a += v;
            }
            add_assign_f32(&mut acc, &row);
            assert_eq!(acc, want, "n={n}");
        }
    }

    #[test]
    fn bf16_add_matches_scalar_decode_bitwise() {
        for n in [1usize, 7, 8, 63, 65] {
            let row: Vec<u16> =
                pattern(n, 1.5).iter().map(|&v| f32_to_bf16(v)).collect();
            let mut acc = pattern(n, 2.0);
            let mut want = acc.clone();
            for (a, &v) in want.iter_mut().zip(&row) {
                *a += bf16_to_f32(v);
            }
            add_assign_bf16(&mut acc, &row);
            assert_eq!(acc, want, "n={n}");
        }
    }

    #[test]
    fn scale_add_matches_scalar_mul_then_add() {
        // a non-power-of-two scale so both roundings are exercised
        for n in [1usize, 7, 64, 65] {
            let acc = pattern(n, 0.5);
            let mut out = pattern(n, -1.0);
            let mut want = out.clone();
            for (o, &v) in want.iter_mut().zip(&acc) {
                *o += v * (1.0 / 3.0);
            }
            scale_add(&mut out, &acc, 1.0 / 3.0);
            assert_eq!(out, want, "n={n}");
        }
    }

    #[test]
    fn prefetch_tolerates_out_of_range() {
        let x = [1.0f32; 4];
        prefetch_f32(&x, 0);
        prefetch_f32(&x, 100); // past the end: must degrade to a no-op
        prefetch_u16(&[1u16; 4], 100);
    }
}
