//! The fused sample+aggregate kernels (paper Algorithms 1–2) as native
//! host compute.
//!
//! One pass per seed: neighbors are drawn inline with the counter-hash
//! rule ([`crate::sampler::sample_neighbors`], bitwise identical to the
//! Pallas kernel and the host baseline sampler) and the running mean is
//! folded into a single `[d]` accumulator per hop — **no** `[B,1+k1,k2,d]`
//! block ever exists. The only per-step outputs are the `[B,d]` aggregate,
//! the optional saved index tensors (`save_indices`, the paper's §3.3
//! deterministic-backward replay), and the sampled-pair count.
//!
//! The gather is cache-blocked over the feature dimension
//! ([`super::D_TILE`]): the accumulator tile stays L1-resident while the
//! k2 sampled rows stream through it. Batch rows are sharded across scoped
//! workers with the degree-aware planner; each worker writes disjoint row
//! ranges of every output, so results are bitwise identical at any thread
//! count.

use crate::graph::{shard, Csr};
use crate::sampler::sample_neighbors;

use super::{resolve_threads, Features, D_TILE, MIN_PAR_ROWS};

/// Output of one fused 2-hop aggregation.
pub struct Fused2Out {
    /// `[B, d]` two-hop mean-of-means aggregate.
    pub agg: Vec<f32>,
    /// `[B, k1]` hop-1 samples (when `save_indices`).
    pub s1: Option<Vec<i32>>,
    /// `[B, k1, k2]` hop-2 samples (when `save_indices`).
    pub s2: Option<Vec<i32>>,
    /// Valid (seed, neighbor) draws — matches
    /// [`crate::sampler::fused2_sampled_pairs`] exactly.
    pub pairs: u64,
}

/// Output of one fused 1-hop aggregation.
pub struct Fused1Out {
    /// `[B, d]` neighbor-mean aggregate.
    pub agg: Vec<f32>,
    /// `[B, k]` samples (when `save_indices`).
    pub samples: Option<Vec<i32>>,
    pub pairs: u64,
}

/// Per-worker scratch: reused across the rows of one shard.
struct Scratch {
    s1row: Vec<i32>,
    s2row: Vec<i32>,
    valid: Vec<u32>,
    tile: Vec<f32>,
}

impl Scratch {
    fn new(k1: usize, k2: usize) -> Scratch {
        Scratch {
            s1row: vec![-1; k1],
            s2row: vec![-1; k2.max(1)],
            valid: Vec::with_capacity(k2.max(k1)),
            tile: vec![0.0; D_TILE],
        }
    }
}

/// Mean of the valid feature rows into `agg_row` with weight `1/k1_eff`
/// applied by the caller afterwards; `acc += mean(x[valid]) `.
#[inline]
fn accumulate_mean(feat: &Features, valid: &[u32], tile: &mut [f32],
                   agg_row: &mut [f32]) {
    if valid.is_empty() {
        return;
    }
    let inv = 1.0 / valid.len() as f32;
    let d = feat.d;
    let mut t0 = 0;
    while t0 < d {
        let t1 = (t0 + D_TILE).min(d);
        let acc = &mut tile[..t1 - t0];
        acc.fill(0.0);
        for &w in valid {
            feat.add_row_slice(w as usize, t0, t1, acc);
        }
        for (a, &v) in agg_row[t0..t1].iter_mut().zip(acc.iter()) {
            *a += v * inv;
        }
        t0 = t1;
    }
}

#[inline]
fn collect_valid(row: &[i32], out: &mut Vec<u32>) {
    out.clear();
    for &v in row {
        if v >= 0 {
            out.push(v as u32);
        }
    }
}

/// Serial kernel body for a contiguous run of seed rows (one shard).
#[allow(clippy::too_many_arguments)]
fn run_rows_2hop(csr: &Csr, feat: &Features, seeds: &[i32], k1: usize,
                 k2: usize, base: u64, agg: &mut [f32],
                 mut s1_out: Option<&mut [i32]>,
                 mut s2_out: Option<&mut [i32]>, pairs: &mut [u64]) {
    let d = feat.d;
    let mut sc = Scratch::new(k1, k2);
    for (bi, &r) in seeds.iter().enumerate() {
        let agg_row = &mut agg[bi * d..(bi + 1) * d];
        sample_neighbors(csr, r, k1, base, 0, &mut sc.s1row);
        if let Some(buf) = s1_out.as_deref_mut() {
            buf[bi * k1..(bi + 1) * k1].copy_from_slice(&sc.s1row);
        }
        let mut k1_eff = 0u64;
        let mut npairs = 0u64;
        for ui in 0..k1 {
            let u = sc.s1row[ui];
            sample_neighbors(csr, u, k2, base, 1, &mut sc.s2row);
            if let Some(buf) = s2_out.as_deref_mut() {
                buf[(bi * k1 + ui) * k2..(bi * k1 + ui + 1) * k2]
                    .copy_from_slice(&sc.s2row);
            }
            if u < 0 {
                continue;
            }
            k1_eff += 1;
            npairs += 1;
            collect_valid(&sc.s2row, &mut sc.valid);
            npairs += sc.valid.len() as u64;
            accumulate_mean(feat, &sc.valid, &mut sc.tile, agg_row);
        }
        let inv = 1.0 / k1_eff.max(1) as f32;
        for v in agg_row.iter_mut() {
            *v *= inv;
        }
        pairs[bi] = npairs;
    }
}

fn run_rows_1hop(csr: &Csr, feat: &Features, seeds: &[i32], k: usize,
                 base: u64, agg: &mut [f32],
                 mut samples_out: Option<&mut [i32]>, pairs: &mut [u64]) {
    let d = feat.d;
    let mut sc = Scratch::new(k, 0);
    for (bi, &r) in seeds.iter().enumerate() {
        sample_neighbors(csr, r, k, base, 0, &mut sc.s1row);
        if let Some(buf) = samples_out.as_deref_mut() {
            buf[bi * k..(bi + 1) * k].copy_from_slice(&sc.s1row);
        }
        collect_valid(&sc.s1row, &mut sc.valid);
        pairs[bi] = sc.valid.len() as u64;
        accumulate_mean(feat, &sc.valid, &mut sc.tile,
                        &mut agg[bi * d..(bi + 1) * d]);
    }
}

/// Split `opt` (when present) at `at`, returning the head and keeping the
/// tail for the next shard.
fn take_chunk<'a>(opt: &mut Option<&'a mut [i32]>, at: usize)
                  -> Option<&'a mut [i32]> {
    opt.take().map(|buf| {
        let (head, tail) = buf.split_at_mut(at);
        *opt = Some(tail);
        head
    })
}

/// Fused 2-hop sample+aggregate over a batch of seeds.
#[allow(clippy::too_many_arguments)]
pub fn fused_2hop(csr: &Csr, feat: &Features, seeds: &[i32], k1: usize,
                  k2: usize, base: u64, save_indices: bool,
                  threads: usize) -> Fused2Out {
    let b = seeds.len();
    let d = feat.d;
    let mut agg = vec![0.0f32; b * d];
    let mut s1 = save_indices.then(|| vec![-1i32; b * k1]);
    let mut s2 = save_indices.then(|| vec![-1i32; b * k1 * k2]);
    let mut pairs = vec![0u64; b];

    let workers = resolve_threads(threads).min((b / MIN_PAR_ROWS).max(1));
    if workers <= 1 {
        run_rows_2hop(csr, feat, seeds, k1, k2, base, &mut agg,
                      s1.as_deref_mut(), s2.as_deref_mut(), &mut pairs);
    } else {
        // cost model: each of the ≤k1 hop-1 draws triggers ≤k2 row adds
        let costs: Vec<u64> = seeds
            .iter()
            .map(|&r| 1 + (shard::sample_cost(csr, r, k1) - 1) * (1 + k2 as u64))
            .collect();
        let plan = shard::plan_shards(&costs, workers);
        std::thread::scope(|s| {
            let mut agg_rest: &mut [f32] = &mut agg;
            let mut s1_rest = s1.as_deref_mut();
            let mut s2_rest = s2.as_deref_mut();
            let mut pairs_rest: &mut [u64] = &mut pairs;
            for r in plan {
                let rows = r.end - r.start;
                let (agg_c, tail) =
                    std::mem::take(&mut agg_rest).split_at_mut(rows * d);
                agg_rest = tail;
                let s1_c = take_chunk(&mut s1_rest, rows * k1);
                let s2_c = take_chunk(&mut s2_rest, rows * k1 * k2);
                let (pairs_c, tail) =
                    std::mem::take(&mut pairs_rest).split_at_mut(rows);
                pairs_rest = tail;
                if rows == 0 {
                    continue;
                }
                let seed_c = &seeds[r];
                s.spawn(move || {
                    run_rows_2hop(csr, feat, seed_c, k1, k2, base, agg_c,
                                  s1_c, s2_c, pairs_c);
                });
            }
        });
    }
    Fused2Out { agg, s1, s2, pairs: pairs.iter().sum() }
}

/// Fused 1-hop sample+aggregate over a batch of seeds.
pub fn fused_1hop(csr: &Csr, feat: &Features, seeds: &[i32], k: usize,
                  base: u64, save_indices: bool, threads: usize) -> Fused1Out {
    let b = seeds.len();
    let d = feat.d;
    let mut agg = vec![0.0f32; b * d];
    let mut samples = save_indices.then(|| vec![-1i32; b * k]);
    let mut pairs = vec![0u64; b];

    let workers = resolve_threads(threads).min((b / MIN_PAR_ROWS).max(1));
    if workers <= 1 {
        run_rows_1hop(csr, feat, seeds, k, base, &mut agg,
                      samples.as_deref_mut(), &mut pairs);
    } else {
        let costs: Vec<u64> =
            seeds.iter().map(|&r| shard::sample_cost(csr, r, k)).collect();
        let plan = shard::plan_shards(&costs, workers);
        std::thread::scope(|s| {
            let mut agg_rest: &mut [f32] = &mut agg;
            let mut samp_rest = samples.as_deref_mut();
            let mut pairs_rest: &mut [u64] = &mut pairs;
            for r in plan {
                let rows = r.end - r.start;
                let (agg_c, tail) =
                    std::mem::take(&mut agg_rest).split_at_mut(rows * d);
                agg_rest = tail;
                let samp_c = take_chunk(&mut samp_rest, rows * k);
                let (pairs_c, tail) =
                    std::mem::take(&mut pairs_rest).split_at_mut(rows);
                pairs_rest = tail;
                if rows == 0 {
                    continue;
                }
                let seed_c = &seeds[r];
                s.spawn(move || {
                    run_rows_1hop(csr, feat, seed_c, k, base, agg_c, samp_c,
                                  pairs_c);
                });
            }
        });
    }
    Fused1Out { agg, samples, pairs: pairs.iter().sum() }
}

/// Parity helper: the 1-hop mean aggregate of `seeds` drawn at an explicit
/// hop counter (the fused 2-hop inner loop draws at `hop = 1`; the golden
/// parity tests compare baseline block means against this). Serial.
pub fn fused_1hop_at_hop(csr: &Csr, feat: &Features, seeds: &[i32], k: usize,
                         base: u64, hop: u64) -> Vec<f32> {
    let d = feat.d;
    let mut agg = vec![0.0f32; seeds.len() * d];
    let mut sc = Scratch::new(k, 0);
    for (bi, &r) in seeds.iter().enumerate() {
        sample_neighbors(csr, r, k, base, hop, &mut sc.s1row);
        collect_valid(&sc.s1row, &mut sc.valid);
        accumulate_mean(feat, &sc.valid, &mut sc.tile,
                        &mut agg[bi * d..(bi + 1) * d]);
    }
    agg
}

// ---------------------------------------------------------------------------
// saved-index replay backward (paper §3.3) — dX for the fused ops.
//
// Not on the training path (features are not trainable parameters); used
// by the gradient tests to pin the replay weights 1/(k1_eff·k2_eff) and
// 1/max(1, take) against direct differentiation of the aggregate.
// ---------------------------------------------------------------------------

/// `dX[n,d]` from saved 2-hop indices and upstream `g[b,d]`.
#[allow(clippy::too_many_arguments)]
pub fn backward_2hop(s1: &[i32], s2: &[i32], g: &[f32], b: usize, k1: usize,
                     k2: usize, n: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(s1.len(), b * k1);
    debug_assert_eq!(s2.len(), b * k1 * k2);
    debug_assert_eq!(g.len(), b * d);
    let mut dx = vec![0.0f32; n * d];
    for bi in 0..b {
        let k1_eff = s1[bi * k1..(bi + 1) * k1]
            .iter()
            .filter(|&&u| u >= 0)
            .count()
            .max(1);
        for ui in 0..k1 {
            if s1[bi * k1 + ui] < 0 {
                continue;
            }
            let row = &s2[(bi * k1 + ui) * k2..(bi * k1 + ui + 1) * k2];
            let k2_eff = row.iter().filter(|&&w| w >= 0).count().max(1);
            let wgt = 1.0 / (k1_eff * k2_eff) as f32;
            for &w in row.iter().filter(|&&w| w >= 0) {
                let dst = &mut dx[w as usize * d..(w as usize + 1) * d];
                for (dv, &gv) in dst.iter_mut().zip(&g[bi * d..(bi + 1) * d]) {
                    *dv += wgt * gv;
                }
            }
        }
    }
    dx
}

/// `dX[n,d]` for the 1-hop op: `dX[v] += g[u] / max(1, take(u))`.
pub fn backward_1hop(samples: &[i32], g: &[f32], b: usize, k: usize,
                     n: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(samples.len(), b * k);
    debug_assert_eq!(g.len(), b * d);
    let mut dx = vec![0.0f32; n * d];
    for bi in 0..b {
        let row = &samples[bi * k..(bi + 1) * k];
        let take = row.iter().filter(|&&v| v >= 0).count().max(1);
        let wgt = 1.0 / take as f32;
        for &v in row.iter().filter(|&&v| v >= 0) {
            let dst = &mut dx[v as usize * d..(v as usize + 1) * d];
            for (dv, &gv) in dst.iter_mut().zip(&g[bi * d..(bi + 1) * d]) {
                *dv += wgt * gv;
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;
    use crate::sampler;

    fn tiny() -> Dataset {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
    }

    /// Reference 2-hop aggregate computed the *baseline* way: materialize
    /// the index tensors with the host sampler, gather, masked-mean.
    fn reference_agg2(ds: &Dataset, seeds: &[i32], k1: usize, k2: usize,
                      base: u64) -> Vec<f32> {
        let d = ds.spec.d;
        let s1 = sampler::sample_frontier(&ds.graph, seeds, k1, base, 0);
        let s2 = sampler::sample_frontier(&ds.graph, &s1, k2, base, 1);
        let mut agg = vec![0.0f32; seeds.len() * d];
        for bi in 0..seeds.len() {
            let mut outer = vec![0.0f64; d];
            let mut k1_eff = 0usize;
            for ui in 0..k1 {
                if s1[bi * k1 + ui] < 0 {
                    continue;
                }
                k1_eff += 1;
                let row = &s2[(bi * k1 + ui) * k2..(bi * k1 + ui + 1) * k2];
                let valid: Vec<i32> =
                    row.iter().copied().filter(|&w| w >= 0).collect();
                if valid.is_empty() {
                    continue;
                }
                for &w in &valid {
                    for j in 0..d {
                        outer[j] += ds.features[w as usize * d + j] as f64
                            / valid.len() as f64;
                    }
                }
            }
            for j in 0..d {
                agg[bi * d + j] = (outer[j] / k1_eff.max(1) as f64) as f32;
            }
        }
        agg
    }

    #[test]
    fn fused2_matches_materialized_reference() {
        let ds = tiny();
        let mut r = SplitMix64::new(5);
        let seeds: Vec<i32> =
            (0..96).map(|_| r.next_below(ds.spec.n as u64) as i32).collect();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let out = fused_2hop(&ds.graph, &feat, &seeds, 5, 3, 42, true, 1);
        let want = reference_agg2(&ds, &seeds, 5, 3, 42);
        for (i, (&a, &w)) in out.agg.iter().zip(&want).enumerate() {
            assert!((a - w).abs() < 1e-5, "agg[{i}]: {a} vs {w}");
        }
        // saved indices equal the host sampler's draws
        let s1 = sampler::sample_frontier(&ds.graph, &seeds, 5, 42, 0);
        let s2 = sampler::sample_frontier(&ds.graph, &s1, 3, 42, 1);
        assert_eq!(out.s1.unwrap(), s1);
        assert_eq!(out.s2.unwrap(), s2);
        assert_eq!(out.pairs,
                   sampler::fused2_sampled_pairs(&ds.graph, &seeds, 5, 3, 42));
    }

    #[test]
    fn fused2_bitwise_identical_across_thread_counts() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..200).map(|i| (i * 2) % ds.spec.n as i32).collect();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let serial = fused_2hop(&ds.graph, &feat, &seeds, 4, 3, 7, true, 1);
        for threads in [2usize, 3, 8] {
            let par = fused_2hop(&ds.graph, &feat, &seeds, 4, 3, 7, true,
                                 threads);
            assert_eq!(par.agg, serial.agg, "threads={threads}");
            assert_eq!(par.s1, serial.s1);
            assert_eq!(par.s2, serial.s2);
            assert_eq!(par.pairs, serial.pairs);
        }
    }

    #[test]
    fn fused1_means_valid_neighbors() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..64).collect();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let out = fused_1hop(&ds.graph, &feat, &seeds, 4, 9, true, 1);
        let samples = out.samples.unwrap();
        let d = ds.spec.d;
        for bi in 0..seeds.len() {
            let valid: Vec<i32> = samples[bi * 4..(bi + 1) * 4]
                .iter()
                .copied()
                .filter(|&v| v >= 0)
                .collect();
            for j in (0..d).step_by(5) {
                let want: f32 = if valid.is_empty() {
                    0.0
                } else {
                    valid.iter()
                        .map(|&v| ds.features[v as usize * d + j])
                        .sum::<f32>() / valid.len() as f32
                };
                let got = out.agg[bi * d + j];
                assert!((got - want).abs() < 1e-4, "row {bi} dim {j}");
            }
        }
        let s1 = sampler::sample_frontier(&ds.graph, &seeds, 4, 9, 0);
        assert_eq!(out.pairs, sampler::valid_pairs(&s1));
    }

    #[test]
    fn bf16_storage_stays_close_to_f32() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..64).collect();
        let f32s = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let bf16 = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, true);
        let a = fused_2hop(&ds.graph, &f32s, &seeds, 5, 3, 11, false, 1);
        let b = fused_2hop(&ds.graph, &bf16, &seeds, 5, 3, 11, false, 1);
        for (&x, &y) in a.agg.iter().zip(&b.agg) {
            assert!((x - y).abs() < 0.05 + x.abs() / 32.0, "{x} vs {y}");
        }
        assert_eq!(a.pairs, b.pairs);
    }

    /// The aggregate is linear in X, so the replay backward must satisfy
    /// ⟨g, agg(x+Δ)−agg(x)⟩ == ⟨dX, Δ⟩ up to f32 rounding.
    #[test]
    fn replay_backward_is_the_exact_adjoint() {
        let ds = tiny();
        let (n, d) = (ds.spec.n, ds.spec.d);
        let mut r = SplitMix64::new(77);
        let seeds: Vec<i32> =
            (0..48).map(|_| r.next_below(n as u64) as i32).collect();
        let (k1, k2, base) = (4usize, 3usize, 123u64);
        let feat = Features::from_f32(&ds.features, n, d, false);
        let out = fused_2hop(&ds.graph, &feat, &seeds, k1, k2, base, true, 1);
        let g: Vec<f32> =
            (0..seeds.len() * d).map(|_| r.next_normal() as f32).collect();
        let dx = backward_2hop(out.s1.as_ref().unwrap(),
                               out.s2.as_ref().unwrap(), &g, seeds.len(),
                               k1, k2, n, d);
        // directional check along a random feature perturbation
        let delta: Vec<f32> =
            (0..n * d).map(|_| r.next_normal() as f32 * 0.1).collect();
        let xp: Vec<f32> =
            ds.features.iter().zip(&delta).map(|(&x, &dl)| x + dl).collect();
        let featp = Features::from_f32(&xp, n, d, false);
        let outp = fused_2hop(&ds.graph, &featp, &seeds, k1, k2, base, false, 1);
        let lhs: f64 = outp
            .agg
            .iter()
            .zip(&out.agg)
            .zip(&g)
            .map(|((&ap, &a), &gv)| ((ap - a) * gv) as f64)
            .sum();
        let rhs: f64 =
            dx.iter().zip(&delta).map(|(&dv, &dl)| (dv * dl) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-2 + 0.01 * rhs.abs(),
                "adjoint mismatch: {lhs} vs {rhs}");

        // 1-hop variant
        let out1 = fused_1hop(&ds.graph, &feat, &seeds, k1, base, true, 1);
        let g1 = &g[..seeds.len() * d];
        let dx1 = backward_1hop(out1.samples.as_ref().unwrap(), g1,
                                seeds.len(), k1, n, d);
        let out1p = fused_1hop(&ds.graph, &featp, &seeds, k1, base, false, 1);
        let lhs1: f64 = out1p
            .agg
            .iter()
            .zip(&out1.agg)
            .zip(g1)
            .map(|((&ap, &a), &gv)| ((ap - a) * gv) as f64)
            .sum();
        let rhs1: f64 =
            dx1.iter().zip(&delta).map(|(&dv, &dl)| (dv * dl) as f64).sum();
        assert!((lhs1 - rhs1).abs() < 1e-2 + 0.01 * rhs1.abs(),
                "1-hop adjoint mismatch: {lhs1} vs {rhs1}");
    }
}
