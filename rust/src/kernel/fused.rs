//! The fused sample+aggregate kernel (paper Algorithms 1–2) as native
//! host compute, generic over sampling depth.
//!
//! One pass per seed: neighbors are drawn inline with the counter-hash
//! rule ([`crate::sampler::sample_neighbors`], bitwise identical to the
//! Pallas kernel and the host baseline sampler) and the running
//! mean-of-means is folded innermost-first into a single `[d]` accumulator
//! per hop level — **no** `[B, Π(1+k), d]` block ever exists. The only
//! per-step outputs are the `[B, d]` aggregate, the optional per-hop
//! saved index tensors (`save_indices`, the paper's §3.3
//! deterministic-backward replay), and the sampled-pair count.
//!
//! Depth is a parameter: [`fused_khop`] recurses over the fanout list,
//! each hop level folding its children's aggregate into the parent
//! accumulator scaled by `1/k_eff`. At depths 1 and 2 the floating-point
//! operation sequence is exactly the pre-generalization `fused_1hop` /
//! `fused_2hop` kernels' (pinned bitwise by `rust/tests/depth.rs`).
//!
//! The gather is cache-blocked over the feature dimension
//! ([`super::d_tile`], sized off detected L1d geometry and sweepable via
//! [`super::set_d_tile`]): the accumulator tile stays L1-resident while
//! the sampled rows stream through it. Under `--simd on` (the default
//! via `auto`) the fold runs the [`super::simd`] vector tier — dtype
//! dispatch hoisted out of the row loop, next-row prefetch, 8-lane adds
//! across the feature dimension — and stays bitwise identical to the
//! scalar reference because lanes never cross neighbors.
//! Batch rows are sharded across scoped
//! workers with the expected-subtree cost planner
//! ([`crate::graph::CostModel`]); each worker writes disjoint row ranges
//! of every output, so results are bitwise identical at any thread count
//! and under every planner flavor. Per-shard wall time is measured into
//! [`FusedOut::stats`] — the feedback signal for the adaptive planner
//! and the bench imbalance column.

use crate::fanout::Fanouts;
use crate::graph::{CostModel, Csr, PlannerChoice, ShardStats};
use crate::metrics::Timer;
use crate::runtime::faults::{Fault, FaultSite};
use crate::sampler::sample_neighbors;

use super::hubcache::HubCache;
use super::{d_tile, resolve_threads, simd, Features, RowData, MIN_PAR_ROWS};

/// Output of one fused L-hop aggregation.
pub struct FusedOut {
    /// `[B, d]` L-level mean-of-means aggregate of the leaf features.
    pub agg: Vec<f32>,
    /// Per-hop samples when `save_indices`: `saved[l]` is
    /// `[B, k1·…·k_{l+1}]` (hop `l`'s draws, -1 padded).
    pub saved: Option<Vec<Vec<i32>>>,
    /// Valid (parent, child) draws — matches
    /// [`crate::sampler::fused_sampled_pairs`] exactly.
    pub pairs: u64,
    /// Per-shard wall time + planned cost of this call's batch sharding
    /// (empty when the kernel ran serially). Timing only — the outputs
    /// above are bitwise independent of the plan.
    pub stats: ShardStats,
}

/// Per-worker scratch: reused across the rows of one shard.
struct Scratch {
    /// One sample-row buffer per hop level (`rows[l].len() == k_{l+1}`).
    rows: Vec<Vec<i32>>,
    /// One `[d]` accumulator per non-leaf level below the seed.
    accs: Vec<Vec<f32>>,
    /// Staging buffer for compacting `-1` entries out of a sampled row;
    /// full-degree rows bypass it entirely ([`valid_slice`]).
    valid: Vec<i32>,
    tile: Vec<f32>,
}

impl Scratch {
    fn new(ks: &[usize], d: usize) -> Scratch {
        Scratch {
            rows: ks.iter().map(|&k| vec![-1i32; k]).collect(),
            accs: (0..ks.len().saturating_sub(1))
                .map(|_| vec![0.0f32; d])
                .collect(),
            valid: Vec::with_capacity(ks.iter().copied().max().unwrap_or(1)),
            tile: vec![0.0; d_tile()],
        }
    }
}

/// The valid (non-negative) entries of a sampled row. When the row has
/// no `-1` padding — the common case on hub nodes, whose degree covers
/// the fanout — the row itself is returned and the staging copy is
/// skipped; otherwise the valid ids are compacted into `stage`.
#[inline]
fn valid_slice<'a>(row: &'a [i32], stage: &'a mut Vec<i32>) -> &'a [i32] {
    if row.iter().all(|&v| v >= 0) {
        return row;
    }
    stage.clear();
    stage.extend(row.iter().copied().filter(|&v| v >= 0));
    stage
}

/// Mean of the valid feature rows into `agg_row`; `agg += mean(x[valid])`.
/// `simd_on` selects the vector fold ([`super::simd`], lanes across the
/// feature dimension) or the scalar per-row-dispatch reference; both
/// produce bitwise-identical output because every element sees the same
/// add-per-neighbor-then-scale operation sequence.
#[inline]
pub(crate) fn accumulate_mean(feat: &Features, valid: &[i32],
                              tile: &mut [f32], agg_row: &mut [f32],
                              simd_on: bool) {
    if valid.is_empty() {
        return;
    }
    let inv = 1.0 / valid.len() as f32;
    let d = feat.d;
    let tw = tile.len();
    let mut t0 = 0;
    while t0 < d {
        let t1 = (t0 + tw).min(d);
        let acc = &mut tile[..t1 - t0];
        acc.fill(0.0);
        if simd_on {
            add_rows_vector(feat, valid, t0, t1, acc);
            simd::scale_add(&mut agg_row[t0..t1], acc, inv);
        } else {
            for &w in valid {
                feat.add_row_slice(w as usize, t0, t1, acc);
            }
            for (a, &v) in agg_row[t0..t1].iter_mut().zip(acc.iter()) {
                *a += v * inv;
            }
        }
        t0 = t1;
    }
}

/// The vector gather: dtype dispatch hoisted to one match per tile
/// (monomorphized f32/bf16 loops instead of `add_row_slice`'s per-row
/// re-match), the next valid neighbor row prefetched one iteration
/// ahead, and the element adds running through the SIMD helpers.
fn add_rows_vector(feat: &Features, valid: &[i32], t0: usize, t1: usize,
                   acc: &mut [f32]) {
    let d = feat.d;
    match feat.rows() {
        RowData::F32(x) => {
            for (i, &w) in valid.iter().enumerate() {
                if let Some(&nx) = valid.get(i + 1) {
                    simd::prefetch_f32(x, feat.phys(nx as usize) * d + t0);
                }
                let base = feat.phys(w as usize) * d;
                simd::add_assign_f32(acc, &x[base + t0..base + t1]);
            }
        }
        RowData::Bf16(x) => {
            for (i, &w) in valid.iter().enumerate() {
                if let Some(&nx) = valid.get(i + 1) {
                    simd::prefetch_u16(x, feat.phys(nx as usize) * d + t0);
                }
                let base = feat.phys(w as usize) * d;
                simd::add_assign_bf16(acc, &x[base + t0..base + t1]);
            }
        }
    }
}

/// Fold the nested mean-of-means aggregate of `node`'s sampling subtree
/// into `out` (`out += agg(node)`): at the leaf hop the mean of the valid
/// sampled features goes straight into `out`; at intermediate hops the
/// children's aggregates accumulate into this level's scratch buffer and
/// fold into `out` scaled by `1/k_eff`. `slot` is the node's flattened
/// position among seed-row `bi`'s hop-`hop` samples; together with
/// `kprod[0]` (this level's per-seed width) it addresses the shard-level
/// saved tensors without any per-row slicing. Invalid children are
/// skipped entirely — the counter RNG is stateless and the saved buffers
/// are -1-prefilled, so the result is identical to sampling below them.
#[allow(clippy::too_many_arguments)]
fn fold_subtree(csr: &Csr, feat: &Features, node: i32, hop: u64,
                ks: &[usize], kprod: &[usize], bi: usize, slot: usize,
                base: u64, rows: &mut [Vec<i32>], accs: &mut [Vec<f32>],
                saved: &mut [Option<&mut [i32]>], valid: &mut Vec<i32>,
                tile: &mut [f32], simd_on: bool,
                cache: Option<&HubCache>, out: &mut [f32],
                pairs: &mut u64) {
    let k = ks[0];
    let (row, rows_rest) = rows.split_first_mut().unwrap();
    let (srow, saved_rest) = saved.split_first_mut().unwrap();
    if ks.len() == 1 {
        // Leaf hop: a live hub-cache entry replays the stored draw into
        // the saved tensor, the stored valid count into `pairs`, and the
        // stored exactly-rounded partial mean into `out` — bitwise what
        // the miss path below would have produced (see kernel::hubcache).
        if let Some(e) = cache.and_then(|c| c.lookup(node)) {
            if let Some(buf) = srow.as_deref_mut() {
                let at = bi * kprod[0] + slot * k;
                buf[at..at + k].copy_from_slice(&e.row);
            }
            *pairs += e.valid as u64;
            for (o, &m) in out.iter_mut().zip(e.mean.iter()) {
                *o += m;
            }
            return;
        }
        sample_neighbors(csr, node, k, base, hop, row);
        if let Some(buf) = srow.as_deref_mut() {
            let at = bi * kprod[0] + slot * k;
            buf[at..at + k].copy_from_slice(row);
        }
        let vs = valid_slice(row.as_slice(), valid);
        *pairs += vs.len() as u64;
        accumulate_mean(feat, vs, tile, out, simd_on);
        return;
    }
    sample_neighbors(csr, node, k, base, hop, row);
    if let Some(buf) = srow.as_deref_mut() {
        let at = bi * kprod[0] + slot * k;
        buf[at..at + k].copy_from_slice(row);
    }
    let (acc, accs_rest) = accs.split_first_mut().unwrap();
    acc.fill(0.0);
    let mut eff = 0u64;
    for i in 0..k {
        let child = row[i];
        if child < 0 {
            continue;
        }
        eff += 1;
        *pairs += 1;
        fold_subtree(csr, feat, child, hop + 1, &ks[1..], &kprod[1..], bi,
                     slot * k + i, base, rows_rest, accs_rest, saved_rest,
                     valid, tile, simd_on, cache, acc, pairs);
    }
    let inv = 1.0 / eff.max(1) as f32;
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o += a * inv;
    }
}

/// Serial kernel body for a contiguous run of seed rows (one shard).
/// `saved[l]`, when present, covers exactly these rows (`rows·K_l` ints,
/// `K_l = kprod[l]`). No per-row allocations: scratch is per-shard and
/// the saved tensors are addressed by (row, slot) arithmetic.
#[allow(clippy::too_many_arguments)]
fn run_rows(csr: &Csr, feat: &Features, seeds: &[i32], ks: &[usize],
            kprod: &[usize], base: u64, agg: &mut [f32],
            saved: &mut [Option<&mut [i32]>], pairs: &mut [u64],
            simd_on: bool, cache: Option<&HubCache>) {
    let d = feat.d;
    let mut sc = Scratch::new(ks, d);
    for (bi, &r) in seeds.iter().enumerate() {
        let agg_row = &mut agg[bi * d..(bi + 1) * d];
        let mut np = 0u64;
        fold_subtree(csr, feat, r, 0, ks, kprod, bi, 0, base, &mut sc.rows,
                     &mut sc.accs, saved, &mut sc.valid, &mut sc.tile,
                     simd_on, cache, agg_row, &mut np);
        pairs[bi] = np;
    }
}

/// Split `opt` (when present) at `at`, returning the head and keeping the
/// tail for the next shard.
fn take_chunk<'a>(opt: &mut Option<&'a mut [i32]>, at: usize)
                  -> Option<&'a mut [i32]> {
    opt.take().map(|buf| {
        let (head, tail) = buf.split_at_mut(at);
        *opt = Some(tail);
        head
    })
}

/// Fused L-hop sample+aggregate over a batch of seeds — the single
/// depth-generic kernel (`fanouts.depth()` = 1 reproduces the old 1-hop
/// kernel bitwise, depth 2 the old 2-hop kernel). Plans its batch shards
/// with the default (quantile) cost model; long-lived callers should
/// build one [`CostModel`] and use [`fused_khop_planned`] instead.
pub fn fused_khop(csr: &Csr, feat: &Features, seeds: &[i32],
                  fanouts: &Fanouts, base: u64, save_indices: bool,
                  threads: usize) -> FusedOut {
    let model = CostModel::new(csr, fanouts, PlannerChoice::default());
    fused_khop_planned(csr, feat, seeds, fanouts, base, save_indices,
                       threads, &model)
}

/// [`fused_khop`] with an explicit shard planner, resolving the
/// scalar/vector choice from the process default (`auto`, i.e. the
/// `FSA_SIMD` override or the vector path). The plan decides only
/// *where* the contiguous seed-range cuts land — every worker writes a
/// disjoint slice of every output and the counter RNG is
/// order-independent, so `agg`/`saved`/`pairs` are bitwise identical
/// under every [`CostModel`] flavor and thread count (pinned by
/// `rust/tests/planner.rs`).
#[allow(clippy::too_many_arguments)]
pub fn fused_khop_planned(csr: &Csr, feat: &Features, seeds: &[i32],
                          fanouts: &Fanouts, base: u64, save_indices: bool,
                          threads: usize, model: &CostModel) -> FusedOut {
    fused_khop_simd(csr, feat, seeds, fanouts, base, save_indices, threads,
                    model, simd::SimdChoice::Auto.enabled())
}

/// [`fused_khop_planned`] with the `--simd` knob resolved explicitly:
/// `simd_on` picks the vector gather/fold (dispatch-hoisted, prefetched,
/// 8-lane folds across the feature dimension) or the scalar
/// per-row-dispatch reference. The two paths are bitwise identical in
/// `agg`/`saved`/`pairs` at every depth, thread count and planner
/// (pinned by `rust/tests/simd.rs`); only step time moves.
#[allow(clippy::too_many_arguments)]
pub fn fused_khop_simd(csr: &Csr, feat: &Features, seeds: &[i32],
                       fanouts: &Fanouts, base: u64, save_indices: bool,
                       threads: usize, model: &CostModel, simd_on: bool)
                       -> FusedOut {
    fused_khop_cached(csr, feat, seeds, fanouts, base, save_indices,
                      threads, model, simd_on, None)
}

/// [`fused_khop_simd`] with an optional [`HubCache`]: leaf-hop calls on
/// cached hub nodes replay the stored draw + partial mean instead of
/// re-gathering. The cache is consulted read-only (shard workers share
/// one `&HubCache`); the caller is responsible for having `prepare`d it
/// for this pass's `(base, leaf hop, leaf k)` generation — entries from
/// any other generation were already evicted there, so a stale replay is
/// impossible by construction. With `cache` = `None` this *is*
/// [`fused_khop_simd`], and every output is bitwise identical either way
/// (pinned by `rust/tests/hubcache.rs`).
#[allow(clippy::too_many_arguments)]
pub fn fused_khop_cached(csr: &Csr, feat: &Features, seeds: &[i32],
                         fanouts: &Fanouts, base: u64, save_indices: bool,
                         threads: usize, model: &CostModel, simd_on: bool,
                         cache: Option<&HubCache>) -> FusedOut {
    let b = seeds.len();
    let d = feat.d;
    let ks = fanouts.as_slice();
    let kprod = fanouts.cumulative();
    let mut agg = vec![0.0f32; b * d];
    let mut pairs = vec![0u64; b];
    let mut stats = ShardStats::default();
    let mut saved_bufs: Vec<Vec<i32>> = if save_indices {
        kprod.iter().map(|&kp| vec![-1i32; b * kp]).collect()
    } else {
        Vec::new()
    };
    {
        let mut view: Vec<Option<&mut [i32]>> = if save_indices {
            saved_bufs.iter_mut().map(|v| Some(v.as_mut_slice())).collect()
        } else {
            ks.iter().map(|_| None).collect()
        };
        let workers = resolve_threads(threads).min((b / MIN_PAR_ROWS).max(1));
        if workers <= 1 {
            run_rows(csr, feat, seeds, ks, &kprod, base, &mut agg, &mut view,
                     &mut pairs, simd_on, cache);
        } else {
            // cost model: expected row-adds of the whole nested subtree
            // below each seed (nominal flavor: full-fanout weights)
            let costs: Vec<u64> =
                seeds.iter().map(|&r| model.seed_cost(csr, r)).collect();
            let plan = model.plan(&costs, workers);
            let mut shard_ms = vec![0.0f64; plan.len()];
            let shard_cost: Vec<u64> = plan
                .iter()
                .map(|r| costs[r.clone()].iter().sum())
                .collect();
            // per-shard timing goes through the model's clock seam
            // (WallClock in production; tests script a VirtualClock to
            // make the adaptive feedback loop deterministic); faults
            // through its fault seam (no-op plane in production)
            let clock = model.clock();
            let faults = model.faults();
            let pass = faults.begin(FaultSite::KernelWorker);
            let plan_ranges = plan.clone();
            let mut failed = vec![false; plan_ranges.len()];
            std::thread::scope(|s| {
                let mut agg_rest: &mut [f32] = &mut agg;
                let mut pairs_rest: &mut [u64] = &mut pairs;
                let mut ms_rest: &mut [f64] = &mut shard_ms;
                let mut failed_rest: &mut [bool] = &mut failed;
                let mut view_rest: Vec<Option<&mut [i32]>> =
                    view.iter_mut().map(|o| o.as_deref_mut()).collect();
                for (j, r) in plan.into_iter().enumerate() {
                    let rows = r.end - r.start;
                    let (agg_c, tail) =
                        std::mem::take(&mut agg_rest).split_at_mut(rows * d);
                    agg_rest = tail;
                    let mut saved_c: Vec<Option<&mut [i32]>> = view_rest
                        .iter_mut()
                        .zip(&kprod)
                        .map(|(o, &kp)| take_chunk(o, rows * kp))
                        .collect();
                    let (pairs_c, tail) =
                        std::mem::take(&mut pairs_rest).split_at_mut(rows);
                    pairs_rest = tail;
                    let (ms_c, tail) =
                        std::mem::take(&mut ms_rest).split_at_mut(1);
                    ms_rest = tail;
                    let (fail_c, tail) =
                        std::mem::take(&mut failed_rest).split_at_mut(1);
                    failed_rest = tail;
                    if rows == 0 {
                        continue;
                    }
                    let seed_c = &seeds[r];
                    let kprod_ref = &kprod;
                    let clock = clock.clone();
                    let faults = faults.clone();
                    let cost_j = shard_cost[j];
                    s.spawn(move || {
                        let t = Timer::start();
                        let res = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                match faults.fault(FaultSite::KernelWorker,
                                                   pass, j) {
                                    Fault::Stall(ms) => std::thread::sleep(
                                        std::time::Duration::from_millis(ms)),
                                    Fault::Panic | Fault::Error => {
                                        panic!("chaos: injected kernel \
                                                panic (op {pass}, worker \
                                                {j})")
                                    }
                                    _ => {}
                                }
                                run_rows(csr, feat, seed_c, ks, kprod_ref,
                                         base, agg_c, &mut saved_c, pairs_c,
                                         simd_on, cache);
                            }));
                        fail_c[0] = res.is_err();
                        ms_c[0] = clock.shard_ms(j, cost_j, t.ms());
                    });
                }
            });
            // Recovery: any shard whose worker panicked is reset and
            // recomputed serially — the counter RNG is stateless, so the
            // redo is bitwise identical to an undisturbed run of that
            // shard (the budgeted-refresh framing: recovery work is
            // exactly the failed shard, nothing more).
            for (j, r) in plan_ranges.iter().enumerate() {
                if !failed[j] {
                    continue;
                }
                eprintln!("warning: kernel shard worker {j} panicked; \
                           recomputing rows {}..{} serially",
                          r.start, r.end);
                agg[r.start * d..r.end * d].fill(0.0);
                pairs[r.start..r.end].fill(0);
                let mut saved_c: Vec<Option<&mut [i32]>> = view
                    .iter_mut()
                    .zip(&kprod)
                    .map(|(o, &kp)| {
                        o.as_deref_mut().map(|buf| {
                            let sl = &mut buf[r.start * kp..r.end * kp];
                            sl.fill(-1);
                            sl
                        })
                    })
                    .collect();
                run_rows(csr, feat, &seeds[r.clone()], ks, &kprod, base,
                         &mut agg[r.start * d..r.end * d], &mut saved_c,
                         &mut pairs[r.start..r.end], simd_on, cache);
            }
            stats = ShardStats::new(shard_ms, shard_cost);
        }
    }
    FusedOut {
        agg,
        saved: save_indices.then_some(saved_bufs),
        pairs: pairs.iter().sum(),
        stats,
    }
}

/// Parity helper: the 1-hop mean aggregate of `seeds` drawn at an explicit
/// hop counter (the fused multi-hop inner loop draws hop `l` at counter
/// `l`; the golden parity tests compare baseline block means against
/// this). Serial.
pub fn fused_1hop_at_hop(csr: &Csr, feat: &Features, seeds: &[i32], k: usize,
                         base: u64, hop: u64) -> Vec<f32> {
    let d = feat.d;
    let simd_on = simd::SimdChoice::Auto.enabled();
    let mut agg = vec![0.0f32; seeds.len() * d];
    let mut sc = Scratch::new(&[k], d);
    for (bi, &r) in seeds.iter().enumerate() {
        let row = &mut sc.rows[0];
        sample_neighbors(csr, r, k, base, hop, row);
        let vs = valid_slice(row.as_slice(), &mut sc.valid);
        accumulate_mean(feat, vs, &mut sc.tile,
                        &mut agg[bi * d..(bi + 1) * d], simd_on);
    }
    agg
}

// ---------------------------------------------------------------------------
// saved-index replay backward (paper §3.3) — dX for the fused op.
//
// Not on the training path (features are not trainable parameters); used
// by the gradient tests to pin the replay weights 1/Π(k_eff along the
// path) against direct differentiation of the aggregate.
// ---------------------------------------------------------------------------

/// Recursive replay: distribute `g` (the seed's upstream row) over the
/// valid leaves below slot `slot` of hop tensor `level`, each weighted by
/// the inverse product of the effective fanouts along its path.
#[allow(clippy::too_many_arguments)]
fn replay(saved: &[Vec<i32>], ks: &[usize], kprod: &[usize], bi: usize,
          level: usize, slot: usize, denom: u64, g: &[f32], dx: &mut [f32],
          d: usize) {
    let k = ks[level];
    let row = &saved[level][bi * kprod[level] + slot * k..][..k];
    let eff = row.iter().filter(|&&v| v >= 0).count().max(1) as u64;
    if level + 1 == ks.len() {
        let wgt = 1.0 / (denom * eff) as f32;
        for &v in row.iter().filter(|&&v| v >= 0) {
            let dst = &mut dx[v as usize * d..(v as usize + 1) * d];
            for (dv, &gv) in dst.iter_mut().zip(g) {
                *dv += wgt * gv;
            }
        }
        return;
    }
    for (i, &c) in row.iter().enumerate() {
        if c < 0 {
            continue;
        }
        replay(saved, ks, kprod, bi, level + 1, slot * k + i, denom * eff, g,
               dx, d);
    }
}

/// `dX[n,d]` from the saved L-hop indices and upstream `g[b,d]` — the
/// exact adjoint of the aggregate (which is linear in X).
pub fn backward_khop(saved: &[Vec<i32>], g: &[f32], b: usize,
                     fanouts: &Fanouts, n: usize, d: usize) -> Vec<f32> {
    let ks = fanouts.as_slice();
    let kprod = fanouts.cumulative();
    debug_assert_eq!(saved.len(), ks.len());
    for (s, &kp) in saved.iter().zip(&kprod) {
        debug_assert_eq!(s.len(), b * kp);
    }
    debug_assert_eq!(g.len(), b * d);
    let mut dx = vec![0.0f32; n * d];
    for bi in 0..b {
        replay(saved, ks, &kprod, bi, 0, 0, 1, &g[bi * d..(bi + 1) * d],
               &mut dx, d);
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;
    use crate::sampler;

    fn tiny() -> Dataset {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
    }

    /// Reference L-hop aggregate computed the *materialized* way: sample
    /// every hop tensor with the host sampler, then nested masked means in
    /// f64.
    fn reference_agg(ds: &Dataset, seeds: &[i32], fanouts: &Fanouts,
                     base: u64) -> Vec<f32> {
        let d = ds.spec.d;
        let depth = fanouts.depth();
        let mut hops: Vec<Vec<i32>> = Vec::new();
        let mut frontier = seeds.to_vec();
        for l in 0..depth {
            let s = sampler::sample_frontier(&ds.graph, &frontier,
                                             fanouts.k(l), base, l as u64);
            hops.push(s.clone());
            frontier = s;
        }
        // recursive nested mean over the materialized tensors
        fn node_agg(ds: &Dataset, hops: &[Vec<i32>], fanouts: &Fanouts,
                    level: usize, slot: usize, bi: usize, d: usize)
                    -> Option<Vec<f64>> {
            let k = fanouts.k(level);
            let kprod: usize = fanouts.as_slice()[..=level].iter().product();
            let row = &hops[level][bi * kprod + slot * k..][..k];
            if level + 1 == fanouts.depth() {
                let valid: Vec<i32> =
                    row.iter().copied().filter(|&v| v >= 0).collect();
                if valid.is_empty() {
                    return None;
                }
                let mut out = vec![0.0f64; d];
                for &v in &valid {
                    for j in 0..d {
                        out[j] += ds.features[v as usize * d + j] as f64
                            / valid.len() as f64;
                    }
                }
                return Some(out);
            }
            let mut out = vec![0.0f64; d];
            let mut eff = 0usize;
            for (i, &c) in row.iter().enumerate() {
                if c < 0 {
                    continue;
                }
                eff += 1;
                if let Some(sub) = node_agg(ds, hops, fanouts, level + 1,
                                            slot * k + i, bi, d) {
                    for j in 0..d {
                        out[j] += sub[j];
                    }
                }
            }
            if eff == 0 {
                return Some(out);
            }
            for o in out.iter_mut() {
                *o /= eff as f64;
            }
            Some(out)
        }
        // note the kernel folds a hop-0 aggregate with eff==0 to zeros and
        // (for depth >= 2) divides by max(1, eff); the reference mirrors
        // that by returning zeros from empty subtrees
        let mut agg = vec![0.0f32; seeds.len() * d];
        for bi in 0..seeds.len() {
            let v = node_agg(ds, &hops, fanouts, 0, 0, bi, d)
                .unwrap_or_else(|| vec![0.0; d]);
            for j in 0..d {
                agg[bi * d + j] = v[j] as f32;
            }
        }
        agg
    }

    #[test]
    fn fused_matches_materialized_reference_at_depths_1_2_3() {
        let ds = tiny();
        let mut r = SplitMix64::new(5);
        let seeds: Vec<i32> =
            (0..96).map(|_| r.next_below(ds.spec.n as u64) as i32).collect();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        for fo in [Fanouts::of(&[5]), Fanouts::of(&[5, 3]),
                   Fanouts::of(&[4, 3, 2])] {
            let out = fused_khop(&ds.graph, &feat, &seeds, &fo, 42, true, 1);
            let want = reference_agg(&ds, &seeds, &fo, 42);
            for (i, (&a, &w)) in out.agg.iter().zip(&want).enumerate() {
                assert!((a - w).abs() < 1e-4, "{fo} agg[{i}]: {a} vs {w}");
            }
            // saved indices equal the host sampler's draws, hop by hop
            let saved = out.saved.unwrap();
            let mut frontier = seeds.clone();
            for (l, s) in saved.iter().enumerate() {
                let want_s = sampler::sample_frontier(&ds.graph, &frontier,
                                                      fo.k(l), 42, l as u64);
                assert_eq!(s, &want_s, "{fo} hop {l} saved indices");
                frontier = want_s;
            }
            assert_eq!(out.pairs,
                       sampler::fused_sampled_pairs(&ds.graph, &seeds, &fo,
                                                    42),
                       "{fo} pair count");
        }
    }

    #[test]
    fn fused_bitwise_identical_across_thread_counts() {
        let ds = tiny();
        let seeds: Vec<i32> =
            (0..200).map(|i| (i * 2) % ds.spec.n as i32).collect();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        for fo in [Fanouts::of(&[4]), Fanouts::of(&[4, 3]),
                   Fanouts::of(&[4, 3, 2])] {
            let serial = fused_khop(&ds.graph, &feat, &seeds, &fo, 7, true, 1);
            for threads in [2usize, 3, 4, 8] {
                let par = fused_khop(&ds.graph, &feat, &seeds, &fo, 7, true,
                                     threads);
                assert_eq!(par.agg, serial.agg, "{fo} threads={threads}");
                assert_eq!(par.saved, serial.saved);
                assert_eq!(par.pairs, serial.pairs);
            }
        }
    }

    #[test]
    fn fused_1hop_means_valid_neighbors() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..64).collect();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let out = fused_khop(&ds.graph, &feat, &seeds, &Fanouts::of(&[4]), 9,
                             true, 1);
        let saved = out.saved.unwrap();
        let samples = &saved[0];
        let d = ds.spec.d;
        for bi in 0..seeds.len() {
            let valid: Vec<i32> = samples[bi * 4..(bi + 1) * 4]
                .iter()
                .copied()
                .filter(|&v| v >= 0)
                .collect();
            for j in (0..d).step_by(5) {
                let want: f32 = if valid.is_empty() {
                    0.0
                } else {
                    valid.iter()
                        .map(|&v| ds.features[v as usize * d + j])
                        .sum::<f32>() / valid.len() as f32
                };
                let got = out.agg[bi * d + j];
                assert!((got - want).abs() < 1e-4, "row {bi} dim {j}");
            }
        }
        let s1 = sampler::sample_frontier(&ds.graph, &seeds, 4, 9, 0);
        assert_eq!(out.pairs, sampler::valid_pairs(&s1));
    }

    #[test]
    fn bf16_storage_stays_close_to_f32() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..64).collect();
        let f32s = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let bf16 = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, true);
        let fo = Fanouts::of(&[5, 3]);
        let a = fused_khop(&ds.graph, &f32s, &seeds, &fo, 11, false, 1);
        let b = fused_khop(&ds.graph, &bf16, &seeds, &fo, 11, false, 1);
        for (&x, &y) in a.agg.iter().zip(&b.agg) {
            assert!((x - y).abs() < 0.05 + x.abs() / 32.0, "{x} vs {y}");
        }
        assert_eq!(a.pairs, b.pairs);
    }

    /// The aggregate is linear in X, so the replay backward must satisfy
    /// ⟨g, agg(x+Δ)−agg(x)⟩ == ⟨dX, Δ⟩ up to f32 rounding — at every depth.
    #[test]
    fn replay_backward_is_the_exact_adjoint() {
        let ds = tiny();
        let (n, d) = (ds.spec.n, ds.spec.d);
        let mut r = SplitMix64::new(77);
        let seeds: Vec<i32> =
            (0..48).map(|_| r.next_below(n as u64) as i32).collect();
        let base = 123u64;
        let feat = Features::from_f32(&ds.features, n, d, false);
        let g: Vec<f32> =
            (0..seeds.len() * d).map(|_| r.next_normal() as f32).collect();
        let delta: Vec<f32> =
            (0..n * d).map(|_| r.next_normal() as f32 * 0.1).collect();
        let xp: Vec<f32> =
            ds.features.iter().zip(&delta).map(|(&x, &dl)| x + dl).collect();
        let featp = Features::from_f32(&xp, n, d, false);
        for fo in [Fanouts::of(&[4]), Fanouts::of(&[4, 3]),
                   Fanouts::of(&[3, 3, 2])] {
            let out = fused_khop(&ds.graph, &feat, &seeds, &fo, base, true, 1);
            let dx = backward_khop(out.saved.as_ref().unwrap(), &g,
                                   seeds.len(), &fo, n, d);
            let outp = fused_khop(&ds.graph, &featp, &seeds, &fo, base,
                                  false, 1);
            let lhs: f64 = outp
                .agg
                .iter()
                .zip(&out.agg)
                .zip(&g)
                .map(|((&ap, &a), &gv)| ((ap - a) * gv) as f64)
                .sum();
            let rhs: f64 =
                dx.iter().zip(&delta).map(|(&dv, &dl)| (dv * dl) as f64).sum();
            assert!((lhs - rhs).abs() < 1e-2 + 0.01 * rhs.abs(),
                    "{fo}: adjoint mismatch {lhs} vs {rhs}");
        }
    }
}
