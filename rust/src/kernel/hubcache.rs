//! Hub-aggregate cache — budgeted reuse of innermost-hop partial means
//! on skewed graphs (the top ROADMAP item, grounded in the budgeted
//! one-pass neighborhood-estimation paper, arxiv 2511.13645).
//!
//! On power-law graphs a tiny set of hub nodes dominates leaf-hop gather
//! cost: every batch and every serve request re-draws and re-folds the
//! same high-degree neighborhoods. This cache stores, per hub node, the
//! leaf-hop sampled row *and* its folded partial mean, keyed by the
//! generation triple `(base seed-epoch, leaf hop counter, leaf fanout)`.
//! Because the counter RNG is stateless — `sample_neighbors(csr, node,
//! k, base, hop)` is a pure function of exactly that triple plus the
//! node — an entry is valid for every leaf-hop call of every kernel
//! pass that shares the triple, and invalidation is deterministic: when
//! the trainer advances its per-step base seed (or an eval pass switches
//! to the fixed [`crate::engine::Engine::infer_base`] epoch), the triple
//! changes and [`HubCache::prepare`] drops every stale entry at once.
//!
//! Bitwise contract (pinned by `rust/tests/hubcache.rs`): a cache hit
//! replays `row` into the saved-index tensors, adds `valid` to the pair
//! count, and adds `mean` to the seed accumulator. `mean` was produced
//! by [`crate::kernel::fused::accumulate_mean`] into a *zeroed* buffer,
//! so each element is exactly `round(acc * inv)` — the same
//! mul-then-add value (deliberately no FMA, see `simd::scale_add`) the
//! miss path would have folded in. Hits therefore change no output bit
//! anywhere: aggregates, saved indices, pair counts, and the replayed
//! backward all match cache-off exactly.
//!
//! Refresh budget (the 2511.13645 framing): [`HubCache::prepare`] runs
//! serially *before* the sharded kernel pass and (re)computes at most
//! `budget` missing entries per call, hottest hubs first — so per-step
//! cache maintenance cost is bounded and a budget of 0 degenerates to
//! cache-off behavior bitwise. During the pass the cache is read-only
//! (`&HubCache` is `Sync`; hit/miss counters are relaxed atomics), so
//! shard workers never contend on it.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::graph::Csr;
use crate::sampler::sample_neighbors;

use super::fused::accumulate_mean;
use super::{d_tile, Features};

/// Hubs kept per graph, after thresholding. Bounds memory (one `[d]`
/// mean per hub) and keeps the budgeted prepare scan short.
const MAX_HUBS: usize = 4096;

/// One cached leaf-hop aggregate for one hub node.
pub struct HubEntry {
    /// The node's sampled leaf row (`k` ids, -1 padded) — replayed into
    /// the saved-index tensors on a hit so backward stays exact.
    pub row: Vec<i32>,
    /// Count of valid (non-negative) ids in `row`.
    pub valid: u32,
    /// `[d]` partial mean of the valid rows' features, rounded exactly
    /// as the miss path would fold it.
    pub mean: Vec<f32>,
}

/// The per-backend hub-aggregate cache. Owned mutably by the native
/// backend (which calls [`HubCache::prepare`] between steps) and read
/// concurrently by kernel shard workers during a pass.
pub struct HubCache {
    /// Max entries (re)computed per `prepare` call; 0 = never populate.
    budget: usize,
    /// Hub node ids, degree-descending (ties id-ascending).
    hubs: Vec<u32>,
    /// node id -> slot in `hubs`/`entries`, -1 for non-hubs.
    slot_of: Vec<i32>,
    /// One optional entry per hub slot.
    entries: Vec<Option<HubEntry>>,
    /// Generation key: (base seed-epoch, leaf hop counter, leaf fanout).
    generation: Option<(u64, u64, usize)>,
    hits: AtomicU64,
    misses: AtomicU64,
    refreshes: u64,
}

impl HubCache {
    /// Select the hubs from the graph's [`crate::graph::DegreeSummary`]
    /// sketch: nodes strictly above the lowest edge-mass quantile bound
    /// that are also at least 2x the mean degree. The quantile bound
    /// filters degenerate summaries; the 2x-mean test is what leaves
    /// uniform graphs with few or no hubs — the neutrality guard. (The
    /// *top* quantile bound would be too aggressive: under extreme Zipf
    /// skew the heaviest 1/8 of edge mass sits on a single node, and the
    /// cache would miss the hundreds of mid-tail hubs that still carry
    /// most of the traffic.)
    pub fn new(csr: &Csr, budget: usize) -> HubCache {
        let summary = csr.degree_summary();
        let uppers = summary.bucket_uppers();
        let floor = uppers.first().copied().unwrap_or(i32::MAX);
        let total_deg: u64 = (0..csr.n as i32).map(|u| csr.degree(u) as u64).sum();
        let mean_deg = total_deg as f64 / csr.n.max(1) as f64;
        let mut hubs: Vec<u32> = (0..csr.n as i32)
            .filter(|&u| {
                let d = csr.degree(u);
                d > floor && d as f64 >= 2.0 * mean_deg
            })
            .map(|u| u as u32)
            .collect();
        hubs.sort_by_key(|&u| (-(csr.degree(u as i32) as i64), u));
        hubs.truncate(MAX_HUBS);
        let mut slot_of = vec![-1i32; csr.n];
        for (s, &u) in hubs.iter().enumerate() {
            slot_of[u as usize] = s as i32;
        }
        let entries = hubs.iter().map(|_| None).collect();
        HubCache { budget, hubs, slot_of, entries, generation: None,
                   hits: AtomicU64::new(0), misses: AtomicU64::new(0),
                   refreshes: 0 }
    }

    /// Roll the cache to the generation `(base, hop, k)` and spend up to
    /// the refresh budget filling missing entries, hottest hubs first.
    /// A changed triple evicts *every* entry (the counter RNG makes all
    /// of them stale at once); an unchanged triple only tops up — the
    /// serve path's cross-request warm-up, since eval passes share one
    /// fixed base seed per session.
    pub fn prepare(&mut self, csr: &Csr, feat: &Features, base: u64,
                   hop: u64, k: usize, simd_on: bool) {
        if self.generation != Some((base, hop, k)) {
            for e in self.entries.iter_mut() {
                *e = None;
            }
            self.generation = Some((base, hop, k));
        }
        if self.budget == 0 {
            return;
        }
        let d = feat.d;
        let mut row = vec![-1i32; k];
        let mut valid: Vec<i32> = Vec::with_capacity(k);
        let mut tile = vec![0.0f32; d_tile()];
        let mut spent = 0usize;
        for slot in 0..self.hubs.len() {
            if spent >= self.budget {
                break;
            }
            if self.entries[slot].is_some() {
                continue;
            }
            let node = self.hubs[slot] as i32;
            sample_neighbors(csr, node, k, base, hop, &mut row);
            valid.clear();
            valid.extend(row.iter().copied().filter(|&v| v >= 0));
            // a zeroed target makes accumulate_mean's fold land each
            // element at exactly round(acc * inv) — the value a miss
            // would have added (see the module docs)
            let mut mean = vec![0.0f32; d];
            accumulate_mean(feat, &valid, &mut tile, &mut mean, simd_on);
            self.entries[slot] = Some(HubEntry {
                row: row.clone(),
                valid: valid.len() as u32,
                mean,
            });
            self.refreshes += 1;
            spent += 1;
        }
    }

    /// Consult the cache for one leaf-hop call. Counts a hit when the
    /// node has a live entry in the current generation, a miss
    /// otherwise (including non-hub nodes — the denominator of the
    /// reported hit rate is *every* leaf-hop call).
    #[inline]
    pub fn lookup(&self, node: i32) -> Option<&HubEntry> {
        let entry = if node >= 0 && (node as usize) < self.slot_of.len() {
            match self.slot_of[node as usize] {
                s if s >= 0 => self.entries[s as usize].as_ref(),
                _ => None,
            }
        } else {
            None
        };
        match entry {
            Some(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Cumulative (hits, misses, refreshes) since construction. Callers
    /// that want per-step deltas snapshot around the step.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.hits.load(Ordering::Relaxed),
         self.misses.load(Ordering::Relaxed), self.refreshes)
    }

    /// Number of hub nodes under management.
    pub fn hub_count(&self) -> usize {
        self.hubs.len()
    }

    /// Number of live entries in the current generation.
    pub fn live_entries(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    /// The current generation triple (tests / diagnostics).
    pub fn generation(&self) -> Option<(u64, u64, usize)> {
        self.generation
    }

    /// The per-prepare refresh budget.
    pub fn budget(&self) -> usize {
        self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};

    fn dataset(name: &str) -> Dataset {
        Dataset::generate(builtin_spec(name).unwrap()).unwrap()
    }

    fn feats(ds: &Dataset) -> Features {
        Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false)
    }

    #[test]
    fn hubs_are_degree_sorted_and_skew_only() {
        let skew = dataset("arxiv_sim");
        let cache = HubCache::new(&skew.graph, 64);
        assert!(cache.hub_count() > 0, "power-law graph must have hubs");
        assert!(cache.hub_count() <= MAX_HUBS);
        let degs: Vec<i32> = cache
            .hubs
            .iter()
            .map(|&u| skew.graph.degree(u as i32))
            .collect();
        for w in degs.windows(2) {
            assert!(w[0] >= w[1], "hubs not degree-descending: {w:?}");
        }
        // every hub clears both thresholds by construction
        let stats = skew.graph.degree_stats();
        for &d in &degs {
            assert!(d as f64 >= 2.0 * stats.mean * 0.99,
                    "hub degree {d} below 2x mean {}", stats.mean);
        }
        // the uniform fixture has no degree skew: no hubs, so the cache
        // is structurally inert there
        let flat = dataset("tiny");
        let none = HubCache::new(&flat.graph, 64);
        assert_eq!(none.hub_count(), 0, "uniform graph grew hubs");
        // the Zipf serving fixture concentrates traffic on a mid-sized
        // hub set — the regime the cache is built for: enough hubs that
        // a budgeted prepare matters, few enough to stay under the cap
        let zipf = dataset("zipf_serve");
        let zc = HubCache::new(&zipf.graph, 64);
        assert!(zc.hub_count() >= 100 && zc.hub_count() <= MAX_HUBS,
                "zipf hub count {}", zc.hub_count());
        // those hubs carry a large share of the edge mass (what makes
        // leaf-hop lookups hit): at least a third of all edges
        let total: u64 = (0..zipf.spec.n as i32)
            .map(|u| zipf.graph.degree(u) as u64)
            .sum();
        let hub_mass: u64 = zc
            .hubs
            .iter()
            .map(|&u| zipf.graph.degree(u as i32) as u64)
            .sum();
        assert!(hub_mass as f64 >= total as f64 / 3.0,
                "zipf hubs carry only {hub_mass}/{total} edges");
    }

    #[test]
    fn prepare_respects_budget_and_generation() {
        let ds = dataset("arxiv_sim");
        let feat = feats(&ds);
        let mut cache = HubCache::new(&ds.graph, 3);
        cache.prepare(&ds.graph, &feat, 42, 1, 10, false);
        assert_eq!(cache.live_entries(), 3.min(cache.hub_count()));
        assert_eq!(cache.generation(), Some((42, 1, 10)));
        // same generation: tops up, never recomputes live entries
        cache.prepare(&ds.graph, &feat, 42, 1, 10, false);
        assert_eq!(cache.live_entries(), 6.min(cache.hub_count()));
        let (_, _, refreshes) = cache.counters();
        assert_eq!(refreshes as usize, cache.live_entries());
        // epoch rollover: every entry evicted, then refilled from the
        // hottest hub under the same budget
        cache.prepare(&ds.graph, &feat, 43, 1, 10, false);
        assert_eq!(cache.live_entries(), 3.min(cache.hub_count()));
        assert_eq!(cache.generation(), Some((43, 1, 10)));
        // a fanout change is its own epoch, too
        cache.prepare(&ds.graph, &feat, 43, 1, 5, false);
        assert_eq!(cache.generation(), Some((43, 1, 5)));
    }

    #[test]
    fn budget_zero_never_populates() {
        let ds = dataset("arxiv_sim");
        let feat = feats(&ds);
        let mut cache = HubCache::new(&ds.graph, 0);
        cache.prepare(&ds.graph, &feat, 42, 1, 10, false);
        assert_eq!(cache.live_entries(), 0);
        let hub = cache.hubs.first().copied().unwrap() as i32;
        assert!(cache.lookup(hub).is_none());
        let (hits, misses, refreshes) = cache.counters();
        assert_eq!((hits, refreshes), (0, 0));
        assert_eq!(misses, 1);
    }

    #[test]
    fn cached_entry_replays_the_sampler_draw_exactly() {
        let ds = dataset("arxiv_sim");
        let feat = feats(&ds);
        let mut cache = HubCache::new(&ds.graph, 8);
        let (base, hop, k) = (7u64, 2u64, 10usize);
        cache.prepare(&ds.graph, &feat, base, hop, k, false);
        let hub = cache.hubs[0] as i32;
        let entry = cache.lookup(hub).expect("hottest hub must be cached");
        let mut want = vec![-1i32; k];
        sample_neighbors(&ds.graph, hub, k, base, hop, &mut want);
        assert_eq!(entry.row, want);
        assert_eq!(entry.valid as usize,
                   want.iter().filter(|&&v| v >= 0).count());
        let (hits, misses, _) = cache.counters();
        assert_eq!((hits, misses), (1, 0));
        // a non-hub lookup is a miss, never a panic
        let non_hub = (0..ds.spec.n as i32)
            .find(|&u| cache.slot_of[u as usize] < 0)
            .unwrap();
        assert!(cache.lookup(non_hub).is_none());
        assert!(cache.lookup(-1).is_none());
    }
}
