//! [`NativeBackend`] — the native CPU implementation of the
//! [`crate::runtime::backend::Backend`] seam: a full synchronized train
//! step (forward + backward + AdamW) as real host compute, no PJRT
//! artifacts required, at any sampling depth.
//!
//! Two step variants, sharing seeds, base-seed schedule, and the
//! counter-hash sampling rule with the PJRT path:
//!
//! * **fused** ([`super::fused`]): sampling + nested mean aggregation in
//!   one pass over the whole fanout list; a `[B,d]` aggregate and
//!   (optionally) the per-hop saved index tensors are the only per-step
//!   intermediates. The model is the depth-independent SAGE head
//!   (`x_self`, multi-hop aggregate → hidden → logits);
//! * **baseline** ([`super::baseline`]): consumes the host-sampled
//!   [`crate::sampler::Block`] from the batch pipeline, materializes the
//!   dense feature gathers, and runs an L-layer SAGE stack — exactly the
//!   DGL-style pipeline the paper measures against, with one parameter
//!   triple (w_self, w_neigh, b) per layer and AdamW state keyed per
//!   tensor.
//!
//! All transient buffers are recorded in the coordinator's
//! [`MemoryMeter`], so `StepTiming::transient_bytes` is a *measured*
//! quantity on this backend (the PJRT path still adds its analytic
//! executable-internal model).

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::fanout::Fanouts;
use crate::gen::Dataset;
use crate::graph::{lock_model, CostModel, PlannerChoice, ShardStats,
                   SharedCostModel};
use crate::memory::MemoryMeter;
use crate::metrics::Timer;
use crate::runtime::backend::{Backend, StepInputs, StepOutcome};
use crate::runtime::faults::FaultPlane;
use crate::runtime::init_params;
use crate::runtime::manifest::AdamwConfig;
use crate::sampler;

use super::hubcache::HubCache;
use super::linalg::{add_bias, col_sum, matmul, matmul_a_bt, matmul_at_b, relu};
use super::{adamw_update, baseline, dgl_param_specs, fsa_param_specs, fused,
            softmax_xent, FeatureLayout, Features, SimdChoice};

const F32: u64 = 4;
const I32: u64 = 4;

/// Evaluation fanouts for a model of the given depth: the classic 15-10
/// protocol for the first two hops (mirroring the `*_eval_*_f15x10_b512`
/// AOT artifacts), 5 for every deeper hop. Both variants evaluate at the
/// same depth-matched fanout. At depth 2 this is exactly the AOT eval
/// protocol, so accuracies are comparable across the backend seam; at
/// other depths the protocol (and, for the baseline, the model itself —
/// one SAGE layer per hop vs the fixed two-layer dgl1 artifacts) is
/// native-only until L-hop manifests land (ROADMAP).
pub fn eval_fanouts(depth: usize) -> Fanouts {
    const BASE: [usize; 2] = [15, 10];
    Fanouts::of(&(0..depth)
        .map(|l| BASE.get(l).copied().unwrap_or(5))
        .collect::<Vec<_>>())
}

/// Configuration of a native training session (the subset of `TrainConfig`
/// the engine needs, kept separate so `bench`/tests can construct it
/// without the coordinator).
#[derive(Clone, Debug)]
pub struct NativeConfig {
    /// Fused sample+aggregate (fsa) vs block-materializing baseline (dgl).
    pub fused: bool,
    /// Per-hop fanouts; depth = number of hops (and, for the baseline,
    /// SAGE layers).
    pub fanouts: Fanouts,
    /// bf16 feature storage (the paper's AMP setting; accumulate stays f32).
    pub amp: bool,
    /// Keep the sampled index tensors per step (§3.3 replay backward).
    pub save_indices: bool,
    pub seed: u64,
    /// Worker threads for the kernel's batch sharding (0 = auto).
    pub threads: usize,
    /// Shard-planner flavor for the fused kernel's batch sharding (the
    /// `--planner` knob; outputs are bitwise identical under every
    /// flavor, only shard cuts — and therefore balance — move).
    pub planner: PlannerChoice,
    pub hidden: usize,
    /// Scalar vs vector gather/fold in the fused kernel (the `--simd`
    /// knob; outputs are bitwise identical either way, only step time
    /// moves).
    pub simd: SimdChoice,
    /// Physical order of the feature-row storage (the `--layout` knob;
    /// `degree` runs the opt-in degree-descending locality pass — node
    /// ids and therefore all outputs are untouched).
    pub layout: FeatureLayout,
    /// Fault-injection plane (the `--chaos` knob; the no-op plane —
    /// [`crate::runtime::faults::none`] — in production). Installed into
    /// every [`CostModel`] this engine plans through, so the kernel's
    /// and sampler's sharded passes consult one seam.
    pub faults: Arc<dyn FaultPlane>,
    /// Hub-aggregate cache refresh budget (the `--hub-cache` knob;
    /// `None` = off). `Some(n)` caches leaf-hop partial means for hub
    /// nodes and recomputes at most `n` stale entries per pass — outputs
    /// are bitwise identical either way, only gather time moves (see
    /// [`super::hubcache`]). `FSA_HUB_CACHE=off|0|N` in the environment
    /// overrides this without re-invoking.
    pub hub_cache: Option<usize>,
}

/// Native CPU training engine; owns the model/optimizer state (and the
/// shard-planner cost model, so adaptive feedback persists across steps
/// — and, via [`NativeBackend::with_shared_model`], across the session's
/// other planning sites and, with planner-state persistence, across
/// sessions).
pub struct NativeBackend {
    cfg: NativeConfig,
    ds: Arc<Dataset>,
    feat: Features,
    adamw: AdamwConfig,
    cost: SharedCostModel,
    params: Vec<Vec<f32>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Shard imbalance of the most recent `eval_logits` pass (None when
    /// it ran serially) — the serving bench reads it per micro-batch.
    last_eval_imbalance: Option<f64>,
    /// Hub-aggregate cache (fused variant with `--hub-cache N` only).
    /// Prepared serially before each pass, read-only during it.
    hub: Option<HubCache>,
}

impl NativeBackend {
    pub fn new(ds: Arc<Dataset>, cfg: NativeConfig,
               adamw: AdamwConfig) -> Result<NativeBackend> {
        // the baseline variant never plans subtrees (its blocks are
        // sharded per level by the sampler), so build the sketch-free
        // nominal model there — the flavor only matters on the fused path
        let cost = CostModel::new(&ds.graph, &cfg.fanouts, if cfg.fused {
            cfg.planner
        } else {
            PlannerChoice::Nominal
        });
        Self::with_shared_model(ds, cfg, adamw,
                                Arc::new(std::sync::Mutex::new(cost)))
    }

    /// [`NativeBackend::new`] planning through an externally owned
    /// [`SharedCostModel`] — the trainer threads one model through the
    /// fused kernel, the host sampler, and the prefetch worker so every
    /// measured shard feeds the same adaptive weights.
    pub fn with_shared_model(ds: Arc<Dataset>, cfg: NativeConfig,
                             adamw: AdamwConfig,
                             cost: SharedCostModel) -> Result<NativeBackend> {
        ensure!(cfg.fanouts.depth() >= 1, "fanout must have at least 1 hop");
        lock_model(&cost).set_faults(cfg.faults.clone());
        let (d, c) = (ds.spec.d, ds.spec.c);
        let mut feat = Features::from_dataset(ds.clone(), cfg.amp);
        if cfg.layout == FeatureLayout::DegreeDesc {
            feat.permute_by_degree(&ds.graph);
        }
        let specs = if cfg.fused {
            fsa_param_specs(d, cfg.hidden, c)
        } else {
            dgl_param_specs(d, cfg.hidden, c, cfg.fanouts.depth())
        };
        let params = init_params(&specs, cfg.seed);
        let m = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let v = params.iter().map(|p| vec![0.0; p.len()]).collect();
        // `FSA_HUB_CACHE=off|0` forces the cache off, `=N` forces budget
        // N, anything else defers to the config (mirrors FSA_SIMD)
        let budget = match std::env::var("FSA_HUB_CACHE") {
            Ok(v) if v == "off" || v == "0" => None,
            Ok(v) => match v.parse::<usize>() {
                Ok(n) => Some(n),
                Err(_) => cfg.hub_cache,
            },
            Err(_) => cfg.hub_cache,
        };
        // only the fused kernel has a leaf-hop gather to cache
        let hub = budget
            .filter(|_| cfg.fused)
            .map(|n| HubCache::new(&ds.graph, n));
        Ok(NativeBackend { cfg, ds, feat, adamw, cost, params, m, v,
                           last_eval_imbalance: None, hub })
    }

    /// Prepare the hub cache for a pass at `fanouts` under `base`: roll
    /// the generation to this pass's `(base, leaf hop, leaf k)` triple
    /// (deterministically evicting every stale entry) and spend the
    /// refresh budget on the hottest missing hubs.
    fn prepare_hub(&mut self, fanouts: &Fanouts, base: u64) {
        if let Some(h) = self.hub.as_mut() {
            let depth = fanouts.depth();
            h.prepare(&self.ds.graph, &self.feat, base, (depth - 1) as u64,
                      fanouts.k(depth - 1), self.cfg.simd.enabled());
        }
    }

    /// The cache handle for a pass at `fanouts` under `base` — `None`
    /// unless [`HubCache::prepare`] rolled it to exactly that
    /// generation. Guards the pub [`NativeBackend::fsa_loss_grads`]
    /// surface: a caller that skips the prepare gets a bypassed cache,
    /// never stale aggregates.
    fn hub_for(&self, fanouts: &Fanouts, base: u64) -> Option<&HubCache> {
        let depth = fanouts.depth();
        self.hub.as_ref().filter(|h| {
            h.generation()
                == Some((base, (depth - 1) as u64, fanouts.k(depth - 1)))
        })
    }

    /// The engine's planner model (shared for feedback/persistence).
    pub fn cost_model(&self) -> SharedCostModel {
        self.cost.clone()
    }

    /// Current parameters (tests; canonical spec order).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Replace the parameters (finite-difference tests).
    pub fn set_params(&mut self, params: Vec<Vec<f32>>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }

    fn param_bytes(&self) -> u64 {
        self.params.iter().map(|p| p.len() as u64 * F32).sum()
    }

    /// Shared SAGE head: `(pre, h, logits)` from `[B,d]` self features and
    /// the `[B,d]` aggregate.
    fn head_forward(&self, x_self: &[f32], agg: &[f32], b: usize)
                    -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (d, h, c) = (self.feat.d, self.cfg.hidden, self.ds.spec.c);
        let (w_self, w_neigh, b_h) =
            (&self.params[0], &self.params[1], &self.params[2]);
        let (w_out, b_out) = (&self.params[3], &self.params[4]);
        let mut pre = vec![0.0f32; b * h];
        matmul(x_self, w_self, &mut pre, b, d, h);
        matmul(agg, w_neigh, &mut pre, b, d, h);
        add_bias(&mut pre, b_h, b, h);
        let mut hbuf = pre.clone();
        relu(&mut hbuf);
        let mut logits = vec![0.0f32; b * c];
        matmul(&hbuf, w_out, &mut logits, b, h, c);
        add_bias(&mut logits, b_out, b, c);
        (pre, hbuf, logits)
    }

    /// Fused-variant loss and parameter gradients on one batch (also the
    /// surface the gradient-parity tests drive). The last element is the
    /// kernel's per-shard timing (empty when it ran serially).
    pub fn fsa_loss_grads(&self, seeds: &[i32], labels: &[i32], base: u64,
                          meter: &mut MemoryMeter)
                          -> Result<(f64, Vec<Vec<f32>>, u64, ShardStats)> {
        ensure!(self.cfg.fused, "fsa_loss_grads on a baseline engine");
        let b = seeds.len();
        let (d, h, c) = (self.feat.d, self.cfg.hidden, self.ds.spec.c);

        // -- fused sample+aggregate (the kernel); `_saved` keeps the index
        // tensors alive for the whole step, like the device buffers would
        // be. Planning uses a snapshot of the shared model so the kernel
        // never holds the session lock across the sharded pass.
        let cost = lock_model(&self.cost).clone();
        let out = fused::fused_khop_cached(
            &self.ds.graph, &self.feat, seeds, &self.cfg.fanouts, base,
            self.cfg.save_indices, self.cfg.threads, &cost,
            self.cfg.simd.enabled(),
            self.hub_for(&self.cfg.fanouts, base));
        meter.alloc((b * d) as u64 * F32);
        if let Some(saved) = &out.saved {
            for s in saved {
                meter.alloc(s.len() as u64 * I32);
            }
        }
        let (agg, _saved, pairs, stats) =
            (out.agg, out.saved, out.pairs, out.stats);

        // -- seed features + head
        let mut x_self = vec![0.0f32; b * d];
        meter.alloc((b * d) as u64 * F32);
        for (i, &s) in seeds.iter().enumerate() {
            ensure!(s >= 0 && (s as usize) < self.feat.n, "seed {s} invalid");
            self.feat.copy_row(s as usize, &mut x_self[i * d..(i + 1) * d]);
        }
        let (pre, hbuf, logits) = self.head_forward(&x_self, &agg, b);
        meter.alloc((2 * b * h + b * c) as u64 * F32);
        let (loss, dlogits) = softmax_xent(&logits, labels, b, c);
        meter.alloc((b * c) as u64 * F32);

        // -- backward through the head
        let mut grads: Vec<Vec<f32>> =
            self.params.iter().map(|p| vec![0.0; p.len()]).collect();
        meter.alloc(self.param_bytes());
        matmul_at_b(&hbuf, &dlogits, &mut grads[3], b, h, c);
        col_sum(&dlogits, &mut grads[4], b, c);
        let mut dpre = vec![0.0f32; b * h];
        meter.alloc((b * h) as u64 * F32);
        matmul_a_bt(&dlogits, &self.params[3], &mut dpre, b, c, h);
        for (dv, &p) in dpre.iter_mut().zip(&pre) {
            if p <= 0.0 {
                *dv = 0.0;
            }
        }
        matmul_at_b(&x_self, &dpre, &mut grads[0], b, d, h);
        matmul_at_b(&agg, &dpre, &mut grads[1], b, d, h);
        col_sum(&dpre, &mut grads[2], b, h);
        Ok((loss, grads, pairs, stats))
    }

    fn apply_adamw(&mut self, grads: &[Vec<f32>], step: usize) {
        for i in 0..self.params.len() {
            adamw_update(&mut self.params[i], &grads[i], &mut self.m[i],
                         &mut self.v[i], step, &self.adamw);
        }
    }

    /// Apply one externally computed gradient set through the shared
    /// AdamW update — the distributed coordinator's optimizer step: it
    /// aggregates per-worker gradients itself and owns the only
    /// optimizer state in the session, so this is exactly the update a
    /// local [`Backend::train_step`] would have applied to the same
    /// gradients at the same step.
    pub fn apply_grads(&mut self, grads: &[Vec<f32>], step: usize)
                       -> Result<()> {
        ensure!(grads.len() == self.params.len(),
                "gradient set holds {} tensors but the model has {}",
                grads.len(), self.params.len());
        for (i, (g, p)) in grads.iter().zip(&self.params).enumerate() {
            ensure!(g.len() == p.len(),
                    "gradient tensor {i} has {} values but the parameter \
                     has {}", g.len(), p.len());
        }
        self.apply_adamw(grads, step);
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn train_step(&mut self, step: usize, inp: &StepInputs<'_>,
                  meter: &mut MemoryMeter) -> Result<StepOutcome> {
        let b = inp.seeds.len();
        ensure!(inp.labels.len() == b, "labels/seeds length mismatch");
        let (h, c) = (self.cfg.hidden, self.ds.spec.c);
        let timer = Timer::start();
        // per-step host tensors handed to the engine
        meter.alloc((2 * b) as u64 * I32 + 8);

        // budgeted hub-cache refresh for this step's seed epoch, before
        // the sharded pass (the pass reads the cache immutably)
        let hub_before = self.hub.as_ref().map(|h| h.counters());
        if self.cfg.fused {
            self.prepare_hub(&self.cfg.fanouts.clone(), inp.base);
        }
        let (loss, pairs, shard_stats) = if self.cfg.fused {
            let (loss, grads, pairs, stats) =
                self.fsa_loss_grads(inp.seeds, inp.labels, inp.base, meter)?;
            self.apply_adamw(&grads, step);
            // adaptive flavor: fold this step's measured per-shard
            // throughput into the next plan's cut targets (the shared
            // model, so the sampler's observations compound with ours)
            lock_model(&self.cost).observe(&stats);
            (loss, Some(pairs),
             (!stats.is_empty()).then_some(stats))
        } else {
            let Some(blk) = inp.block else {
                bail!("native baseline step without a prepared block")
            };
            ensure!(blk.batch == b && blk.fanouts == self.cfg.fanouts,
                    "block dims mismatch: block {}x{}, config {}x{}",
                    blk.batch, blk.fanouts, b, self.cfg.fanouts);
            meter.alloc(blk.index_len() as u64 * I32);
            let fwd = baseline::forward(&self.feat, blk, &self.params, h, c,
                                        self.cfg.threads, meter);
            let (loss, dlogits) = softmax_xent(&fwd.logits, inp.labels, b, c);
            meter.alloc((b * c) as u64 * F32);
            let mut grads: Vec<Vec<f32>> =
                self.params.iter().map(|p| vec![0.0; p.len()]).collect();
            meter.alloc(self.param_bytes());
            baseline::backward(&fwd, blk, &self.params, &dlogits, h, c,
                               &mut grads, meter);
            self.apply_adamw(&grads, step);
            (loss, None, None)
        };

        // per-step cache counter deltas (zeros when the cache is off);
        // saturating like `bench::throughput::hub_delta` so a counter
        // reset can never wrap to a garbage delta
        let (hub_hits, hub_misses, hub_refreshes) =
            match (hub_before, self.hub.as_ref().map(|h| h.counters())) {
                (Some((h0, m0, r0)), Some((h1, m1, r1))) => {
                    (h1.saturating_sub(h0), m1.saturating_sub(m0),
                     r1.saturating_sub(r0))
                }
                _ => (0, 0, 0),
            };
        Ok(StepOutcome {
            loss,
            upload_ms: 0.0, // no device, nothing crosses a bus
            execute_ms: timer.ms(),
            post_ms: 0.0,
            pairs,
            shard_stats,
            hub_hits,
            hub_misses,
            hub_refreshes,
        })
    }

    fn eval_logits(&mut self, seeds: &[i32], base: u64)
                   -> Result<Option<Vec<f32>>> {
        let b = seeds.len();
        if b == 0 {
            return Ok(Some(Vec::new()));
        }
        let (d, h, c) = (self.feat.d, self.cfg.hidden, self.ds.spec.c);
        let mut scratch = MemoryMeter::new(); // eval is not metered
        // Depth-matched eval protocol: the 15-10(-5…) fanout at the
        // model's own depth (see [`eval_fanouts`]). At depth 2 this is
        // exactly the fixed f15x10 protocol of the AOT eval artifacts.
        let ef = eval_fanouts(self.cfg.fanouts.depth());
        if self.cfg.fused {
            // eval/serve shares one seed epoch (`base` is fixed per
            // session), so entries refreshed here persist and get
            // re-hit across subsequent requests.
            self.prepare_hub(&ef, base);
        }
        let logits = if self.cfg.fused {
            // eval fanouts differ from the training fanouts, so the
            // session's cost model does not apply — but the *flavor*
            // must: --planner nominal must not build the degree sketch.
            // The adaptive flavor still seeds the cuts from the shared
            // model's learned per-worker weights, and feeds the measured
            // shard times back — forward-only sessions (serving) keep
            // the feedback loop alive this way.
            let mut model = CostModel::new(&self.ds.graph, &ef,
                                           self.cfg.planner);
            model.set_faults(self.cfg.faults.clone());
            let (weights, steps) = {
                let shared = lock_model(&self.cost);
                (shared.worker_weights().to_vec(), shared.steps_observed())
            };
            if !weights.is_empty() {
                model.warm_start(&weights, steps);
            }
            let out = fused::fused_khop_cached(&self.ds.graph, &self.feat,
                                               seeds, &ef, base, false,
                                               self.cfg.threads, &model,
                                               self.cfg.simd.enabled(),
                                               self.hub_for(&ef, base));
            self.last_eval_imbalance =
                (!out.stats.is_empty()).then(|| out.stats.imbalance());
            lock_model(&self.cost).observe(&out.stats);
            let agg = out.agg;
            let mut x_self = vec![0.0f32; b * d];
            for (i, &s) in seeds.iter().enumerate() {
                self.feat.copy_row(s as usize, &mut x_self[i * d..(i + 1) * d]);
            }
            self.head_forward(&x_self, &agg, b).2
        } else {
            self.last_eval_imbalance = None;
            let blk = sampler::build_block(&self.ds.graph, seeds, &ef, base);
            baseline::forward(&self.feat, &blk, &self.params, h, c,
                              self.cfg.threads, &mut scratch).logits
        };
        Ok(Some(logits))
    }

    fn params_f32(&self) -> Result<Vec<Vec<f32>>> {
        Ok(self.params.clone())
    }

    fn set_params_f32(&mut self, params: &[Vec<f32>]) -> Result<()> {
        ensure!(params.len() == self.params.len(),
                "checkpoint holds {} tensors but this model has {} \
                 (different variant or depth?)",
                params.len(), self.params.len());
        for (i, (new, cur)) in params.iter().zip(&self.params).enumerate() {
            ensure!(new.len() == cur.len(),
                    "checkpoint tensor {i} has {} values but the model \
                     wants {} (different dataset dims, hidden width, or \
                     depth?)", new.len(), cur.len());
            for (j, v) in new.iter().enumerate() {
                ensure!(v.is_finite(),
                        "checkpoint tensor {i} value {j} is non-finite \
                         ({v})");
            }
        }
        self.params = params.to_vec();
        // restored parameters start a fresh optimizer trajectory
        for t in self.m.iter_mut().chain(self.v.iter_mut()) {
            t.iter_mut().for_each(|x| *x = 0.0);
        }
        Ok(())
    }

    fn eval_imbalance(&self) -> Option<f64> {
        self.last_eval_imbalance
    }

    fn hub_counters(&self) -> Option<(u64, u64, u64)> {
        self.hub.as_ref().map(|h| h.counters())
    }

    fn opt_state_f32(&self) -> Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        Some((self.m.clone(), self.v.clone()))
    }

    fn set_opt_state_f32(&mut self, m: &[Vec<f32>], v: &[Vec<f32>])
                         -> Result<()> {
        ensure!(m.len() == self.m.len() && v.len() == self.v.len(),
                "checkpoint holds {}/{} moment tensors but this model \
                 has {}", m.len(), v.len(), self.m.len());
        for (i, (new, cur)) in m.iter().chain(v.iter())
            .zip(self.m.iter().chain(self.v.iter()))
            .enumerate()
        {
            ensure!(new.len() == cur.len(),
                    "checkpoint moment tensor {i} has {} values but the \
                     model wants {}", new.len(), cur.len());
        }
        self.m = m.to_vec();
        self.v = v.to_vec();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::builtin_spec;

    fn tiny() -> Arc<Dataset> {
        Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap())
    }

    fn cfg(fused: bool, ks: &[usize]) -> NativeConfig {
        NativeConfig {
            fused,
            fanouts: Fanouts::of(ks),
            amp: false,
            save_indices: true,
            seed: 42,
            threads: 1,
            planner: PlannerChoice::default(),
            hidden: 32,
            simd: SimdChoice::Auto,
            layout: FeatureLayout::Natural,
            faults: crate::runtime::faults::none(),
            hub_cache: None,
        }
    }

    fn adamw() -> AdamwConfig {
        AdamwConfig { lr: 3e-3, b1: 0.9, b2: 0.999, eps: 1e-8, wd: 5e-4 }
    }

    fn step_inputs<'a>(seeds: &'a [i32], labels: &'a [i32], base: u64)
                       -> StepInputs<'a> {
        StepInputs { seeds, labels, base, block: None }
    }

    #[test]
    fn eval_fanouts_follow_model_depth() {
        assert_eq!(eval_fanouts(1), Fanouts::of(&[15]));
        assert_eq!(eval_fanouts(2), Fanouts::of(&[15, 10]));
        assert_eq!(eval_fanouts(3), Fanouts::of(&[15, 10, 5]));
        assert_eq!(eval_fanouts(4), Fanouts::of(&[15, 10, 5, 5]));
    }

    #[test]
    fn fused_engine_decreases_loss_at_every_depth() {
        let ds = tiny();
        for ks in [&[5][..], &[5, 3][..], &[4, 3, 2][..]] {
            let mut eng =
                NativeBackend::new(ds.clone(), cfg(true, ks), adamw()).unwrap();
            let seeds: Vec<i32> = (0..64).collect();
            let labels: Vec<i32> =
                seeds.iter().map(|&u| ds.labels[u as usize]).collect();
            let mut meter = MemoryMeter::new();
            let mut losses = Vec::new();
            for step in 0..30 {
                let base = crate::rng::mix(42 + step as u64);
                let out = eng
                    .train_step(step, &step_inputs(&seeds, &labels, base),
                                &mut meter)
                    .unwrap();
                assert!(out.loss.is_finite());
                assert!(out.pairs.unwrap() > 0);
                losses.push(out.loss);
                meter.reset_step();
            }
            assert!(losses[29] < losses[0] * 0.8,
                    "depth {}: loss {} -> {}", ks.len(), losses[0],
                    losses[29]);
        }
    }

    #[test]
    fn baseline_engine_requires_block_and_trains() {
        let ds = tiny();
        let fo = Fanouts::of(&[5, 3]);
        let mut eng =
            NativeBackend::new(ds.clone(), cfg(false, &[5, 3]), adamw())
                .unwrap();
        let seeds: Vec<i32> = (0..64).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let mut meter = MemoryMeter::new();
        assert!(eng
            .train_step(0, &step_inputs(&seeds, &labels, 1), &mut meter)
            .is_err(), "missing block must be an error");
        // mismatched fanouts must also be rejected
        let wrong = sampler::build_block(&ds.graph, &seeds,
                                         &Fanouts::of(&[5]), 1);
        let inp = StepInputs { seeds: &seeds, labels: &labels, base: 1,
                               block: Some(&wrong) };
        assert!(eng.train_step(0, &inp, &mut meter).is_err(),
                "depth-mismatched block must be an error");
        let mut losses = Vec::new();
        for step in 0..30 {
            let base = crate::rng::mix(42 + step as u64);
            let blk = sampler::build_block(&ds.graph, &seeds, &fo, base);
            let inp = StepInputs { seeds: &seeds, labels: &labels, base,
                                   block: Some(&blk) };
            losses.push(eng.train_step(step, &inp, &mut meter).unwrap().loss);
            meter.reset_step();
        }
        assert!(losses[29] < losses[0] * 0.8,
                "loss {} -> {}", losses[0], losses[29]);
    }

    #[test]
    fn baseline_engine_trains_at_depth_3() {
        let ds = tiny();
        let fo = Fanouts::of(&[4, 3, 2]);
        let mut eng =
            NativeBackend::new(ds.clone(), cfg(false, &[4, 3, 2]), adamw())
                .unwrap();
        assert_eq!(eng.params().len(), 9, "3 layers x (w_self, w_neigh, b)");
        let seeds: Vec<i32> = (0..64).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let mut meter = MemoryMeter::new();
        let mut losses = Vec::new();
        for step in 0..30 {
            let base = crate::rng::mix(42 + step as u64);
            let blk = sampler::build_block(&ds.graph, &seeds, &fo, base);
            let inp = StepInputs { seeds: &seeds, labels: &labels, base,
                                   block: Some(&blk) };
            losses.push(eng.train_step(step, &inp, &mut meter).unwrap().loss);
            meter.reset_step();
        }
        assert!(losses[29] < losses[0] * 0.8,
                "loss {} -> {}", losses[0], losses[29]);
    }

    #[test]
    fn engine_is_deterministic_across_threads() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..128).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let run = |threads: usize| -> Vec<f64> {
            let mut c = cfg(true, &[5, 3]);
            c.threads = threads;
            let mut eng = NativeBackend::new(ds.clone(), c, adamw()).unwrap();
            let mut meter = MemoryMeter::new();
            (0..10)
                .map(|step| {
                    let base = crate::rng::mix(7 + step as u64);
                    eng.train_step(step,
                                   &step_inputs(&seeds, &labels, base),
                                   &mut meter)
                        .unwrap()
                        .loss
                })
                .collect()
        };
        let serial = run(1);
        assert_eq!(serial, run(4), "thread count changed the trajectory");
        assert_eq!(serial, run(0), "auto threads changed the trajectory");
    }

    #[test]
    fn eval_logits_shape_and_accuracy_signal() {
        let ds = tiny();
        let mut eng =
            NativeBackend::new(ds.clone(), cfg(true, &[5, 3]), adamw())
                .unwrap();
        let seeds: Vec<i32> = (0..32).collect();
        let logits = eng.eval_logits(&seeds, 9).unwrap().unwrap();
        assert_eq!(logits.len(), 32 * ds.spec.c);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(eng.eval_logits(&[], 9).unwrap().unwrap().is_empty());
        // 3-hop configs evaluate through the depth-matched protocol
        let mut eng3 =
            NativeBackend::new(ds.clone(), cfg(true, &[4, 3, 2]), adamw())
                .unwrap();
        let logits3 = eng3.eval_logits(&seeds, 9).unwrap().unwrap();
        assert_eq!(logits3.len(), 32 * ds.spec.c);
        assert!(logits3.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_transient_far_below_baseline() {
        let ds = tiny();
        let seeds: Vec<i32> = (0..64).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let mut fsa =
            NativeBackend::new(ds.clone(), cfg(true, &[5, 3]), adamw())
                .unwrap();
        let mut meter = MemoryMeter::new();
        fsa.train_step(0, &step_inputs(&seeds, &labels, 3), &mut meter)
            .unwrap();
        let fsa_peak = meter.peak();
        let mut dgl =
            NativeBackend::new(ds.clone(), cfg(false, &[5, 3]), adamw())
                .unwrap();
        let blk = sampler::build_block(&ds.graph, &seeds,
                                       &Fanouts::of(&[5, 3]), 3);
        let inp = StepInputs { seeds: &seeds, labels: &labels, base: 3,
                               block: Some(&blk) };
        let mut meter = MemoryMeter::new();
        dgl.train_step(0, &inp, &mut meter).unwrap();
        let dgl_peak = meter.peak();
        assert!(dgl_peak > 2 * fsa_peak,
                "baseline {dgl_peak} vs fused {fsa_peak}");
    }
}
