//! The DGL-style baseline as native host compute, generic over depth:
//! host-sampled index tensors → **materialized** dense feature blocks →
//! an L-layer SAGEConv(mean) stack. This is the
//! sample→materialize→aggregate pipeline the fused kernel removes; the
//! `[B·Π(1+k_j), k_L, d]` leaf block is genuinely allocated, written,
//! re-read, and reduced every step (the `optimization_barrier` of the JAX
//! baseline made literal), and every materialized buffer is reported to
//! the [`MemoryMeter`] so the bench compares *measured* transient bytes.
//!
//! Layer `i` (1-based, innermost first) computes, for every node of the
//! self-inclusive frontier at depth `L-i`,
//! `relu(self·W_self + mean(children)·W_neigh + b)` — layer 1 reads raw
//! features and the leaf block, upper layers read the previous layer's
//! hidden rows through the nested `[…, 1+k, h]` group layout (slot 0 =
//! self, slots 1.. = children). The last layer drops the relu and emits
//! logits for the seeds. At depth 2 the float-op sequence is exactly the
//! pre-generalization `forward2`/`backward2` pair (mirroring
//! `python/compile/baseline.py`); gradients cover the `3·L` parameter
//! tensors only (features are inputs, not parameters).

use crate::memory::MemoryMeter;
use crate::sampler::Block;

use super::linalg::{add_bias, col_sum, matmul, matmul_a_bt, matmul_at_b, relu};
use super::{par_fill_rows, simd, Features};

const F32: u64 = 4;

/// Kept activations of one SAGE layer (inputs + pre-activation).
pub struct LayerFwd {
    /// `[rows, in]` self inputs (features for layer 1, hidden rows above).
    pub x_self: Vec<f32>,
    /// `[rows, in]` masked neighbor means.
    pub x_neigh: Vec<f32>,
    /// `[rows, out]` pre-activation (empty for the last layer — its
    /// pre-activation *is* the logits).
    pub pre: Vec<f32>,
    /// `[rows, out]` relu'd, invalid-frontier rows zeroed (empty for the
    /// last layer).
    pub h: Vec<f32>,
}

/// Forward activations of one baseline L-hop step (kept for backward).
pub struct Fwd {
    /// Innermost layer first: `layers[0]` consumes features, the last
    /// entry produces the logits.
    pub layers: Vec<LayerFwd>,
    /// `[B, c]` output logits.
    pub logits: Vec<f32>,
}

/// Gather + materialize + aggregate + L SAGE layers (paper §5 baseline).
/// `params` order: `[w1_self, w1_neigh, b1, …]`
/// ([`super::dgl_param_specs`]).
pub fn forward(feat: &Features, blk: &Block, params: &[Vec<f32>],
               hidden: usize, classes: usize, threads: usize,
               meter: &mut MemoryMeter) -> Fwd {
    let depth = blk.fanouts.depth();
    debug_assert_eq!(params.len(), 3 * depth, "params/depth mismatch");
    let (b, d, h, c) = (blk.batch, feat.d, hidden, classes);
    let kl = blk.fanouts.k(depth - 1);
    let deepest = &blk.frontiers[depth - 1];
    let w = deepest.len() / b; // Π_{j<L}(1+k_j)

    // per-row gather cost: number of feature rows touched
    let costs: Vec<u64> = (0..b).map(|bi| {
        1 + deepest[bi * w..(bi + 1) * w]
            .iter()
            .filter(|&&u| u >= 0)
            .count() as u64
            * (1 + kl as u64)
    }).collect();

    // -- deepest frontier features, zeroed where the frontier is padding
    let mut xf = vec![0.0f32; b * w * d];
    meter.alloc(xf.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut xf, w * d, |bi, row| {
        for col in 0..w {
            let u = deepest[bi * w + col];
            if u >= 0 {
                feat.copy_row(u as usize, &mut row[col * d..(col + 1) * d]);
            }
        }
    });

    // -- THE BLOCK: dense [B·Π(1+k_j), k_L, d] leaf gather (pads gather
    // row 0, like x[max(leaf, 0)]); this materialization is the cost the
    // fused op kills, and it scales multiplicatively with depth
    let mut block = vec![0.0f32; b * w * kl * d];
    meter.alloc(block.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut block, w * kl * d, |bi, row| {
        for slot in 0..w * kl {
            let v = blk.leaf[bi * w * kl + slot].max(0);
            feat.copy_row(v as usize, &mut row[slot * d..(slot + 1) * d]);
        }
    });

    // -- masked mean over the k_L axis (re-reads the whole block)
    let mut mean = vec![0.0f32; b * w * d];
    meter.alloc(mean.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut mean, w * d, |bi, row| {
        for col in 0..w {
            let leaf_row =
                &blk.leaf[(bi * w + col) * kl..(bi * w + col + 1) * kl];
            let valid = leaf_row.iter().filter(|&&v| v >= 0).count();
            let den = valid.max(1) as f32;
            let dst = &mut row[col * d..(col + 1) * d];
            for (j2, &v) in leaf_row.iter().enumerate() {
                if v < 0 {
                    continue;
                }
                let src = &block[((bi * w + col) * kl + j2) * d..][..d];
                simd::add_assign_f32(dst, src);
            }
            for o in dst.iter_mut() {
                *o /= den;
            }
        }
    });
    meter.free(block.len() as u64 * F32);
    drop(block);

    let mut layers: Vec<LayerFwd> = Vec::with_capacity(depth);

    // -- layer 1 over all B·Π(1+k_j) rows
    let m = b * w;
    let out1 = if depth == 1 { c } else { h };
    let mut pre = vec![0.0f32; m * out1];
    meter.alloc(pre.len() as u64 * F32);
    matmul(&xf, &params[0], &mut pre, m, d, out1);
    matmul(&mean, &params[1], &mut pre, m, d, out1);
    add_bias(&mut pre, &params[2], m, out1);
    if depth == 1 {
        let logits = pre;
        layers.push(LayerFwd { x_self: xf, x_neigh: mean, pre: Vec::new(),
                               h: Vec::new() });
        return Fwd { layers, logits };
    }
    let mut hbuf = pre.clone();
    meter.alloc(hbuf.len() as u64 * F32);
    relu(&mut hbuf);
    // zero padded frontier rows so the next layer's mean sees true zeros
    for (p, &u) in deepest.iter().enumerate() {
        if u < 0 {
            hbuf[p * h..(p + 1) * h].fill(0.0);
        }
    }
    layers.push(LayerFwd { x_self: xf, x_neigh: mean, pre, h: hbuf });

    // -- layers 2..=L: parents ← nested child groups of the layer below
    for i in 2..=depth {
        let lvl = depth - i; // parent frontier depth
        let parents = &blk.frontiers[lvl];
        let children = &blk.frontiers[lvl + 1];
        let rows = parents.len();
        let gw = children.len() / rows; // 1 + k_{lvl+1}
        let out_i = if i == depth { c } else { h };
        let hprev = &layers[i - 2].h;

        let mut x_self = vec![0.0f32; rows * h];
        let mut x_neigh = vec![0.0f32; rows * h];
        meter.alloc(2 * (rows * h) as u64 * F32);
        for p in 0..rows {
            x_self[p * h..(p + 1) * h]
                .copy_from_slice(&hprev[p * gw * h..(p * gw + 1) * h]);
            let valid = children[p * gw + 1..(p + 1) * gw]
                .iter()
                .filter(|&&u| u >= 0)
                .count();
            let den = valid.max(1) as f32;
            let dst = &mut x_neigh[p * h..(p + 1) * h];
            for col in 1..gw {
                if children[p * gw + col] < 0 {
                    continue;
                }
                let src = &hprev[(p * gw + col) * h..(p * gw + col + 1) * h];
                simd::add_assign_f32(dst, src);
            }
            for o in dst.iter_mut() {
                *o /= den;
            }
        }

        let base = 3 * (i - 1);
        let mut pre = vec![0.0f32; rows * out_i];
        meter.alloc(pre.len() as u64 * F32);
        matmul(&x_self, &params[base], &mut pre, rows, h, out_i);
        matmul(&x_neigh, &params[base + 1], &mut pre, rows, h, out_i);
        add_bias(&mut pre, &params[base + 2], rows, out_i);
        if i == depth {
            let logits = pre;
            layers.push(LayerFwd { x_self, x_neigh, pre: Vec::new(),
                                   h: Vec::new() });
            return Fwd { layers, logits };
        }
        let mut hbuf = pre.clone();
        meter.alloc(hbuf.len() as u64 * F32);
        relu(&mut hbuf);
        for (p, &u) in parents.iter().enumerate() {
            if u < 0 {
                hbuf[p * h..(p + 1) * h].fill(0.0);
            }
        }
        layers.push(LayerFwd { x_self, x_neigh, pre, h: hbuf });
    }
    unreachable!("loop returns at i == depth")
}

/// Backward of [`forward`] into `grads` (same order/shapes as `params`),
/// accumulating (callers zero the buffers). Features are not parameters,
/// so propagation stops below layer 1.
#[allow(clippy::too_many_arguments)]
pub fn backward(fwd: &Fwd, blk: &Block, params: &[Vec<f32>],
                dlogits: &[f32], hidden: usize, classes: usize,
                grads: &mut [Vec<f32>], meter: &mut MemoryMeter) {
    let depth = blk.fanouts.depth();
    let h = hidden;
    let d = fwd.layers[0].x_self.len() / blk.frontiers[depth - 1].len();
    let mut g_own: Option<Vec<f32>> = None;
    for i in (1..=depth).rev() {
        let layer = &fwd.layers[i - 1];
        let in_i = if i == 1 { d } else { h };
        let out_i = if i == depth { classes } else { h };
        let rows = layer.x_self.len() / in_i;
        let base = 3 * (i - 1);
        {
            let g: &[f32] = g_own.as_deref().unwrap_or(dlogits);
            // layer-i parameter grads
            matmul_at_b(&layer.x_self, g, &mut grads[base], rows, in_i, out_i);
            matmul_at_b(&layer.x_neigh, g, &mut grads[base + 1], rows, in_i,
                        out_i);
            col_sum(g, &mut grads[base + 2], rows, out_i);
        }
        if i == 1 {
            break;
        }

        // -- propagate into the layer below through the group layout
        let lvl = depth - i;
        let children = &blk.frontiers[lvl + 1];
        let gw = children.len() / rows;
        let mut d_self = vec![0.0f32; rows * h];
        let mut d_neigh = vec![0.0f32; rows * h];
        meter.alloc(2 * (rows * h) as u64 * F32);
        {
            let g: &[f32] = g_own.as_deref().unwrap_or(dlogits);
            matmul_a_bt(g, &params[base], &mut d_self, rows, out_i, h);
            matmul_a_bt(g, &params[base + 1], &mut d_neigh, rows, out_i, h);
        }
        let mut dpre = vec![0.0f32; children.len() * h];
        meter.alloc(dpre.len() as u64 * F32);
        for p in 0..rows {
            // self slot
            dpre[p * gw * h..(p * gw + 1) * h]
                .copy_from_slice(&d_self[p * h..(p + 1) * h]);
            // child slots share d_neigh / n_valid
            let valid = children[p * gw + 1..(p + 1) * gw]
                .iter()
                .filter(|&&u| u >= 0)
                .count();
            let inv = 1.0 / valid.max(1) as f32;
            for col in 1..gw {
                if children[p * gw + col] < 0 {
                    continue;
                }
                let dst =
                    &mut dpre[(p * gw + col) * h..(p * gw + col + 1) * h];
                for (o, &v) in dst.iter_mut().zip(&d_neigh[p * h..(p + 1) * h])
                {
                    *o = v * inv;
                }
            }
        }
        // relu mask (pre-activation sign of the layer below)
        for (dv, &pv) in dpre.iter_mut().zip(&fwd.layers[i - 2].pre) {
            if pv <= 0.0 {
                *dv = 0.0;
            }
        }
        meter.free(2 * (rows * h) as u64 * F32);
        if let Some(prev) = g_own.take() {
            meter.free(prev.len() as u64 * F32);
        }
        g_own = Some(dpre);
    }
    if let Some(prev) = g_own.take() {
        meter.free(prev.len() as u64 * F32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fanout::Fanouts;
    use crate::gen::{builtin_spec, Dataset};
    use crate::kernel::{dgl_param_specs, fused, softmax_xent};
    use crate::runtime::init_params;
    use crate::sampler;

    fn tiny() -> Dataset {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
    }

    fn tiny_setup(depth: usize) -> (Dataset, Features, Vec<Vec<f32>>) {
        let ds = tiny();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let params =
            init_params(&dgl_param_specs(ds.spec.d, 32, ds.spec.c, depth), 42);
        (ds, feat, params)
    }

    /// The baseline's layer-1 neighbor mean over the materialized block
    /// must equal the fused kernel's aggregate for the same frontier node
    /// (the paired-sampling property, now at the feature level).
    #[test]
    fn block_mean_matches_fused_agg_per_frontier_node() {
        let (ds, feat, params) = tiny_setup(2);
        let seeds: Vec<i32> = (0..64).collect();
        let (k1, k2, base) = (5usize, 3usize, 42u64);
        let fo = Fanouts::of(&[k1, k2]);
        let blk = sampler::build_block(&ds.graph, &seeds, &fo, base);
        let mut meter = crate::memory::MemoryMeter::new();
        let fwd = forward(&feat, &blk, &params, 32, ds.spec.c, 1, &mut meter);
        // layer-1 neighbor-mean column ui+1 == 1-hop fused agg of
        // frontiers[1][ui+1] at hop=1 counters
        let d = ds.spec.d;
        let f1w = 1 + k1;
        for bi in 0..4 {
            for ui in 0..k1 {
                let u = blk.frontiers[1][bi * f1w + 1 + ui];
                if u < 0 {
                    continue;
                }
                let one = fused::fused_1hop_at_hop(&ds.graph, &feat, &[u], k2,
                                                   base, 1);
                let col = &fwd.layers[0].x_neigh[(bi * f1w + 1 + ui) * d..][..d];
                for (j, (&a, &w)) in col.iter().zip(&one).enumerate() {
                    assert!((a - w).abs() < 1e-4,
                            "bi={bi} ui={ui} j={j}: {a} vs {w}");
                }
            }
        }
    }

    #[test]
    fn forward_shapes_and_masking_at_depths_2_and_3() {
        for fo in [Fanouts::of(&[4, 3]), Fanouts::of(&[3, 2, 2])] {
            let depth = fo.depth();
            let (ds, feat, params) = tiny_setup(depth);
            let seeds: Vec<i32> = (0..32).collect();
            let blk = sampler::build_block(&ds.graph, &seeds, &fo, 7);
            let mut meter = crate::memory::MemoryMeter::new();
            let fwd =
                forward(&feat, &blk, &params, 32, ds.spec.c, 1, &mut meter);
            assert_eq!(fwd.layers.len(), depth);
            assert_eq!(fwd.logits.len(), 32 * ds.spec.c);
            assert!(fwd.logits.iter().all(|v| v.is_finite()));
            // hidden rows for padded frontier entries are zero at every
            // non-final layer
            for i in 1..depth {
                let frontier = &blk.frontiers[depth - i];
                for (p, &u) in frontier.iter().enumerate() {
                    if u < 0 {
                        assert!(fwd.layers[i - 1].h[p * 32..(p + 1) * 32]
                            .iter()
                            .all(|&v| v == 0.0), "{fo} layer {i} row {p}");
                    }
                }
            }
            // the leaf block was materialized and released: peak covers it
            let w = blk.frontiers[depth - 1].len() / 32;
            let block_bytes =
                (32 * w * fo.k(depth - 1) * ds.spec.d * 4) as u64;
            assert!(meter.peak() > block_bytes,
                    "{fo}: peak missed the block");
        }
    }

    /// Analytic parameter gradients must match a directional finite
    /// difference of the loss, at every depth (1, 2, and 3 layers).
    #[test]
    fn backward_matches_finite_difference_at_depths_1_2_3() {
        for fo in [Fanouts::of(&[5]), Fanouts::of(&[4, 3]),
                   Fanouts::of(&[3, 2, 2])] {
            let depth = fo.depth();
            let (ds, feat, params) = tiny_setup(depth);
            let seeds: Vec<i32> = (40..72).collect();
            let labels: Vec<i32> =
                seeds.iter().map(|&u| ds.labels[u as usize]).collect();
            let blk = sampler::build_block(&ds.graph, &seeds, &fo, 99);
            let (h, c) = (32usize, ds.spec.c);
            let b = seeds.len();
            let mut meter = crate::memory::MemoryMeter::new();

            let loss_of = |p: &[Vec<f32>]| -> f64 {
                let mut m = crate::memory::MemoryMeter::new();
                let fwd = forward(&feat, &blk, p, h, c, 1, &mut m);
                softmax_xent(&fwd.logits, &labels, b, c).0
            };

            let fwd = forward(&feat, &blk, &params, h, c, 1, &mut meter);
            let (_, dlogits) = softmax_xent(&fwd.logits, &labels, b, c);
            let mut grads: Vec<Vec<f32>> =
                params.iter().map(|p| vec![0.0; p.len()]).collect();
            backward(&fwd, &blk, &params, &dlogits, h, c, &mut grads,
                     &mut meter);

            let mut r = crate::rng::SplitMix64::new(8);
            for (ti, g) in grads.iter().enumerate() {
                let delta: Vec<f32> = (0..g.len())
                    .map(|_| r.next_normal() as f32 / (g.len() as f32).sqrt())
                    .collect();
                let eps = 1e-2f32;
                let mut pp = params.clone();
                let mut pm = params.clone();
                for ((a, b_), &dl) in
                    pp[ti].iter_mut().zip(pm[ti].iter_mut()).zip(&delta)
                {
                    *a += eps * dl;
                    *b_ -= eps * dl;
                }
                let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * eps as f64);
                let analytic: f64 = g
                    .iter()
                    .zip(&delta)
                    .map(|(&gv, &dl)| (gv * dl) as f64)
                    .sum();
                assert!((fd - analytic).abs() < 2e-3 + 0.05 * analytic.abs(),
                        "{fo} tensor {ti}: fd {fd} vs analytic {analytic}");
            }
        }
    }

    /// Depth-1 stack is a single SAGE layer d → c: three parameter
    /// tensors, all with nonzero gradients on a trained batch.
    #[test]
    fn depth1_stack_has_three_tensors_and_live_grads() {
        let (ds, feat, params) = tiny_setup(1);
        assert_eq!(params.len(), 3);
        let seeds: Vec<i32> = (0..48).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let blk = sampler::build_block(&ds.graph, &seeds, &Fanouts::of(&[5]),
                                       3);
        let (b, c) = (seeds.len(), ds.spec.c);
        let mut meter = crate::memory::MemoryMeter::new();
        let fwd = forward(&feat, &blk, &params, 32, c, 1, &mut meter);
        let (_, dlogits) = softmax_xent(&fwd.logits, &labels, b, c);
        let mut grads: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0.0; p.len()]).collect();
        backward(&fwd, &blk, &params, &dlogits, 32, c, &mut grads, &mut meter);
        for (ti, g) in grads.iter().enumerate() {
            assert!(g.iter().any(|&v| v != 0.0), "tensor {ti} all-zero grad");
        }
    }
}
