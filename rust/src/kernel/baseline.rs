//! The DGL-style baseline as native host compute: host-sampled index
//! tensors → **materialized** dense feature blocks → SAGEConv(mean)
//! layers. This is the sample→materialize→aggregate pipeline the fused
//! kernel removes; the `[B, 1+k1, k2, d]` block is genuinely allocated,
//! written, re-read, and reduced every step (the `optimization_barrier`
//! of the JAX baseline made literal), and every materialized buffer is
//! reported to the [`MemoryMeter`] so the bench compares *measured*
//! transient bytes.
//!
//! Forward/backward mirror `python/compile/baseline.py` line by line;
//! gradients are produced for the six parameter tensors only (features
//! are inputs, not parameters).

use crate::memory::MemoryMeter;
use crate::sampler::{Block1, Block2};

use super::linalg::{add_bias, col_sum, matmul, matmul_a_bt, matmul_at_b, relu};
use super::{par_fill_rows, Features};

const F32: u64 = 4;

/// Forward activations of one baseline 2-hop step (kept for backward).
pub struct Fwd2 {
    /// `[B, 1+k1, d]` frontier features, invalid rows zeroed.
    pub xf1: Vec<f32>,
    /// `[B, 1+k1, d]` masked mean over the hop-2 block.
    pub mean2: Vec<f32>,
    /// `[B, 1+k1, h]` pre-activation of layer 1.
    pub pre1: Vec<f32>,
    /// `[B, 1+k1, h]` relu'd, invalid frontier rows zeroed.
    pub h1: Vec<f32>,
    /// `[B, h]` seed row of `h1`.
    pub h_self: Vec<f32>,
    /// `[B, h]` masked mean over the frontier rows of `h1`.
    pub h_neigh: Vec<f32>,
    /// `[B, c]` output logits.
    pub logits: Vec<f32>,
}

/// Layer-1 input rows per batch element.
fn f1w_of(blk: &Block2) -> usize {
    1 + blk.k1
}

/// Gather + materialize + aggregate + two SAGE layers (paper §5 baseline).
/// `params` order: `[w1_self, w1_neigh, b1, w2_self, w2_neigh, b2]`.
pub fn forward2(feat: &Features, blk: &Block2, params: &[Vec<f32>],
                hidden: usize, classes: usize, threads: usize,
                meter: &mut MemoryMeter) -> Fwd2 {
    let (b, k2, d, h, c) = (blk.batch, blk.k2, feat.d, hidden, classes);
    let f1w = f1w_of(blk);
    let (w1s, w1n, b1) = (&params[0], &params[1], &params[2]);
    let (w2s, w2n, b2) = (&params[3], &params[4], &params[5]);

    // per-row gather cost: number of feature rows touched
    let costs: Vec<u64> = (0..b).map(|bi| {
        1 + blk.f1[bi * f1w..(bi + 1) * f1w]
            .iter()
            .filter(|&&u| u >= 0)
            .count() as u64
            * (1 + k2 as u64)
    }).collect();

    // -- frontier features, zeroed where f1 is padding
    let mut xf1 = vec![0.0f32; b * f1w * d];
    meter.alloc(xf1.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut xf1, f1w * d, |bi, row| {
        for col in 0..f1w {
            let u = blk.f1[bi * f1w + col];
            if u >= 0 {
                feat.copy_row(u as usize, &mut row[col * d..(col + 1) * d]);
            }
        }
    });

    // -- THE BLOCK: dense [B, 1+k1, k2, d] gather (pads gather row 0, like
    // x[max(s2, 0)]); this materialization is the cost the fused op kills
    let mut block = vec![0.0f32; b * f1w * k2 * d];
    meter.alloc(block.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut block, f1w * k2 * d, |bi, row| {
        for slot in 0..f1w * k2 {
            let w = blk.s2[bi * f1w * k2 + slot].max(0);
            feat.copy_row(w as usize, &mut row[slot * d..(slot + 1) * d]);
        }
    });

    // -- masked mean over the k2 axis (re-reads the whole block)
    let mut mean2 = vec![0.0f32; b * f1w * d];
    meter.alloc(mean2.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut mean2, f1w * d, |bi, row| {
        for col in 0..f1w {
            let valid = blk.s2[(bi * f1w + col) * k2..(bi * f1w + col + 1) * k2]
                .iter()
                .filter(|&&w| w >= 0)
                .count();
            let den = valid.max(1) as f32;
            let dst = &mut row[col * d..(col + 1) * d];
            for j2 in 0..k2 {
                if blk.s2[(bi * f1w + col) * k2 + j2] < 0 {
                    continue;
                }
                let src = &block[((bi * f1w + col) * k2 + j2) * d..][..d];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
            for o in dst.iter_mut() {
                *o /= den;
            }
        }
    });
    meter.free(block.len() as u64 * F32);
    drop(block);

    // -- layer 1 over all B·(1+k1) rows
    let m = b * f1w;
    let mut pre1 = vec![0.0f32; m * h];
    meter.alloc(pre1.len() as u64 * F32);
    matmul(&xf1, w1s, &mut pre1, m, d, h);
    matmul(&mean2, w1n, &mut pre1, m, d, h);
    add_bias(&mut pre1, b1, m, h);
    let mut h1 = pre1.clone();
    meter.alloc(h1.len() as u64 * F32);
    relu(&mut h1);
    // zero padded frontier rows so layer 2's mean sees true zeros
    for bi in 0..b {
        for col in 0..f1w {
            if blk.f1[bi * f1w + col] < 0 {
                h1[(bi * f1w + col) * h..(bi * f1w + col + 1) * h].fill(0.0);
            }
        }
    }

    // -- layer 2: seeds ← frontier
    let mut h_self = vec![0.0f32; b * h];
    let mut h_neigh = vec![0.0f32; b * h];
    meter.alloc(2 * (b * h) as u64 * F32);
    for bi in 0..b {
        h_self[bi * h..(bi + 1) * h]
            .copy_from_slice(&h1[bi * f1w * h..(bi * f1w + 1) * h]);
        let valid = blk.f1[bi * f1w + 1..(bi + 1) * f1w]
            .iter()
            .filter(|&&u| u >= 0)
            .count();
        let den = valid.max(1) as f32;
        let dst = &mut h_neigh[bi * h..(bi + 1) * h];
        for col in 1..f1w {
            if blk.f1[bi * f1w + col] < 0 {
                continue;
            }
            let src = &h1[(bi * f1w + col) * h..(bi * f1w + col + 1) * h];
            for (o, &v) in dst.iter_mut().zip(src) {
                *o += v;
            }
        }
        for o in dst.iter_mut() {
            *o /= den;
        }
    }
    let mut logits = vec![0.0f32; b * c];
    meter.alloc(logits.len() as u64 * F32);
    matmul(&h_self, w2s, &mut logits, b, h, c);
    matmul(&h_neigh, w2n, &mut logits, b, h, c);
    add_bias(&mut logits, b2, b, c);

    Fwd2 { xf1, mean2, pre1, h1, h_self, h_neigh, logits }
}

/// Backward of [`forward2`] into `grads` (same order/shapes as `params`),
/// accumulating (callers zero the buffers).
#[allow(clippy::too_many_arguments)]
pub fn backward2(fwd: &Fwd2, blk: &Block2, params: &[Vec<f32>],
                 dlogits: &[f32], hidden: usize, classes: usize,
                 grads: &mut [Vec<f32>], meter: &mut MemoryMeter) {
    let (b, d) = (blk.batch, fwd.xf1.len() / (blk.batch * f1w_of(blk)));
    let (h, c) = (hidden, classes);
    let f1w = f1w_of(blk);
    let (w2s, w2n) = (&params[3], &params[4]);

    // layer-2 parameter grads
    matmul_at_b(&fwd.h_self, dlogits, &mut grads[3], b, h, c);
    matmul_at_b(&fwd.h_neigh, dlogits, &mut grads[4], b, h, c);
    col_sum(dlogits, &mut grads[5], b, c);

    // into the frontier activations
    let mut dh_self = vec![0.0f32; b * h];
    let mut dh_neigh = vec![0.0f32; b * h];
    meter.alloc(2 * (b * h) as u64 * F32);
    matmul_a_bt(dlogits, w2s, &mut dh_self, b, c, h);
    matmul_a_bt(dlogits, w2n, &mut dh_neigh, b, c, h);

    let m = b * f1w;
    let mut dpre1 = vec![0.0f32; m * h];
    meter.alloc(dpre1.len() as u64 * F32);
    for bi in 0..b {
        // seed row
        dpre1[bi * f1w * h..(bi * f1w + 1) * h]
            .copy_from_slice(&dh_self[bi * h..(bi + 1) * h]);
        // frontier rows share dh_neigh / n_valid
        let valid = blk.f1[bi * f1w + 1..(bi + 1) * f1w]
            .iter()
            .filter(|&&u| u >= 0)
            .count();
        let inv = 1.0 / valid.max(1) as f32;
        for col in 1..f1w {
            if blk.f1[bi * f1w + col] < 0 {
                continue;
            }
            let dst = &mut dpre1[(bi * f1w + col) * h..(bi * f1w + col + 1) * h];
            for (o, &v) in dst.iter_mut().zip(&dh_neigh[bi * h..(bi + 1) * h]) {
                *o = v * inv;
            }
        }
    }
    // relu mask (pre-activation sign)
    for (dv, &p) in dpre1.iter_mut().zip(&fwd.pre1) {
        if p <= 0.0 {
            *dv = 0.0;
        }
    }

    // layer-1 parameter grads
    matmul_at_b(&fwd.xf1, &dpre1, &mut grads[0], m, d, h);
    matmul_at_b(&fwd.mean2, &dpre1, &mut grads[1], m, d, h);
    col_sum(&dpre1, &mut grads[2], m, h);
    meter.free((2 * b * h + m * h) as u64 * F32);
}

/// Forward activations of the baseline 1-hop step.
pub struct Fwd1 {
    pub h_self: Vec<f32>,
    pub h_neigh: Vec<f32>,
    pub pre: Vec<f32>,
    pub h: Vec<f32>,
    pub logits: Vec<f32>,
}

/// 1-layer SAGE baseline over a materialized `[B, 1+k, d]` frontier
/// gather (`w2_neigh` exists for layout parity but is unused).
pub fn forward1(feat: &Features, blk: &Block1, params: &[Vec<f32>],
                hidden: usize, classes: usize, threads: usize,
                meter: &mut MemoryMeter) -> Fwd1 {
    let (b, d, h, c) = (blk.batch, feat.d, hidden, classes);
    let f1w = 1 + blk.k;
    let (w1s, w1n, b1) = (&params[0], &params[1], &params[2]);
    let (w2s, b2) = (&params[3], &params[5]);

    let costs: Vec<u64> = (0..b).map(|bi| {
        1 + blk.f1[bi * f1w..(bi + 1) * f1w]
            .iter()
            .filter(|&&u| u >= 0)
            .count() as u64
    }).collect();
    let mut xf1 = vec![0.0f32; b * f1w * d];
    meter.alloc(xf1.len() as u64 * F32);
    par_fill_rows(threads, &costs, &mut xf1, f1w * d, |bi, row| {
        for col in 0..f1w {
            let u = blk.f1[bi * f1w + col];
            if u >= 0 {
                feat.copy_row(u as usize, &mut row[col * d..(col + 1) * d]);
            }
        }
    });

    let mut h_self = vec![0.0f32; b * d];
    let mut h_neigh = vec![0.0f32; b * d];
    meter.alloc(2 * (b * d) as u64 * F32);
    for bi in 0..b {
        h_self[bi * d..(bi + 1) * d]
            .copy_from_slice(&xf1[bi * f1w * d..(bi * f1w + 1) * d]);
        let valid = blk.f1[bi * f1w + 1..(bi + 1) * f1w]
            .iter()
            .filter(|&&u| u >= 0)
            .count();
        let den = valid.max(1) as f32;
        let dst = &mut h_neigh[bi * d..(bi + 1) * d];
        for col in 1..f1w {
            if blk.f1[bi * f1w + col] < 0 {
                continue;
            }
            let src = &xf1[(bi * f1w + col) * d..(bi * f1w + col + 1) * d];
            for (o, &v) in dst.iter_mut().zip(src) {
                *o += v;
            }
        }
        for o in dst.iter_mut() {
            *o /= den;
        }
    }
    meter.free(xf1.len() as u64 * F32);
    drop(xf1);

    let mut pre = vec![0.0f32; b * h];
    meter.alloc(pre.len() as u64 * F32);
    matmul(&h_self, w1s, &mut pre, b, d, h);
    matmul(&h_neigh, w1n, &mut pre, b, d, h);
    add_bias(&mut pre, b1, b, h);
    let mut hbuf = pre.clone();
    meter.alloc(hbuf.len() as u64 * F32);
    relu(&mut hbuf);
    let mut logits = vec![0.0f32; b * c];
    meter.alloc(logits.len() as u64 * F32);
    matmul(&hbuf, w2s, &mut logits, b, h, c);
    add_bias(&mut logits, b2, b, c);

    Fwd1 { h_self, h_neigh, pre, h: hbuf, logits }
}

/// Backward of [`forward1`] into `grads` (`w2_neigh` gradient stays 0).
#[allow(clippy::too_many_arguments)]
pub fn backward1(fwd: &Fwd1, params: &[Vec<f32>], dlogits: &[f32], b: usize,
                 d: usize, hidden: usize, classes: usize,
                 grads: &mut [Vec<f32>], meter: &mut MemoryMeter) {
    let (h, c) = (hidden, classes);
    let w2s = &params[3];
    matmul_at_b(&fwd.h, dlogits, &mut grads[3], b, h, c);
    col_sum(dlogits, &mut grads[5], b, c);
    let mut dpre = vec![0.0f32; b * h];
    meter.alloc(dpre.len() as u64 * F32);
    matmul_a_bt(dlogits, w2s, &mut dpre, b, c, h);
    for (dv, &p) in dpre.iter_mut().zip(&fwd.pre) {
        if p <= 0.0 {
            *dv = 0.0;
        }
    }
    matmul_at_b(&fwd.h_self, &dpre, &mut grads[0], b, d, h);
    matmul_at_b(&fwd.h_neigh, &dpre, &mut grads[1], b, d, h);
    col_sum(&dpre, &mut grads[2], b, h);
    meter.free(dpre.len() as u64 * F32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::kernel::{dgl_param_specs, fused, softmax_xent};
    use crate::runtime::init_params;
    use crate::sampler;

    fn tiny() -> Dataset {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap()
    }

    fn tiny_setup() -> (Dataset, Features, Vec<Vec<f32>>) {
        let ds = tiny();
        let feat = Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        let params = init_params(&dgl_param_specs(ds.spec.d, 32, ds.spec.c), 42);
        (ds, feat, params)
    }

    /// The baseline's layer-1 neighbor mean over the materialized block
    /// must equal the fused kernel's aggregate for the same frontier node
    /// (the paired-sampling property, now at the feature level).
    #[test]
    fn block_mean_matches_fused_agg_per_frontier_node() {
        let (ds, feat, params) = tiny_setup();
        let seeds: Vec<i32> = (0..64).collect();
        let (k1, k2, base) = (5usize, 3usize, 42u64);
        let blk = sampler::build_block2(&ds.graph, &seeds, k1, k2, base);
        let mut meter = crate::memory::MemoryMeter::new();
        let fwd = forward2(&feat, &blk, &params, 32, ds.spec.c, 1, &mut meter);
        // mean2 column ui+1 of the baseline == 1-hop fused agg of s1[ui]
        // at hop=1 counters
        let d = ds.spec.d;
        let f1w = 1 + k1;
        for bi in 0..4 {
            for ui in 0..k1 {
                let u = blk.f1[bi * f1w + 1 + ui];
                if u < 0 {
                    continue;
                }
                let one = fused::fused_1hop_at_hop(&ds.graph, &feat, &[u], k2,
                                                   base, 1);
                let col = &fwd.mean2[(bi * f1w + 1 + ui) * d..][..d];
                for (j, (&a, &w)) in col.iter().zip(&one).enumerate() {
                    assert!((a - w).abs() < 1e-4,
                            "bi={bi} ui={ui} j={j}: {a} vs {w}");
                }
            }
        }
    }

    #[test]
    fn forward2_shapes_and_masking() {
        let (ds, feat, params) = tiny_setup();
        let seeds: Vec<i32> = (0..32).collect();
        let blk = sampler::build_block2(&ds.graph, &seeds, 4, 3, 7);
        let mut meter = crate::memory::MemoryMeter::new();
        let fwd = forward2(&feat, &blk, &params, 32, ds.spec.c, 1, &mut meter);
        assert_eq!(fwd.logits.len(), 32 * ds.spec.c);
        assert!(fwd.logits.iter().all(|v| v.is_finite()));
        // h1 rows for padded frontier entries are zero
        let f1w = 5;
        for bi in 0..32 {
            for col in 0..f1w {
                if blk.f1[bi * f1w + col] < 0 {
                    assert!(fwd.h1[(bi * f1w + col) * 32..][..32]
                        .iter()
                        .all(|&v| v == 0.0));
                }
            }
        }
        // the block was materialized and released: peak covers it, and
        // everything still live is less than the peak
        let block_bytes = (32 * f1w * 3 * ds.spec.d * 4) as u64;
        assert!(meter.peak() > block_bytes, "peak missed the block");
    }

    /// Analytic parameter gradients must match a directional finite
    /// difference of the loss (2-hop baseline).
    #[test]
    fn backward2_matches_finite_difference() {
        let (ds, feat, params) = tiny_setup();
        let seeds: Vec<i32> = (40..72).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let blk = sampler::build_block2(&ds.graph, &seeds, 4, 3, 99);
        let (h, c) = (32usize, ds.spec.c);
        let b = seeds.len();
        let mut meter = crate::memory::MemoryMeter::new();

        let loss_of = |p: &[Vec<f32>]| -> f64 {
            let mut m = crate::memory::MemoryMeter::new();
            let fwd = forward2(&feat, &blk, p, h, c, 1, &mut m);
            softmax_xent(&fwd.logits, &labels, b, c).0
        };

        let fwd = forward2(&feat, &blk, &params, h, c, 1, &mut meter);
        let (_, dlogits) = softmax_xent(&fwd.logits, &labels, b, c);
        let mut grads: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0.0; p.len()]).collect();
        backward2(&fwd, &blk, &params, &dlogits, h, c, &mut grads, &mut meter);

        let mut r = crate::rng::SplitMix64::new(8);
        for (ti, g) in grads.iter().enumerate() {
            let delta: Vec<f32> = (0..g.len())
                .map(|_| r.next_normal() as f32 / (g.len() as f32).sqrt())
                .collect();
            let eps = 1e-2f32;
            let mut pp = params.clone();
            let mut pm = params.clone();
            for ((a, b_), &dl) in
                pp[ti].iter_mut().zip(pm[ti].iter_mut()).zip(&delta)
            {
                *a += eps * dl;
                *b_ -= eps * dl;
            }
            let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * eps as f64);
            let analytic: f64 = g
                .iter()
                .zip(&delta)
                .map(|(&gv, &dl)| (gv * dl) as f64)
                .sum();
            assert!((fd - analytic).abs() < 2e-3 + 0.05 * analytic.abs(),
                    "tensor {ti}: fd {fd} vs analytic {analytic}");
        }
    }

    #[test]
    fn forward1_and_backward1_run_and_fd_check() {
        let (ds, feat, params) = tiny_setup();
        let seeds: Vec<i32> = (0..48).collect();
        let labels: Vec<i32> =
            seeds.iter().map(|&u| ds.labels[u as usize]).collect();
        let blk = sampler::build_block1(&ds.graph, &seeds, 5, 3);
        let (h, c, b, d) = (32usize, ds.spec.c, seeds.len(), ds.spec.d);
        let mut meter = crate::memory::MemoryMeter::new();
        let fwd = forward1(&feat, &blk, &params, h, c, 1, &mut meter);
        let (_, dlogits) = softmax_xent(&fwd.logits, &labels, b, c);
        let mut grads: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0.0; p.len()]).collect();
        backward1(&fwd, &params, &dlogits, b, d, h, c, &mut grads, &mut meter);
        // w2_neigh untouched in the 1-hop model
        assert!(grads[4].iter().all(|&v| v == 0.0));

        let loss_of = |p: &[Vec<f32>]| -> f64 {
            let mut m = crate::memory::MemoryMeter::new();
            let fwd = forward1(&feat, &blk, p, h, c, 1, &mut m);
            softmax_xent(&fwd.logits, &labels, b, c).0
        };
        let mut r = crate::rng::SplitMix64::new(4);
        for ti in [0usize, 2, 3] {
            let g = &grads[ti];
            let delta: Vec<f32> = (0..g.len())
                .map(|_| r.next_normal() as f32 / (g.len() as f32).sqrt())
                .collect();
            let eps = 1e-2f32;
            let mut pp = params.clone();
            let mut pm = params.clone();
            for ((a, b_), &dl) in
                pp[ti].iter_mut().zip(pm[ti].iter_mut()).zip(&delta)
            {
                *a += eps * dl;
                *b_ -= eps * dl;
            }
            let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * eps as f64);
            let analytic: f64 =
                g.iter().zip(&delta).map(|(&gv, &dl)| (gv * dl) as f64).sum();
            assert!((fd - analytic).abs() < 2e-3 + 0.05 * analytic.abs(),
                    "tensor {ti}: fd {fd} vs analytic {analytic}");
        }
    }
}
