//! Dense f32 primitives for the native backend's SAGE head.
//!
//! Row-major, accumulate-into-output (`+=`) so the backward pass can fold
//! several contributions into one gradient buffer without temporaries. The
//! loop orders are chosen so the innermost loop is always a contiguous
//! stream over both operands (ikj for `A·B`, the same shape for `Aᵀ·G`),
//! which rustc auto-vectorizes; at the head sizes of this repo
//! (d = h = 64, c ≤ 47) that is within a small factor of an optimized BLAS
//! and far off the critical path next to the feature gathers.
//!
//! The axpy-shaped inner loops (`row += scalar · row`, `row += row`) run
//! through [`super::simd`] unconditionally: they are elementwise with one
//! rounding per multiply and per add, in the same per-element order as
//! the plain loops, so the explicit vector tier changes no bits — only
//! [`matmul_a_bt`]'s dot products stay scalar (a vectorized horizontal
//! sum would reassociate the reduction).

use super::simd;

/// `c[m,n] += a[m,k] @ b[k,n]`.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // relu outputs are sparse; skip dead rows of b
            }
            simd::scale_add(crow, &b[p * n..(p + 1) * n], av);
        }
    }
}

/// `c[k,n] += a[m,k]ᵀ @ g[m,n]` — the `dW = activationsᵀ · upstream` shape.
pub fn matmul_at_b(a: &[f32], g: &[f32], c: &mut [f32], m: usize, k: usize,
                   n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let grow = &g[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            simd::scale_add(&mut c[p * n..(p + 1) * n], grow, av);
        }
    }
}

/// `c[m,k] += g[m,n] @ b[k,n]ᵀ` — the `dA = upstream · Wᵀ` backprop shape
/// (`b` is the *forward* weight, not pre-transposed).
pub fn matmul_a_bt(g: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize,
                   k: usize) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (p, cv) in crow.iter_mut().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            let mut acc = 0.0f32;
            for (&gv, &bv) in grow.iter().zip(brow) {
                acc += gv * bv;
            }
            *cv += acc;
        }
    }
}

/// `row[j] += bias[j]` for every row of `x[m,n]`.
pub fn add_bias(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for i in 0..m {
        simd::add_assign_f32(&mut x[i * n..(i + 1) * n], bias);
    }
}

/// `out[j] += Σ_i g[i,j]` — bias gradient (column sum).
pub fn col_sum(g: &[f32], out: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(out.len(), n);
    for i in 0..m {
        simd::add_assign_f32(out, &g[i * n..(i + 1) * n]);
    }
}

/// In-place ReLU; returns nothing (the pre-activation is kept by callers
/// that need the backward mask).
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
        // accumulates
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [38.0, 44.0, 86.0, 100.0]);
    }

    #[test]
    fn transposed_products_agree_with_naive() {
        let (m, k, n) = (5, 7, 3);
        let mut r = crate::rng::SplitMix64::new(3);
        let a: Vec<f32> =
            (0..m * k).map(|_| r.next_normal() as f32).collect();
        let g: Vec<f32> =
            (0..m * n).map(|_| r.next_normal() as f32).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| r.next_normal() as f32).collect();

        let mut atb = vec![0.0f32; k * n];
        matmul_at_b(&a, &g, &mut atb, m, k, n);
        for p in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + p] * g[i * n + j]).sum();
                assert!((atb[p * n + j] - want).abs() < 1e-4);
            }
        }

        let mut abt = vec![0.0f32; m * k];
        matmul_a_bt(&g, &b, &mut abt, m, n, k);
        for i in 0..m {
            for p in 0..k {
                let want: f32 = (0..n).map(|j| g[i * n + j] * b[p * n + j]).sum();
                assert!((abt[i * k + p] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn bias_relu_colsum() {
        let mut x = vec![-1.0f32, 2.0, 3.0, -4.0];
        add_bias(&mut x, &[0.5, -0.5], 2, 2);
        assert_eq!(x, [-0.5, 1.5, 3.5, -4.5]);
        relu(&mut x);
        assert_eq!(x, [0.0, 1.5, 3.5, 0.0]);
        let mut s = vec![0.0f32; 2];
        col_sum(&x, &mut s, 2, 2);
        assert_eq!(s, [3.5, 1.5]);
    }
}
