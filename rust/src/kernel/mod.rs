//! Native CPU execution engine — real host compute for both step variants.
//!
//! This subsystem is the "fused kernel written for the host" half of the
//! paper's claim, generic over sampling depth: [`fused`] implements
//! Algorithms 1–2 for any fanout list (sample neighbors with the
//! counter-hash rule and fold the running mean-of-means into one `[B, d]`
//! register tile, innermost hop first, **no** materialized block), while
//! [`baseline`] implements the DGL-style pipeline it is compared against
//! (gather the sampled index tensors into dense
//! `[B, Π(1+k_j)·k_L, d]`-shaped feature blocks, then run an L-layer
//! SAGE stack). [`engine::NativeBackend`] composes either kernel with
//! softmax cross-entropy and AdamW below into a full train step behind
//! the [`crate::runtime::backend::Backend`] seam.
//!
//! Numerics: all accumulation is f32 (loss reduction in f64); the optional
//! AMP mode stores the feature matrix as bf16 (round-to-nearest-even, the
//! same conversion as the PJRT upload path) and decodes rows on gather —
//! mirroring the paper's bf16-feature setting where the gather traffic, not
//! the matmul precision, is what AMP halves.
//!
//! Parallelism: batch rows are sharded across scoped worker threads with
//! the PR-1 degree-aware planner ([`crate::graph::shard`]); every worker
//! writes a disjoint row range, so results are bitwise identical at any
//! thread count.

pub mod baseline;
pub mod engine;
pub mod fused;
pub mod hubcache;
pub mod linalg;
pub mod simd;

pub use engine::{NativeBackend, NativeConfig};
pub use hubcache::HubCache;
pub use simd::SimdChoice;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::gen::Dataset;
use crate::runtime::{Dtype, TensorSpec};

/// Below this many batch rows per worker the kernels fall back to the
/// serial loop (thread spawn would dominate the per-row work).
pub const MIN_PAR_ROWS: usize = 16;

/// Fallback feature-dimension tile for the gather loops when cache
/// geometry cannot be detected: the running-mean accumulator slice stays
/// L1-resident while the sampled rows stream through it (the CPU
/// analogue of the kernel's VMEM tile over `d`). [`d_tile`] is the
/// measured/derived value the kernels actually use.
pub const D_TILE: usize = 256;

/// Process-wide feature-tile override (0 = automatic). The tile_sweep
/// bench flips it between timed runs; safe because the tile partitions
/// the feature dimension without reordering any per-element fold, so
/// outputs are bitwise identical at every size (pinned by
/// `rust/tests/simd.rs`).
static D_TILE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the native feature tile (floats per accumulator slice);
/// `0` restores automatic selection.
pub fn set_d_tile(n: usize) {
    D_TILE_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The feature tile the native kernels use: the explicit override, else
/// `FSA_D_TILE` from the environment, else a size derived from the
/// detected L1d geometry, else the [`D_TILE`] fallback.
pub fn d_tile() -> usize {
    let over = D_TILE_OVERRIDE.load(Ordering::Relaxed);
    if over != 0 {
        return over.max(simd::LANES);
    }
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        if let Some(n) = std::env::var("FSA_D_TILE")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            return n.max(simd::LANES) & !(simd::LANES - 1);
        }
        detected_d_tile().unwrap_or(D_TILE)
    })
}

/// Tile from L1d size: each tile's hot set is the accumulator slice, the
/// streaming neighbor-row slice, and the output slice (~12 bytes per
/// feature column in f32) plus rowptr/col traffic, so budgeting the tile
/// at 1/32 of the L1d's float capacity keeps it resident with headroom.
/// A standard 32 KiB L1d lands exactly on the historical 256 default —
/// the tile_sweep bench's native axis is the empirical check.
fn detected_d_tile() -> Option<usize> {
    let l1 = l1d_cache_bytes()?;
    Some(((l1 / 128) & !(simd::LANES - 1)).clamp(64, 1024))
}

/// Scan `/sys/devices/system/cpu/cpu0/cache/index*` for the level-1
/// data-cache size (Linux sysfs; other platforms return `None` and take
/// the [`D_TILE`] fallback).
fn l1d_cache_bytes() -> Option<usize> {
    let cache = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    for i in 0..8 {
        let dir = cache.join(format!("index{i}"));
        let read = |f: &str| std::fs::read_to_string(dir.join(f)).ok();
        let (Some(level), Some(kind)) = (read("level"), read("type")) else {
            continue;
        };
        if level.trim() != "1" || kind.trim() == "Instruction" {
            continue;
        }
        return parse_cache_size(read("size")?.trim());
    }
    None
}

/// Cache sizes as sysfs spells them: `32K`, `1M`, or plain bytes.
fn parse_cache_size(s: &str) -> Option<usize> {
    let (num, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    num.parse::<usize>().ok().map(|n| n * mult)
}

/// `--layout natural|degree` — physical order of the native feature-row
/// storage. `degree` runs the opt-in locality pass: rows are permuted
/// into degree-descending order behind an index map so hub-heavy gathers
/// on power-law graphs hit a hot, contiguous region. Node ids — and
/// therefore the counter-hash RNG draws, saved indices, and planner
/// costs — are untouched, so outputs are bitwise identical under either
/// layout (pinned by `rust/tests/simd.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FeatureLayout {
    #[default]
    Natural,
    DegreeDesc,
}

impl FeatureLayout {
    pub fn parse(s: &str) -> anyhow::Result<FeatureLayout> {
        Ok(match s {
            "natural" => FeatureLayout::Natural,
            "degree" | "degree-desc" => FeatureLayout::DegreeDesc,
            other => anyhow::bail!("--layout must be natural|degree, \
                                    got {other:?}"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FeatureLayout::Natural => "natural",
            FeatureLayout::DegreeDesc => "degree",
        }
    }
}

/// Resolve a thread-count knob (0 = machine parallelism, min 1).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
    .max(1)
}

// ---------------------------------------------------------------------------
// feature storage (f32 or bf16-compressed)
// ---------------------------------------------------------------------------

use crate::util::{bf16_to_f32, f32_to_bf16};

enum Storage {
    /// Owned f32 copy (test fixtures, perturbed matrices).
    F32(Vec<f32>),
    /// Zero-copy view of a dataset's feature matrix (the engine's f32
    /// path — the largest allocation in the process is never duplicated).
    Shared(Arc<Dataset>),
    Bf16(Vec<u16>),
}

/// Borrowed view of the raw row-major storage, for gather loops that
/// hoist the dtype dispatch out of their per-row body; index physical
/// rows via [`Features::phys`].
pub(crate) enum RowData<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
}

/// The `[n, d]` feature matrix in the native engine's storage dtype.
pub struct Features {
    pub n: usize,
    pub d: usize,
    store: Storage,
    /// Logical node id → physical storage row when a layout pass has
    /// permuted the rows ([`Features::permute_by_degree`]); `None` is
    /// the identity (natural) layout.
    perm: Option<Vec<u32>>,
}

impl Features {
    /// Build from row-major f32 data (copies); `amp` selects bf16 storage.
    pub fn from_f32(x: &[f32], n: usize, d: usize, amp: bool) -> Features {
        assert_eq!(x.len(), n * d, "feature shape mismatch");
        let store = if amp {
            Storage::Bf16(x.iter().map(|&v| f32_to_bf16(v)).collect())
        } else {
            Storage::F32(x.to_vec())
        };
        Features { n, d, store, perm: None }
    }

    /// Build over a dataset's features: shares the `Arc` in f32 mode (no
    /// copy), converts once in bf16 (AMP) mode.
    pub fn from_dataset(ds: Arc<Dataset>, amp: bool) -> Features {
        let (n, d) = (ds.spec.n, ds.spec.d);
        let store = if amp {
            Storage::Bf16(ds.features.iter().map(|&v| f32_to_bf16(v)).collect())
        } else {
            Storage::Shared(ds)
        };
        Features { n, d, store, perm: None }
    }

    /// The opt-in locality pass (`--layout degree`): physically reorder
    /// the rows into degree-descending order (ties by id, so the result
    /// is deterministic) and install the index map. A `Shared` view
    /// cannot survive a permutation and becomes an owned f32 copy. All
    /// gathers are redirected through [`Features::phys`], so every
    /// logical read — and therefore every kernel output — is unchanged.
    pub fn permute_by_degree(&mut self, csr: &crate::graph::Csr) {
        assert_eq!(csr.n, self.n,
                   "layout pass: graph/features shape mismatch");
        let mut order: Vec<u32> = (0..self.n as u32).collect();
        order.sort_by_key(|&u| (std::cmp::Reverse(csr.degree(u as i32)), u));
        let mut perm = vec![0u32; self.n];
        for (p, &u) in order.iter().enumerate() {
            perm[u as usize] = p as u32;
        }
        self.store = match &self.store {
            Storage::F32(x) => Storage::F32(permute_rows(x, &order, self.d)),
            Storage::Shared(ds) => {
                Storage::F32(permute_rows(&ds.features, &order, self.d))
            }
            Storage::Bf16(x) => Storage::Bf16(permute_rows(x, &order, self.d)),
        };
        self.perm = Some(perm);
    }

    /// Physical storage row of logical node `u` under the active layout.
    #[inline]
    pub(crate) fn phys(&self, u: usize) -> usize {
        match &self.perm {
            Some(p) => p[u] as usize,
            None => u,
        }
    }

    /// The raw storage for monomorphized (dispatch-hoisted) gather loops.
    #[inline]
    pub(crate) fn rows(&self) -> RowData<'_> {
        match &self.store {
            Storage::F32(x) => RowData::F32(x),
            Storage::Shared(ds) => RowData::F32(&ds.features),
            Storage::Bf16(x) => RowData::Bf16(x),
        }
    }

    #[inline]
    fn f32_data(&self) -> Option<&[f32]> {
        match &self.store {
            Storage::F32(x) => Some(x),
            Storage::Shared(ds) => Some(&ds.features),
            Storage::Bf16(_) => None,
        }
    }

    /// Static storage bytes owned by this view (excluded from transient
    /// accounting, like the device-resident feature buffer; 0 when the
    /// matrix is shared with the dataset).
    pub fn bytes(&self) -> u64 {
        match &self.store {
            Storage::F32(v) => (v.len() * 4) as u64,
            Storage::Shared(_) => 0,
            Storage::Bf16(v) => (v.len() * 2) as u64,
        }
    }

    /// `acc[..hi-lo] += x[u][lo..hi]` (decoding bf16 on the fly). This
    /// per-row dispatch is the scalar (`--simd off`) reference path; the
    /// vector kernel hoists the match via [`Features::rows`].
    #[inline]
    pub fn add_row_slice(&self, u: usize, lo: usize, hi: usize,
                         acc: &mut [f32]) {
        debug_assert!(u < self.n && hi <= self.d);
        let base = self.phys(u) * self.d;
        match self.f32_data() {
            Some(x) => {
                for (a, &v) in acc.iter_mut().zip(&x[base + lo..base + hi]) {
                    *a += v;
                }
            }
            None => {
                let Storage::Bf16(x) = &self.store else { unreachable!() };
                for (a, &v) in acc.iter_mut().zip(&x[base + lo..base + hi]) {
                    *a += bf16_to_f32(v);
                }
            }
        }
    }

    /// `out[..d] = x[u]` (decoding bf16 on the fly).
    #[inline]
    pub fn copy_row(&self, u: usize, out: &mut [f32]) {
        debug_assert!(u < self.n);
        let base = self.phys(u) * self.d;
        match self.f32_data() {
            Some(x) => out[..self.d].copy_from_slice(&x[base..base + self.d]),
            None => {
                let Storage::Bf16(x) = &self.store else { unreachable!() };
                for (o, &v) in out.iter_mut().zip(&x[base..base + self.d]) {
                    *o = bf16_to_f32(v);
                }
            }
        }
    }
}

/// `out[p] = x[order[p]]`, row-major `[n, d]`.
fn permute_rows<T: Copy>(x: &[T], order: &[u32], d: usize) -> Vec<T> {
    let mut out = Vec::with_capacity(x.len());
    for &u in order {
        out.extend_from_slice(&x[u as usize * d..(u as usize + 1) * d]);
    }
    out
}

// ---------------------------------------------------------------------------
// shared loss / optimizer math
// ---------------------------------------------------------------------------

/// Mean softmax cross-entropy over `[b, c]` logits; returns the loss (f64
/// accumulation) and `dlogits = (softmax − onehot) / b`.
pub fn softmax_xent(logits: &[f32], labels: &[i32], b: usize, c: usize)
                    -> (f64, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * c);
    debug_assert_eq!(labels.len(), b);
    let mut loss = 0.0f64;
    let mut dlogits = vec![0.0f32; b * c];
    let inv_b = 1.0 / b as f32;
    for i in 0..b {
        let row = &logits[i * c..(i + 1) * c];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - max).exp();
        }
        let log_sum = sum.ln();
        let y = labels[i] as usize;
        debug_assert!(y < c, "label {y} out of range");
        loss += -((row[y] - max - log_sum) as f64);
        let drow = &mut dlogits[i * c..(i + 1) * c];
        for (j, dv) in drow.iter_mut().enumerate() {
            let p = (row[j] - max).exp() / sum;
            *dv = (p - if j == y { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    (loss / b as f64, dlogits)
}

/// One AdamW update for a single tensor, in place. `step0` is the 0-based
/// step count (the python contract passes the same and adds 1), and the
/// hyper-parameters come from the manifest (paper §5 defaults).
pub fn adamw_update(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32],
                    step0: usize, hp: &crate::runtime::manifest::AdamwConfig) {
    let t = step0 as f64 + 1.0;
    let (b1, b2) = (hp.b1 as f32, hp.b2 as f32);
    let bc1 = (1.0 - hp.b1.powf(t)) as f32;
    let bc2 = (1.0 - hp.b2.powf(t)) as f32;
    let (lr, eps, wd) = (hp.lr as f32, hp.eps as f32, hp.wd as f32);
    for ((pv, &gv), (mv, vv)) in
        p.iter_mut().zip(g).zip(m.iter_mut().zip(v.iter_mut()))
    {
        *mv = b1 * *mv + (1.0 - b1) * gv;
        *vv = b2 * *vv + (1.0 - b2) * gv * gv;
        let mhat = *mv / bc1;
        let vhat = *vv / bc2;
        *pv -= lr * (mhat / (vhat.sqrt() + eps) + wd * *pv);
    }
}

// ---------------------------------------------------------------------------
// parameter layout (the manifest contract, re-derived for manifest-less runs)
// ---------------------------------------------------------------------------

fn spec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: Dtype::F32 }
}

/// FSA head parameters, canonical order (python `model.sage_head`). The
/// head consumes the `[B, d]` multi-hop aggregate, so its shapes are
/// independent of sampling depth.
pub fn fsa_param_specs(d: usize, h: usize, c: usize) -> Vec<TensorSpec> {
    vec![spec("w_self", &[d, h]), spec("w_neigh", &[d, h]),
         spec("b_hidden", &[h]), spec("w_out", &[h, c]), spec("b_out", &[c])]
}

/// DGL baseline parameters for an L-layer SAGE stack, canonical order:
/// `[w1_self, w1_neigh, b1, w2_self, w2_neigh, b2, …]` with layer widths
/// `d → h → … → h → c`. Depth 2 reproduces the python
/// `baseline.dgl2_forward` layout exactly.
pub fn dgl_param_specs(d: usize, h: usize, c: usize,
                       depth: usize) -> Vec<TensorSpec> {
    assert!(depth >= 1, "SAGE stack needs at least one layer");
    let mut specs = Vec::with_capacity(3 * depth);
    for i in 1..=depth {
        let inp = if i == 1 { d } else { h };
        let out = if i == depth { c } else { h };
        specs.push(spec(&format!("w{i}_self"), &[inp, out]));
        specs.push(spec(&format!("w{i}_neigh"), &[inp, out]));
        specs.push(spec(&format!("b{i}"), &[out]));
    }
    specs
}

/// Degree-balanced parallel fill of row-major `out[rows, width]`:
/// `f(row, out_row)` runs on scoped workers over contiguous shards planned
/// by `costs` (length `rows`). Bitwise identical at any thread count —
/// every worker owns a disjoint slice.
pub(crate) fn par_fill_rows<F>(threads: usize, costs: &[u64], out: &mut [f32],
                               width: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = costs.len();
    debug_assert_eq!(out.len(), rows * width);
    let workers = resolve_threads(threads).min((rows / MIN_PAR_ROWS).max(1));
    if workers <= 1 {
        for (i, row) in out.chunks_exact_mut(width).enumerate() {
            f(i, row);
        }
        return;
    }
    let plan = crate::graph::shard::plan_shards(costs, workers);
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = out;
        for r in plan {
            let take = (r.end - r.start) * width;
            let slab = std::mem::take(&mut rest);
            let (chunk, tail) = slab.split_at_mut(take);
            rest = tail;
            if r.is_empty() {
                continue;
            }
            let f = &f;
            s.spawn(move || {
                for (i, row) in chunk.chunks_exact_mut(width).enumerate() {
                    f(r.start + i, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trip_is_close() {
        for x in [0.0f32, 1.0, -3.5, 0.1, 123.456, -1e-3] {
            let back = bf16_to_f32(f32_to_bf16(x));
            assert!((back - x).abs() <= x.abs() / 128.0 + 1e-38, "{x} {back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_matches_runtime_byte_converter() {
        let xs = [1.0f32, -3.5, 0.1, 65504.0, 1e-8];
        let bytes = crate::runtime::f32_to_bf16_bytes(&xs);
        for (i, &x) in xs.iter().enumerate() {
            let want = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            assert_eq!(f32_to_bf16(x), want);
        }
    }

    #[test]
    fn features_gather_both_dtypes() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        for amp in [false, true] {
            let f = Features::from_f32(&x, 3, 2, amp);
            let mut acc = [10.0f32, 20.0];
            f.add_row_slice(1, 0, 2, &mut acc);
            assert!((acc[0] - 13.0).abs() < 0.1 && (acc[1] - 24.0).abs() < 0.1);
            let mut row = [0.0f32; 2];
            f.copy_row(2, &mut row);
            assert!((row[0] - 5.0).abs() < 0.1 && (row[1] - 6.0).abs() < 0.1);
        }
        assert_eq!(Features::from_f32(&x, 3, 2, true).bytes(), 12);
        assert_eq!(Features::from_f32(&x, 3, 2, false).bytes(), 24);
    }

    #[test]
    fn shared_dataset_storage_reads_identically_and_owns_nothing() {
        let ds = Arc::new(
            crate::gen::Dataset::generate(
                crate::gen::builtin_spec("tiny").unwrap()).unwrap());
        let shared = Features::from_dataset(ds.clone(), false);
        let owned =
            Features::from_f32(&ds.features, ds.spec.n, ds.spec.d, false);
        assert_eq!(shared.bytes(), 0, "shared view must not copy");
        let d = ds.spec.d;
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        for u in [0usize, 17, 511] {
            shared.copy_row(u, &mut a);
            owned.copy_row(u, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn degree_permuted_features_read_identically() {
        let ds = Arc::new(
            crate::gen::Dataset::generate(
                crate::gen::builtin_spec("tiny").unwrap()).unwrap());
        let d = ds.spec.d;
        for amp in [false, true] {
            let plain = Features::from_dataset(ds.clone(), amp);
            let mut permuted = Features::from_dataset(ds.clone(), amp);
            permuted.permute_by_degree(&ds.graph);
            let mut a = vec![0.0f32; d];
            let mut b = vec![0.0f32; d];
            for u in [0usize, 3, 17, 200, 511] {
                plain.copy_row(u, &mut a);
                permuted.copy_row(u, &mut b);
                assert_eq!(a, b, "amp={amp} node {u}");
                a.fill(0.5);
                b.fill(0.5);
                plain.add_row_slice(u, 1, d, &mut a[1..]);
                permuted.add_row_slice(u, 1, d, &mut b[1..]);
                assert_eq!(a, b, "amp={amp} node {u} slice");
            }
        }
        // the hottest row moved to the front of physical storage
        let mut permuted = Features::from_dataset(ds.clone(), false);
        permuted.permute_by_degree(&ds.graph);
        let hub = (0..ds.spec.n)
            .min_by_key(|&u| {
                (std::cmp::Reverse(ds.graph.degree(u as i32)), u)
            })
            .unwrap();
        assert_eq!(permuted.phys(hub), 0);
    }

    #[test]
    fn d_tile_override_env_and_detection_agree_on_bounds() {
        set_d_tile(96);
        assert_eq!(d_tile(), 96);
        set_d_tile(0);
        let auto = d_tile();
        assert!((64..=1024).contains(&auto) && auto % simd::LANES == 0,
                "auto tile {auto}");
    }

    #[test]
    fn cache_size_strings_parse() {
        assert_eq!(parse_cache_size("32K"), Some(32 * 1024));
        assert_eq!(parse_cache_size("1M"), Some(1024 * 1024));
        assert_eq!(parse_cache_size("49152"), Some(49152));
        assert_eq!(parse_cache_size("weird"), None);
    }

    #[test]
    fn layout_choice_parses() {
        assert_eq!(FeatureLayout::parse("natural").unwrap(),
                   FeatureLayout::Natural);
        assert_eq!(FeatureLayout::parse("degree").unwrap(),
                   FeatureLayout::DegreeDesc);
        assert!(FeatureLayout::parse("random").is_err());
        assert_eq!(FeatureLayout::default().as_str(), "natural");
    }

    #[test]
    fn xent_uniform_logits_give_log_c() {
        let (b, c) = (4, 8);
        let logits = vec![0.0f32; b * c];
        let labels = vec![3i32; b];
        let (loss, d) = softmax_xent(&logits, &labels, b, c);
        assert!((loss - (c as f64).ln()).abs() < 1e-6, "{loss}");
        // gradient rows sum to 0 and point away from the label
        for i in 0..b {
            let row = &d[i * c..(i + 1) * c];
            let sum: f32 = row.iter().sum();
            assert!(sum.abs() < 1e-6);
            assert!(row[3] < 0.0 && row[0] > 0.0);
        }
    }

    #[test]
    fn xent_is_shift_invariant_and_stable() {
        let logits = vec![1000.0f32, 1001.0, 999.0];
        let (loss, _) = softmax_xent(&logits, &[1], 1, 3);
        let logits2 = vec![0.0f32, 1.0, -1.0];
        let (loss2, _) = softmax_xent(&logits2, &[1], 1, 3);
        assert!((loss - loss2).abs() < 1e-6);
        assert!(loss.is_finite());
    }

    #[test]
    fn adamw_moves_against_gradient_and_decays() {
        let hp = crate::runtime::manifest::AdamwConfig {
            lr: 0.01, b1: 0.9, b2: 0.999, eps: 1e-8, wd: 0.1,
        };
        let mut p = vec![1.0f32, -1.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adamw_update(&mut p, &[1.0, -1.0], &mut m, &mut v, 0, &hp);
        // gradient step ~ lr (bias-corrected first step) + weight decay
        assert!(p[0] < 1.0 && p[0] > 0.97, "{:?}", p);
        assert!(p[1] > -1.0 && p[1] < -0.97, "{:?}", p);
        // zero gradient: only decay moves params
        let p0 = p[0];
        adamw_update(&mut p, &[0.0, 0.0], &mut m, &mut v, 1, &hp);
        assert!(p[0] < p0);
    }

    #[test]
    fn par_fill_rows_matches_serial_at_any_thread_count() {
        let rows = 137;
        let width = 5;
        let costs: Vec<u64> = (0..rows as u64).map(|i| 1 + i % 7).collect();
        let fill = |i: usize, row: &mut [f32]| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (i * width + j) as f32;
            }
        };
        let mut serial = vec![0.0f32; rows * width];
        par_fill_rows(1, &costs, &mut serial, width, fill);
        for threads in [2usize, 3, 8] {
            let mut par = vec![0.0f32; rows * width];
            par_fill_rows(threads, &costs, &mut par, width, fill);
            assert_eq!(par, serial, "threads={threads}");
        }
    }
}
