//! `fsa serve` — micro-batched online inference over [`Engine::infer`].
//!
//! Request lifecycle: a client thread calls [`ServeHandle::submit`] with
//! a set of seed node ids. Admission control is a bounded queue
//! (`--queue-depth`): when it is full the request is *shed* immediately
//! ([`Submit::Shed`]) instead of queueing unboundedly — the client gets
//! a synchronous rejection it can retry against. Admitted requests wait
//! in the queue until the server loop ([`run_server`]) coalesces them
//! into a micro-batch: starting from the first request dequeued, it
//! keeps pulling until either `--max-batch` seeds are gathered or
//! `--batch-window-ms` has elapsed since the batch opened. One
//! [`Engine::infer`] call serves the whole micro-batch; per-request
//! logits are split back out and sent over each request's private reply
//! channel, stamped with the enqueue→reply latency.
//!
//! Determinism scope: the engine's counter RNG is keyed per *node* on a
//! fixed forward base seed ([`Engine::infer_base`]), and each output row
//! of the head matmuls depends only on that row's aggregate — so the
//! logits for a given seed are bitwise identical no matter which
//! micro-batch it lands in, how large that batch is, or in which order
//! requests arrived (pinned in `rust/tests/serve.rs`). What the batching
//! policy changes is *latency*, never values.
//!
//! The engine is not `Send` (it may hold PJRT runtime handles), so the
//! server loop runs on the thread that owns the engine; clients are the
//! threads holding [`ServeHandle`] clones. The loop exits when every
//! handle has been dropped and the queue is drained — shutdown is
//! graceful by construction, and dropping the engine afterwards persists
//! planner state exactly like a training session's shutdown does.
//!
//! Graceful degradation (the fault-tolerance contract, pinned in
//! `rust/tests/faults.rs`): every admitted request gets exactly one
//! typed reply. Requests that exceed `--deadline-ms` — whether waiting
//! for dispatch or while their micro-batch computes (the deadline is
//! re-checked at reply time) — are answered [`ReplyBody::Timeout`]
//! instead of stale scores; a micro-batch whose forward pass panics or
//! errors is
//! *isolated* — its requests get [`ReplyBody::Error`] and the server
//! keeps draining (`catch_unwind` around the one `infer` call, chaos
//! site `serve`). Both outcomes are counted ([`ServeStats::timeouts`],
//! [`ServeStats::faults`]) and land in `serving.csv`.

pub mod bench;

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::engine::Engine;
use crate::metrics::percentile_sorted;
use crate::runtime::faults::{self, FaultSite};

/// Micro-batching + admission policy of one serving loop.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// How long a micro-batch stays open for more requests after the
    /// first one arrives (0 = serve each queue drain immediately).
    pub batch_window_ms: f64,
    /// Seed budget per micro-batch: the batch closes as soon as the
    /// gathered requests reach this many seeds.
    pub max_batch: usize,
    /// Bounded queue depth (admission control): submissions beyond this
    /// many waiting requests are shed.
    pub queue_depth: usize,
    /// Per-request deadline, ms (0 = none): a request that exceeds this
    /// — before its batch dispatches *or* while the batch computes
    /// (checked again at reply time) — is answered
    /// [`ReplyBody::Timeout`] instead of stale scores.
    pub deadline_ms: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch_window_ms: 2.0, max_batch: 512, queue_depth: 64,
                      deadline_ms: 0.0 }
    }
}

/// One admitted request, queued for the server loop.
pub struct Request {
    pub seeds: Vec<i32>,
    pub enqueued: Instant,
    reply: mpsc::Sender<Reply>,
}

/// What a reply carries: scores on success, a typed degradation
/// otherwise. Every admitted request gets exactly one reply.
#[derive(Clone, Debug)]
pub enum ReplyBody {
    /// Row-major `[seeds.len(), classes]` scores.
    Scores(Vec<f32>),
    /// The request missed its `--deadline-ms` before dispatch.
    Timeout,
    /// The request's micro-batch panicked or errored; the failure was
    /// isolated to the batch and the server kept serving.
    Error(String),
}

/// Per-request response: the typed body plus the measured enqueue→reply
/// latency.
#[derive(Clone, Debug)]
pub struct Reply {
    pub body: ReplyBody,
    pub latency_ms: f64,
}

impl Reply {
    /// The scores, when this reply has any (None for timeout/error).
    pub fn scores(&self) -> Option<&[f32]> {
        match &self.body {
            ReplyBody::Scores(s) => Some(s),
            _ => None,
        }
    }
}

/// Outcome of a submission attempt.
pub enum Submit {
    /// Admitted; the reply arrives on this channel.
    Accepted(mpsc::Receiver<Reply>),
    /// Queue full — shed at admission (retry later or back off).
    Shed,
}

/// Client-side handle: cheap to clone, one per client thread. The server
/// loop ends when all handles are dropped and the queue is drained.
#[derive(Clone)]
pub struct ServeHandle {
    tx: mpsc::SyncSender<Request>,
    n_nodes: usize,
}

impl ServeHandle {
    /// Submit one request. Malformed requests (empty, out-of-range ids)
    /// are hard errors — only a *full queue* sheds. Errors also signal a
    /// shut-down server (queue receiver dropped).
    pub fn submit(&self, seeds: Vec<i32>) -> Result<Submit> {
        ensure!(!seeds.is_empty(), "request has no seed ids");
        for &s in &seeds {
            ensure!(s >= 0 && (s as usize) < self.n_nodes,
                    "seed {s} out of range: the graph has nodes \
                     0..{}", self.n_nodes);
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { seeds, enqueued: Instant::now(),
                            reply: reply_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(Submit::Accepted(reply_rx)),
            Err(mpsc::TrySendError::Full(_)) => Ok(Submit::Shed),
            Err(mpsc::TrySendError::Disconnected(_)) => {
                bail!("server is shut down")
            }
        }
    }
}

/// Build the bounded request queue: a client handle and the receiver the
/// server loop drains. `n_nodes` bounds valid seed ids at admission.
pub fn channel(cfg: &ServeConfig, n_nodes: usize)
               -> (ServeHandle, mpsc::Receiver<Request>) {
    let (tx, rx) = mpsc::sync_channel(cfg.queue_depth.max(1));
    (ServeHandle { tx, n_nodes }, rx)
}

/// Serving-side accounting for one `run_server` lifetime.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests answered (each with its own reply).
    pub completed: u64,
    /// Micro-batches dispatched (fused forward passes).
    pub batches: u64,
    /// Total seeds inferred across all batches.
    pub seeds: u64,
    /// Per-request enqueue→reply latencies, ms.
    pub latencies_ms: Vec<f64>,
    /// Per-batch measured shard imbalance (sharded passes only).
    pub imbalances: Vec<f64>,
    /// Requests answered [`ReplyBody::Error`] (micro-batch panic or
    /// engine failure, isolated to the batch).
    pub faults: u64,
    /// Requests answered [`ReplyBody::Timeout`] (missed `deadline_ms`).
    pub timeouts: u64,
    /// Bounded-backoff persistence retries the engine consumed while
    /// this loop ran (delta of [`Engine::retries_total`]).
    pub retries: u64,
}

impl ServeStats {
    /// (p50, p95, p99) of the per-request latencies, ms. An empty
    /// window (every request shed or timed out under `--bench`) reports
    /// all-zero percentiles with a warning instead of panicking, so
    /// serving.csv rows stay finite and schema-valid; NaN latencies
    /// order via `f64::total_cmp` (after every non-NaN) rather than
    /// aborting the sort.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        if self.latencies_ms.is_empty() {
            if self.completed + self.timeouts + self.faults > 0 {
                eprintln!("warning: serve window recorded no reply \
                           latencies; reporting 0.0 percentiles");
            }
            return (0.0, 0.0, 0.0);
        }
        let mut sorted = self.latencies_ms.clone();
        sorted.sort_by(f64::total_cmp);
        (percentile_sorted(&sorted, 50.0),
         percentile_sorted(&sorted, 95.0),
         percentile_sorted(&sorted, 99.0))
    }

    /// Median per-batch shard imbalance (1.0 when nothing sharded —
    /// serial passes are balanced by definition).
    pub fn median_imbalance(&self) -> f64 {
        if self.imbalances.is_empty() {
            return 1.0;
        }
        let mut sorted = self.imbalances.clone();
        sorted.sort_by(f64::total_cmp);
        percentile_sorted(&sorted, 50.0)
    }

    pub fn mean_batch_seeds(&self) -> f64 {
        self.seeds as f64 / self.batches.max(1) as f64
    }
}

/// The serving loop: drain the queue, coalesce micro-batches under the
/// policy, infer, reply. Runs on the calling thread (which owns the
/// engine) until every [`ServeHandle`] is dropped and the queue is
/// empty; returns the accumulated stats. A failing micro-batch —
/// panic or engine error — never aborts the loop: its requests get
/// [`ReplyBody::Error`] and serving continues (see the module docs).
pub fn run_server(engine: &mut Engine<'_>, cfg: &ServeConfig,
                  rx: &mpsc::Receiver<Request>) -> Result<ServeStats> {
    let window = Duration::from_secs_f64(cfg.batch_window_ms.max(0.0) / 1e3);
    let max_batch = cfg.max_batch.max(1);
    let retries_before = engine.retries_total();
    let mut stats = ServeStats::default();
    // blocks for the first request of each batch; Err = all handles
    // dropped and queue drained = graceful shutdown
    while let Ok(first) = rx.recv() {
        let mut batch = vec![first];
        let mut gathered = batch[0].seeds.len();
        let deadline = Instant::now() + window;
        while gathered < max_batch {
            let now = Instant::now();
            let left = deadline.saturating_duration_since(now);
            if left.is_zero() {
                // window closed: take only what is already queued
                match rx.try_recv() {
                    Ok(req) => {
                        gathered += req.seeds.len();
                        batch.push(req);
                    }
                    Err(_) => break,
                }
            } else {
                match rx.recv_timeout(left) {
                    Ok(req) => {
                        gathered += req.seeds.len();
                        batch.push(req);
                    }
                    // Timeout: window closed. Disconnected: shutting
                    // down — serve what we have, outer recv() exits.
                    Err(_) => break,
                }
            }
        }
        serve_batch(engine, cfg, batch, &mut stats);
    }
    stats.retries = engine.retries_total() - retries_before;
    Ok(stats)
}

/// Latency of `req` measured at `at`, in ms.
fn latency_at(req: &Request, at: Instant) -> f64 {
    at.duration_since(req.enqueued).as_secs_f64() * 1e3
}

/// Answer every request in `batch` with the same degraded body.
fn reply_all(batch: Vec<Request>, body: &ReplyBody, stats: &mut ServeStats) {
    let done = Instant::now();
    for req in batch {
        let latency_ms = latency_at(&req, done);
        stats.completed += 1;
        stats.latencies_ms.push(latency_ms);
        let _ = req.reply.send(Reply { body: body.clone(), latency_ms });
    }
}

/// Run one coalesced micro-batch through the engine and fan the logits
/// back out to the per-request reply channels. Degradations stay inside
/// this batch: deadline-expired requests get `Timeout`, and a panicking
/// or erroring forward pass gets every remaining request an `Error`.
fn serve_batch(engine: &mut Engine<'_>, cfg: &ServeConfig,
               mut batch: Vec<Request>, stats: &mut ServeStats) {
    if cfg.deadline_ms > 0.0 {
        let now = Instant::now();
        batch.retain(|req| {
            if latency_at(req, now) <= cfg.deadline_ms {
                return true;
            }
            let latency_ms = latency_at(req, now);
            stats.completed += 1;
            stats.timeouts += 1;
            stats.latencies_ms.push(latency_ms);
            let _ = req.reply.send(Reply { body: ReplyBody::Timeout,
                                           latency_ms });
            false
        });
        if batch.is_empty() {
            return;
        }
    }
    let all: Vec<i32> = batch
        .iter()
        .flat_map(|r| r.seeds.iter().copied())
        .collect();
    // one op per micro-batch (the chaos `serve` site); the unwind
    // barrier turns a poisoned batch into per-request Error replies
    // instead of a dead server
    let plane = engine.cfg.faults.clone();
    let op = plane.begin(FaultSite::ServeBatch);
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> Result<Vec<f32>> {
            faults::inject(plane.as_ref(), FaultSite::ServeBatch, op)?;
            engine.infer(&all)
        }));
    let logits = match outcome {
        Ok(Ok(logits)) => logits,
        Ok(Err(e)) => {
            eprintln!("warning: serve batch failed ({} requests): {e:#}",
                      batch.len());
            stats.faults += batch.len() as u64;
            reply_all(batch, &ReplyBody::Error(format!("{e:#}")), stats);
            return;
        }
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "unknown panic".to_string());
            eprintln!("warning: serve batch panicked ({} requests): {msg}; \
                       isolating the batch and continuing", batch.len());
            stats.faults += batch.len() as u64;
            reply_all(batch, &ReplyBody::Error(format!("batch panicked: \
                                                        {msg}")), stats);
            return;
        }
    };
    if let Some(imb) = engine.infer_imbalance() {
        stats.imbalances.push(imb);
    }
    let c = logits.len() / all.len().max(1);
    let done = Instant::now();
    let mut offset = 0usize;
    stats.batches += 1;
    stats.seeds += all.len() as u64;
    for req in batch {
        let take = req.seeds.len() * c;
        let scores = logits[offset..offset + take].to_vec();
        offset += take;
        let latency_ms = latency_at(&req, done);
        stats.completed += 1;
        stats.latencies_ms.push(latency_ms);
        // re-check the deadline at reply time: a request admitted just
        // under the wire that expired while its batch computed must get
        // Timeout (and be counted), not stale scores
        let body = if cfg.deadline_ms > 0.0 && latency_ms > cfg.deadline_ms {
            stats.timeouts += 1;
            ReplyBody::Timeout
        } else {
            ReplyBody::Scores(scores)
        };
        // the client may have given up and dropped its receiver; that
        // only loses the reply, not the server
        let _ = req.reply.send(Reply { body, latency_ms });
    }
}

/// Parse one stdin-protocol request line — node ids separated by
/// spaces, commas, or tabs — into a seed set. Malformed lines are
/// errors the caller answers with an `ERR` reply; they must never kill
/// the server.
pub fn parse_request_line(line: &str) -> Result<Vec<i32>> {
    let mut seeds = Vec::new();
    let toks = line
        .split(|c: char| c.is_whitespace() || c == ',')
        .filter(|t| !t.is_empty());
    for tok in toks {
        match tok.parse::<i32>() {
            Ok(id) => seeds.push(id),
            Err(_) => bail!("bad node id {tok:?} (expected a non-negative \
                             integer)"),
        }
    }
    ensure!(!seeds.is_empty(), "empty request line");
    Ok(seeds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_validates_and_sheds() {
        let cfg = ServeConfig { batch_window_ms: 0.0, max_batch: 512,
                                queue_depth: 2, deadline_ms: 0.0 };
        let (handle, rx) = channel(&cfg, 100);
        assert!(matches!(handle.submit(vec![1]).unwrap(),
                         Submit::Accepted(_)));
        assert!(matches!(handle.submit(vec![2, 3]).unwrap(),
                         Submit::Accepted(_)));
        // queue full: shed, not an error
        assert!(matches!(handle.submit(vec![4]).unwrap(), Submit::Shed));
        // malformed requests: errors, not sheds
        assert!(handle.submit(vec![]).is_err());
        let err = handle.submit(vec![100]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        let err = handle.submit(vec![-1]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // server gone: error with a clear message
        drop(rx);
        let err = handle.submit(vec![5]).unwrap_err().to_string();
        assert!(err.contains("shut down"), "{err}");
    }

    #[test]
    fn stats_percentiles_and_means() {
        let stats = ServeStats {
            completed: 4,
            batches: 2,
            seeds: 6,
            latencies_ms: vec![4.0, 1.0, 3.0, 2.0],
            imbalances: vec![1.5, 1.0, 2.0],
            ..Default::default()
        };
        let (p50, p95, p99) = stats.latency_percentiles();
        assert!(p50 >= 1.0 && p50 <= 4.0 && p95 <= 4.0 && p99 <= 4.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert_eq!(stats.median_imbalance(), 1.5);
        assert_eq!(stats.mean_batch_seeds(), 3.0);
        assert_eq!(ServeStats::default().median_imbalance(), 1.0);
        let (z50, _, z99) = ServeStats::default().latency_percentiles();
        assert_eq!((z50, z99), (0.0, 0.0));
    }

    /// A NaN latency or imbalance (a clock glitch, a div-by-zero shard
    /// ratio) must not panic the percentile sort, and an all-shed bench
    /// window (latencies empty, timeouts > 0) must report finite zeros
    /// rather than unwrap on an empty comparison.
    #[test]
    fn stats_survive_nan_and_empty_windows() {
        let stats = ServeStats {
            completed: 3,
            latencies_ms: vec![2.0, f64::NAN, 1.0],
            imbalances: vec![f64::NAN, 1.5, 1.0],
            ..Default::default()
        };
        // total_cmp puts the NaN last: the median stays finite (the
        // tail percentiles may interpolate into the NaN, but nothing
        // panics)
        let (p50, _p95, _p99) = stats.latency_percentiles();
        assert_eq!(p50, 2.0, "NaN must sort last, not poison p50");
        assert_eq!(stats.median_imbalance(), 1.5);
        let shed_everything = ServeStats {
            timeouts: 7,
            ..Default::default()
        };
        let (a, b, c) = shed_everything.latency_percentiles();
        assert_eq!((a, b, c), (0.0, 0.0, 0.0));
    }

    #[test]
    fn request_lines_parse_or_error_with_a_reason() {
        assert_eq!(parse_request_line("3 1 4").unwrap(), vec![3, 1, 4]);
        assert_eq!(parse_request_line("3,1,4").unwrap(), vec![3, 1, 4]);
        assert_eq!(parse_request_line("  7\t").unwrap(), vec![7]);
        for (line, needle) in [("", "empty"), ("   ", "empty"),
                               ("1 two 3", "bad node id"),
                               ("1.5", "bad node id"),
                               ("99999999999999", "bad node id")] {
            let err = parse_request_line(line).unwrap_err().to_string();
            assert!(err.contains(needle), "{line:?}: {err}");
        }
    }

    #[test]
    fn reply_scores_accessor_matches_body() {
        let ok = Reply { body: ReplyBody::Scores(vec![0.5]),
                         latency_ms: 1.0 };
        assert_eq!(ok.scores(), Some(&[0.5f32][..]));
        for body in [ReplyBody::Timeout, ReplyBody::Error("x".into())] {
            assert!(Reply { body, latency_ms: 1.0 }.scores().is_none());
        }
    }
}
