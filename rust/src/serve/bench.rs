//! `fsa serve --bench` — closed-loop load generator over the serving
//! stack (arrival rates × batch windows → `serving.csv`).
//!
//! Each grid cell spawns `clients` closed-loop client threads against a
//! fresh queue: every client draws deterministic seed sets (SplitMix64
//! keyed per client), submits, *waits for the reply* before pacing its
//! next send — so offered load beyond the server's capacity shows up as
//! rising latency and shed counts rather than an unbounded backlog. The
//! server loop runs on the calling thread (it owns the engine) for the
//! cell's duration; when the clients finish and drop their handles the
//! loop drains and exits, and the cell's stats become one
//! [`ServingRow`].

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::engine::Engine;
use crate::metrics::ServingRow;
use crate::rng::{mix, SplitMix64};

use super::{channel, run_server, ServeConfig, ServeHandle, Submit};

/// The bench grid: one serving cell per (rate, window) pair.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Offered arrival rates, requests/second (summed over clients).
    pub rates: Vec<f64>,
    /// Batch windows to sweep, ms.
    pub windows_ms: Vec<f64>,
    /// Wall-clock duration of each cell, ms.
    pub duration_ms: f64,
    /// Closed-loop client threads.
    pub clients: usize,
    /// Seed ids per request.
    pub seeds_per_request: usize,
    /// Micro-batch seed budget (`ServeConfig::max_batch`).
    pub max_batch: usize,
    /// Admission queue depth (`ServeConfig::queue_depth`).
    pub queue_depth: usize,
    /// Per-request deadline (`ServeConfig::deadline_ms`; 0 = none).
    pub deadline_ms: f64,
    /// RNG seed for the clients' node draws.
    pub seed: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            rates: vec![200.0, 1000.0],
            windows_ms: vec![0.0, 2.0],
            duration_ms: 1000.0,
            clients: 4,
            seeds_per_request: 4,
            max_batch: 512,
            queue_depth: 64,
            deadline_ms: 0.0,
            seed: 42,
        }
    }
}

/// Run the grid; one [`ServingRow`] per (rate, window) cell.
pub fn run_bench(engine: &mut Engine<'_>, bc: &BenchConfig)
                 -> Result<Vec<ServingRow>> {
    ensure!(!bc.rates.is_empty() && !bc.windows_ms.is_empty(),
            "--rates and --windows must be non-empty");
    ensure!(bc.duration_ms > 0.0, "--duration-ms must be positive");
    let n_nodes = engine.ds.spec.n;
    let clients = bc.clients.max(1);
    let spr = bc.seeds_per_request.max(1);
    let backend = engine.backend_name().to_string();
    let mut rows = Vec::new();
    for &rate in &bc.rates {
        ensure!(rate.is_finite() && rate > 0.0,
                "--rates entries must be positive, got {rate}");
        for &window in &bc.windows_ms {
            ensure!(window.is_finite() && window >= 0.0,
                    "--windows entries must be >= 0, got {window}");
            let scfg = ServeConfig {
                batch_window_ms: window,
                max_batch: bc.max_batch,
                queue_depth: bc.queue_depth,
                deadline_ms: bc.deadline_ms,
            };
            let (handle, rx) = channel(&scfg, n_nodes);
            // each client paces at rate/clients so the *sum* offered
            // load is `rate`
            let interval = Duration::from_secs_f64(clients as f64 / rate);
            let started = Instant::now();
            let deadline =
                started + Duration::from_secs_f64(bc.duration_ms / 1e3);
            let workers: Vec<_> = (0..clients)
                .map(|ci| {
                    let h = handle.clone();
                    let seed = mix(bc.seed ^ (0xC11E + ci as u64));
                    std::thread::spawn(move || {
                        client_loop(h, n_nodes, spr, interval, deadline,
                                    seed)
                    })
                })
                .collect();
            // the clients' clones are the only live handles now, so the
            // server exits when they all finish
            drop(handle);
            let hub0 = engine.hub_counters();
            let stats = run_server(engine, &scfg, &rx)?;
            // hub-cache activity attributable to this cell (serve cells
            // share one eval seed epoch, so warm cells approach the hub
            // traffic share on skewed graphs; 0.0/0 when off)
            let (hub_hit_rate, hub_refreshes) =
                crate::bench::throughput::hub_delta(
                    hub0, engine.hub_counters());
            let elapsed_s = started.elapsed().as_secs_f64().max(1e-9);
            let mut shed = 0u64;
            for w in workers {
                shed += w.join().expect("bench client thread panicked");
            }
            let (p50, p95, p99) = stats.latency_percentiles();
            eprintln!("serve-bench: rate {rate:>6.0} rps window \
                       {window:>4.1} ms -> {} completed, {shed} shed, \
                       p99 {p99:.2} ms", stats.completed);
            rows.push(ServingRow {
                dataset: engine.cfg.dataset.clone(),
                fanout: engine.cfg.fanouts.label(),
                backend: backend.clone(),
                planner: engine.cfg.planner.as_str().to_string(),
                batch_window_ms: window,
                max_batch: bc.max_batch as u32,
                queue_depth: bc.queue_depth as u32,
                offered_rps: rate,
                completed: stats.completed,
                shed,
                achieved_rps: stats.completed as f64 / elapsed_s,
                p50_ms: p50,
                p95_ms: p95,
                p99_ms: p99,
                imbalance: stats.median_imbalance(),
                faults: stats.faults,
                retries: stats.retries,
                timeouts: stats.timeouts,
                hub_hit_rate,
                hub_refreshes,
            });
        }
    }
    Ok(rows)
}

/// One closed-loop client: draw seeds, submit, block on the reply, pace
/// to `interval`. Returns its shed count.
fn client_loop(handle: ServeHandle, n_nodes: usize, seeds_per_request: usize,
               interval: Duration, deadline: Instant, seed: u64) -> u64 {
    let mut rng = SplitMix64::new(seed);
    let mut shed = 0u64;
    let mut next = Instant::now();
    while Instant::now() < deadline {
        let seeds: Vec<i32> = (0..seeds_per_request)
            .map(|_| rng.next_below(n_nodes as u64) as i32)
            .collect();
        match handle.submit(seeds) {
            Ok(Submit::Accepted(reply)) => {
                // closed loop: wait for the answer before the next send
                let _ = reply.recv();
            }
            Ok(Submit::Shed) => shed += 1,
            Err(_) => break, // server is gone; stop offering load
        }
        next += interval;
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        } else {
            next = now; // fell behind; don't try to catch up in a burst
        }
    }
    shed
}

/// Human-readable table of the grid (printed after the CSV is written).
pub fn render_table(rows: &[ServingRow]) -> String {
    let mut out = String::new();
    out.push_str("offered_rps  window_ms  completed   shed  \
                  achieved_rps  p50_ms  p95_ms  p99_ms  imbalance  \
                  faults  retries  timeouts  hub_hit  refreshes\n");
    for r in rows {
        let _ = writeln!(
            out,
            "{:>11.0}  {:>9.1}  {:>9}  {:>5}  {:>12.1}  {:>6.2}  \
             {:>6.2}  {:>6.2}  {:>9.3}  {:>6}  {:>7}  {:>8}  {:>7.3}  \
             {:>9}",
            r.offered_rps, r.batch_window_ms, r.completed, r.shed,
            r.achieved_rps, r.p50_ms, r.p95_ms, r.p99_ms, r.imbalance,
            r.faults, r.retries, r.timeouts, r.hub_hit_rate,
            r.hub_refreshes);
    }
    out
}
