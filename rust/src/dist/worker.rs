//! The data-parallel worker: the hidden `fsa dist-worker` child
//! entrypoint, and the in-process variant the deterministic tests run
//! (`--workers` thread mode) — both drive the same [`run`] loop over a
//! connected socket.
//!
//! A worker owns a full local copy of the graph (datasets are generated
//! deterministically from their spec, so "shipping the shard" is a
//! no-op on localhost) and a [`NativeBackend`] it never optimizes with:
//! every `Step` frame carries the coordinator's current parameters, the
//! worker installs them verbatim ([`NativeBackend::set_params`]), runs
//! [`NativeBackend::fsa_loss_grads`] per assigned micro-batch, and
//! ships the raw f32 gradients back. All floating-point decisions —
//! the weighted fold and the AdamW update — live on the coordinator,
//! which is what keeps the trajectory independent of which worker
//! computed which micro.
//!
//! Liveness is a dedicated heartbeat thread writing `Heartbeat` frames
//! on a timer, so a worker deep in a long kernel pass still looks alive
//! — only a dead or truly stalled process goes silent. Socket writes
//! from the compute loop and the heartbeat thread are serialized
//! through one mutex so frames never interleave.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::fanout::Fanouts;
use crate::gen::Dataset;
use crate::graph::PlannerChoice;
use crate::kernel::{FeatureLayout, NativeBackend, NativeConfig, SimdChoice};
use crate::memory::MemoryMeter;
use crate::metrics::Timer;
use crate::runtime::manifest::Manifest;

use super::proto::{self, Msg};

/// Everything a worker needs to rebuild the coordinator's model shape
/// locally. Process-mode children parse this from their CLI args;
/// thread-mode workers receive it directly.
#[derive(Clone)]
pub struct WorkerConfig {
    pub rank: u32,
    pub ds: Arc<Dataset>,
    pub fanouts: Fanouts,
    pub amp: bool,
    pub seed: u64,
    pub threads: usize,
    pub hidden: usize,
    pub simd: SimdChoice,
    pub layout: FeatureLayout,
    pub heartbeat_ms: u64,
}

impl WorkerConfig {
    /// The worker-side engine config: fused variant, no planner state,
    /// no hub cache, no fault plane — workers are pure gradient
    /// functions; every stateful concern lives on the coordinator.
    fn native_config(&self) -> NativeConfig {
        NativeConfig {
            fused: true,
            fanouts: self.fanouts.clone(),
            amp: self.amp,
            save_indices: false,
            seed: self.seed,
            threads: self.threads,
            planner: PlannerChoice::Nominal,
            hidden: self.hidden,
            simd: self.simd,
            layout: self.layout,
            faults: crate::runtime::faults::none(),
            hub_cache: None,
        }
    }
}

/// Run one worker session over an already connected socket: send
/// `Hello`, then serve `Step` frames until `Shutdown` or the socket
/// closes. Returns cleanly on `Shutdown`/EOF so thread-mode tests can
/// join; protocol violations are errors.
pub fn run(stream: TcpStream, cfg: WorkerConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone().context("clone worker socket")?;
    let writer = Arc::new(Mutex::new(stream));
    let done = Arc::new(AtomicBool::new(false));

    // liveness beacon, independent of compute
    let hb_writer = writer.clone();
    let hb_done = done.clone();
    let rank = cfg.rank;
    let tick = Duration::from_millis(cfg.heartbeat_ms.clamp(10, 10_000) / 2
                                     + 1);
    let heartbeat = std::thread::spawn(move || {
        while !hb_done.load(Ordering::Relaxed) {
            {
                let mut w = hb_writer.lock().unwrap();
                if proto::write_msg(&mut *w, &Msg::Heartbeat { rank })
                    .is_err()
                {
                    break; // coordinator gone; main loop will see EOF
                }
            }
            std::thread::sleep(tick);
        }
    });

    let result = serve_steps(&mut reader, &writer, &cfg);
    done.store(true, Ordering::Relaxed);
    // unblock the heartbeat thread's next write by closing our half
    writer.lock().unwrap().shutdown(std::net::Shutdown::Both).ok();
    heartbeat.join().ok();
    result
}

fn serve_steps(reader: &mut TcpStream, writer: &Arc<Mutex<TcpStream>>,
               cfg: &WorkerConfig) -> Result<()> {
    // the worker never optimizes, so the AdamW hyper-params are inert —
    // the builtin manifest's values keep the constructor honest
    let mut backend = NativeBackend::new(cfg.ds.clone(), cfg.native_config(),
                                         Manifest::builtin().adamw)?;
    let n = cfg.ds.spec.n;
    {
        let mut w = writer.lock().unwrap();
        proto::write_msg(&mut *w, &Msg::Hello { rank: cfg.rank })
            .context("send hello")?;
    }
    let mut meter = MemoryMeter::new();
    loop {
        let msg = match proto::read_msg(reader) {
            Ok(m) => m,
            // coordinator crashed or closed without Shutdown: exit
            // quietly, the coordinator side owns the failure story
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                return Ok(());
            }
            Err(e) => return Err(e).context("read coordinator frame"),
        };
        match msg {
            Msg::Step { step, base, params, micros } => {
                ensure!(params.len() == backend.params().len(),
                        "step {step}: coordinator sent {} param tensors, \
                         model has {}", params.len(), backend.params().len());
                backend.set_params(params);
                for micro in micros {
                    let timer = Timer::start();
                    for &s in &micro.seeds {
                        ensure!(s >= 0 && (s as usize) < n,
                                "step {step} micro {}: seed {s} out of \
                                 range 0..{n}", micro.id);
                    }
                    let labels: Vec<i32> = micro.seeds.iter()
                        .map(|&s| cfg.ds.labels[s as usize])
                        .collect();
                    let (loss, grads, pairs, _stats) = backend
                        .fsa_loss_grads(&micro.seeds, &labels, base,
                                        &mut meter)?;
                    meter.reset_step();
                    let reply = Msg::Grads {
                        step,
                        micro_id: micro.id,
                        count: micro.seeds.len() as u32,
                        loss,
                        pairs,
                        compute_ms: timer.ms(),
                        grads,
                    };
                    let mut w = writer.lock().unwrap();
                    proto::write_msg(&mut *w, &reply)
                        .context("send grads")?;
                }
            }
            Msg::Shutdown => return Ok(()),
            Msg::Hello { .. } | Msg::Grads { .. } | Msg::Heartbeat { .. } => {
                bail!("unexpected {msg:?} from coordinator");
            }
        }
    }
}

/// Connect to the coordinator and run a worker session (thread mode and
/// the child entrypoint both end up here).
pub fn connect_and_run(addr: &str, cfg: WorkerConfig) -> Result<()> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("dist-worker: connect {addr}"))?;
    run(stream, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::builtin_spec;
    use std::net::TcpListener;

    fn tiny_cfg(rank: u32) -> WorkerConfig {
        let ds = Arc::new(
            Dataset::generate(builtin_spec("tiny").unwrap()).unwrap());
        WorkerConfig {
            rank,
            ds,
            fanouts: Fanouts::of(&[5, 3]),
            amp: false,
            seed: 42,
            threads: 1,
            hidden: 32,
            simd: SimdChoice::Auto,
            layout: FeatureLayout::Natural,
            heartbeat_ms: 50,
        }
    }

    /// Drive one worker end-to-end over a real localhost socket: it
    /// must say hello, heartbeat while idle, answer a Step with one
    /// Grads frame per micro, and exit on Shutdown.
    #[test]
    fn worker_answers_steps_and_shuts_down() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = tiny_cfg(7);
        let ds = cfg.ds.clone();
        let worker = std::thread::spawn(move || connect_and_run(&addr, cfg));
        let (mut sock, _) = listener.accept().unwrap();
        sock.set_nodelay(true).ok();

        let hello = proto::read_msg(&mut sock).unwrap();
        assert_eq!(hello, Msg::Hello { rank: 7 });

        // build a reference backend with the same shape for the params
        let refcfg = tiny_cfg(0);
        let backend = NativeBackend::new(
            ds.clone(), refcfg.native_config(),
            Manifest::builtin().adamw).unwrap();
        let params: Vec<Vec<f32>> = backend.params().to_vec();
        let micros = vec![
            proto::Micro { id: 0, seeds: (0..32).collect() },
            proto::Micro { id: 1, seeds: (32..48).collect() },
        ];
        proto::write_msg(&mut sock, &Msg::Step {
            step: 0, base: 99, params: params.clone(),
            micros: micros.clone(),
        }).unwrap();

        // collect exactly one Grads per micro (heartbeats interleave)
        let mut got = std::collections::BTreeMap::new();
        while got.len() < 2 {
            match proto::read_msg(&mut sock).unwrap() {
                Msg::Grads { step, micro_id, count, loss, grads, .. } => {
                    assert_eq!(step, 0);
                    assert!(loss.is_finite());
                    assert_eq!(grads.len(), params.len());
                    got.insert(micro_id, (count, grads));
                }
                Msg::Heartbeat { rank } => assert_eq!(rank, 7),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got[&0].0, 32);
        assert_eq!(got[&1].0, 16);

        // the worker's grads must equal a local compute bitwise
        let mut meter = MemoryMeter::new();
        let seeds: Vec<i32> = micros[0].seeds.clone();
        let labels: Vec<i32> =
            seeds.iter().map(|&s| ds.labels[s as usize]).collect();
        let (_, local, _, _) = backend
            .fsa_loss_grads(&seeds, &labels, 99, &mut meter).unwrap();
        assert_eq!(got[&0].1, local,
                   "worker grads differ from local compute");

        proto::write_msg(&mut sock, &Msg::Shutdown).unwrap();
        worker.join().unwrap().unwrap();
    }

    /// A coordinator that disappears without Shutdown (crash) must end
    /// the worker cleanly, not hang or error.
    #[test]
    fn worker_exits_cleanly_on_coordinator_eof() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = tiny_cfg(0);
        let worker = std::thread::spawn(move || connect_and_run(&addr, cfg));
        let (mut sock, _) = listener.accept().unwrap();
        let hello = proto::read_msg(&mut sock).unwrap();
        assert!(matches!(hello, Msg::Hello { rank: 0 }));
        drop(sock); // simulated coordinator crash
        worker.join().unwrap().unwrap();
    }
}
