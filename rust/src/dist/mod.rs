//! Localhost multi-process data-parallel training.
//!
//! `fsa train --workers N` runs this module's [`train`] loop: one
//! coordinator process owning the optimizer, N workers (child
//! processes spawned as the hidden `fsa dist-worker` subcommand, or
//! in-process threads for the deterministic tests) each owning a full
//! local copy of the graph and answering gradient requests over a
//! length-prefixed protocol on a localhost TCP socket
//! ([`proto`] / [`worker`]).
//!
//! # Work decomposition and bitwise reproducibility
//!
//! Every optimizer step draws the *same* seed batch the single-process
//! scheduler would draw (`BatchScheduler` keyed by the session seed),
//! splits it into fixed-size micro-batches, and assigns micro `m` to
//! live worker `m % N`. The decomposition depends only on the batch
//! and `--micro-batch` — never on N — and the coordinator folds worker
//! gradients **in micro id order** with weights `count/batch`, so the
//! loss trajectory is bitwise identical for any worker count at a
//! matched config. With `--micro-batch >= batch` there is exactly one
//! micro whose weight is exactly 1.0, which makes the run additionally
//! bitwise identical to plain single-process `fsa train` (the fold is
//! seeded from the first micro's weighted gradients rather than a
//! zero-filled accumulator precisely so `1.0 * g` preserves every bit,
//! including negative-zero signs).
//!
//! # Failure handling
//!
//! Liveness is heartbeat-based: each worker beacons on a timer
//! independent of compute, and a worker silent for ~4 heartbeat
//! intervals (or whose socket closes) is declared dead. Its node shard
//! is folded into the least-loaded survivor and its outstanding micros
//! are re-dispatched — the `Step` frame re-broadcasts the current
//! parameters, so recovery needs no state transfer and cannot perturb
//! the trajectory. Chaos hooks (`dist-send` / `dist-recv` fault sites)
//! drop frames or stall writes under `--chaos`; dropped result frames
//! are recovered by a rate-limited re-dispatch of whatever is still
//! outstanding, which is safe because gradient acceptance is
//! idempotent (first `Grads` frame per micro id wins).

pub mod proto;
pub mod worker;

use std::collections::BTreeMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::{BatchScheduler, TrainConfig, Variant};
use crate::engine::{ParamsCheckpoint, TrainState};
use crate::gen::Dataset;
use crate::graph::plan_shards;
use crate::kernel::NativeBackend;
use crate::metrics::{DistRow, Timer};
use crate::runtime::backend::Backend as _;
use crate::runtime::faults::{Fault, FaultSite};
use crate::runtime::manifest::AdamwConfig;

use proto::{Micro, Msg};

/// How the coordinator launches its workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerMode {
    /// `fsa dist-worker` child processes — the `fsa train --workers`
    /// path, and the only mode where SIGKILLing a worker is a real
    /// process death.
    Process,
    /// In-process threads over real localhost sockets — same protocol,
    /// same code path, deterministic to drive from tests.
    Thread,
}

/// Knobs for a distributed session beyond the shared [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Worker count (N >= 1).
    pub workers: usize,
    /// Seeds per micro-batch; 0 derives `ceil(batch / 4)`. Values past
    /// the batch clamp to one micro per step, which is the
    /// `fsa train`-bitwise-identical configuration.
    pub micro_batch: usize,
    /// Worker heartbeat period; silence past ~4x this marks a worker
    /// dead.
    pub heartbeat_ms: u64,
    pub mode: WorkerMode,
    /// Timed optimizer steps (after `warmup`).
    pub steps: usize,
    /// Untimed warmup steps.
    pub warmup: usize,
    /// Snapshot the optimizer every this many timed steps (0 = off;
    /// requires `ckpt_path`).
    pub ckpt_every: usize,
    /// Params checkpoint path (`--save-params`).
    pub ckpt_path: Option<PathBuf>,
    /// Resume from `ckpt_path` instead of starting fresh.
    pub resume: bool,
    /// Where to write the per-worker `dist.csv` (None = don't).
    pub dist_out: Option<PathBuf>,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            workers: 1,
            micro_batch: 0,
            heartbeat_ms: 500,
            mode: WorkerMode::Process,
            steps: 30,
            warmup: 5,
            ckpt_every: 0,
            ckpt_path: None,
            resume: false,
            dist_out: None,
        }
    }
}

/// What a distributed session produced, for callers and tests.
#[derive(Clone, Debug)]
pub struct DistReport {
    /// Loss per executed optimizer step (warmup included; starts at
    /// the resume point when resuming).
    pub losses: Vec<f64>,
    /// Final model parameters (bitwise comparable across runs).
    pub params: Vec<Vec<f32>>,
    /// One row per worker rank.
    pub rows: Vec<DistRow>,
    /// Worst relative deviation of a shard's edge share from the ideal
    /// `1/N` under the cost-weighted cut.
    pub edge_load_dev: f64,
    /// Shard reassignments performed after worker deaths.
    pub reassigned: u64,
    /// Wall-clock per timed step, ms.
    pub step_ms: Vec<f64>,
}

/// One worker's result for one micro-batch (the fold input).
struct MicroResult {
    count: u32,
    loss: f64,
    grads: Vec<Vec<f32>>,
}

/// Coordinator-side view of one worker rank.
struct Peer {
    rank: usize,
    /// Send half; `None` before hello and after death.
    writer: Option<TcpStream>,
    alive: bool,
    last_seen: Instant,
    /// The rank's original node shard (for the locality stat).
    orig: Range<usize>,
    /// Edges currently owned (grows when absorbing a dead peer's
    /// shard).
    edges: u64,
    steps: u32,
    stepped: bool,
    micros: u64,
    seeds: u64,
    local_seeds: u64,
    comp_ms: f64,
    comm_ms: f64,
    reassigned: u32,
}

/// What a per-connection reader thread forwards to the coordinator.
enum Event {
    Msg(usize, Msg),
    Gone(usize),
}

struct Coord<'a> {
    cfg: &'a TrainConfig,
    peers: Vec<Peer>,
    /// Connection index -> rank, filled in by each `Hello`.
    conn_rank: Vec<Option<usize>>,
    /// Send halves parked per connection until the hello claims them.
    conn_writers: Vec<Option<TcpStream>>,
    rx: mpsc::Receiver<Event>,
    stale_after: Duration,
    reassigned: u64,
    /// Connections that died before identifying themselves.
    unmapped_gone: usize,
}

impl Coord<'_> {
    fn live(&self) -> Vec<usize> {
        self.peers.iter().filter(|p| p.alive).map(|p| p.rank).collect()
    }

    /// Adopt a fresh connection: spawn its reader thread and park the
    /// send half until its `Hello` arrives (heartbeats can legitimately
    /// precede the hello — the worker's beacon thread starts before its
    /// backend finishes building).
    fn register(&mut self, stream: TcpStream, tx: &mpsc::Sender<Event>)
                -> Result<()> {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(false).ok();
        // a blocked send to a stalled-but-undead worker must not pin
        // the coordinator past the liveness deadline
        stream.set_write_timeout(Some(Duration::from_secs(30))).ok();
        let conn = self.conn_writers.len();
        let mut reader = stream.try_clone().context("clone worker socket")?;
        let tx = tx.clone();
        std::thread::spawn(move || loop {
            match proto::read_msg(&mut reader) {
                Ok(m) => {
                    if tx.send(Event::Msg(conn, m)).is_err() {
                        return; // coordinator is done with this session
                    }
                }
                Err(_) => {
                    tx.send(Event::Gone(conn)).ok();
                    return;
                }
            }
        });
        self.conn_writers.push(Some(stream));
        self.conn_rank.push(None);
        Ok(())
    }

    /// Wait for one event and absorb the bookkeeping kinds; returns a
    /// message only when it came from an identified rank.
    fn pump(&mut self, wait: Duration) -> Result<Option<(usize, Msg)>> {
        match self.rx.recv_timeout(wait) {
            Ok(Event::Msg(conn, msg)) => {
                let rank = match (self.conn_rank[conn], &msg) {
                    (Some(r), _) => r,
                    (None, Msg::Hello { rank }) => {
                        let r = *rank as usize;
                        ensure!(r < self.peers.len(),
                                "hello from out-of-range rank {r}");
                        ensure!(self.conn_rank.iter().all(|m| *m != Some(r)),
                                "two connections claimed rank {r}");
                        self.conn_rank[conn] = Some(r);
                        self.peers[r].writer = self.conn_writers[conn].take();
                        self.peers[r].alive = true;
                        r
                    }
                    // pre-hello heartbeat: liveness starts at the hello
                    (None, _) => return Ok(None),
                };
                self.peers[rank].last_seen = Instant::now();
                Ok(Some((rank, msg)))
            }
            Ok(Event::Gone(conn)) => {
                match self.conn_rank[conn] {
                    Some(r) => self.mark_dead(r, "socket closed"),
                    None => self.unmapped_gone += 1,
                }
                Ok(None)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // every reader thread has exited, so every socket is
                // gone; the staleness sweep's caller will notice
                for r in 0..self.peers.len() {
                    self.mark_dead(r, "reader exited");
                }
                Ok(None)
            }
        }
    }

    /// Declare heartbeat-silent workers dead.
    fn sweep(&mut self) {
        let now = Instant::now();
        for r in 0..self.peers.len() {
            if self.peers[r].alive
                && now.duration_since(self.peers[r].last_seen)
                    > self.stale_after
            {
                self.mark_dead(r, "heartbeat silence");
            }
        }
    }

    /// Kill a peer: close its socket (unblocking both sides) and fold
    /// its shard into the least-loaded survivor. Idempotent.
    fn mark_dead(&mut self, rank: usize, why: &str) {
        if !self.peers[rank].alive {
            return;
        }
        self.peers[rank].alive = false;
        if let Some(w) = self.peers[rank].writer.take() {
            w.shutdown(Shutdown::Both).ok();
        }
        let edges = std::mem::take(&mut self.peers[rank].edges);
        let heir = self.live().into_iter()
            .min_by_key(|&r| self.peers[r].edges);
        match heir {
            Some(t) => {
                self.peers[t].edges += edges;
                self.peers[t].reassigned += 1;
                self.reassigned += 1;
                eprintln!("dist: worker {rank} lost ({why}); shard \
                           reassigned to worker {t}");
            }
            None => eprintln!("dist: worker {rank} lost ({why}); no \
                               survivors to absorb its shard"),
        }
    }

    /// Send one `Step` frame, running the `dist-send` chaos site.
    /// `false` means the worker is unreachable (caller buries it).
    fn send_step(&mut self, rank: usize, step: u64, base: u64,
                 params: &[Vec<f32>], micros: Vec<Micro>) -> bool {
        let op = self.cfg.faults.begin(FaultSite::DistSend);
        match self.cfg.faults.fault(FaultSite::DistSend, op, rank) {
            Fault::Error => return false, // scripted socket drop
            Fault::Stall(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            Fault::Panic => panic!("chaos: scripted panic at dist-send \
                                    op {op}"),
            Fault::None | Fault::Corrupt => {}
        }
        let Some(w) = self.peers[rank].writer.as_mut() else {
            return false;
        };
        let msg = Msg::Step { step, base, params: params.to_vec(), micros };
        proto::write_msg(w, &msg).is_ok()
    }

    /// Assign `todo` micros round-robin over the live ranks (micro id
    /// modulo live count — with everyone alive that is the canonical
    /// `m % N`) and send the per-worker `Step` frames. Send failures
    /// bury the worker and loop until everything is parked on a live
    /// rank. `outstanding` tracks who owes which micro.
    fn dispatch(&mut self, step: u64, base: u64, params: &[Vec<f32>],
                mut todo: Vec<Micro>,
                outstanding: &mut BTreeMap<u32, (usize, Micro)>)
                -> Result<()> {
        while !todo.is_empty() {
            let live = self.live();
            ensure!(!live.is_empty(),
                    "step {step}: every worker died; cannot place \
                     {} micro(s)", todo.len());
            let mut per: BTreeMap<usize, Vec<Micro>> = BTreeMap::new();
            for m in todo.drain(..) {
                per.entry(live[m.id as usize % live.len()])
                    .or_default()
                    .push(m);
            }
            for (rank, micros) in per {
                for m in &micros {
                    outstanding.insert(m.id, (rank, m.clone()));
                }
                if !self.send_step(rank, step, base, params,
                                   micros.clone()) {
                    self.mark_dead(rank, "step send failed");
                    todo.extend(micros);
                }
            }
        }
        Ok(())
    }
}

/// Run a distributed training session. The coordinator owns the
/// scheduler, the optimizer, and every checkpoint; workers are pure
/// gradient functions (see the module docs for the contract).
pub fn train(ds: Arc<Dataset>, cfg: &TrainConfig, hidden: usize,
             adamw: AdamwConfig, opts: &DistOptions) -> Result<DistReport> {
    ensure!(matches!(cfg.variant, Variant::Fsa),
            "distributed training drives the fused native path; run it \
             with --variant fsa (got {})", cfg.variant.as_str());
    ensure!(cfg.batch > 0, "--batch must be positive");
    let workers = opts.workers.max(1);
    ensure!(workers <= 64,
            "--workers {workers} is past the localhost simulation's \
             sanity cap (64)");
    if opts.ckpt_every > 0 || opts.resume {
        ensure!(opts.ckpt_path.is_some(),
                "--checkpoint-every/--resume need --save-params");
    }
    let micro = if opts.micro_batch == 0 {
        cfg.batch.div_ceil(4).max(1)
    } else {
        opts.micro_batch.clamp(1, cfg.batch)
    };
    let micros_per_step = cfg.batch.div_ceil(micro);

    // edge-balanced contiguous node shards via the cost-weighted cut
    // (degree + 1, the sampling-cost proxy the planner already uses)
    let n = ds.spec.n;
    let costs: Vec<u64> =
        (0..n).map(|u| 1 + ds.graph.degree(u as i32) as u64).collect();
    let shards = plan_shards(&costs, workers);
    let shard_edges: Vec<u64> = shards.iter()
        .map(|r| r.clone().map(|u| ds.graph.degree(u as i32) as u64).sum())
        .collect();
    let total_edges: u64 = shard_edges.iter().sum::<u64>().max(1);
    let ideal = 1.0 / workers as f64;
    let edge_load_dev = shard_edges.iter()
        .map(|&e| (e as f64 / total_edges as f64 - ideal).abs() / ideal)
        .fold(0.0, f64::max);

    let mut backend =
        NativeBackend::new(ds.clone(), cfg.native_config(hidden), adamw)?;
    let mut sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed)?;
    let total = opts.warmup + opts.steps;
    let mut start = 0usize;
    if opts.resume {
        let path = opts.ckpt_path.as_deref().expect("checked above");
        start = restore(&mut backend, &mut sched, cfg, hidden, opts, path)?;
        ensure!(start <= total,
                "checkpoint stops at step {start}, past this run's \
                 {total} total steps");
    }

    // bring up the fleet
    let listener =
        TcpListener::bind("127.0.0.1:0").context("bind dist coordinator")?;
    let addr = listener.local_addr()?.to_string();
    let mut children: Vec<Child> = Vec::new();
    let mut threads: Vec<JoinHandle<Result<()>>> = Vec::new();
    for rank in 0..workers {
        match opts.mode {
            WorkerMode::Process => {
                children.push(spawn_child(&addr, rank, cfg, hidden,
                                          opts.heartbeat_ms)?);
            }
            WorkerMode::Thread => {
                let wcfg = worker::WorkerConfig {
                    rank: rank as u32,
                    ds: ds.clone(),
                    fanouts: cfg.fanouts.clone(),
                    amp: cfg.amp,
                    seed: cfg.seed,
                    threads: cfg.threads,
                    hidden,
                    simd: cfg.simd,
                    layout: cfg.layout,
                    heartbeat_ms: opts.heartbeat_ms,
                };
                let a = addr.clone();
                threads.push(std::thread::spawn(move || {
                    worker::connect_and_run(&a, wcfg)
                }));
            }
        }
    }

    let (tx, rx) = mpsc::channel();
    let mut co = Coord {
        cfg,
        peers: (0..workers)
            .map(|rank| Peer {
                rank,
                writer: None,
                alive: false,
                last_seen: Instant::now(),
                orig: shards[rank].clone(),
                edges: shard_edges[rank],
                steps: 0,
                stepped: false,
                micros: 0,
                seeds: 0,
                local_seeds: 0,
                comp_ms: 0.0,
                comm_ms: 0.0,
                reassigned: 0,
            })
            .collect(),
        conn_rank: Vec::new(),
        conn_writers: Vec::new(),
        rx,
        stale_after: Duration::from_millis(
            (opts.heartbeat_ms.saturating_mul(4)).clamp(200, 60_000)),
        reassigned: 0,
        unmapped_gone: 0,
    };

    // accept N connections, then wait for N hellos (process-mode
    // children regenerate the dataset and build a backend first, so
    // the deadline is generous)
    let deadline = Instant::now() + Duration::from_secs(180);
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let mut accepted = 0;
    while accepted < workers {
        ensure!(Instant::now() < deadline,
                "timed out waiting for {workers} workers to connect \
                 ({accepted} so far)");
        match listener.accept() {
            Ok((stream, _)) => {
                co.register(stream, &tx)?;
                accepted += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accept dist-worker"),
        }
    }
    drop(listener);
    // readers hold the only live senders now: a disconnected channel
    // later means every socket is gone
    drop(tx);
    let mut joined = 0;
    while joined < workers {
        ensure!(Instant::now() < deadline,
                "timed out waiting for worker hellos ({joined}/{workers})");
        ensure!(co.unmapped_gone == 0,
                "a worker exited before its hello ({joined}/{workers} \
                 joined)");
        if let Some((_, Msg::Hello { .. })) =
            co.pump(Duration::from_millis(50))?
        {
            joined += 1;
        }
    }

    // the training loop: same schedule, same optimizer, remote grads
    let mut losses = Vec::with_capacity(total.saturating_sub(start));
    let mut timed_ms: Vec<f64> = Vec::new();
    for s in start..total {
        let t = Timer::start();
        let base = sched.base_seed(s);
        let seeds = sched.next_seeds();
        let micros: Vec<Micro> = seeds.chunks(micro)
            .enumerate()
            .map(|(i, c)| Micro { id: i as u32, seeds: c.to_vec() })
            .collect();
        let want = micros.len();
        let params = backend.params().to_vec();
        let mut outstanding: BTreeMap<u32, (usize, Micro)> = BTreeMap::new();
        co.sweep();
        co.dispatch(s as u64, base, &params, micros, &mut outstanding)?;
        let dispatch_ms = t.ms();
        let sent_at = Instant::now();

        let mut done: BTreeMap<u32, MicroResult> = BTreeMap::new();
        let mut last_progress = Instant::now();
        let mut last_redispatch = Instant::now();
        while done.len() < want {
            co.sweep();
            if let Some((rank, msg)) = co.pump(Duration::from_millis(20))? {
                match msg {
                    Msg::Grads { step, micro_id, count, loss, compute_ms,
                                 grads, .. } => {
                        if step != s as u64 {
                            continue; // stale answer from a past step
                        }
                        let op = cfg.faults.begin(FaultSite::DistRecv);
                        match cfg.faults.fault(FaultSite::DistRecv, op,
                                               rank) {
                            Fault::Error | Fault::Corrupt => continue,
                            Fault::Stall(ms) => std::thread::sleep(
                                Duration::from_millis(ms)),
                            Fault::Panic => panic!("chaos: scripted panic \
                                                    at dist-recv op {op}"),
                            Fault::None => {}
                        }
                        if done.contains_key(&micro_id) {
                            continue; // re-dispatch overlap: first wins
                        }
                        ensure!(grads.len() == params.len(),
                                "worker {rank} sent {} grad tensors, \
                                 model has {}", grads.len(), params.len());
                        if let Some((_, m)) = outstanding.remove(&micro_id) {
                            let p = &mut co.peers[rank];
                            p.micros += 1;
                            p.seeds += count as u64;
                            p.local_seeds += m.seeds.iter()
                                .filter(|&&u| p.orig.contains(&(u as usize)))
                                .count() as u64;
                            p.comp_ms += compute_ms;
                            p.comm_ms += (sent_at.elapsed().as_secs_f64()
                                * 1e3
                                - compute_ms)
                                .max(0.0);
                            p.stepped = true;
                        }
                        done.insert(micro_id,
                                    MicroResult { count, loss, grads });
                        last_progress = Instant::now();
                    }
                    Msg::Heartbeat { .. } | Msg::Hello { .. } => {}
                    Msg::Step { .. } | Msg::Shutdown => {
                        bail!("unexpected frame from worker {rank}");
                    }
                }
            }
            // micros parked on a worker that died since dispatch move
            // to a survivor immediately
            let orphaned: Vec<Micro> = outstanding.values()
                .filter(|(r, _)| !co.peers[*r].alive)
                .map(|(_, m)| m.clone())
                .collect();
            if !orphaned.is_empty() {
                eprintln!("dist: step {s}: re-dispatching {} micro(s) \
                           from dead worker(s)", orphaned.len());
                co.dispatch(s as u64, base, &params, orphaned,
                            &mut outstanding)?;
                last_redispatch = Instant::now();
            }
            // a live worker may simply never answer (chaos-dropped
            // frame): after a quiet staleness window, re-offer what is
            // still outstanding — idempotent, so over-delivery is safe
            if !outstanding.is_empty()
                && last_progress.elapsed() > co.stale_after
                && last_redispatch.elapsed() > co.stale_after
            {
                let todo: Vec<Micro> =
                    outstanding.values().map(|(_, m)| m.clone()).collect();
                eprintln!("dist: step {s}: re-dispatching {} stalled \
                           micro(s)", todo.len());
                co.dispatch(s as u64, base, &params, todo,
                            &mut outstanding)?;
                last_redispatch = Instant::now();
            }
        }

        // fold in micro id order, seeding the accumulator from the
        // first micro (see the module docs for why not zero-init)
        let mut acc: Vec<Vec<f32>> = Vec::new();
        let mut loss = 0.0f64;
        for r in done.values() {
            let w = r.count as f32 / cfg.batch as f32;
            if acc.is_empty() {
                acc = r.grads.iter()
                    .map(|g| g.iter().map(|&x| w * x).collect())
                    .collect();
            } else {
                for (a, g) in acc.iter_mut().zip(&r.grads) {
                    ensure!(a.len() == g.len(),
                            "gradient shape drifted between micros");
                    for (ai, gi) in a.iter_mut().zip(g) {
                        *ai += w * gi;
                    }
                }
            }
            loss += r.count as f64 / cfg.batch as f64 * r.loss;
        }
        backend.apply_grads(&acc, s)?;
        losses.push(loss);
        for p in co.peers.iter_mut() {
            if p.stepped {
                p.steps += 1;
                p.stepped = false;
            }
        }

        if s >= opts.warmup {
            let timed = s - opts.warmup;
            let ms = t.ms();
            timed_ms.push(ms);
            if timed % 10 == 0 || timed + 1 == opts.steps {
                println!("step {timed:>4}: {ms:.2} ms (dispatch \
                          {dispatch_ms:.2} collect {:.2}) loss {loss:.4}",
                         (ms - dispatch_ms).max(0.0));
            }
            if opts.ckpt_every > 0 && (timed + 1) % opts.ckpt_every == 0 {
                if let Some(p) = &opts.ckpt_path {
                    save_checkpoint(&backend, cfg, hidden, (s + 1) as u64,
                                    p)?;
                }
            }
        }
    }

    // orderly teardown: shutdown frames, then reap
    for p in co.peers.iter_mut() {
        if let Some(w) = p.writer.as_mut() {
            proto::write_msg(w, &Msg::Shutdown).ok();
        }
        if let Some(w) = p.writer.take() {
            w.shutdown(Shutdown::Write).ok();
        }
    }
    for h in threads {
        if let Ok(Err(e)) = h.join() {
            eprintln!("dist: worker thread error: {e:#}");
        }
    }
    for mut c in children {
        c.wait().ok();
    }

    if let Some(p) = &opts.ckpt_path {
        save_checkpoint(&backend, cfg, hidden, total as u64, p)?;
        println!("saved params checkpoint to {}", p.display());
    }

    let rows: Vec<DistRow> = co.peers.iter()
        .map(|p| DistRow {
            workers: workers as u32,
            rank: p.rank as u32,
            steps: p.steps,
            micros: p.micros,
            seeds: p.seeds,
            local_frac: if p.seeds > 0 {
                p.local_seeds as f64 / p.seeds as f64
            } else {
                0.0
            },
            step_ms: p.comp_ms,
            comm_ms: p.comm_ms,
            edge_share: p.edges as f64 / total_edges as f64,
            edge_load_dev,
            reassigned: p.reassigned,
            completed: p.alive,
        })
        .collect();
    if let Some(out) = &opts.dist_out {
        // stats are advisory: a full disk must not fail a finished run
        match crate::metrics::write_dist_csv(out, &rows) {
            Ok(()) => println!("wrote {} worker row(s) to {}", rows.len(),
                               out.display()),
            Err(e) => eprintln!("dist: could not write {}: {e:#}",
                                out.display()),
        }
    }
    println!("distributed: {workers} worker(s), micro-batch {micro} \
              ({micros_per_step} micro(s)/step), edge-load deviation \
              {:.2}%, {} shard reassignment(s)",
             edge_load_dev * 100.0, co.reassigned);

    Ok(DistReport {
        losses,
        params: backend.params().to_vec(),
        rows,
        edge_load_dev,
        reassigned: co.reassigned,
        step_ms: timed_ms,
    })
}

/// Launch one `fsa dist-worker` child against our own binary. The
/// child rebuilds the dataset from its spec (generation is
/// deterministic), so nothing graph-sized crosses a pipe.
fn spawn_child(addr: &str, rank: usize, cfg: &TrainConfig, hidden: usize,
               heartbeat_ms: u64) -> Result<Child> {
    let exe = std::env::current_exe().context("locate the fsa binary")?;
    let mut cmd = Command::new(exe);
    cmd.arg("dist-worker")
        .arg("--connect").arg(addr)
        .arg("--rank").arg(rank.to_string())
        .arg("--dataset").arg(&cfg.dataset)
        .arg("--fanout").arg(cfg.fanouts.label())
        .arg("--hidden").arg(hidden.to_string())
        .arg("--seed").arg(cfg.seed.to_string())
        .arg("--threads").arg(cfg.threads.to_string())
        .arg("--heartbeat-ms").arg(heartbeat_ms.to_string())
        .arg("--simd").arg(cfg.simd.as_str())
        .arg("--layout").arg(cfg.layout.as_str())
        .stdin(Stdio::null());
    if !cfg.amp {
        cmd.arg("--no-amp");
    }
    cmd.spawn().with_context(|| format!("spawn dist-worker rank {rank}"))
}

/// Install a checkpoint into the coordinator's backend and fast-forward
/// the schedule; returns the step to resume at. Mirrors
/// `Engine::restore_training` (params before moments — installing
/// params zeroes the AdamW state).
fn restore(backend: &mut NativeBackend, sched: &mut BatchScheduler,
           cfg: &TrainConfig, hidden: usize, opts: &DistOptions,
           path: &Path) -> Result<usize> {
    let ck = ParamsCheckpoint::load(path)?;
    ensure!(ck.variant == cfg.variant.as_str(),
            "checkpoint {} is for variant {}, this run is {}",
            path.display(), ck.variant, cfg.variant.as_str());
    ensure!(ck.dataset == cfg.dataset,
            "checkpoint {} is for dataset {}, this run is {}",
            path.display(), ck.dataset, cfg.dataset);
    ensure!(ck.fanout == cfg.fanouts.label(),
            "checkpoint {} is for fanout {}, this run is {}",
            path.display(), ck.fanout, cfg.fanouts.label());
    ensure!(ck.hidden == hidden,
            "checkpoint {} has hidden {}, this run has {}",
            path.display(), ck.hidden, hidden);
    let Some(ts) = &ck.train else {
        bail!("checkpoint {} has no training state to resume from",
              path.display());
    };
    backend.set_params_f32(&ck.params)?;
    backend.set_opt_state_f32(&ts.m, &ts.v)?;
    let done = ts.step as usize;
    ensure!(done >= opts.warmup,
            "checkpoint stops at step {done}, inside the {}-step warmup",
            opts.warmup);
    for _ in 0..done {
        sched.next_seeds();
    }
    println!("resumed from {} at step {done} (timed step {})",
             path.display(), done - opts.warmup);
    Ok(done)
}

/// Snapshot the coordinator's params + AdamW state, compatible with
/// `Engine::restore_training` and [`restore`].
fn save_checkpoint(backend: &NativeBackend, cfg: &TrainConfig,
                   hidden: usize, step: u64, path: &Path) -> Result<()> {
    let ck = ParamsCheckpoint {
        variant: cfg.variant.as_str().to_string(),
        dataset: cfg.dataset.clone(),
        fanout: cfg.fanouts.label(),
        hidden,
        params: backend.params_f32()?,
        train: backend.opt_state_f32()
            .map(|(m, v)| TrainState { step, m, v }),
    };
    ck.save(path)
        .with_context(|| format!("save dist checkpoint {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::builtin_spec;
    use crate::graph::PlannerChoice;
    use crate::kernel::{FeatureLayout, SimdChoice};
    use crate::runtime::backend::BackendChoice;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            variant: Variant::Fsa,
            dataset: "tiny".to_string(),
            fanouts: crate::fanout::Fanouts::of(&[5, 3]),
            batch: 64,
            amp: false,
            save_indices: false,
            seed: 42,
            threads: 1,
            prefetch: false,
            backend: BackendChoice::Native,
            planner: PlannerChoice::Nominal,
            planner_state: None,
            faults: crate::runtime::faults::none(),
            simd: SimdChoice::Auto,
            layout: FeatureLayout::Natural,
            hub_cache: None,
        }
    }

    /// The shard cut must cover every node exactly once, in order, and
    /// keep the realized edge imbalance tight on the builtin graphs.
    #[test]
    fn shard_cut_covers_and_balances() {
        let ds = Dataset::generate(builtin_spec("tiny").unwrap()).unwrap();
        let n = ds.spec.n;
        let costs: Vec<u64> =
            (0..n).map(|u| 1 + ds.graph.degree(u as i32) as u64).collect();
        for parts in [1usize, 2, 4] {
            let shards = plan_shards(&costs, parts);
            assert_eq!(shards.len(), parts);
            let mut next = 0usize;
            for r in &shards {
                assert_eq!(r.start, next, "shards must tile the node id \
                                           space in order");
                next = r.end;
            }
            assert_eq!(next, n, "shards must cover every node");
            let edges: Vec<u64> = shards.iter()
                .map(|r| r.clone()
                    .map(|u| ds.graph.degree(u as i32) as u64)
                    .sum())
                .collect();
            let total: u64 = edges.iter().sum();
            let ideal = total as f64 / parts as f64;
            for &e in &edges {
                let dev = (e as f64 - ideal).abs() / ideal.max(1.0);
                assert!(dev < 0.05,
                        "{parts}-way cut is {:.1}% off ideal",
                        dev * 100.0);
            }
        }
    }

    /// Micro decomposition is a function of (batch, micro) only — the
    /// contract that makes the trajectory independent of N.
    #[test]
    fn micro_decomposition_is_worker_count_free() {
        let seeds: Vec<i32> = (0..100).collect();
        for micro in [1usize, 7, 25, 100, 1000] {
            let micros: Vec<Micro> = seeds.chunks(micro.min(seeds.len()))
                .enumerate()
                .map(|(i, c)| Micro { id: i as u32, seeds: c.to_vec() })
                .collect();
            let back: Vec<i32> =
                micros.iter().flat_map(|m| m.seeds.clone()).collect();
            assert_eq!(back, seeds, "chunking must preserve seed order");
            let ids: Vec<u32> = micros.iter().map(|m| m.id).collect();
            let want: Vec<u32> = (0..micros.len() as u32).collect();
            assert_eq!(ids, want);
        }
    }

    /// One thread-mode worker, micro-batch == batch: the distributed
    /// session's first-micro fold must leave the gradients untouched,
    /// so losses and params match a local single-process run bitwise.
    #[test]
    fn single_worker_single_micro_matches_local_compute() {
        let ds = Arc::new(
            Dataset::generate(builtin_spec("tiny").unwrap()).unwrap());
        let cfg = tiny_cfg();
        let adamw = crate::runtime::manifest::Manifest::builtin().adamw;
        let opts = DistOptions {
            workers: 1,
            micro_batch: cfg.batch,
            heartbeat_ms: 50,
            mode: WorkerMode::Thread,
            steps: 3,
            warmup: 1,
            ..DistOptions::default()
        };
        let report = train(ds.clone(), &cfg, 32, adamw, &opts).unwrap();
        assert_eq!(report.losses.len(), 4);

        // local reference: the exact single-process update loop
        let mut backend = NativeBackend::new(
            ds.clone(), cfg.native_config(32), adamw).unwrap();
        let mut sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed)
            .unwrap();
        let mut meter = crate::memory::MemoryMeter::new();
        let mut losses = Vec::new();
        for s in 0..4 {
            let base = sched.base_seed(s);
            let seeds = sched.next_seeds();
            let labels: Vec<i32> =
                seeds.iter().map(|&x| ds.labels[x as usize]).collect();
            let (loss, grads, _, _) = backend
                .fsa_loss_grads(&seeds, &labels, base, &mut meter)
                .unwrap();
            backend.apply_grads(&grads, s).unwrap();
            losses.push(loss);
        }
        assert_eq!(report.losses, losses,
                   "distributed losses must be bitwise identical");
        assert_eq!(report.params, backend.params().to_vec(),
                   "distributed params must be bitwise identical");
    }
}
