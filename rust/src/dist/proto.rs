//! Length-prefixed wire protocol for the localhost data-parallel
//! sessions — hand-rolled bincode-style framing, no new dependencies.
//!
//! Every frame is `u32` little-endian payload length followed by the
//! payload; the payload's first byte is the message tag. All integers
//! are little-endian; `f32` tensors travel as raw `to_le_bytes`
//! patterns, so encode→decode is **bitwise exact** — the coordinator's
//! fold over worker gradients sees precisely the floats the worker
//! computed, which is what makes the N-worker trajectory reproducible
//! bit for bit.
//!
//! Message flow (coordinator ⇄ worker):
//!
//! ```text
//! worker      → Hello{rank}                     once, after connect
//! coordinator → Step{step, base, params, micros}  per step (and per
//!                                                  re-dispatch)
//! worker      → Grads{step, micro_id, ...}      one per assigned micro
//! worker      → Heartbeat{rank}                 every heartbeat tick
//! coordinator → Shutdown                        end of session
//! ```
//!
//! Decoding is defensive: a frame longer than [`MAX_FRAME`] or a
//! payload that does not parse exactly is an `InvalidData` error, never
//! a huge allocation or a panic — the coordinator treats a bad frame
//! like a dead socket.

use std::io::{Read, Write};

/// Upper bound on one frame's payload (guards the length-prefix
/// allocation against a corrupt/hostile peer). Params for realistic
/// models are a few MB; 1 GiB is far above anything legitimate.
pub const MAX_FRAME: u32 = 1 << 30;

const TAG_HELLO: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_GRADS: u8 = 3;
const TAG_HEARTBEAT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// One seed micro-batch: the unit of work assignment and of gradient
/// dedup (`id` is unique within a step; the coordinator accepts the
/// first `Grads` frame per id and ignores duplicates).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Micro {
    pub id: u32,
    pub seeds: Vec<i32>,
}

/// A protocol message (see the module docs for the flow).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker `rank` has connected.
    Hello { rank: u32 },
    /// Compute these micros at `step` under `base`, starting from
    /// `params` (broadcast every step so a late-joining or re-dispatch
    /// target needs no history).
    Step { step: u64, base: u64, params: Vec<Vec<f32>>, micros: Vec<Micro> },
    /// One micro's result: the loss over its `count` seeds, the
    /// parameter gradients, the kernel's sampled-pair count, and the
    /// worker-side compute time.
    Grads {
        step: u64,
        micro_id: u32,
        count: u32,
        loss: f64,
        pairs: u64,
        compute_ms: f64,
        grads: Vec<Vec<f32>>,
    },
    /// Liveness beacon, sent on a timer independent of compute.
    Heartbeat { rank: u32 },
    /// Clean end of session; the worker exits its loop.
    Shutdown,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_i32s(buf: &mut Vec<u8>, vs: &[i32]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_tensors(buf: &mut Vec<u8>, ts: &[Vec<f32>]) {
    put_u32(buf, ts.len() as u32);
    for t in ts {
        put_u32(buf, t.len() as u32);
        for v in t {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Cursor over one received payload; every take is bounds-checked so a
/// truncated frame decodes to an error, not a panic.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

fn bad(what: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData,
                        format!("dist frame: {what}"))
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> std::io::Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(bad("truncated payload"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> std::io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> std::io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> std::io::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32s(&mut self) -> std::io::Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn tensors(&mut self) -> std::io::Result<Vec<Vec<f32>>> {
        let n = self.u32()? as usize;
        let mut ts = Vec::with_capacity(n.min(64));
        for _ in 0..n {
            let len = self.u32()? as usize;
            let raw = self.take(len * 4)?;
            ts.push(raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect());
        }
        Ok(ts)
    }

    fn done(&self) -> std::io::Result<()> {
        if self.pos != self.buf.len() {
            return Err(bad("trailing bytes in payload"));
        }
        Ok(())
    }
}

/// Serialize `msg` into one framed byte buffer (length prefix included).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut p = Vec::new();
    match msg {
        Msg::Hello { rank } => {
            p.push(TAG_HELLO);
            put_u32(&mut p, *rank);
        }
        Msg::Step { step, base, params, micros } => {
            p.push(TAG_STEP);
            put_u64(&mut p, *step);
            put_u64(&mut p, *base);
            put_tensors(&mut p, params);
            put_u32(&mut p, micros.len() as u32);
            for m in micros {
                put_u32(&mut p, m.id);
                put_i32s(&mut p, &m.seeds);
            }
        }
        Msg::Grads { step, micro_id, count, loss, pairs, compute_ms,
                     grads } => {
            p.push(TAG_GRADS);
            put_u64(&mut p, *step);
            put_u32(&mut p, *micro_id);
            put_u32(&mut p, *count);
            put_f64(&mut p, *loss);
            put_u64(&mut p, *pairs);
            put_f64(&mut p, *compute_ms);
            put_tensors(&mut p, grads);
        }
        Msg::Heartbeat { rank } => {
            p.push(TAG_HEARTBEAT);
            put_u32(&mut p, *rank);
        }
        Msg::Shutdown => p.push(TAG_SHUTDOWN),
    }
    let mut framed = Vec::with_capacity(4 + p.len());
    put_u32(&mut framed, p.len() as u32);
    framed.extend_from_slice(&p);
    framed
}

/// Decode one payload (the bytes after the length prefix).
pub fn decode(payload: &[u8]) -> std::io::Result<Msg> {
    let mut c = Cur { buf: payload, pos: 0 };
    let tag = c.take(1)?[0];
    let msg = match tag {
        TAG_HELLO => Msg::Hello { rank: c.u32()? },
        TAG_STEP => {
            let step = c.u64()?;
            let base = c.u64()?;
            let params = c.tensors()?;
            let n = c.u32()? as usize;
            let mut micros = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let id = c.u32()?;
                let seeds = c.i32s()?;
                micros.push(Micro { id, seeds });
            }
            Msg::Step { step, base, params, micros }
        }
        TAG_GRADS => Msg::Grads {
            step: c.u64()?,
            micro_id: c.u32()?,
            count: c.u32()?,
            loss: c.f64()?,
            pairs: c.u64()?,
            compute_ms: c.f64()?,
            grads: c.tensors()?,
        },
        TAG_HEARTBEAT => Msg::Heartbeat { rank: c.u32()? },
        TAG_SHUTDOWN => Msg::Shutdown,
        other => return Err(bad(&format!("unknown tag {other}"))),
    };
    c.done()?;
    Ok(msg)
}

/// Write one framed message to `w` (a blocking socket write; the caller
/// serializes concurrent writers — frames must never interleave).
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> std::io::Result<()> {
    w.write_all(&encode(msg))
}

/// Read one framed message from `r`. A cleanly closed socket surfaces
/// as `UnexpectedEof` on the length prefix.
pub fn read_msg(r: &mut impl Read) -> std::io::Result<Msg> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME {
        return Err(bad(&format!("frame length {len} exceeds cap")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: &Msg) -> Msg {
        let framed = encode(msg);
        let mut r = &framed[..];
        let back = read_msg(&mut r).unwrap();
        assert!(r.is_empty(), "frame must consume exactly");
        back
    }

    #[test]
    fn every_message_round_trips() {
        let msgs = [
            Msg::Hello { rank: 3 },
            Msg::Step {
                step: 17,
                base: 0xDEADBEEF_u64,
                params: vec![vec![1.0, -2.5, 3.25e-7], vec![], vec![0.0]],
                micros: vec![
                    Micro { id: 0, seeds: vec![5, 1, 9] },
                    Micro { id: 1, seeds: vec![] },
                ],
            },
            Msg::Grads {
                step: 17,
                micro_id: 1,
                count: 256,
                loss: 2.302585,
                pairs: 123_456,
                compute_ms: 4.25,
                grads: vec![vec![1e-8, -0.5], vec![f32::MIN_POSITIVE]],
            },
            Msg::Heartbeat { rank: 2 },
            Msg::Shutdown,
        ];
        for msg in &msgs {
            assert_eq!(&round_trip(msg), msg);
        }
    }

    /// The bitwise contract: f32 payloads survive the wire exactly,
    /// including subnormals, negative zero, infinities, and NaN bit
    /// patterns.
    #[test]
    fn f32_payloads_are_bitwise_exact() {
        let specials = vec![
            0.0f32, -0.0, 1.0, -1.0, f32::MIN_POSITIVE, f32::EPSILON,
            f32::MAX, f32::MIN, f32::INFINITY, f32::NEG_INFINITY,
            f32::from_bits(0x7FC0_0001), // a quiet NaN with payload bits
            f32::from_bits(0x0000_0001), // smallest subnormal
        ];
        let msg = Msg::Grads {
            step: 0, micro_id: 0, count: 1, loss: 0.0, pairs: 0,
            compute_ms: 0.0, grads: vec![specials.clone()],
        };
        let Msg::Grads { grads, .. } = round_trip(&msg) else {
            panic!("wrong tag back");
        };
        let bits: Vec<u32> = grads[0].iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = specials.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want, "wire transit changed f32 bit patterns");
    }

    #[test]
    fn corrupt_frames_error_instead_of_panicking() {
        // truncated length prefix
        let mut r = &[0u8, 0][..];
        assert!(read_msg(&mut r).is_err());
        // length prefix over the cap
        let mut framed = Vec::new();
        framed.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut r = &framed[..];
        assert!(read_msg(&mut r).is_err());
        // unknown tag
        assert!(decode(&[99]).is_err());
        // truncated payloads at every prefix length of a real message
        let full = encode(&Msg::Step {
            step: 1, base: 2,
            params: vec![vec![1.0, 2.0]],
            micros: vec![Micro { id: 0, seeds: vec![3, 4] }],
        });
        let payload = &full[4..];
        for cut in 0..payload.len() {
            assert!(decode(&payload[..cut]).is_err(),
                    "prefix of {cut} bytes must not decode");
        }
        // trailing garbage after a valid message
        let mut long = payload.to_vec();
        long.push(0);
        assert!(decode(&long).is_err(), "trailing bytes must be rejected");
        // an empty payload
        assert!(decode(&[]).is_err());
    }

    #[test]
    fn eof_on_closed_socket_is_unexpected_eof() {
        let mut r: &[u8] = &[];
        let err = read_msg(&mut r).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }
}
