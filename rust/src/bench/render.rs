//! Table/figure renderers — regenerate every exhibit of the paper's
//! evaluation section from `results/bench.csv` rows.
//!
//! | renderer | paper exhibit |
//! |---|---|
//! | [`table1`] | Table 1: step time + sampled-pairs/s, DGL→FSA |
//! | [`fig1`]   | Fig 1: step-time speedup bars |
//! | [`fig2`]   | Fig 2: throughput vs batch size (products, 15-10) |
//! | [`fig3`]   | Fig 3: step time vs fanout (arxiv, B=1024) |
//! | [`table2`] | Table 2: peak transient memory + ratio |
//! | [`fig4`]   | Fig 4: memory-reduction ratio bars |
//! | [`fig5`]   | Fig 5: absolute peak memory (log scale) |
//! | [`table3`] | Table 3: profiler breakdown (takes a ProfileReport) |

use std::collections::BTreeMap;
use std::fmt::Write;

use crate::coordinator::profile::ProfileReport;
use crate::metrics::{median_over_repeats, BenchRow};
use crate::util::{bytes_to_mb, fmt_ms};

/// A paired (dgl, fsa) measurement for one configuration.
#[derive(Clone, Debug)]
pub struct Pair {
    pub dataset: String,
    /// Sampling depth (fanout segment count).
    pub hops: u32,
    /// Canonical fanout label, e.g. "15x10" or "10x5x5".
    pub fanout: String,
    pub batch: u32,
    pub dgl: BenchRow,
    pub fsa: BenchRow,
}

impl Pair {
    pub fn fanout(&self) -> &str {
        &self.fanout
    }

    pub fn step_speedup(&self) -> f64 {
        self.dgl.step_ms / self.fsa.step_ms
    }

    pub fn pairs_speedup(&self) -> f64 {
        self.fsa.pairs_per_s / self.dgl.pairs_per_s
    }

    pub fn mem_ratio(&self) -> f64 {
        self.dgl.peak_transient_bytes as f64
            / self.fsa.peak_transient_bytes.max(1) as f64
    }
}

/// Median over repeats, then join dgl/fsa rows per configuration.
pub fn pair_rows(rows: &[BenchRow]) -> Vec<Pair> {
    let med = median_over_repeats(rows);
    let mut by_key: BTreeMap<(String, u32, String, u32, bool),
                             (Option<BenchRow>, Option<BenchRow>)> =
        BTreeMap::new();
    for r in med {
        let key =
            (r.dataset.clone(), r.hops, r.fanout.clone(), r.batch, r.amp);
        let slot = by_key.entry(key).or_default();
        match r.variant.as_str() {
            "dgl" => slot.0 = Some(r),
            "fsa" => slot.1 = Some(r),
            _ => {}
        }
    }
    by_key
        .into_iter()
        .filter_map(|((ds, h, fo, b, _amp), (d, f))| {
            Some(Pair { dataset: ds, hops: h, fanout: fo, batch: b,
                        dgl: d?, fsa: f? })
        })
        .collect()
}

fn bar(value: f64, max: f64, width: usize) -> String {
    let filled = ((value / max.max(1e-12)) * width as f64).round() as usize;
    "█".repeat(filled.min(width))
}

/// Table 1: step time and sampled-pairs/s at B=1024, AMP on.
pub fn table1(rows: &[BenchRow]) -> String {
    let pairs: Vec<Pair> = pair_rows(rows)
        .into_iter()
        .filter(|p| p.batch == 1024 && p.hops >= 2)
        .collect();
    let mut out = String::new();
    let _ = writeln!(out, "Table 1. Step time and sampled-pairs/s: DGL -> FuseSampleAgg (B=1024, AMP on).");
    let _ = writeln!(out, "Medians over repeats; step time includes sampling, uploads, fwd+bwd+AdamW, sync.");
    let _ = writeln!(out, "{:-<98}", "");
    let _ = writeln!(out, "{:<14} {:<8} {:>22} {:>9} {:>28} {:>9}",
                     "Dataset", "Fanout", "Step (ms) DGL->FSA", "Speedup",
                     "Sampled-pairs/s DGL->FSA", "Speedup");
    let _ = writeln!(out, "{:-<98}", "");
    for p in &pairs {
        let _ = writeln!(
            out,
            "{:<14} {:<8} {:>10} -> {:>8} {:>8.2}x {:>13.0} -> {:>11.0} {:>8.2}x",
            p.dataset, p.fanout(), fmt_ms(p.dgl.step_ms), fmt_ms(p.fsa.step_ms),
            p.step_speedup(), p.dgl.pairs_per_s, p.fsa.pairs_per_s,
            p.pairs_speedup());
    }
    let _ = writeln!(out, "{:-<98}", "");
    out
}

/// Fig 1: median step-time speedup bars per dataset/fanout (B=1024).
pub fn fig1(rows: &[BenchRow]) -> String {
    let pairs: Vec<Pair> = pair_rows(rows)
        .into_iter()
        .filter(|p| p.batch == 1024 && p.hops >= 2)
        .collect();
    let max = pairs.iter().map(Pair::step_speedup).fold(1.0f64, f64::max);
    let mut out = String::new();
    let _ = writeln!(out, "Fig 1. Median step-time speedup of FSA over DGL (B=1024, AMP on; dashed = parity 1.0x).");
    let mut last_ds = String::new();
    for p in &pairs {
        if p.dataset != last_ds {
            let _ = writeln!(out, "\n[{}]", p.dataset);
            last_ds = p.dataset.clone();
        }
        let s = p.step_speedup();
        let marker = if s < 1.0 { " (<1x: fusion loses)" } else { "" };
        let _ = writeln!(out, "  {:<8} {:>6.2}x |{}{}", p.fanout(), s,
                         bar(s, max, 48), marker);
    }
    out
}

/// Fig 2: throughput (seeds/s) scaling with batch size (products, 15-10).
pub fn fig2(rows: &[BenchRow]) -> String {
    let med = median_over_repeats(rows);
    let mut series: BTreeMap<u32, (f64, f64)> = BTreeMap::new();
    for r in &med {
        if r.dataset == "products_sim" && r.fanout == "15x10" {
            let e = series.entry(r.batch).or_default();
            match r.variant.as_str() {
                "dgl" => e.0 = r.nodes_per_s,
                "fsa" => e.1 = r.nodes_per_s,
                _ => {}
            }
        }
    }
    let max = series
        .values()
        .map(|(a, b)| a.max(*b))
        .fold(1.0f64, f64::max);
    let mut out = String::new();
    let _ = writeln!(out, "Fig 2. Throughput (seed nodes/s) vs batch size on products_sim (fanout 15-10, AMP on).");
    let _ = writeln!(out, "{:<8} {:>12} {:>12}   scaling", "batch", "DGL", "FSA");
    for (b, (dgl, fsa)) in &series {
        let _ = writeln!(out, "{:<8} {:>12.0} {:>12.0}", b, dgl, fsa);
        let _ = writeln!(out, "   DGL |{}", bar(*dgl, max, 50));
        let _ = writeln!(out, "   FSA |{}", bar(*fsa, max, 50));
    }
    out
}

/// Fig 3: median step time vs fanout (arxiv_sim, B=1024; lower is better).
pub fn fig3(rows: &[BenchRow]) -> String {
    let pairs: Vec<Pair> = pair_rows(rows)
        .into_iter()
        .filter(|p| p.dataset == "arxiv_sim" && p.batch == 1024
            && p.hops >= 2)
        .collect();
    let max = pairs
        .iter()
        .map(|p| p.dgl.step_ms.max(p.fsa.step_ms))
        .fold(1.0f64, f64::max);
    let mut out = String::new();
    let _ = writeln!(out, "Fig 3. Median step time (ms) vs fanout on arxiv_sim (B=1024, AMP on). Lower is better.");
    for p in &pairs {
        let _ = writeln!(out, "fanout {:<7}", p.fanout());
        let _ = writeln!(out, "   DGL {:>9} |{}", fmt_ms(p.dgl.step_ms),
                         bar(p.dgl.step_ms, max, 50));
        let _ = writeln!(out, "   FSA {:>9} |{}", fmt_ms(p.fsa.step_ms),
                         bar(p.fsa.step_ms, max, 50));
    }
    out
}

/// Table 2: peak transient memory (MB), DGL→FSA, with ratio (B=1024).
pub fn table2(rows: &[BenchRow]) -> String {
    let pairs: Vec<Pair> = pair_rows(rows)
        .into_iter()
        .filter(|p| p.batch == 1024 && p.hops >= 2)
        .collect();
    let mut out = String::new();
    let _ = writeln!(out, "Table 2. Peak transient memory (MB) per training step (B=1024, AMP on).");
    let _ = writeln!(out, "Transient = per-step uploads + executable intermediates + outputs (DESIGN.md §3).");
    let _ = writeln!(out, "{:-<72}", "");
    let _ = writeln!(out, "{:<14} {:<8} {:>24} {:>10}", "Dataset", "Fanout",
                     "Peak MB (DGL -> FSA)", "Ratio");
    let _ = writeln!(out, "{:-<72}", "");
    for p in &pairs {
        let _ = writeln!(out, "{:<14} {:<8} {:>10.1} -> {:>10.2} {:>9.2}x",
                         p.dataset, p.fanout(),
                         bytes_to_mb(p.dgl.peak_transient_bytes),
                         bytes_to_mb(p.fsa.peak_transient_bytes),
                         p.mem_ratio());
    }
    let _ = writeln!(out, "{:-<72}", "");
    out
}

/// Fig 4: memory-reduction ratio bars (higher is better).
pub fn fig4(rows: &[BenchRow]) -> String {
    let pairs: Vec<Pair> = pair_rows(rows)
        .into_iter()
        .filter(|p| p.batch == 1024 && p.hops >= 2)
        .collect();
    let max = pairs.iter().map(Pair::mem_ratio).fold(1.0f64, f64::max);
    let mut out = String::new();
    let _ = writeln!(out, "Fig 4. Peak-memory reduction (DGL / FSA, B=1024, AMP on). Higher is better.");
    let mut last_ds = String::new();
    for p in &pairs {
        if p.dataset != last_ds {
            let _ = writeln!(out, "\n[{}]", p.dataset);
            last_ds = p.dataset.clone();
        }
        let r = p.mem_ratio();
        let _ = writeln!(out, "  {:<8} {:>7.2}x |{}", p.fanout(), r,
                         bar(r, max, 48));
    }
    out
}

/// Fig 5: absolute peak memory, log10 scale, both variants.
pub fn fig5(rows: &[BenchRow]) -> String {
    let pairs: Vec<Pair> = pair_rows(rows)
        .into_iter()
        .filter(|p| p.batch == 1024 && p.hops >= 2)
        .collect();
    let logmax = pairs
        .iter()
        .map(|p| bytes_to_mb(p.dgl.peak_transient_bytes).max(
            bytes_to_mb(p.fsa.peak_transient_bytes)))
        .fold(1.0f64, f64::max)
        .log10();
    let mut out = String::new();
    let _ = writeln!(out, "Fig 5. Absolute peak transient memory (MB, log scale), DGL vs FSA (B=1024).");
    for p in &pairs {
        let dgl_mb = bytes_to_mb(p.dgl.peak_transient_bytes);
        let fsa_mb = bytes_to_mb(p.fsa.peak_transient_bytes);
        let _ = writeln!(out, "{} {}", p.dataset, p.fanout());
        let _ = writeln!(out, "   DGL {:>10.2} MB |{}", dgl_mb,
                         bar(dgl_mb.max(0.01).log10().max(0.0), logmax, 50));
        let _ = writeln!(out, "   FSA {:>10.2} MB |{}", fsa_mb,
                         bar(fsa_mb.max(0.01).log10().max(0.0), logmax, 50));
    }
    out
}

/// Table 3: stage-split profiler breakdown of the baseline step.
pub fn table3(report: &ProfileReport) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "Table 3. Stage-split profile of the DGL-like baseline ({}, fanout 15-10, B=1024, AMP on).",
                     report.dataset);
    let _ = writeln!(out, "Exclusive time per stage; {} timed steps, medians. PJRT analogue of the paper's PyTorch profiler.",
                     report.steps);
    let _ = writeln!(out, "{:-<64}", "");
    let _ = writeln!(out, "{:<18} {:>10} {:>12} {:>8}", "Stage", "Self %",
                     "Self (ms)", "#Calls");
    let _ = writeln!(out, "{:-<64}", "");
    for r in &report.rows {
        let _ = writeln!(out, "{:<18} {:>9.2}% {:>12.3} {:>8}", r.name, r.pct,
                         r.median_ms, r.calls);
    }
    let _ = writeln!(out, "{:-<64}", "");
    let _ = writeln!(out, "{:<18} {:>10} {:>12.3}", "total", "100%",
                     report.total_ms);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(ds: &str, variant: &str, fanout: &str, hops: u32, batch: u32,
           seed: u64, step_ms: f64, peak: u64) -> BenchRow {
        BenchRow {
            dataset: ds.into(),
            variant: variant.into(),
            hops,
            fanout: fanout.into(),
            batch,
            amp: true,
            repeat_seed: seed,
            steps: 30,
            step_ms,
            sample_ms: 0.0,
            upload_ms: 0.0,
            execute_ms: step_ms,
            pairs_per_s: 1e6 / step_ms,
            nodes_per_s: 1e3 / step_ms,
            peak_transient_bytes: peak,
            loss: 1.0,
            imbalance: 1.0,
            planner: "quantile".into(),
            simd: "on".into(),
        }
    }

    fn sample_rows() -> Vec<BenchRow> {
        let mut rows = Vec::new();
        for seed in [42, 43, 44] {
            rows.push(row("arxiv_sim", "dgl", "15x10", 2, 1024, seed, 10.0,
                          50_000_000));
            rows.push(row("arxiv_sim", "fsa", "15x10", 2, 1024, seed, 2.0,
                          5_000_000));
        }
        rows
    }

    #[test]
    fn pairing_and_speedup() {
        let pairs = pair_rows(&sample_rows());
        assert_eq!(pairs.len(), 1);
        assert!((pairs[0].step_speedup() - 5.0).abs() < 1e-9);
        assert!((pairs[0].mem_ratio() - 10.0).abs() < 1e-9);
        assert_eq!(pairs[0].fanout(), "15x10");
    }

    #[test]
    fn depth3_pairs_render_in_tables() {
        let mut rows = sample_rows();
        for seed in [42, 43, 44] {
            rows.push(row("arxiv_sim", "dgl", "10x5x5", 3, 1024, seed, 20.0,
                          200_000_000));
            rows.push(row("arxiv_sim", "fsa", "10x5x5", 3, 1024, seed, 2.5,
                          5_500_000));
        }
        let pairs = pair_rows(&rows);
        assert_eq!(pairs.len(), 2);
        let t1 = table1(&rows);
        assert!(t1.contains("10x5x5"), "{t1}");
        let t2 = table2(&rows);
        assert!(t2.contains("10x5x5"), "{t2}");
    }

    #[test]
    fn table1_mentions_both_variants() {
        let t = table1(&sample_rows());
        assert!(t.contains("arxiv_sim"));
        assert!(t.contains("5.00x"));
    }

    #[test]
    fn fig1_flags_regressions() {
        let mut rows = sample_rows();
        for seed in [42, 43, 44] {
            rows.push(row("reddit_sim", "dgl", "25x10", 2, 1024, seed, 2.0,
                          1));
            rows.push(row("reddit_sim", "fsa", "25x10", 2, 1024, seed, 4.0,
                          1));
        }
        let f = fig1(&rows);
        assert!(f.contains("fusion loses"));
    }

    #[test]
    fn table2_ratio_rendering() {
        let t = table2(&sample_rows());
        assert!(t.contains("10.00x"));
    }

    #[test]
    fn unpaired_rows_are_dropped() {
        let rows = vec![row("solo", "dgl", "10x10", 2, 1024, 42, 1.0, 1)];
        assert!(pair_rows(&rows).is_empty());
    }
}
