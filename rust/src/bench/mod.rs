//! Benchmark grid runner — the Rust analogue of the paper's
//! `scripts/bench_grid.py` (§5 "Command to reproduce").
//!
//! Runs (dataset × fanout × batch × variant × repeat) with the paper's
//! protocol (warmup then timed steps, medians over repeats with seeds
//! {42,43,44}), emits a single CSV (`results/bench.csv`), and [`render`]
//! regenerates every table/figure from that CSV. Fanouts are full
//! [`Fanouts`] lists, so a grid can sweep depth as well as width (see
//! [`Grid::depth_axis`]).

pub mod render;
pub mod throughput;

use anyhow::Result;

use crate::coordinator::{measure, DatasetCache, TrainConfig, Trainer, Variant};
use crate::fanout::Fanouts;
use crate::graph::PlannerChoice;
use crate::kernel::{FeatureLayout, SimdChoice};
use crate::metrics::{median, median_over_repeats, BenchRow};
use crate::runtime::{BackendChoice, Runtime};

/// Grid specification (defaults = the paper's main grid, CPU-scaled).
#[derive(Clone, Debug)]
pub struct Grid {
    pub datasets: Vec<String>,
    pub fanouts: Vec<Fanouts>,
    pub batches: Vec<usize>,
    pub amp: bool,
    pub steps: usize,
    pub warmup: usize,
    pub seeds: Vec<u64>,
    pub variants: Vec<Variant>,
    /// Host sampler threads (paper protocol: 1 = serial; output identical).
    pub threads: usize,
    /// Overlap host sampling with dispatch (paper protocol: off).
    pub prefetch: bool,
    /// Execution backend for every cell (default auto: PJRT when
    /// artifacts compile, native CPU engine otherwise).
    pub backend: BackendChoice,
    /// Shard-planner cost model for every cell (`--planner`).
    pub planner: PlannerChoice,
    /// Planner-state persistence file for adaptive cells
    /// (`--planner-state <path|off>`; None = off, the grid default —
    /// paper-protocol cells should not inherit another run's weights).
    pub planner_state: Option<std::path::PathBuf>,
    /// Native vector tier for every cell (`--simd`); outputs are bitwise
    /// identical either way, so the grid records rather than re-pairs it.
    pub simd: SimdChoice,
    /// Feature-row storage order for every cell (`--layout`).
    pub layout: FeatureLayout,
    /// Hub-aggregate cache refresh budget for every cell
    /// (`--hub-cache off|N`; None = off, the grid default). Outputs
    /// are bitwise identical either way — only step time moves.
    pub hub_cache: Option<usize>,
}

impl Default for Grid {
    fn default() -> Self {
        Grid {
            datasets: vec!["arxiv_sim".into(), "reddit_sim".into(),
                           "products_sim".into()],
            fanouts: vec![Fanouts::of(&[10, 10]), Fanouts::of(&[15, 10]),
                          Fanouts::of(&[25, 10])],
            batches: vec![512, 1024],
            amp: true,
            steps: 30,
            warmup: 5,
            seeds: vec![42, 43, 44],
            variants: vec![Variant::Dgl, Variant::Fsa],
            threads: 1,
            prefetch: false,
            backend: BackendChoice::Auto,
            planner: PlannerChoice::default(),
            planner_state: None,
            simd: SimdChoice::default(),
            layout: FeatureLayout::default(),
            hub_cache: None,
        }
    }
}

impl Grid {
    /// A fast smoke grid for CI / tests.
    pub fn quick() -> Self {
        Grid {
            datasets: vec!["arxiv_sim".into()],
            fanouts: vec![Fanouts::of(&[15, 10])],
            batches: vec![512],
            steps: 5,
            warmup: 1,
            seeds: vec![42],
            ..Default::default()
        }
    }

    /// Fig 2 grid: batch scaling on products_sim at fanout 15-10.
    pub fn fig2() -> Self {
        Grid {
            datasets: vec!["products_sim".into()],
            fanouts: vec![Fanouts::of(&[15, 10])],
            batches: vec![128, 256, 512, 1024, 2048],
            ..Default::default()
        }
    }

    /// Fig 3 grid: fanout sweep on arxiv_sim at B=1024.
    pub fn fig3() -> Self {
        Grid {
            datasets: vec!["arxiv_sim".into()],
            batches: vec![1024],
            ..Default::default()
        }
    }

    /// Depth axis: fanouts of depth 1/2/3 at a matched 150-leaves-per-seed
    /// budget (150 = 15·10 = 15·5·2), so cross-depth rows compare the
    /// same leaf gather volume and isolate the depth cost itself.
    pub fn depth_axis() -> Self {
        Grid {
            fanouts: vec![Fanouts::of(&[150]), Fanouts::of(&[15, 10]),
                          Fanouts::of(&[15, 5, 2])],
            batches: vec![1024],
            ..Default::default()
        }
    }
}

/// Apply `FSA_BENCH_STEPS` / `FSA_BENCH_WARMUP` / `FSA_BENCH_SEEDS` /
/// `FSA_BENCH_QUICK` environment overrides (used by the bench targets so a
/// full `cargo bench` can be scaled down without editing code).
pub fn env_overrides(mut grid: Grid) -> Grid {
    if std::env::var("FSA_BENCH_QUICK").is_ok() {
        grid.steps = 5;
        grid.warmup = 1;
        grid.seeds = vec![42];
    }
    if let Ok(v) = std::env::var("FSA_BENCH_STEPS") {
        if let Ok(n) = v.parse() {
            grid.steps = n;
        }
    }
    if let Ok(v) = std::env::var("FSA_BENCH_WARMUP") {
        if let Ok(n) = v.parse() {
            grid.warmup = n;
        }
    }
    if let Ok(v) = std::env::var("FSA_BENCH_SEEDS") {
        let seeds: Vec<u64> = v.split(',').filter_map(|s| s.trim().parse().ok()).collect();
        if !seeds.is_empty() {
            grid.seeds = seeds;
        }
    }
    grid
}

/// Print an exhibit and persist it under `results/<name>.txt`.
pub fn save_exhibit(name: &str, text: &str) {
    println!("{text}");
    let path = crate::util::results_dir().join(format!("{name}.txt"));
    if let Err(e) = std::fs::write(&path, text) {
        eprintln!("warning: could not write {path:?}: {e}");
    } else {
        println!("(saved to {})", path.display());
    }
}

/// Run one configuration (one repeat) and reduce to a BenchRow.
pub fn run_config(rt: &Runtime, cache: &mut DatasetCache, cfg: TrainConfig,
                  warmup: usize, steps: usize) -> Result<BenchRow> {
    let mut trainer = Trainer::new(rt, cache, cfg.clone())?;
    let timings = measure(&mut trainer, warmup, steps)?;

    let step_ms = median(&timings.iter().map(|t| t.total_ms()).collect::<Vec<_>>());
    let sample_ms = median(&timings.iter().map(|t| t.sample_ms).collect::<Vec<_>>());
    let upload_ms = median(&timings.iter().map(|t| t.upload_ms).collect::<Vec<_>>());
    let execute_ms = median(&timings.iter().map(|t| t.execute_ms).collect::<Vec<_>>());
    let pairs = median(&timings.iter().map(|t| t.pairs as f64).collect::<Vec<_>>());
    let peak = timings.iter().map(|t| t.transient_bytes).max().unwrap_or(0);
    let loss = timings.last().map(|t| t.loss).unwrap_or(f64::NAN);
    let imbalance =
        median(&timings.iter().map(|t| t.imbalance).collect::<Vec<_>>());
    // hub-cache activity totals over the timed window (all zero when
    // `--hub-cache off`: no lookups happen at all, so the rate is 0.0)
    let hub_hits: u64 = timings.iter().map(|t| t.hub_hits).sum();
    let hub_lookups: u64 =
        hub_hits + timings.iter().map(|t| t.hub_misses).sum::<u64>();
    let hub_hit_rate = if hub_lookups == 0 {
        0.0
    } else {
        hub_hits as f64 / hub_lookups as f64
    };
    let hub_refreshes: u64 = timings.iter().map(|t| t.hub_refreshes).sum();

    Ok(BenchRow {
        dataset: cfg.dataset.clone(),
        variant: cfg.variant.as_str().to_string(),
        hops: cfg.hops(),
        fanout: cfg.fanouts.label(),
        batch: cfg.batch as u32,
        amp: cfg.amp,
        repeat_seed: cfg.seed,
        steps: steps as u32,
        step_ms,
        sample_ms,
        upload_ms,
        execute_ms,
        pairs_per_s: pairs / (step_ms / 1e3),
        nodes_per_s: cfg.batch as f64 / (step_ms / 1e3),
        peak_transient_bytes: peak,
        loss,
        imbalance,
        planner: cfg.planner.as_str().to_string(),
        simd: if cfg.simd.enabled() { "on" } else { "off" }.to_string(),
        hub_hit_rate,
        hub_refreshes,
    })
}

/// Run a full grid; returns one row per (config × repeat).
pub fn run_grid(rt: &Runtime, cache: &mut DatasetCache, grid: &Grid,
                mut progress: impl FnMut(&BenchRow)) -> Result<Vec<BenchRow>> {
    let mut rows = Vec::new();
    for ds in &grid.datasets {
        for fanouts in &grid.fanouts {
            for &batch in &grid.batches {
                for &variant in &grid.variants {
                    for &seed in &grid.seeds {
                        let cfg = TrainConfig {
                            variant,
                            dataset: ds.clone(),
                            fanouts: fanouts.clone(),
                            batch,
                            amp: grid.amp,
                            save_indices: true,
                            seed,
                            threads: grid.threads,
                            prefetch: grid.prefetch,
                            backend: grid.backend,
                            planner: grid.planner,
                            planner_state: grid.planner_state.clone(),
                            faults: crate::runtime::faults::none(),
                            simd: grid.simd,
                            layout: grid.layout,
                            hub_cache: grid.hub_cache,
                        };
                        let row = run_config(rt, cache, cfg, grid.warmup,
                                             grid.steps)?;
                        progress(&row);
                        rows.push(row);
                    }
                }
            }
        }
    }
    Ok(rows)
}

/// Reduce fused-vs-baseline rows to the `BENCH_native.json` trajectory
/// artifact: one cell per (dataset, fanout, batch) with the depth, the
/// median step time, steps/sec, and peak transient bytes of each variant,
/// plus the fused-over-baseline ratios. Written from `fsa bench-grid`
/// native runs and the `fused_vs_baseline` bench target so the perf
/// numbers — including the transient-ratio-vs-depth trajectory — are
/// comparable across PRs.
pub fn native_bench_json(rows: &[BenchRow], planner: PlannerChoice,
                         simd: SimdChoice) -> crate::json::Value {
    use crate::json::Value;
    use std::collections::BTreeMap;

    let med = median_over_repeats(rows);
    let mut cells: BTreeMap<(String, u32, String, u32),
                            (Option<BenchRow>, Option<BenchRow>)> =
        BTreeMap::new();
    for r in med {
        let key = (r.dataset.clone(), r.hops, r.fanout.clone(), r.batch);
        let slot = cells.entry(key).or_default();
        match r.variant.as_str() {
            "fsa" => slot.0 = Some(r),
            "dgl" => slot.1 = Some(r),
            _ => {}
        }
    }

    let num = Value::Num;
    let mut out_cells = Vec::new();
    for ((dataset, hops, fanout, batch), (fsa, dgl)) in cells {
        let mut obj = BTreeMap::new();
        obj.insert("dataset".into(), Value::Str(dataset));
        obj.insert("depth".into(), num(hops as f64));
        obj.insert("fanout".into(), Value::Str(fanout));
        obj.insert("batch".into(), num(batch as f64));
        if let Some(f) = &fsa {
            obj.insert("fused_step_ms".into(), num(f.step_ms));
            obj.insert("fused_steps_per_s".into(),
                       num(1e3 / f.step_ms.max(1e-9)));
            obj.insert("fused_peak_transient_bytes".into(),
                       num(f.peak_transient_bytes as f64));
            obj.insert("fused_loss".into(), num(f.loss));
            // per-depth measured shard-imbalance ratio of the fused
            // kernel's batch sharding (1.0 = balanced or serial)
            obj.insert("imbalance".into(), num(f.imbalance));
            // hub-cache hit rate over the timed window (0.0 when off)
            obj.insert("hub_hit_rate".into(), num(f.hub_hit_rate));
            obj.insert("hub_refreshes".into(), num(f.hub_refreshes as f64));
        }
        if let Some(d) = &dgl {
            obj.insert("baseline_step_ms".into(), num(d.step_ms));
            obj.insert("baseline_steps_per_s".into(),
                       num(1e3 / d.step_ms.max(1e-9)));
            obj.insert("baseline_peak_transient_bytes".into(),
                       num(d.peak_transient_bytes as f64));
            obj.insert("baseline_loss".into(), num(d.loss));
            obj.insert("baseline_imbalance".into(), num(d.imbalance));
        }
        if let (Some(f), Some(d)) = (&fsa, &dgl) {
            obj.insert("speedup".into(),
                       num(d.step_ms / f.step_ms.max(1e-9)));
            obj.insert("transient_ratio".into(),
                       num(d.peak_transient_bytes as f64
                           / (f.peak_transient_bytes as f64).max(1.0)));
        }
        out_cells.push(Value::Obj(obj));
    }

    let mut root = BTreeMap::new();
    root.insert("bench".into(), Value::Str("fused_vs_baseline".into()));
    root.insert("backend".into(), Value::Str("native".into()));
    // the imbalance cells depend on the planner flavor; record it so
    // artifacts from different flavors are distinguishable
    root.insert("planner".into(), Value::Str(planner.as_str().into()));
    // the step-time cells depend on the vector tier the run resolved to
    // (outputs never do); record the resolved "on"/"off", not the knob,
    // so `auto` artifacts from different machines stay distinguishable
    root.insert("simd".into(),
                Value::Str(if simd.enabled() { "on" } else { "off" }.into()));
    root.insert("cells".into(), Value::Arr(out_cells));
    Value::Obj(root)
}

/// Write [`native_bench_json`] to `path`.
pub fn write_native_json(rows: &[BenchRow], planner: PlannerChoice,
                         simd: SimdChoice,
                         path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path,
                   format!("{}\n", native_bench_json(rows, planner, simd)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_is_the_paper_grid() {
        let g = Grid::default();
        assert_eq!(g.datasets.len(), 3);
        assert_eq!(g.fanouts,
                   vec![Fanouts::of(&[10, 10]), Fanouts::of(&[15, 10]),
                        Fanouts::of(&[25, 10])]);
        assert_eq!(g.batches, vec![512, 1024]);
        assert_eq!(g.steps, 30);
        assert_eq!(g.warmup, 5);
        assert_eq!(g.seeds, vec![42, 43, 44]);
    }

    #[test]
    fn fig_grids_cover_their_axes() {
        assert_eq!(Grid::fig2().batches, vec![128, 256, 512, 1024, 2048]);
        assert_eq!(Grid::fig3().fanouts.len(), 3);
        assert_eq!(Grid::fig3().batches, vec![1024]);
    }

    #[test]
    fn depth_axis_matches_leaf_budget_across_depths() {
        let g = Grid::depth_axis();
        assert_eq!(g.fanouts.len(), 3);
        for (i, f) in g.fanouts.iter().enumerate() {
            assert_eq!(f.depth(), i + 1);
            assert_eq!(f.leaf_count(), 150, "{f}");
        }
    }

    fn row(variant: &str, fanout: &str, hops: u32, seed: u64, step_ms: f64,
           peak: u64) -> BenchRow {
        BenchRow {
            dataset: "tiny".into(),
            variant: variant.into(),
            hops,
            fanout: fanout.into(),
            batch: 64,
            amp: true,
            repeat_seed: seed,
            steps: 5,
            step_ms,
            sample_ms: 0.0,
            upload_ms: 0.0,
            execute_ms: step_ms,
            pairs_per_s: 1.0,
            nodes_per_s: 1.0,
            peak_transient_bytes: peak,
            loss: 1.0,
            imbalance: 1.1,
            planner: "quantile".into(),
            simd: "on".into(),
            hub_hit_rate: 0.0,
            hub_refreshes: 0,
        }
    }

    #[test]
    fn native_json_pairs_variants_and_computes_ratios() {
        let rows = vec![
            row("fsa", "5x3", 2, 42, 1.0, 100),
            row("fsa", "5x3", 2, 43, 1.2, 110),
            row("dgl", "5x3", 2, 42, 3.0, 1000),
            row("dgl", "5x3", 2, 43, 3.4, 1100),
        ];
        let v = native_bench_json(&rows, PlannerChoice::default(),
                                  SimdChoice::On);
        assert_eq!(v.get("bench").unwrap().as_str(),
                   Some("fused_vs_baseline"));
        assert_eq!(v.get("simd").unwrap().as_str(), Some("on"));
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("fanout").unwrap().as_str(), Some("5x3"));
        assert_eq!(cells[0].get("depth").unwrap().as_f64(), Some(2.0));
        assert_eq!(cells[0].get("imbalance").unwrap().as_f64(), Some(1.1));
        let speedup = cells[0].get("speedup").unwrap().as_f64().unwrap();
        assert!((speedup - 3.2 / 1.1).abs() < 1e-9, "speedup {speedup}");
        let ratio =
            cells[0].get("transient_ratio").unwrap().as_f64().unwrap();
        assert!(ratio > 9.0, "ratio {ratio}");
        // round-trips through the writer grammar
        let text = format!("{v}");
        assert!(crate::json::parse(&text).is_ok(), "{text}");
    }

    #[test]
    fn native_json_keeps_depth_cells_separate() {
        let rows = vec![
            row("fsa", "150", 1, 42, 1.0, 100),
            row("dgl", "150", 1, 42, 2.0, 500),
            row("fsa", "15x10", 2, 42, 1.0, 120),
            row("dgl", "15x10", 2, 42, 3.0, 1500),
            row("fsa", "15x5x2", 3, 42, 1.0, 140),
            row("dgl", "15x5x2", 3, 42, 4.0, 4000),
        ];
        let v = native_bench_json(&rows, PlannerChoice::default(),
                                  SimdChoice::Off);
        assert_eq!(v.get("simd").unwrap().as_str(), Some("off"));
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 3);
        // the transient ratio trajectory across depth is recoverable
        let ratios: Vec<f64> = cells
            .iter()
            .map(|c| c.get("transient_ratio").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ratios.len(), 3);
        assert!(ratios.iter().all(|&r| r > 1.0), "{ratios:?}");
    }
}
