//! Benchmark grid runner — the Rust analogue of the paper's
//! `scripts/bench_grid.py` (§5 "Command to reproduce").
//!
//! Runs (dataset × fanout × batch × variant × repeat) with the paper's
//! protocol (warmup then timed steps, medians over repeats with seeds
//! {42,43,44}), emits a single CSV (`results/bench.csv`), and [`render`]
//! regenerates every table/figure from that CSV.

pub mod render;
pub mod throughput;

use anyhow::Result;

use crate::coordinator::{measure, DatasetCache, TrainConfig, Trainer, Variant};
use crate::metrics::{median, BenchRow};
use crate::runtime::Runtime;

/// Grid specification (defaults = the paper's main grid, CPU-scaled).
#[derive(Clone, Debug)]
pub struct Grid {
    pub datasets: Vec<String>,
    pub fanouts: Vec<(usize, usize)>,
    pub batches: Vec<usize>,
    pub amp: bool,
    pub steps: usize,
    pub warmup: usize,
    pub seeds: Vec<u64>,
    pub variants: Vec<Variant>,
    /// 2 for the main grid; 1 runs the 1-hop ablation artifacts.
    pub hops: u32,
    /// Host sampler threads (paper protocol: 1 = serial; output identical).
    pub threads: usize,
    /// Overlap host sampling with dispatch (paper protocol: off).
    pub prefetch: bool,
}

impl Default for Grid {
    fn default() -> Self {
        Grid {
            datasets: vec!["arxiv_sim".into(), "reddit_sim".into(),
                           "products_sim".into()],
            fanouts: vec![(10, 10), (15, 10), (25, 10)],
            batches: vec![512, 1024],
            amp: true,
            steps: 30,
            warmup: 5,
            seeds: vec![42, 43, 44],
            variants: vec![Variant::Dgl, Variant::Fsa],
            hops: 2,
            threads: 1,
            prefetch: false,
        }
    }
}

impl Grid {
    /// A fast smoke grid for CI / tests.
    pub fn quick() -> Self {
        Grid {
            datasets: vec!["arxiv_sim".into()],
            fanouts: vec![(15, 10)],
            batches: vec![512],
            steps: 5,
            warmup: 1,
            seeds: vec![42],
            ..Default::default()
        }
    }

    /// Fig 2 grid: batch scaling on products_sim at fanout 15-10.
    pub fn fig2() -> Self {
        Grid {
            datasets: vec!["products_sim".into()],
            fanouts: vec![(15, 10)],
            batches: vec![128, 256, 512, 1024, 2048],
            ..Default::default()
        }
    }

    /// Fig 3 grid: fanout sweep on arxiv_sim at B=1024.
    pub fn fig3() -> Self {
        Grid {
            datasets: vec!["arxiv_sim".into()],
            batches: vec![1024],
            ..Default::default()
        }
    }
}

/// Apply `FSA_BENCH_STEPS` / `FSA_BENCH_WARMUP` / `FSA_BENCH_SEEDS` /
/// `FSA_BENCH_QUICK` environment overrides (used by the bench targets so a
/// full `cargo bench` can be scaled down without editing code).
pub fn env_overrides(mut grid: Grid) -> Grid {
    if std::env::var("FSA_BENCH_QUICK").is_ok() {
        grid.steps = 5;
        grid.warmup = 1;
        grid.seeds = vec![42];
    }
    if let Ok(v) = std::env::var("FSA_BENCH_STEPS") {
        if let Ok(n) = v.parse() {
            grid.steps = n;
        }
    }
    if let Ok(v) = std::env::var("FSA_BENCH_WARMUP") {
        if let Ok(n) = v.parse() {
            grid.warmup = n;
        }
    }
    if let Ok(v) = std::env::var("FSA_BENCH_SEEDS") {
        let seeds: Vec<u64> = v.split(',').filter_map(|s| s.trim().parse().ok()).collect();
        if !seeds.is_empty() {
            grid.seeds = seeds;
        }
    }
    grid
}

/// Print an exhibit and persist it under `results/<name>.txt`.
pub fn save_exhibit(name: &str, text: &str) {
    println!("{text}");
    let path = crate::util::results_dir().join(format!("{name}.txt"));
    if let Err(e) = std::fs::write(&path, text) {
        eprintln!("warning: could not write {path:?}: {e}");
    } else {
        println!("(saved to {})", path.display());
    }
}

/// Run one configuration (one repeat) and reduce to a BenchRow.
pub fn run_config(rt: &Runtime, cache: &mut DatasetCache, cfg: TrainConfig,
                  warmup: usize, steps: usize) -> Result<BenchRow> {
    let mut trainer = Trainer::new(rt, cache, cfg.clone())?;
    let timings = measure(&mut trainer, warmup, steps)?;

    let step_ms = median(&timings.iter().map(|t| t.total_ms()).collect::<Vec<_>>());
    let sample_ms = median(&timings.iter().map(|t| t.sample_ms).collect::<Vec<_>>());
    let upload_ms = median(&timings.iter().map(|t| t.upload_ms).collect::<Vec<_>>());
    let execute_ms = median(&timings.iter().map(|t| t.execute_ms).collect::<Vec<_>>());
    let pairs = median(&timings.iter().map(|t| t.pairs as f64).collect::<Vec<_>>());
    let peak = timings.iter().map(|t| t.transient_bytes).max().unwrap_or(0);
    let loss = timings.last().map(|t| t.loss).unwrap_or(f64::NAN);

    Ok(BenchRow {
        dataset: cfg.dataset.clone(),
        variant: cfg.variant.as_str().to_string(),
        hops: cfg.hops,
        k1: cfg.k1 as u32,
        k2: cfg.k2 as u32,
        batch: cfg.batch as u32,
        amp: cfg.amp,
        repeat_seed: cfg.seed,
        steps: steps as u32,
        step_ms,
        sample_ms,
        upload_ms,
        execute_ms,
        pairs_per_s: pairs / (step_ms / 1e3),
        nodes_per_s: cfg.batch as f64 / (step_ms / 1e3),
        peak_transient_bytes: peak,
        loss,
    })
}

/// Run a full grid; returns one row per (config × repeat).
pub fn run_grid(rt: &Runtime, cache: &mut DatasetCache, grid: &Grid,
                mut progress: impl FnMut(&BenchRow)) -> Result<Vec<BenchRow>> {
    let mut rows = Vec::new();
    for ds in &grid.datasets {
        for &(k1, k2) in &grid.fanouts {
            for &batch in &grid.batches {
                for &variant in &grid.variants {
                    for &seed in &grid.seeds {
                        let cfg = TrainConfig {
                            variant,
                            hops: grid.hops,
                            dataset: ds.clone(),
                            k1,
                            k2: if grid.hops == 2 { k2 } else { 0 },
                            batch,
                            amp: grid.amp,
                            save_indices: true,
                            seed,
                            threads: grid.threads,
                            prefetch: grid.prefetch,
                        };
                        let row = run_config(rt, cache, cfg, grid.warmup,
                                             grid.steps)?;
                        progress(&row);
                        rows.push(row);
                    }
                }
            }
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_is_the_paper_grid() {
        let g = Grid::default();
        assert_eq!(g.datasets.len(), 3);
        assert_eq!(g.fanouts, vec![(10, 10), (15, 10), (25, 10)]);
        assert_eq!(g.batches, vec![512, 1024]);
        assert_eq!(g.steps, 30);
        assert_eq!(g.warmup, 5);
        assert_eq!(g.seeds, vec![42, 43, 44]);
    }

    #[test]
    fn fig_grids_cover_their_axes() {
        assert_eq!(Grid::fig2().batches, vec![128, 256, 512, 1024, 2048]);
        assert_eq!(Grid::fig3().fanouts.len(), 3);
        assert_eq!(Grid::fig3().batches, vec![1024]);
    }
}
