//! `throughput` bench mode — steps/sec and pipeline utilization of the
//! host sampling/batch pipeline.
//!
//! This measures exactly the stage PR 1 parallelized: seed scheduling →
//! (sharded, multi-threaded) neighbor sampling → block materialization,
//! with optional double-buffered prefetch, at any fanout depth. It needs
//! **no AOT artifacts and no PJRT backend**: by default the device
//! dispatch the prefetcher overlaps with is emulated by a fixed per-step
//! sleep (`dispatch_ms`); with `native: true` ([`ThroughputConfig`]) each
//! step instead runs a *real* fwd+bwd+AdamW dispatch on the native CPU
//! engine ([`crate::kernel::NativeBackend`]), so the overlap numbers
//! reflect genuine compute and perf regressions in the engine fail the CI
//! smoke.
//!
//! Reported metrics:
//! * `steps_per_s` — timed steps per wall-clock second (headline);
//! * `sample_ms` — median critical-path sampling per step (block build
//!   when synchronous, prefetch-wait when overlapped);
//! * `overlap_ms` — median sampling wall-clock hidden behind dispatch;
//! * `utilization` — fraction of total host sampling work that was
//!   hidden, `1 - Σcritical / Σwork` (0 without prefetch).

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::coordinator::pipeline::{prepare_batch, BatchPrefetcher,
                                   BatchScheduler, HostWork};
use crate::coordinator::{TrainConfig, Variant};
use crate::fanout::Fanouts;
use crate::gen::Dataset;
use crate::graph::cost::shared_session_model;
use crate::graph::PlannerChoice;
use crate::kernel::{FeatureLayout, NativeBackend, SimdChoice};
use crate::memory::MemoryMeter;
use crate::metrics::{summarize, ThroughputRow, Timer};
use crate::runtime::manifest::AdamwConfig;
use crate::runtime::{Backend, BackendChoice, Manifest, StepInputs};
use crate::sampler::ParallelSampler;

/// One throughput-mode configuration.
#[derive(Clone, Debug)]
pub struct ThroughputConfig {
    pub dataset: String,
    /// Per-hop fanouts (depth = hops).
    pub fanouts: Fanouts,
    pub batch: usize,
    pub steps: usize,
    pub warmup: usize,
    /// Sampler worker threads (0 = auto).
    pub threads: usize,
    pub prefetch: bool,
    /// Emulated dispatch per step, ms (the device work prefetch overlaps).
    /// Ignored when `native` is set.
    pub dispatch_ms: f64,
    pub seed: u64,
    /// Dispatch real native-engine train steps instead of sleeping.
    pub native: bool,
    /// Variant for the native dispatch (and the host work it implies:
    /// Dgl builds blocks, Fsa samples inside the kernel).
    pub variant: Variant,
    /// Model hidden width for native dispatch. Defaults to the builtin
    /// manifest; `cmd_throughput` overrides from the runtime manifest so
    /// the smoke measures the same model as `fsa train --backend native`.
    pub hidden: usize,
    /// Optimizer hyper-parameters for native dispatch (same source).
    pub adamw: AdamwConfig,
    /// Shard-planner cost model (`--planner`).
    pub planner: PlannerChoice,
    /// Native vector tier for the dispatch (`--simd`; bitwise-invariant).
    pub simd: SimdChoice,
    /// Feature-row storage order (`--layout`; bitwise-invariant).
    pub layout: FeatureLayout,
    /// Hub-aggregate cache refresh budget (`--hub-cache off|N`;
    /// bitwise-invariant, native fused dispatch only).
    pub hub_cache: Option<usize>,
}

impl ThroughputConfig {
    /// Defaults mirroring the paper's main grid cell (fanout 15-10,
    /// B=1024) with a dispatch stand-in in the CPU-step ballpark.
    pub fn new(dataset: &str) -> Self {
        let builtin = Manifest::builtin();
        ThroughputConfig {
            dataset: dataset.to_string(),
            fanouts: Fanouts::of(&[15, 10]),
            batch: 1024,
            steps: 30,
            warmup: 3,
            threads: 1,
            prefetch: false,
            dispatch_ms: 2.0,
            seed: 42,
            native: false,
            variant: Variant::Dgl,
            hidden: builtin.hidden,
            adamw: builtin.adamw,
            planner: PlannerChoice::default(),
            simd: SimdChoice::default(),
            layout: FeatureLayout::default(),
            hub_cache: None,
        }
    }

    /// The equivalent training configuration of this throughput run —
    /// the single home of the knob→`NativeConfig` mapping
    /// ([`TrainConfig::native_config`]), so the native dispatch here and
    /// `fsa train --backend native` always measure the same model.
    fn train_config(&self) -> TrainConfig {
        TrainConfig {
            variant: self.variant,
            dataset: self.dataset.clone(),
            fanouts: self.fanouts.clone(),
            batch: self.batch,
            amp: false, // throughput smoke measures the f32 storage path
            save_indices: true,
            seed: self.seed,
            threads: self.threads,
            prefetch: self.prefetch,
            backend: BackendChoice::Native,
            planner: self.planner,
            // throughput runs are ephemeral measurements; they never
            // warm-start from or persist planner state
            planner_state: None,
            faults: crate::runtime::faults::none(),
            simd: self.simd,
            layout: self.layout,
            hub_cache: self.hub_cache,
        }
    }
}

/// Run the host pipeline for `warmup + steps` steps and reduce to a row.
pub fn run_throughput(ds: Arc<Dataset>,
                      cfg: &ThroughputConfig) -> Result<ThroughputRow> {
    ensure!(cfg.steps > 0, "throughput: need at least one timed step");
    let work = match (cfg.native, cfg.variant) {
        (true, Variant::Fsa) => HostWork::SeedsOnly,
        _ => HostWork::Block,
    };
    // adaptive: one shared planner model for the whole run, so the
    // sampler, the prefetch thread, and (for the fused variant) the
    // native engine all feed the same per-worker weights
    let shared = shared_session_model(&ds.graph, &cfg.fanouts, cfg.planner);
    let mut engine = if cfg.native {
        let native_cfg = cfg.train_config().native_config(cfg.hidden);
        Some(match (&shared, cfg.variant) {
            (Some(m), Variant::Fsa) => NativeBackend::with_shared_model(
                ds.clone(), native_cfg, cfg.adamw, m.clone())?,
            _ => NativeBackend::new(ds.clone(), native_cfg, cfg.adamw)?,
        })
    } else {
        None
    };
    let mut meter = MemoryMeter::new();
    let mut sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed)?;
    let mut sampler = ParallelSampler::with_planner(cfg.threads, cfg.planner);
    if let Some(m) = &shared {
        sampler = sampler.with_model(m.clone());
    }
    let mut prefetcher = if cfg.prefetch {
        Some(BatchPrefetcher::spawn(ds.clone(), work, cfg.fanouts.clone(),
                                    sampler.fresh_stats()))
    } else {
        None
    };

    let mut step_wall: Vec<f64> = Vec::with_capacity(cfg.steps);
    let mut critical: Vec<f64> = Vec::with_capacity(cfg.steps);
    let mut overlapped: Vec<f64> = Vec::with_capacity(cfg.steps);
    let mut dispatched: Vec<f64> = Vec::with_capacity(cfg.steps);
    let mut imbalances: Vec<f64> = Vec::with_capacity(cfg.steps);
    let mut wall = Timer::start();
    let mut hub_start = None;

    for step in 0..cfg.warmup + cfg.steps {
        if step == cfg.warmup {
            wall = Timer::start(); // timed window begins
            hub_start = engine.as_ref().and_then(|e| e.hub_counters());
        }
        let step_timer = Timer::start();
        let prepared = match prefetcher.as_mut() {
            None => {
                let s = sched.steps_drawn();
                let seeds = sched.next_seeds();
                prepare_batch(&ds, work, &cfg.fanouts, &sampler, s, seeds,
                              sched.base_seed(s))
            }
            Some(pf) => pf.next_batch(&mut sched)?,
        };
        let (crit, over) = match prepared.wait_ms {
            None => (prepared.sample_ms, 0.0),
            Some(w) => (w, prepared.sample_ms),
        };
        // the synchronized dispatch the next batch overlaps with: a real
        // native-engine train step, or the emulated fixed sleep
        let disp = Timer::start();
        let mut engine_stats = None;
        match engine.as_mut() {
            Some(eng) => {
                let inp = StepInputs {
                    seeds: &prepared.seeds,
                    labels: &prepared.labels,
                    base: prepared.base,
                    block: prepared.block.as_ref(),
                };
                let out = eng.train_step(step, &inp, &mut meter)?;
                ensure!(out.loss.is_finite(),
                        "native dispatch produced a non-finite loss");
                engine_stats = out.shard_stats;
                meter.reset_step();
            }
            None if cfg.dispatch_ms > 0.0 => {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    cfg.dispatch_ms / 1e3));
            }
            None => {}
        }
        let disp_ms = disp.ms();
        // shard balance: engine batch shards when the dispatch sharded,
        // else the sampler's block shards (1.0 = balanced or serial)
        let imb = engine_stats
            .as_ref()
            .map(|s| s.imbalance())
            .or(prepared.sample_imbalance)
            .unwrap_or(1.0);
        std::hint::black_box(&prepared);
        if step >= cfg.warmup {
            step_wall.push(step_timer.ms());
            critical.push(crit);
            overlapped.push(over);
            dispatched.push(disp_ms);
            imbalances.push(imb);
        }
    }
    let wall_s = wall.ms() / 1e3;

    // utilization: share of sampling work hidden behind dispatch
    let work_ms: f64 = critical
        .iter()
        .zip(&overlapped)
        .map(|(&c, &o)| if o > 0.0 { o } else { c })
        .sum();
    let crit_ms: f64 = critical.iter().sum();
    let utilization = if work_ms > 0.0 {
        (1.0 - crit_ms / work_ms).clamp(0.0, 1.0)
    } else {
        0.0
    };

    // hub-cache activity over the timed window (0.0/0 when off)
    let hub_end = engine.as_ref().and_then(|e| e.hub_counters());
    let (hub_hit_rate, hub_refreshes) = hub_delta(hub_start, hub_end);

    Ok(ThroughputRow {
        dataset: cfg.dataset.clone(),
        hops: cfg.fanouts.depth() as u32,
        fanout: cfg.fanouts.label(),
        batch: cfg.batch as u32,
        threads: sampler.threads() as u32,
        prefetch: cfg.prefetch,
        steps: cfg.steps as u32,
        steps_per_s: cfg.steps as f64 / wall_s.max(1e-9),
        step_ms: summarize(&step_wall).median,
        sample_ms: summarize(&critical).median,
        overlap_ms: summarize(&overlapped).median,
        dispatch_ms: if cfg.native {
            summarize(&dispatched).median
        } else {
            cfg.dispatch_ms
        },
        utilization,
        imbalance: summarize(&imbalances).median,
        planner: cfg.planner.as_str().to_string(),
        hub_hit_rate,
        hub_refreshes,
    })
}

/// Hub-cache hit rate + refresh count over a start/end counter pair.
/// The counters are cumulative per engine, so an engine rebuild or
/// counter reset mid-window makes `end < start`; raw subtraction would
/// wrap to huge u64 deltas and a hit rate far outside [0,1] in the
/// CSVs. Deltas saturate at 0 instead and the rate is clamped to [0,1],
/// so a reset window degrades to "no observed activity", never to
/// garbage rows.
pub fn hub_delta(start: Option<(u64, u64, u64)>, end: Option<(u64, u64, u64)>)
                 -> (f64, u64) {
    match (start, end) {
        (Some((h0, m0, r0)), Some((h1, m1, r1))) => {
            let hits = h1.saturating_sub(h0);
            let lookups = hits + m1.saturating_sub(m0);
            let rate = if lookups == 0 {
                0.0
            } else {
                (hits as f64 / lookups as f64).clamp(0.0, 1.0)
            };
            (rate, r1.saturating_sub(r0))
        }
        _ => (0.0, 0),
    }
}

/// Render a throughput comparison table (rows share a dataset/config).
pub fn render_table(rows: &[ThroughputRow]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "Host pipeline throughput — sharded parallel \
                           sampling + batch prefetch.");
    let _ = writeln!(out, "{:-<86}", "");
    let _ = writeln!(out,
                     "{:<10} {:>8} {:>10} {:>10} {:>12} {:>11} {:>7} {:>9}",
                     "threads", "prefetch", "steps/s", "step ms",
                     "sample ms", "overlap ms", "imbal", "util");
    let _ = writeln!(out, "{:-<86}", "");
    let baseline = rows.first().map(|r| r.steps_per_s);
    for r in rows {
        let speedup = baseline
            .map(|b| format!(" ({:.2}x)", r.steps_per_s / b.max(1e-9)))
            .unwrap_or_default();
        let _ = writeln!(
            out,
            "{:<10} {:>8} {:>10.1} {:>10.2} {:>12.2} {:>11.2} {:>7.2} \
             {:>8.0}%{}",
            r.threads, if r.prefetch { "on" } else { "off" }, r.steps_per_s,
            r.step_ms, r.sample_ms, r.overlap_ms, r.imbalance,
            100.0 * r.utilization, speedup);
    }
    let _ = writeln!(out, "{:-<86}", "");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::builtin_spec;

    fn tiny() -> Arc<Dataset> {
        Arc::new(Dataset::generate(builtin_spec("tiny").unwrap()).unwrap())
    }

    fn quick_cfg() -> ThroughputConfig {
        ThroughputConfig {
            batch: 64,
            fanouts: Fanouts::of(&[5, 3]),
            steps: 4,
            warmup: 1,
            dispatch_ms: 0.5,
            ..ThroughputConfig::new("tiny")
        }
    }

    #[test]
    fn sync_mode_reports_zero_overlap() {
        let r = run_throughput(tiny(), &quick_cfg()).unwrap();
        assert_eq!(r.overlap_ms, 0.0);
        assert_eq!(r.utilization, 0.0);
        assert!(r.steps_per_s > 0.0);
        assert_eq!(r.threads, 1);
        assert_eq!(r.steps, 4);
        assert_eq!(r.fanout, "5x3");
    }

    #[test]
    fn prefetch_mode_reports_overlap() {
        let cfg = ThroughputConfig { prefetch: true, threads: 2,
                                     ..quick_cfg() };
        let r = run_throughput(tiny(), &cfg).unwrap();
        assert!(r.prefetch);
        assert_eq!(r.threads, 2);
        assert!(r.utilization >= 0.0 && r.utilization <= 1.0);
        // worker-side sampling time is reported as overlapped
        assert!(r.overlap_ms > 0.0);
    }

    #[test]
    fn one_hop_and_three_hop_modes_run() {
        for ks in [&[5][..], &[4, 2, 2][..]] {
            let cfg = ThroughputConfig { fanouts: Fanouts::of(ks),
                                         ..quick_cfg() };
            let r = run_throughput(tiny(), &cfg).unwrap();
            assert_eq!(r.hops, ks.len() as u32);
            assert!(r.steps_per_s > 0.0);
        }
    }

    #[test]
    fn native_dispatch_runs_real_steps_for_both_variants() {
        for variant in [Variant::Dgl, Variant::Fsa] {
            let cfg = ThroughputConfig { native: true, variant,
                                         ..quick_cfg() };
            let r = run_throughput(tiny(), &cfg).unwrap();
            assert!(r.steps_per_s > 0.0, "{variant:?}");
            assert!(r.dispatch_ms > 0.0,
                    "{variant:?}: native dispatch must take real time");
            // the imbalance ratio is always reported: finite and >= 1
            // (exactly 1.0 for this serial run)
            assert!(r.imbalance.is_finite() && r.imbalance >= 1.0,
                    "{variant:?}: bad imbalance {}", r.imbalance);
            if variant == Variant::Fsa {
                // fused path samples inside the kernel: no host blocks
                assert_eq!(r.sample_ms, 0.0);
            }
        }
    }

    #[test]
    fn native_dispatch_runs_depth3() {
        let cfg = ThroughputConfig { native: true, variant: Variant::Fsa,
                                     fanouts: Fanouts::of(&[4, 2, 2]),
                                     ..quick_cfg() };
        let r = run_throughput(tiny(), &cfg).unwrap();
        assert_eq!(r.hops, 3);
        assert!(r.steps_per_s > 0.0 && r.dispatch_ms > 0.0);
    }

    /// The ISSUE's wraparound regression: a counter reset mid-run
    /// (engine rebuild) makes end < start; the deltas must saturate to
    /// zero and the rate stay in [0,1], never wrap.
    #[test]
    fn hub_delta_survives_counter_resets() {
        // normal window: 8 hits, 2 misses, 1 refresh
        assert_eq!(hub_delta(Some((10, 5, 3)), Some((18, 7, 4))),
                   (0.8, 1));
        // full reset mid-window: every end counter below its start —
        // degrades to "no observed activity"
        let (rate, refreshes) =
            hub_delta(Some((100, 50, 9)), Some((3, 1, 0)));
        assert!((0.0..=1.0).contains(&rate), "wrapped rate {rate}");
        assert_eq!((rate, refreshes), (0.0, 0));
        // partial reset: hits wrapped, misses advanced
        let (rate, refreshes) =
            hub_delta(Some((100, 5, 2)), Some((0, 9, 5)));
        assert_eq!((rate, refreshes), (0.0, 3));
        // cache off on either side: inert zeros
        assert_eq!(hub_delta(None, Some((1, 1, 1))), (0.0, 0));
        assert_eq!(hub_delta(Some((1, 1, 1)), None), (0.0, 0));
        assert_eq!(hub_delta(None, None), (0.0, 0));
        // zero-activity window
        assert_eq!(hub_delta(Some((5, 5, 5)), Some((5, 5, 5))), (0.0, 0));
    }

    #[test]
    fn table_renders_speedup_column() {
        let cfg = quick_cfg();
        let a = run_throughput(tiny(), &cfg).unwrap();
        let b = run_throughput(
            tiny(), &ThroughputConfig { prefetch: true, ..cfg }).unwrap();
        let t = render_table(&[a, b]);
        assert!(t.contains("steps/s") && t.contains("1.00x"), "{t}");
        assert!(t.contains("imbal"), "imbalance column missing:\n{t}");
    }
}
