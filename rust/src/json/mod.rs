//! Minimal JSON parser + writer.
//!
//! The offline build environment has no serde, so the manifest loader uses
//! this hand-rolled, well-tested recursive-descent parser instead (only
//! `anyhow` is a real dependency). Supports the full JSON grammar including
//! escapes and `\uXXXX` (BMP + surrogate pairs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null-ish None when missing.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut v = self;
        for k in keys {
            v = v.get(k)?;
        }
        Some(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    /// Non-negative integer view (counters, versions, timestamps).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            (x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64)
                .then_some(x as u64)
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(hi)
                        };
                        out.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn u64_view_accepts_counters_only() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"žćš — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "žćš — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\x\"").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"artifacts":[{"batch":1024,"name":"x","shape":[1,2,3]}],"nested":{"a":true,"b":null,"s":"q\"uote"}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap().to_string(), "[]");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 2);
    }

    /// Property-style: writer output must re-parse to the same value for
    /// randomly generated values (in-house generator, DESIGN.md §7 tests).
    #[test]
    fn prop_roundtrip_random_values() {
        use crate::rng::SplitMix64;
        let mut r = SplitMix64::new(99);
        for _ in 0..200 {
            let v = random_value(&mut r, 0);
            let s = v.to_string();
            assert_eq!(parse(&s).unwrap(), v, "failed on {s}");
        }
    }

    fn random_value(r: &mut crate::rng::SplitMix64, depth: u32) -> Value {
        match if depth > 3 { r.next_below(4) } else { r.next_below(6) } {
            0 => Value::Null,
            1 => Value::Bool(r.next_below(2) == 0),
            2 => Value::Num((r.next_below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let len = r.next_below(12) as usize;
                Value::Str(
                    (0..len)
                        .map(|_| {
                            char::from_u32(0x20 + r.next_below(0x50) as u32)
                                .unwrap()
                        })
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..r.next_below(5)).map(|_| random_value(r, depth + 1)).collect(),
            ),
            _ => Value::Obj(
                (0..r.next_below(5))
                    .map(|i| (format!("k{i}"), random_value(r, depth + 1)))
                    .collect(),
            ),
        }
    }
}
