//! Transient-memory accounting — the Table 2 / Figs 4–5 meter.
//!
//! The paper measures the peak GPU-memory *delta* during the timed loop
//! (NVML delta, falling back to `torch.cuda.max_memory_allocated`). Static
//! buffers (graph, features, parameters) are excluded by construction; what
//! remains is exactly the per-step transient footprint: uploaded index
//! tensors, materialized blocks, activations, gradients, optimizer temps.
//!
//! Our meter mirrors that (DESIGN.md §3). On the **native backend** the
//! per-step transient footprint is fully *measured*: the kernels record
//! every materialized buffer (blocks, gathers, activations, gradients)
//! into the [`MemoryMeter`] as it is allocated/released. On the PJRT
//! backend the runtime reports measured upload/output buffer bytes and
//! this module contributes the analytic model of the executable-internal
//! intermediates, derived from the same shape arithmetic as the paper's
//! complexity summary (§4):
//!   baseline 2-hop:  Θ(B·(1+k1)·k2·D) block + activations
//!   fused 2-hop:     Θ(B·D) output + saved indices; the gathered tile
//!                    lives in VMEM only (reported separately).

/// Dimensions of one training-step configuration.
#[derive(Clone, Copy, Debug)]
pub struct StepDims {
    pub batch: usize,
    pub k1: usize,
    pub k2: usize, // 0 for 1-hop
    pub d: usize,
    pub hidden: usize,
    pub classes: usize,
    /// Fused-kernel seed-tile (0 for baseline variants).
    pub tile: usize,
}

/// Per-step transient footprint breakdown (bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct Transient {
    /// Host→device per-step uploads (index tensors, seeds, labels).
    pub upload: u64,
    /// Executable-internal HBM intermediates (blocks, activations, grads).
    pub intermediates: u64,
    /// Device→host / param-churn outputs (updated params+opt state, loss).
    pub outputs: u64,
    /// VMEM-resident gather tile (fused kernel only; NOT HBM).
    pub vmem_tile: u64,
}

impl Transient {
    /// Peak transient HBM bytes — the Table 2 quantity.
    pub fn peak_hbm(&self) -> u64 {
        self.upload + self.intermediates + self.outputs
    }
}

const F32: u64 = 4;
const I32: u64 = 4;

fn fsa_param_bytes(dims: &StepDims) -> u64 {
    // w_self[d,h] + w_neigh[d,h] + b[h] + w_out[h,c] + b_out[c]
    ((2 * dims.d * dims.hidden + dims.hidden
        + dims.hidden * dims.classes + dims.classes) as u64) * F32
}

fn dgl_param_bytes(dims: &StepDims) -> u64 {
    // w1_self[d,h] + w1_neigh[d,h] + b1[h] + w2_self[h,c] + w2_neigh[h,c] + b2[c]
    ((2 * dims.d * dims.hidden + dims.hidden
        + 2 * dims.hidden * dims.classes + dims.classes) as u64) * F32
}

/// Analytic transient model for the baseline (DGL-like) 2-hop step.
pub fn baseline2_transient(dims: &StepDims) -> Transient {
    let (b, k1, k2, d, h, c) =
        (dims.batch as u64, dims.k1 as u64, dims.k2 as u64,
         dims.d as u64, dims.hidden as u64, dims.classes as u64);
    let f1w = 1 + k1;
    let params = dgl_param_bytes(dims);
    let upload = b * f1w * I32          // f1
        + b * f1w * k2 * I32            // s2
        + b * I32;                      // labels
    let intermediates =
        b * f1w * d * F32               // xf1 (materialized)
        + b * f1w * k2 * d * F32        // block (materialized) — the gap
        + b * f1w * d * F32             // mean2
        + b * f1w * h * F32             // h1
        + b * h * F32                   // h_neigh
        + b * c * F32                   // logits
        + b * c * F32                   // glogits
        + b * f1w * h * F32             // gh1
        + params                        // grads
        + 2 * params;                   // adam m̂/v̂ temps
    let outputs = 3 * params + F32;     // new params+m+v, loss
    Transient { upload, intermediates, outputs, vmem_tile: 0 }
}

/// Analytic transient model for the baseline 1-hop step.
pub fn baseline1_transient(dims: &StepDims) -> Transient {
    let (b, k1, d, h, c) = (dims.batch as u64, dims.k1 as u64,
                            dims.d as u64, dims.hidden as u64,
                            dims.classes as u64);
    let f1w = 1 + k1;
    let params = dgl_param_bytes(dims);
    let upload = b * f1w * I32 + b * I32;
    let intermediates = b * f1w * d * F32      // xf1 (materialized)
        + b * d * F32                           // h_neigh mean
        + b * h * F32                           // h
        + 2 * b * c * F32                       // logits + glogits
        + b * h * F32                           // gh
        + 3 * params;
    let outputs = 3 * params + F32;
    Transient { upload, intermediates, outputs, vmem_tile: 0 }
}

/// Analytic transient model for the fused 2-hop step.
pub fn fused2_transient(dims: &StepDims, save_indices: bool) -> Transient {
    let (b, k1, k2, d, h, c) =
        (dims.batch as u64, dims.k1 as u64, dims.k2 as u64,
         dims.d as u64, dims.hidden as u64, dims.classes as u64);
    let params = fsa_param_bytes(dims);
    let upload = b * I32                // seeds
        + b * I32                       // labels
        + 8;                            // base_seed
    let indices = if save_indices {
        b * k1 * I32 + b * k1 * k2 * I32
    } else {
        0
    };
    let intermediates = indices
        + b * d * F32                   // agg output of the fused op
        + b * d * F32                   // x_self gather
        + b * h * F32                   // head hidden
        + 2 * b * c * F32               // logits + glogits
        + b * h * F32                   // ghead
        + params                        // grads
        + 2 * params;                   // adam temps
    let outputs = 3 * params + F32;
    // the gathered feature tile never touches HBM: seed-tile × k1·k2 × D
    let vmem_tile = (dims.tile.max(1) as u64) * k1 * k2.max(1) * d * F32;
    Transient { upload, intermediates, outputs, vmem_tile }
}

/// Analytic transient model for the fused 1-hop step.
pub fn fused1_transient(dims: &StepDims, save_indices: bool) -> Transient {
    let (b, k1, d, h, c) = (dims.batch as u64, dims.k1 as u64,
                            dims.d as u64, dims.hidden as u64,
                            dims.classes as u64);
    let params = fsa_param_bytes(dims);
    let upload = 2 * b * I32 + 8;
    let indices = if save_indices { b * k1 * I32 + b * I32 } else { 0 };
    let intermediates = indices
        + 2 * b * d * F32
        + b * h * F32
        + 2 * b * c * F32
        + b * h * F32
        + 3 * params;
    let outputs = 3 * params + F32;
    let vmem_tile = (dims.tile.max(1) as u64) * k1 * d * F32;
    Transient { upload, intermediates, outputs, vmem_tile }
}

/// Runtime meter: accumulates *measured* buffer bytes as the coordinator
/// creates/receives literals, tracking the per-step high-water mark.
#[derive(Debug, Default)]
pub struct MemoryMeter {
    current: u64,
    peak: u64,
}

impl MemoryMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an allocation of `bytes` live within the current step.
    pub fn alloc(&mut self, bytes: u64) {
        self.current += bytes;
        self.peak = self.peak.max(self.current);
    }

    /// Record that `bytes` became dead (freed / dropped).
    pub fn free(&mut self, bytes: u64) {
        self.current = self.current.saturating_sub(bytes);
    }

    /// Step boundary: everything transient is dropped.
    pub fn reset_step(&mut self) {
        self.current = 0;
    }

    /// High-water mark since construction (or [`Self::reset_peak`]).
    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn reset_peak(&mut self) {
        self.peak = self.current;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(batch: usize, k1: usize, k2: usize, tile: usize) -> StepDims {
        StepDims { batch, k1, k2, d: 64, hidden: 64, classes: 47, tile }
    }

    #[test]
    fn baseline_dominated_by_block() {
        let t = baseline2_transient(&dims(1024, 15, 10, 0));
        // block = 1024*16*10*64*4 ≈ 41.9 MB must dominate
        let block = 1024u64 * 16 * 10 * 64 * 4;
        assert!(t.intermediates > block);
        assert!(t.peak_hbm() > block);
        assert!(t.peak_hbm() < 3 * block, "model blew up: {}", t.peak_hbm());
    }

    #[test]
    fn fused_is_orders_of_magnitude_smaller() {
        let d = dims(1024, 15, 10, 64);
        let base = baseline2_transient(&d).peak_hbm();
        let fsa = fused2_transient(&d, true).peak_hbm();
        let ratio = base as f64 / fsa as f64;
        assert!(ratio > 5.0, "expected large reduction, got {ratio:.2}x");
    }

    #[test]
    fn fanout_grows_baseline_not_fused_output() {
        let small = baseline2_transient(&dims(1024, 10, 10, 0)).peak_hbm();
        let large = baseline2_transient(&dims(1024, 25, 10, 0)).peak_hbm();
        assert!(large as f64 > small as f64 * 1.8);
        let fs = fused2_transient(&dims(1024, 10, 10, 64), true).peak_hbm();
        let fl = fused2_transient(&dims(1024, 25, 10, 64), true).peak_hbm();
        // fused grows only by the saved-index tensors
        assert!((fl as f64) < (fs as f64) * 1.6);
    }

    #[test]
    fn save_indices_off_shrinks_fused() {
        let d = dims(1024, 15, 10, 64);
        assert!(fused2_transient(&d, false).peak_hbm()
            < fused2_transient(&d, true).peak_hbm());
    }

    #[test]
    fn vmem_tile_respects_tile_size() {
        let t = fused2_transient(&dims(1024, 15, 10, 64), true);
        assert_eq!(t.vmem_tile, 64 * 15 * 10 * 64 * 4);
        let t1 = fused1_transient(&dims(1024, 10, 0, 128), true);
        assert_eq!(t1.vmem_tile, 128 * 10 * 64 * 4);
    }

    #[test]
    fn meter_tracks_high_water() {
        let mut m = MemoryMeter::new();
        m.alloc(100);
        m.alloc(50);
        m.free(100);
        m.alloc(30);
        assert_eq!(m.peak(), 150);
        m.reset_step();
        m.alloc(10);
        assert_eq!(m.peak(), 150, "peak persists across steps");
        m.reset_peak();
        assert_eq!(m.peak(), 10);
    }

    #[test]
    fn meter_monotone_peak_property() {
        use crate::rng::SplitMix64;
        let mut r = SplitMix64::new(9);
        let mut m = MemoryMeter::new();
        let mut last_peak = 0;
        for _ in 0..1000 {
            if r.next_below(2) == 0 {
                m.alloc(r.next_below(1000));
            } else {
                m.free(r.next_below(1000));
            }
            assert!(m.peak() >= last_peak, "peak decreased");
            last_peak = m.peak();
        }
    }
}
