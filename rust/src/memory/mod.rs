//! Transient-memory accounting — the Table 2 / Figs 4–5 meter.
//!
//! The paper measures the peak GPU-memory *delta* during the timed loop
//! (NVML delta, falling back to `torch.cuda.max_memory_allocated`). Static
//! buffers (graph, features, parameters) are excluded by construction; what
//! remains is exactly the per-step transient footprint: uploaded index
//! tensors, materialized blocks, activations, gradients, optimizer temps.
//!
//! Our meter mirrors that (DESIGN.md §3). On the **native backend** the
//! per-step transient footprint is fully *measured*: the kernels record
//! every materialized buffer (blocks, gathers, activations, gradients)
//! into the [`MemoryMeter`] as it is allocated/released. On the PJRT
//! backend the runtime reports measured upload/output buffer bytes and
//! this module contributes the analytic model of the executable-internal
//! intermediates, derived from the same shape arithmetic as the paper's
//! complexity summary (§4), generic over depth L:
//!   baseline L-hop:  Θ(B·Π(1+k_j)·k_L·D) leaf block + nested activations
//!   fused L-hop:     Θ(B·D) output + saved indices; the gathered tile
//!                    lives in VMEM only (reported separately).

use crate::fanout::Fanouts;

/// Dimensions of one training-step configuration.
#[derive(Clone, Debug)]
pub struct StepDims {
    pub batch: usize,
    /// Per-hop fanouts; depth decides block widths and layer count.
    pub fanouts: Fanouts,
    pub d: usize,
    pub hidden: usize,
    pub classes: usize,
    /// Fused-kernel seed-tile (0 for baseline variants).
    pub tile: usize,
}

/// Per-step transient footprint breakdown (bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct Transient {
    /// Host→device per-step uploads (index tensors, seeds, labels).
    pub upload: u64,
    /// Executable-internal HBM intermediates (blocks, activations, grads).
    pub intermediates: u64,
    /// Device→host / param-churn outputs (updated params+opt state, loss).
    pub outputs: u64,
    /// VMEM-resident gather tile (fused kernel only; NOT HBM).
    pub vmem_tile: u64,
}

impl Transient {
    /// Peak transient HBM bytes — the Table 2 quantity.
    pub fn peak_hbm(&self) -> u64 {
        self.upload + self.intermediates + self.outputs
    }
}

const F32: u64 = 4;
const I32: u64 = 4;

fn fsa_param_bytes(dims: &StepDims) -> u64 {
    // w_self[d,h] + w_neigh[d,h] + b[h] + w_out[h,c] + b_out[c] —
    // depth-independent (the head consumes the [B,d] aggregate)
    ((2 * dims.d * dims.hidden + dims.hidden
        + dims.hidden * dims.classes + dims.classes) as u64) * F32
}

fn dgl_param_bytes(dims: &StepDims) -> u64 {
    // per layer: w{i}_self[in,out] + w{i}_neigh[in,out] + b{i}[out],
    // widths d → h → … → h → c
    let depth = dims.fanouts.depth();
    let mut total = 0u64;
    for i in 1..=depth {
        let inp = if i == 1 { dims.d } else { dims.hidden };
        let out = if i == depth { dims.classes } else { dims.hidden };
        total += (2 * inp * out + out) as u64;
    }
    total * F32
}

/// Analytic transient model for the baseline (DGL-like) L-hop step.
pub fn baseline_transient(dims: &StepDims) -> Transient {
    let depth = dims.fanouts.depth();
    let (b, d, h, c) = (dims.batch as u64, dims.d as u64,
                        dims.hidden as u64, dims.classes as u64);
    let params = dgl_param_bytes(dims);

    // self-inclusive frontier widths: w grows to Π_{j<L}(1+k_j)
    let mut w = 1u64;
    let mut frontier_ints = 0u64;
    for l in 0..depth - 1 {
        w *= 1 + dims.fanouts.k(l) as u64;
        frontier_ints += b * w;
    }
    let kl = dims.fanouts.k(depth - 1) as u64;
    let upload = frontier_ints * I32    // nested frontier levels
        + b * w * kl * I32              // leaf samples
        + b * I32;                      // labels

    let mut intermediates =
        b * w * d * F32                 // deepest-frontier gather
        + b * w * kl * d * F32          // leaf block (materialized) — the gap
        + b * w * d * F32               // leaf masked mean
        + 2 * b * c * F32               // logits + glogits
        + 3 * params;                   // grads + adam m̂/v̂ temps
    // hidden activations + their backward temps per non-final layer, plus
    // the neighbor-mean buffer each upper layer reduces into
    let mut wl = w;
    for i in 1..depth {
        intermediates += 2 * b * wl * h * F32; // h_i + dpre_i
        wl /= 1 + dims.fanouts.k(depth - 1 - i) as u64;
        intermediates += b * wl * h * F32;     // layer-(i+1) neigh mean
    }
    let outputs = 3 * params + F32;     // new params+m+v, loss
    Transient { upload, intermediates, outputs, vmem_tile: 0 }
}

/// Analytic transient model for the fused L-hop step.
pub fn fused_transient(dims: &StepDims, save_indices: bool) -> Transient {
    let (b, d, h, c) = (dims.batch as u64, dims.d as u64,
                        dims.hidden as u64, dims.classes as u64);
    let params = fsa_param_bytes(dims);
    let upload = 2 * b * I32            // seeds + labels
        + 8;                            // base_seed
    let indices = if save_indices {
        dims.fanouts
            .cumulative()
            .iter()
            .map(|&kp| b * kp as u64 * I32)
            .sum()
    } else {
        0
    };
    let intermediates = indices
        + b * d * F32                   // agg output of the fused op
        + b * d * F32                   // x_self gather
        + b * h * F32                   // head hidden
        + 2 * b * c * F32               // logits + glogits
        + b * h * F32                   // ghead
        + params                        // grads
        + 2 * params;                   // adam temps
    let outputs = 3 * params + F32;
    // the gathered feature tile never touches HBM: seed-tile × Πk × D
    let vmem_tile = (dims.tile.max(1) as u64)
        * dims.fanouts.leaf_count() as u64 * d * F32;
    Transient { upload, intermediates, outputs, vmem_tile }
}

/// Runtime meter: accumulates *measured* buffer bytes as the coordinator
/// creates/receives literals, tracking the per-step high-water mark.
#[derive(Debug, Default)]
pub struct MemoryMeter {
    current: u64,
    peak: u64,
}

impl MemoryMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an allocation of `bytes` live within the current step.
    pub fn alloc(&mut self, bytes: u64) {
        self.current += bytes;
        self.peak = self.peak.max(self.current);
    }

    /// Record that `bytes` became dead (freed / dropped).
    pub fn free(&mut self, bytes: u64) {
        self.current = self.current.saturating_sub(bytes);
    }

    /// Step boundary: everything transient is dropped.
    pub fn reset_step(&mut self) {
        self.current = 0;
    }

    /// High-water mark since construction (or [`Self::reset_peak`]).
    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn reset_peak(&mut self) {
        self.peak = self.current;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(batch: usize, ks: &[usize], tile: usize) -> StepDims {
        StepDims { batch, fanouts: Fanouts::of(ks), d: 64, hidden: 64,
                   classes: 47, tile }
    }

    #[test]
    fn baseline_dominated_by_block() {
        let t = baseline_transient(&dims(1024, &[15, 10], 0));
        // block = 1024*16*10*64*4 ≈ 41.9 MB must dominate
        let block = 1024u64 * 16 * 10 * 64 * 4;
        assert!(t.intermediates > block);
        assert!(t.peak_hbm() > block);
        assert!(t.peak_hbm() < 3 * block, "model blew up: {}", t.peak_hbm());
    }

    #[test]
    fn fused_is_orders_of_magnitude_smaller() {
        let d = dims(1024, &[15, 10], 64);
        let base = baseline_transient(&d).peak_hbm();
        let fsa = fused_transient(&d, true).peak_hbm();
        let ratio = base as f64 / fsa as f64;
        assert!(ratio > 5.0, "expected large reduction, got {ratio:.2}x");
    }

    /// The baseline's block term multiplies with depth while the fused
    /// path only adds saved-index rows, so the analytic reduction ratio
    /// grows with depth at a matched leaf budget.
    #[test]
    fn reduction_ratio_grows_with_depth() {
        // matched leaf budget: 150 leaves per seed at depths 1/2/3
        let ratio = |ks: &[usize]| {
            let d = dims(1024, ks, 64);
            baseline_transient(&d).peak_hbm() as f64
                / fused_transient(&d, true).peak_hbm() as f64
        };
        let (r1, r2, r3) =
            (ratio(&[150]), ratio(&[15, 10]), ratio(&[15, 5, 2]));
        assert!(r1 > 1.0, "depth 1 ratio {r1:.2}");
        assert!(r2 > r1, "depth 2 ratio {r2:.2} <= depth 1 {r1:.2}");
        assert!(r3 > r2, "depth 3 ratio {r3:.2} <= depth 2 {r2:.2}");
    }

    #[test]
    fn fanout_grows_baseline_not_fused_output() {
        let small = baseline_transient(&dims(1024, &[10, 10], 0)).peak_hbm();
        let large = baseline_transient(&dims(1024, &[25, 10], 0)).peak_hbm();
        assert!(large as f64 > small as f64 * 1.8);
        let fs = fused_transient(&dims(1024, &[10, 10], 64), true).peak_hbm();
        let fl = fused_transient(&dims(1024, &[25, 10], 64), true).peak_hbm();
        // fused grows only by the saved-index tensors
        assert!((fl as f64) < (fs as f64) * 1.6);
    }

    #[test]
    fn save_indices_off_shrinks_fused() {
        let d = dims(1024, &[15, 10], 64);
        assert!(fused_transient(&d, false).peak_hbm()
            < fused_transient(&d, true).peak_hbm());
    }

    #[test]
    fn vmem_tile_respects_tile_size() {
        let t = fused_transient(&dims(1024, &[15, 10], 64), true);
        assert_eq!(t.vmem_tile, 64 * 15 * 10 * 64 * 4);
        let t1 = fused_transient(&dims(1024, &[10], 128), true);
        assert_eq!(t1.vmem_tile, 128 * 10 * 64 * 4);
        let t3 = fused_transient(&dims(1024, &[15, 10, 5], 8), true);
        assert_eq!(t3.vmem_tile, 8 * 15 * 10 * 5 * 64 * 4);
    }

    #[test]
    fn meter_tracks_high_water() {
        let mut m = MemoryMeter::new();
        m.alloc(100);
        m.alloc(50);
        m.free(100);
        m.alloc(30);
        assert_eq!(m.peak(), 150);
        m.reset_step();
        m.alloc(10);
        assert_eq!(m.peak(), 150, "peak persists across steps");
        m.reset_peak();
        assert_eq!(m.peak(), 10);
    }

    #[test]
    fn meter_monotone_peak_property() {
        use crate::rng::SplitMix64;
        let mut r = SplitMix64::new(9);
        let mut m = MemoryMeter::new();
        let mut last_peak = 0;
        for _ in 0..1000 {
            if r.next_below(2) == 0 {
                m.alloc(r.next_below(1000));
            } else {
                m.free(r.next_below(1000));
            }
            assert!(m.peak() >= last_peak, "peak decreased");
            last_peak = m.peak();
        }
    }
}
