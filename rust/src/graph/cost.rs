//! Expected-subtree cost model for the shard planner.
//!
//! The fused kernel shards its seed batch with a per-seed *cost*; until
//! this module the cost assumed every hop-0 draw expands to the full
//! nominal fanout below it (`nominal_subtree_weight`). On hub-heavy
//! power-law graphs that assumption is exactly wrong where it matters
//! most: a hub seed whose neighbors are degree-1 leaves does a fraction
//! of the row-adds the nominal model charges it for, while a mid-degree
//! seed sitting in a dense core does far more than its share — so shard
//! balance degrades with depth (ROADMAP "Depth-aware shard planner
//! tuning"; SALIENT, arXiv 2110.08450, makes the same observation about
//! sampler load balance dominating once aggregation is fused).
//!
//! [`CostModel`] replaces the nominal weight with *expected* row-adds,
//! folded innermost-first exactly like `fused_khop` folds its
//! accumulators:
//!
//! ```text
//! sub(L)      = 1                                 (a leaf draw = 1 row-add)
//! sub(l)      = 1 + ebar(k_{l+1}) · sub(l+1)      (global, hops 2..L)
//! cost(seed)  = 1 + min(deg(seed), k1)
//!                 · (1 + emin(seed, k2) · sub(2)) (per-node, hops 0..1)
//! ```
//!
//! where `ebar(k) = E[min(deg(child), k)]` over the graph's *edge-weighted*
//! child-degree distribution and `emin(u, k)` is the same expectation
//! restricted to `u`'s own neighbor list. Both come from a
//! [`DegreeSummary`]: a compact degree-quantile sketch (Q global buckets
//! of the child-degree distribution plus a per-node neighbor histogram
//! over those buckets) built once per graph and cached on the
//! [`Csr`] (`Csr::degree_summary`, the `Runtime::graph_bufs` reuse
//! pattern) — so planning stays O(frontier · Q) = O(frontier).
//!
//! Three planner flavors ([`PlannerChoice`], the `--planner` CLI knob):
//!
//! * `nominal`  — bit-for-bit the pre-cost-model *cost arithmetic*
//!   (full-fanout subtree weights); cut positions may still differ from
//!   the pre-PR planner because [`plan_shards`] itself now rounds cuts
//!   to the nearest prefix;
//! * `quantile` — the expected-subtree costs above (default);
//! * `adaptive` — quantile costs plus measured-throughput feedback: the
//!   engine records per-shard wall time into [`ShardStats`] and
//!   [`CostModel::observe`] folds an EWMA of each worker's cost/ms into
//!   weighted cut targets for the next step's plan.
//!
//! **Determinism**: the planner only decides *where* contiguous shard
//! cuts land, never *what* is computed — every worker still writes a
//! disjoint slice and the counter RNG is order-independent — so sampler
//! and kernel outputs are bitwise identical under every planner choice
//! and thread count (pinned by `rust/tests/planner.rs`).

use std::ops::Range;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, Result};

use super::shard::{plan_shards, plan_shards_weighted, resize_weights,
                   sample_cost};
use super::Csr;
use crate::fanout::Fanouts;
use crate::runtime::faults::{self, FaultPlane};

/// Which cost model the shard planner runs on (`--planner`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlannerChoice {
    /// Full-nominal-fanout subtree weights (the legacy cost arithmetic,
    /// reproduced bit-for-bit).
    Nominal,
    /// Degree-quantile expected-subtree costs (default).
    #[default]
    Quantile,
    /// Quantile costs + measured per-shard throughput feedback.
    Adaptive,
}

impl PlannerChoice {
    pub fn parse(s: &str) -> Result<PlannerChoice> {
        Ok(match s {
            "nominal" => PlannerChoice::Nominal,
            "quantile" => PlannerChoice::Quantile,
            "adaptive" => PlannerChoice::Adaptive,
            other => {
                bail!("--planner must be nominal|quantile|adaptive, \
                       got {other:?}")
            }
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            PlannerChoice::Nominal => "nominal",
            PlannerChoice::Quantile => "quantile",
            PlannerChoice::Adaptive => "adaptive",
        }
    }
}

/// Cost-model weight of the subtree hanging off one hop-0 draw under the
/// *nominal* full-fanout assumption: `1 + k2·(1 + k3·(…))` row-adds per
/// sampled hop-0 neighbor. Depth-0 / depth-1 fanout lists have no hops
/// below hop 0, so the weight degenerates to 1 (one row-add per draw) —
/// the explicit guard the old `kernel::fused::subtree_weight` lacked (it
/// indexed `ks[1..]` unconditionally and panicked on an empty list).
pub fn nominal_subtree_weight(ks: &[usize]) -> u64 {
    ks.get(1..)
        .unwrap_or(&[])
        .iter()
        .rev()
        .fold(1u64, |w, &k| 1 + k as u64 * w)
}

// ---------------------------------------------------------------------------
// ShardClock — the injectable timing seam
// ---------------------------------------------------------------------------

/// How a sharded pass times its workers. Production uses [`WallClock`]
/// (the measured elapsed time, verbatim); tests use [`VirtualClock`] to
/// script deterministic per-worker slowdowns so the adaptive feedback
/// loop can be proven to converge without any wall-clock dependence
/// (`rust/tests/adaptive.rs`). The clock only shapes the *timing signal*
/// — plans decide where cuts land, never what is computed, so outputs
/// stay bitwise identical under every clock.
pub trait ShardClock: std::fmt::Debug + Send + Sync {
    /// Reported wall time of one shard: `worker` is the shard index,
    /// `cost` the shard's planned cost, `elapsed_ms` the measured
    /// elapsed wall clock of the worker's body.
    fn shard_ms(&self, worker: usize, cost: u64, elapsed_ms: f64) -> f64;
}

/// The production clock: report the measured elapsed time unchanged.
#[derive(Clone, Copy, Debug, Default)]
pub struct WallClock;

impl ShardClock for WallClock {
    fn shard_ms(&self, _worker: usize, _cost: u64, elapsed_ms: f64) -> f64 {
        elapsed_ms
    }
}

/// Deterministic test clock: shard time = planned cost × the worker's
/// scripted ms-per-cost-unit (workers past the script run at 1.0). The
/// real elapsed time is ignored entirely, so every simulated trajectory
/// is exactly reproducible.
#[derive(Clone, Debug)]
pub struct VirtualClock {
    ms_per_unit: Vec<f64>,
}

impl VirtualClock {
    pub fn new(ms_per_unit: Vec<f64>) -> VirtualClock {
        VirtualClock { ms_per_unit }
    }

    /// A clock where worker `slow` runs `factor`× slower than the other
    /// `parts - 1` workers (the canonical straggler scenario).
    pub fn with_slow_worker(parts: usize, slow: usize,
                            factor: f64) -> VirtualClock {
        let mut ms = vec![1.0; parts];
        if slow < parts {
            ms[slow] = factor;
        }
        VirtualClock::new(ms)
    }
}

impl ShardClock for VirtualClock {
    fn shard_ms(&self, worker: usize, cost: u64, _elapsed_ms: f64) -> f64 {
        cost as f64 * self.ms_per_unit.get(worker).copied().unwrap_or(1.0)
    }
}

// ---------------------------------------------------------------------------
// DegreeSummary — the per-dataset degree-quantile sketch
// ---------------------------------------------------------------------------

/// Number of degree-quantile buckets. Small on purpose: per-row planning
/// work is O(Q), and power-law degree distributions are summarized well
/// by a handful of log-spaced mass quantiles.
pub const SUMMARY_BUCKETS: usize = 8;

/// Compact degree-quantile summary of a graph: Q buckets of the
/// edge-weighted child-degree distribution (a sampled neighbor is a
/// uniform draw from its parent's list, so across parents a child of
/// degree d appears with weight proportional to d), plus a per-node
/// histogram of each node's own neighbors over those buckets.
#[derive(Debug)]
pub struct DegreeSummary {
    /// Inclusive upper degree bound of each bucket (ascending).
    upper: Vec<i32>,
    /// Edge-weight share of each bucket (sums to 1 when edges exist).
    frac: Vec<f64>,
    /// Weighted mean child degree of each bucket.
    mean: Vec<f64>,
    /// `[n, Q]` per-node neighbor counts per bucket.
    hist: Vec<u32>,
}

impl DegreeSummary {
    /// Build the sketch: O(E log Q) once per graph (cache it via
    /// [`Csr::degree_summary`]).
    pub fn build(csr: &Csr) -> DegreeSummary {
        let n = csr.n;
        let q = SUMMARY_BUCKETS;
        // weighted degree histogram: a degree-d node contributes weight d
        // (it is the endpoint of d edges)
        let mut by_degree: Vec<(i32, u64)> = Vec::new();
        {
            let mut degs: Vec<i32> =
                (0..n as i32).map(|u| csr.degree(u)).filter(|&d| d > 0).collect();
            degs.sort_unstable();
            for d in degs {
                match by_degree.last_mut() {
                    Some((dv, w)) if *dv == d => *w += d as u64,
                    _ => by_degree.push((d, d as u64)),
                }
            }
        }
        let total: u64 = by_degree.iter().map(|&(_, w)| w).sum();
        // bucket upper bounds at the cumulative-weight quantiles
        let mut upper = vec![0i32; q];
        let mut acc = 0u64;
        let mut vi = 0usize;
        for (b, up) in upper.iter_mut().enumerate().take(q - 1) {
            let target = total as u128 * (b as u128 + 1) / q as u128;
            while vi < by_degree.len() && (acc as u128) < target {
                acc += by_degree[vi].1;
                vi += 1;
            }
            *up = if vi > 0 { by_degree[vi - 1].0 } else { 0 };
        }
        upper[q - 1] = by_degree.last().map(|&(d, _)| d).unwrap_or(0);
        let bucket_of = |d: i32| -> usize {
            upper.partition_point(|&u| u < d).min(q - 1)
        };
        // per-bucket weight share and mean degree
        let mut wsum = vec![0.0f64; q];
        let mut dsum = vec![0.0f64; q];
        for &(d, w) in &by_degree {
            let b = bucket_of(d);
            wsum[b] += w as f64;
            dsum[b] += w as f64 * d as f64;
        }
        let frac: Vec<f64> = wsum
            .iter()
            .map(|&w| if total > 0 { w / total as f64 } else { 0.0 })
            .collect();
        let mean: Vec<f64> = wsum
            .iter()
            .zip(&dsum)
            .map(|(&w, &dw)| if w > 0.0 { dw / w } else { 0.0 })
            .collect();
        // per-node neighbor histogram over the buckets
        let mut hist = vec![0u32; n * q];
        for u in 0..n as i32 {
            let row = &mut hist[u as usize * q..(u as usize + 1) * q];
            for &v in csr.neighbors(u) {
                let dv = csr.degree(v);
                if dv > 0 {
                    row[bucket_of(dv)] += 1;
                }
            }
        }
        DegreeSummary { upper, frac, mean, hist }
    }

    /// Global `E[min(deg(child), k)]` over the edge-weighted child-degree
    /// distribution (the expected effective fanout of one draw at hops
    /// deep enough that per-node information has washed out).
    pub fn expected_child_min(&self, k: usize) -> f64 {
        self.frac
            .iter()
            .zip(&self.mean)
            .map(|(&f, &m)| f * m.min(k as f64))
            .sum()
    }

    /// `E[min(deg(child), k)]` restricted to `u`'s own neighbor list —
    /// the per-node term that separates a hub ringed by leaves from a
    /// node wired into a dense core. Falls back to the global expectation
    /// for isolated nodes.
    pub fn node_child_min(&self, u: usize, k: usize) -> f64 {
        let q = self.mean.len();
        let row = &self.hist[u * q..(u + 1) * q];
        let total: u32 = row.iter().sum();
        if total == 0 {
            return self.expected_child_min(k);
        }
        let kf = k as f64;
        row.iter()
            .zip(&self.mean)
            .map(|(&c, &m)| c as f64 * m.min(kf))
            .sum::<f64>()
            / total as f64
    }

    /// Bucket upper bounds (tests / diagnostics).
    pub fn bucket_uppers(&self) -> &[i32] {
        &self.upper
    }
}

// ---------------------------------------------------------------------------
// ShardStats — measured per-shard wall time (the feedback signal)
// ---------------------------------------------------------------------------

/// Per-shard wall time and planned cost of one sharded pass (one fused
/// kernel call, or one level of a parallel block build). Shard `j` is the
/// slice worker `j` executed; empty shards carry zeros.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStats {
    /// Measured wall-clock per shard, ms.
    pub shard_ms: Vec<f64>,
    /// Planned cost per shard (the planner's own units).
    pub shard_cost: Vec<u64>,
}

impl ShardStats {
    pub fn new(shard_ms: Vec<f64>, shard_cost: Vec<u64>) -> ShardStats {
        debug_assert_eq!(shard_ms.len(), shard_cost.len());
        ShardStats { shard_ms, shard_cost }
    }

    /// No sharded pass recorded (serial execution).
    pub fn is_empty(&self) -> bool {
        self.shard_ms.is_empty()
    }

    /// Slowest shard, ms (idle shards are 0).
    pub fn max_ms(&self) -> f64 {
        self.shard_ms.iter().fold(0.0, |m, &ms| m.max(ms))
    }

    /// Mean over *all planned* shards, ms — the per-worker time a
    /// perfectly balanced plan would have achieved. Idle (empty) shards
    /// count: a plan that leaves workers idle is an imbalanced plan.
    pub fn mean_ms(&self) -> f64 {
        let parts = self.shard_ms.len();
        if parts == 0 {
            return 0.0;
        }
        self.shard_ms.iter().sum::<f64>() / parts as f64
    }

    /// Measured imbalance ratio of this pass: slowest shard over the
    /// balanced ideal (`max / (total / parts)`, ≥ 1). 1.0 is a perfectly
    /// balanced pass; the serial (unsharded) case also reports 1.0 by
    /// convention. A plan that starves workers (empty shards) scores
    /// high, not low — exactly the planner failure the metric guards.
    pub fn imbalance(&self) -> f64 {
        let ideal = self.mean_ms();
        if ideal <= 0.0 || self.shard_ms.len() < 2 {
            return 1.0;
        }
        self.max_ms() / ideal
    }
}

/// Aggregate of several sharded passes (the levels of one block build,
/// or every step in a measurement window). Passes may plan different
/// worker counts, so per-shard vectors are *not* summed elementwise;
/// instead each pass contributes its critical path (`max_ms`) and its
/// balanced ideal (`mean_ms`), and the aggregate imbalance is
/// `Σ critical / Σ ideal` — the measured wall clock of the sharded work
/// over what perfect balance would have cost.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ImbalanceAcc {
    crit_ms: f64,
    ideal_ms: f64,
    passes: usize,
}

impl ImbalanceAcc {
    /// Fold one sharded pass in.
    pub fn add(&mut self, stats: &ShardStats) {
        if stats.is_empty() {
            return;
        }
        self.add_pass(stats.max_ms(), stats.mean_ms());
    }

    /// Fold one pass given its critical-path and balanced-ideal ms (for
    /// callers that never materialize a [`ShardStats`]).
    pub fn add_pass(&mut self, crit_ms: f64, ideal_ms: f64) {
        self.crit_ms += crit_ms;
        self.ideal_ms += ideal_ms;
        self.passes += 1;
    }

    /// No pass recorded yet.
    pub fn is_empty(&self) -> bool {
        self.passes == 0
    }

    /// `Σ critical / Σ ideal` over the recorded passes (1.0 when nothing
    /// was recorded or the timers were below resolution).
    pub fn imbalance(&self) -> f64 {
        if self.ideal_ms <= 0.0 {
            return 1.0;
        }
        self.crit_ms / self.ideal_ms
    }
}

// ---------------------------------------------------------------------------
// CostModel — the planner
// ---------------------------------------------------------------------------

/// Fixed-point scale for expected (fractional) costs; plans only care
/// about relative weight, so 1/16-row-add resolution is plenty.
pub const COST_SCALE: u64 = 16;

/// EWMA factor for the adaptive planner's per-worker throughput blend.
const FEEDBACK_ALPHA: f64 = 0.3;
/// Clamp on a worker's relative speed weight (keeps one noisy
/// measurement from starving a worker).
const FEEDBACK_CLAMP: (f64, f64) = (0.25, 4.0);

/// One planner model shared across the session's planning sites — the
/// fused kernel's batch sharding and the parallel sampler's per-level
/// frontier sharding (including the prefetch thread's) all plan and
/// [`CostModel::observe`] through the same weights, so every measured
/// shard feeds the same adaptive feedback loop.
pub type SharedCostModel = Arc<Mutex<CostModel>>;

/// Lock a [`SharedCostModel`], recovering from poisoning (a panicked
/// worker must not also wedge the planner — stale weights are safe, the
/// plan never changes computed values).
pub fn lock_model(model: &SharedCostModel) -> MutexGuard<'_, CostModel> {
    model.lock().unwrap_or_else(|e| e.into_inner())
}

/// The session-shared planner model for one `(graph, fanouts, flavor)`
/// configuration — `Some` only for the adaptive flavor, which is the
/// one with cross-step state worth sharing (and persisting); the other
/// flavors keep site-local planning. The single home of this rule:
/// trainer and throughput mode both build their session model here.
pub fn shared_session_model(csr: &Csr, fanouts: &Fanouts,
                            choice: PlannerChoice)
                            -> Option<SharedCostModel> {
    (choice == PlannerChoice::Adaptive).then(|| {
        Arc::new(Mutex::new(CostModel::new(csr, fanouts, choice)))
    })
}

/// A planner for one `(graph, fanouts)` configuration: turns frontier
/// rows into costs and costs into contiguous shard plans. Cheap to build
/// (the degree summary is cached on the [`Csr`]); hold one per training
/// session so the adaptive flavor can accumulate feedback.
#[derive(Clone, Debug)]
pub struct CostModel {
    choice: PlannerChoice,
    ks: Vec<usize>,
    /// Nominal integer subtree weight below one hop-0 draw.
    wb_nominal: u64,
    /// Degree sketch (quantile/adaptive only).
    summary: Option<Arc<DegreeSummary>>,
    /// Expected subtree rooted at a hop-1 draw (`sub(2)` in the module
    /// docs; 1.0 at depth ≤ 2).
    sub2: f64,
    /// Adaptive: per-worker relative speed (empty = uniform).
    weights: Vec<f64>,
    /// Sharded passes folded into the weights so far (this session plus
    /// any warm-started history).
    steps_observed: u64,
    /// Timing seam for every sharded pass planned through this model.
    clock: Arc<dyn ShardClock>,
    /// Fault seam for every sharded pass planned through this model
    /// (prod: the zero-cost no-op plane).
    faults: Arc<dyn FaultPlane>,
}

impl CostModel {
    pub fn new(csr: &Csr, fanouts: &Fanouts,
               choice: PlannerChoice) -> CostModel {
        let ks = fanouts.as_slice().to_vec();
        let wb_nominal = nominal_subtree_weight(&ks);
        let (summary, sub2) = match choice {
            PlannerChoice::Nominal => (None, 1.0),
            _ => {
                let s = csr.degree_summary();
                // fold expected effective fanouts innermost-first:
                // sub(L) = 1; sub(l) = 1 + ebar(k_l) * sub(l+1), down to
                // sub(2) — hops 0 and 1 use per-node terms instead.
                let mut sub = 1.0f64;
                for &k in ks.iter().skip(2).rev() {
                    sub = 1.0 + s.expected_child_min(k) * sub;
                }
                (Some(s), sub)
            }
        };
        CostModel {
            choice,
            ks,
            wb_nominal,
            summary,
            sub2,
            weights: Vec::new(),
            steps_observed: 0,
            clock: Arc::new(WallClock),
            faults: faults::none(),
        }
    }

    pub fn choice(&self) -> PlannerChoice {
        self.choice
    }

    /// Replace the timing seam (tests script a [`VirtualClock`] here;
    /// production keeps the default [`WallClock`]).
    pub fn with_clock(mut self, clock: Arc<dyn ShardClock>) -> CostModel {
        self.clock = clock;
        self
    }

    /// The timing seam every sharded pass planned by this model must
    /// route its per-shard measurements through.
    pub fn clock(&self) -> Arc<dyn ShardClock> {
        self.clock.clone()
    }

    /// Replace the fault seam (chaos runs and the fault-tolerance tests;
    /// production keeps the default no-op plane).
    pub fn with_faults(mut self, faults: Arc<dyn FaultPlane>) -> CostModel {
        self.faults = faults;
        self
    }

    /// Install the fault seam in place (the engine wires a `--chaos`
    /// plane into an already-shared model this way).
    pub fn set_faults(&mut self, faults: Arc<dyn FaultPlane>) {
        self.faults = faults;
    }

    /// The fault seam every sharded pass planned by this model consults.
    pub fn faults(&self) -> Arc<dyn FaultPlane> {
        self.faults.clone()
    }

    /// Sharded passes folded into the adaptive weights so far.
    pub fn steps_observed(&self) -> u64 {
        self.steps_observed
    }

    /// Planner cost of the full sampling subtree under one seed row.
    /// Nominal reproduces the legacy arithmetic bit-for-bit; quantile /
    /// adaptive charge expected row-adds (fixed-point, ×[`COST_SCALE`]).
    /// Guarded for every depth ≥ 1 and for invalid / isolated rows.
    pub fn seed_cost(&self, csr: &Csr, node: i32) -> u64 {
        let k0 = self.ks.first().copied().unwrap_or(0);
        match (self.choice, &self.summary) {
            (PlannerChoice::Nominal, _) | (_, None) => {
                1 + (sample_cost(csr, node, k0) - 1) * self.wb_nominal
            }
            (_, Some(s)) => {
                if node < 0 || node as usize >= csr.n {
                    return COST_SCALE;
                }
                let deg = csr.degree(node);
                if deg == 0 {
                    return COST_SCALE;
                }
                let m0 = (deg as usize).min(k0) as f64;
                let c = if self.ks.len() == 1 {
                    1.0 + m0
                } else {
                    let e1 = s.node_child_min(node as usize, self.ks[1]);
                    1.0 + m0 * (1.0 + e1 * self.sub2)
                };
                ((c * COST_SCALE as f64).round() as u64).max(1)
            }
        }
    }

    /// Planner cost of sampling one frontier row at hop `hop` (the
    /// parallel block sampler's per-level unit). At this granularity the
    /// degree-aware cost is already *exact* — a row's work is its own
    /// `1 + min(deg, k)` draws, with no subtree below it in the same
    /// tensor — so every flavor shares it; the flavors differ in the cut
    /// targets ([`CostModel::plan`]).
    pub fn frontier_cost(&self, csr: &Csr, node: i32, hop: usize) -> u64 {
        let k = self.ks.get(hop).copied().unwrap_or(0);
        sample_cost(csr, node, k)
    }

    /// Cut `costs` into at most `parts` contiguous shards. Adaptive
    /// applies the measured per-worker speed weights (resized on the fly
    /// when this plan's worker count differs from the observed one — a
    /// warm-started session must not wait for its first observation);
    /// the others use plain cost quantiles.
    pub fn plan(&self, costs: &[u64], parts: usize) -> Vec<Range<usize>> {
        if self.choice == PlannerChoice::Adaptive && !self.weights.is_empty()
        {
            if self.weights.len() == parts {
                return plan_shards_weighted(costs, parts, &self.weights);
            }
            let w = resize_weights(&self.weights, parts);
            return plan_shards_weighted(costs, parts, &w);
        }
        plan_shards(costs, parts)
    }

    /// Fold one step's measured per-shard throughput into the adaptive
    /// weights (no-op for the other flavors). Shard `j` feeds worker
    /// `j`'s EWMA of cost-units per ms; weights are normalized to mean 1
    /// and clamped so the next plan's cut targets shift toward the
    /// faster workers. A changed shard count resizes the learned weights
    /// (truncate / pad + renormalize) instead of resetting them, and a
    /// single live shard still adapts (its worker decays toward the
    /// uniform weight; starved workers keep their history).
    pub fn observe(&mut self, stats: &ShardStats) {
        if self.choice != PlannerChoice::Adaptive || stats.is_empty() {
            return;
        }
        let parts = stats.shard_ms.len().min(stats.shard_cost.len());
        if self.weights.len() != parts {
            self.weights = resize_weights(&self.weights, parts);
        }
        // per-shard throughput, normalized to this step's mean
        let mut tp = vec![0.0f64; parts];
        let (mut sum, mut cnt) = (0.0f64, 0usize);
        for j in 0..parts {
            if stats.shard_cost[j] > 0 && stats.shard_ms[j] > 0.0 {
                tp[j] = stats.shard_cost[j] as f64 / stats.shard_ms[j];
                sum += tp[j];
                cnt += 1;
            }
        }
        if cnt == 0 {
            return;
        }
        self.steps_observed += 1;
        let mean_tp = sum / cnt as f64;
        for j in 0..parts {
            if tp[j] > 0.0 {
                let rel = tp[j] / mean_tp;
                let w = (1.0 - FEEDBACK_ALPHA) * self.weights[j]
                    + FEEDBACK_ALPHA * rel;
                self.weights[j] = w.clamp(FEEDBACK_CLAMP.0, FEEDBACK_CLAMP.1);
            }
        }
    }

    /// Seed the adaptive weights from a persisted session (the
    /// planner-state warm start). Non-adaptive flavors and invalid
    /// weight vectors (empty, non-finite, non-positive) are rejected —
    /// the model stays uniform and returns `false` instead of erroring.
    /// Accepted weights are renormalized to mean 1 and clamped exactly
    /// like observed ones.
    pub fn warm_start(&mut self, weights: &[f64], steps: u64) -> bool {
        if self.choice != PlannerChoice::Adaptive
            || weights.is_empty()
            || weights.iter().any(|w| !w.is_finite() || *w <= 0.0)
        {
            return false;
        }
        self.weights = resize_weights(weights, weights.len())
            .iter()
            .map(|w| w.clamp(FEEDBACK_CLAMP.0, FEEDBACK_CLAMP.1))
            .collect();
        self.steps_observed = steps;
        true
    }

    /// Current adaptive per-worker weights (diagnostics / tests).
    pub fn worker_weights(&self) -> &[f64] {
        &self.weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};

    fn tiny_graph() -> Csr {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap().graph
    }

    #[test]
    fn planner_choice_parses_and_round_trips() {
        for s in ["nominal", "quantile", "adaptive"] {
            assert_eq!(PlannerChoice::parse(s).unwrap().as_str(), s);
        }
        assert!(PlannerChoice::parse("bogus").is_err());
        assert_eq!(PlannerChoice::default(), PlannerChoice::Quantile);
    }

    #[test]
    fn nominal_weight_guards_short_fanouts() {
        // the old kernel helper panicked on these; the guard returns the
        // degenerate one-row-add-per-draw weight instead
        assert_eq!(nominal_subtree_weight(&[]), 1);
        assert_eq!(nominal_subtree_weight(&[7]), 1);
        assert_eq!(nominal_subtree_weight(&[5, 3]), 4);
        assert_eq!(nominal_subtree_weight(&[5, 3, 2]), 10); // 1 + 3*(1+2)
    }

    #[test]
    fn summary_fractions_and_expectations_are_sane() {
        let csr = tiny_graph();
        let s = DegreeSummary::build(&csr);
        let total: f64 = s.frac.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "bucket mass {total}");
        // E[min(deg, k)] is monotone in k and bounded by the mean degree
        let mut last = 0.0;
        for k in [1usize, 2, 4, 8, 1024] {
            let e = s.expected_child_min(k);
            assert!(e >= last - 1e-12, "not monotone at k={k}");
            assert!(e <= k as f64 + 1e-12);
            last = e;
        }
        // with a huge k the min never binds: expectation = edge-weighted
        // mean degree ≥ plain mean degree
        let mean_deg = csr.num_edges() as f64 / csr.n as f64;
        assert!(s.expected_child_min(1 << 20) >= mean_deg * 0.99);
        // per-node expectations stay within the same bounds
        for u in 0..csr.n {
            let e = s.node_child_min(u, 4);
            assert!((0.0..=4.0).contains(&e), "node {u}: {e}");
        }
    }

    #[test]
    fn nominal_costs_reproduce_legacy_arithmetic() {
        let csr = tiny_graph();
        let fo = Fanouts::of(&[5, 3, 2]);
        let m = CostModel::new(&csr, &fo, PlannerChoice::Nominal);
        let wb = nominal_subtree_weight(fo.as_slice());
        for u in [-1i32, 0, 7, 100, 511] {
            assert_eq!(m.seed_cost(&csr, u),
                       1 + (sample_cost(&csr, u, 5) - 1) * wb);
        }
    }

    #[test]
    fn quantile_costs_are_positive_and_depth_aware() {
        let csr = tiny_graph();
        let shallow = CostModel::new(&csr, &Fanouts::of(&[5]),
                                     PlannerChoice::Quantile);
        let deep = CostModel::new(&csr, &Fanouts::of(&[5, 3, 2]),
                                  PlannerChoice::Quantile);
        assert_eq!(shallow.seed_cost(&csr, -1), COST_SCALE);
        for u in 0..csr.n as i32 {
            let cs = shallow.seed_cost(&csr, u);
            let cd = deep.seed_cost(&csr, u);
            assert!(cs >= 1 && cd >= cs,
                    "node {u}: depth-1 {cs} vs depth-3 {cd}");
        }
    }

    #[test]
    fn shard_stats_imbalance_counts_idle_workers() {
        let s = ShardStats::default();
        assert!(s.is_empty());
        assert_eq!(s.imbalance(), 1.0);
        let balanced = ShardStats::new(vec![2.0, 2.0], vec![10, 10]);
        assert!((balanced.imbalance() - 1.0).abs() < 1e-12);
        // a plan that leaves half the workers idle is 2x off ideal even
        // though the live shards match each other exactly
        let idle = ShardStats::new(vec![3.0, 3.0, 0.0, 0.0],
                                   vec![10, 10, 0, 0]);
        assert_eq!(idle.max_ms(), 3.0);
        assert!((idle.mean_ms() - 1.5).abs() < 1e-12);
        assert!((idle.imbalance() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_acc_aggregates_passes_of_different_widths() {
        let mut acc = ImbalanceAcc::default();
        assert!(acc.is_empty());
        assert_eq!(acc.imbalance(), 1.0);
        // two perfectly balanced passes with different worker counts
        // must aggregate to 1.0 (no phantom imbalance from widths)
        acc.add(&ShardStats::new(vec![4.0, 4.0], vec![5, 5]));
        acc.add(&ShardStats::new(vec![1.0; 8], vec![2; 8]));
        assert!((acc.imbalance() - 1.0).abs() < 1e-12, "{acc:?}");
        // a pass using 1 of 4 workers drags the aggregate up:
        // crit += 4, ideal += 1
        acc.add(&ShardStats::new(vec![4.0, 0.0, 0.0, 0.0], vec![9, 0, 0, 0]));
        // totals: crit = 4 + 1 + 4 = 9, ideal = 4 + 1 + 1 = 6
        assert!((acc.imbalance() - 1.5).abs() < 1e-12, "{acc:?}");
        assert!(!acc.is_empty());
        acc.add(&ShardStats::default()); // empty pass is a no-op
        assert!((acc.imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn adaptive_feedback_moves_weights_toward_fast_workers() {
        let csr = tiny_graph();
        let fo = Fanouts::of(&[5, 3]);
        let mut m = CostModel::new(&csr, &fo, PlannerChoice::Adaptive);
        // worker 0 is twice as fast (same cost in half the time)
        for _ in 0..20 {
            m.observe(&ShardStats::new(vec![1.0, 2.0], vec![100, 100]));
        }
        let w = m.worker_weights();
        assert_eq!(w.len(), 2);
        assert!(w[0] > 1.2 && w[1] < 0.9, "weights {w:?}");
        // weighted plan hands worker 0 the bigger contiguous range
        let costs = vec![1u64; 100];
        let plan = m.plan(&costs, 2);
        assert_eq!(plan.len(), 2);
        assert!(plan[0].len() > 55, "plan {plan:?}");
        assert_eq!(plan[0].end, plan[1].start);
        assert_eq!(plan[1].end, 100);
        // non-adaptive flavors ignore feedback entirely
        let mut q = CostModel::new(&csr, &fo, PlannerChoice::Quantile);
        q.observe(&ShardStats::new(vec![1.0, 2.0], vec![100, 100]));
        assert!(q.worker_weights().is_empty());
        assert_eq!(q.steps_observed(), 0);
    }

    #[test]
    fn observe_resizes_instead_of_resetting_on_shard_count_change() {
        let csr = tiny_graph();
        let mut m = CostModel::new(&csr, &Fanouts::of(&[5, 3]),
                                   PlannerChoice::Adaptive);
        // learn a 4-worker skew: worker 0 is 2x fast
        for _ in 0..20 {
            m.observe(&ShardStats::new(vec![0.5, 1.0, 1.0, 1.0],
                                       vec![100, 100, 100, 100]));
        }
        let before = m.worker_weights().to_vec();
        assert!(before[0] > 1.2, "setup failed: {before:?}");
        // a 2-worker pass must inherit the learned skew, not reset it
        m.observe(&ShardStats::new(vec![0.5, 1.0], vec![100, 100]));
        let after = m.worker_weights();
        assert_eq!(after.len(), 2);
        assert!(after[0] > 1.1 && after[0] > after[1],
                "skew lost on resize: {before:?} -> {after:?}");
        // growing back pads with uniform workers, keeping worker 0 fast
        m.observe(&ShardStats::new(vec![0.5, 1.0, 1.0], vec![50, 50, 50]));
        let grown = m.worker_weights();
        assert_eq!(grown.len(), 3);
        assert!(grown[0] > grown[1] && grown[0] > grown[2], "{grown:?}");
    }

    #[test]
    fn observe_adapts_with_a_single_live_shard() {
        let csr = tiny_graph();
        let mut m = CostModel::new(&csr, &Fanouts::of(&[5, 3]),
                                   PlannerChoice::Adaptive);
        // skew worker 0 fast, then feed a pass where worker 1 starved
        for _ in 0..10 {
            m.observe(&ShardStats::new(vec![0.5, 1.0], vec![100, 100]));
        }
        let w0 = m.worker_weights()[0];
        let w1 = m.worker_weights()[1];
        let steps = m.steps_observed();
        assert!(w0 > 1.2, "{w0}");
        m.observe(&ShardStats::new(vec![3.0, 0.0], vec![200, 0]));
        // the lone live worker decays toward uniform; the starved
        // worker's history is untouched; the step still counts
        let w = m.worker_weights();
        assert!(w[0] < w0, "lone-shard pass did not adapt: {w0} -> {}", w[0]);
        assert_eq!(w[1], w1, "starved worker's history was touched");
        assert_eq!(m.steps_observed(), steps + 1);
        // a pass with no live shard at all is still a no-op
        m.observe(&ShardStats::new(vec![0.0, 0.0], vec![0, 0]));
        assert_eq!(m.steps_observed(), steps + 1);
    }

    #[test]
    fn warm_start_seeds_weights_and_rejects_garbage() {
        let csr = tiny_graph();
        let fo = Fanouts::of(&[5, 3]);
        let mut m = CostModel::new(&csr, &fo, PlannerChoice::Adaptive);
        assert!(m.warm_start(&[1.6, 0.4], 12));
        assert_eq!(m.steps_observed(), 12);
        let w = m.worker_weights();
        assert!((w[0] - 1.6).abs() < 1e-12 && (w[1] - 0.4).abs() < 1e-12);
        // a warm-started model plans weighted immediately, even at a
        // different worker count (resize on the fly)
        let costs = vec![1u64; 120];
        let plan = m.plan(&costs, 4);
        assert_eq!(plan.len(), 4);
        assert!(plan[0].len() > 30, "warm weights ignored: {plan:?}");
        // invalid inputs are rejected without touching the model
        let before = m.worker_weights().to_vec();
        for bad in [&[][..], &[0.0, 1.0][..], &[f64::NAN, 1.0][..],
                    &[-1.0, 1.0][..]] {
            assert!(!m.warm_start(bad, 99), "{bad:?} accepted");
        }
        assert_eq!(m.worker_weights(), &before[..]);
        // non-adaptive flavors refuse warm starts entirely
        let mut q = CostModel::new(&csr, &fo, PlannerChoice::Quantile);
        assert!(!q.warm_start(&[2.0, 0.5], 5));
        assert!(q.worker_weights().is_empty());
    }

    #[test]
    fn clocks_report_wall_vs_scripted_time() {
        let wall = WallClock;
        assert_eq!(wall.shard_ms(3, 999, 1.25), 1.25);
        let v = VirtualClock::with_slow_worker(4, 0, 2.0);
        // cost × ms-per-unit, real elapsed ignored; workers past the
        // script run at 1.0
        assert_eq!(v.shard_ms(0, 10, 123.0), 20.0);
        assert_eq!(v.shard_ms(1, 10, 123.0), 10.0);
        assert_eq!(v.shard_ms(9, 10, 123.0), 10.0);
        // the seam rides on the model
        let csr = tiny_graph();
        let m = CostModel::new(&csr, &Fanouts::of(&[5]),
                               PlannerChoice::Adaptive)
            .with_clock(Arc::new(VirtualClock::new(vec![3.0])));
        assert_eq!(m.clock().shard_ms(0, 7, 0.0), 21.0);
    }
}
