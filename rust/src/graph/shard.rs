//! Degree-aware shard planner for the parallel host sampler.
//!
//! The frontier sampler partitions its row range across worker threads.
//! A naive even split load-balances badly on the hub-heavy / power-law
//! graphs the paper targets: one worker inherits the hubs and the rest
//! idle. This planner weighs each frontier row by its *sampling cost* —
//! `1 + min(degree, k)` (`k` hash draws when `deg > k`, a `deg`-element
//! copy otherwise, plus a per-row constant) — and cuts the range at the
//! cost quantiles, so every shard carries roughly `total_cost / parts`.
//!
//! Shards are **contiguous, ordered, and exactly cover** the input range.
//! That invariant is what lets the parallel sampler hand each worker a
//! disjoint `&mut` slice of the output tensor and stay bitwise identical
//! to the serial sampler at any thread count (the counter RNG is
//! order-independent; only the write layout has to be preserved).

use std::ops::Range;

use super::Csr;

/// Host-sampling cost model for one frontier row (arbitrary units).
///
/// Invalid (`-1`) rows still pay the per-row constant; `deg <= k` rows pay
/// the take-all copy; `deg > k` rows pay `k` counter-hash draws.
pub fn sample_cost(csr: &Csr, node: i32, k: usize) -> u64 {
    if node < 0 || node as usize >= csr.n {
        return 1;
    }
    1 + (csr.degree(node) as usize).min(k) as u64
}

/// Cut `costs` into at most `parts` contiguous ranges of near-equal total
/// cost. The ranges are ordered and cover `0..costs.len()` exactly; some
/// may be empty when the distribution is extremely skewed. Prefix sums
/// accumulate in u128, so totals near (or past) `u64::MAX` plan without
/// truncation.
pub fn plan_shards(costs: &[u64], parts: usize) -> Vec<Range<usize>> {
    plan_with_targets(costs, parts, |total, j, parts| {
        total * j as u128 / parts as u128
    })
}

/// [`plan_shards`] with per-part speed weights (the adaptive planner's
/// measured-throughput blend): part `j` is targeted at a cost share
/// proportional to `weights[j]`. Non-finite, non-positive, or
/// wrong-length weights degrade to the unweighted quantile cuts.
pub fn plan_shards_weighted(costs: &[u64], parts: usize,
                            weights: &[f64]) -> Vec<Range<usize>> {
    if weights.len() != parts
        || weights.iter().any(|w| !w.is_finite() || *w <= 0.0)
    {
        return plan_shards(costs, parts);
    }
    let wsum: f64 = weights.iter().sum();
    // cumulative weight share before each cut j (cut j separates parts
    // j-1 and j, so it accumulates weights[..j])
    let mut cum = vec![0.0f64; parts];
    for j in 1..parts {
        cum[j] = cum[j - 1] + weights[j - 1];
    }
    plan_with_targets(costs, parts, move |total, j, _| {
        ((total as f64) * (cum[j] / wsum)) as u128
    })
}

/// Adapt a learned per-worker weight vector to a different worker count
/// without discarding what was measured: truncate (or pad with the
/// uniform weight 1.0), then renormalize to mean 1 so the relative
/// speeds of the surviving workers are preserved. Empty input yields
/// uniform weights of the requested length (callers that treat an
/// empty vector as "no feedback yet" must gate on that *before*
/// resizing, as [`super::CostModel::plan`] does).
pub fn resize_weights(weights: &[f64], parts: usize) -> Vec<f64> {
    if weights.is_empty() || parts == 0 {
        return vec![1.0; parts];
    }
    let mut w = weights.to_vec();
    w.resize(parts, 1.0);
    let mean = w.iter().sum::<f64>() / parts as f64;
    if mean > 0.0 && mean.is_finite() {
        for v in w.iter_mut() {
            *v /= mean;
        }
    }
    w
}

/// Shared quantile-cut body: `target(total, j, parts)` names the prefix
/// cost at which cut `j` (1-based, `1..parts`) should land.
fn plan_with_targets(costs: &[u64], parts: usize,
                     target: impl Fn(u128, usize, usize) -> u128)
                     -> Vec<Range<usize>> {
    let n = costs.len();
    let parts = parts.max(1);
    if parts == 1 || n <= 1 {
        return vec![0..n];
    }
    let total: u128 = costs.iter().map(|&c| c as u128).sum();
    if total == 0 {
        // degenerate (all-zero costs): fall back to an even row split
        let step = (n + parts - 1) / parts;
        return (0..parts)
            .map(|j| (j * step).min(n)..((j + 1) * step).min(n))
            .collect();
    }
    // prefix[i] = sum of costs[..i]; cut j at the index whose prefix is
    // *nearest* the j-th cost quantile. Nearest (not first-reaching)
    // matters when one giant row sits at the end of the range: its prefix
    // jump would otherwise swallow every cut before it and the giant row
    // would be packed together with the whole preceding range.
    let mut prefix = Vec::with_capacity(n + 1);
    prefix.push(0u128);
    for &c in costs {
        prefix.push(prefix.last().unwrap() + c as u128);
    }
    let mut cuts = Vec::with_capacity(parts + 1);
    cuts.push(0usize);
    for j in 1..parts {
        let t = target(total, j, parts);
        let mut cut = prefix.partition_point(|&p| p < t);
        if cut > 0 && cut <= n && t - prefix[cut - 1] < prefix[cut] - t {
            cut -= 1;
        }
        let lo = *cuts.last().unwrap();
        cuts.push(cut.clamp(lo, n));
    }
    cuts.push(n);
    cuts.windows(2).map(|w| w[0]..w[1]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn assert_covering(ranges: &[Range<usize>], n: usize) {
        let mut pos = 0;
        for r in ranges {
            assert_eq!(r.start, pos, "shards not contiguous: {ranges:?}");
            assert!(r.end >= r.start);
            pos = r.end;
        }
        assert_eq!(pos, n, "shards do not cover 0..{n}: {ranges:?}");
    }

    #[test]
    fn uniform_costs_split_evenly() {
        let costs = vec![1u64; 100];
        let shards = plan_shards(&costs, 4);
        assert_covering(&shards, 100);
        assert_eq!(shards.len(), 4);
        for r in &shards {
            assert_eq!(r.end - r.start, 25);
        }
    }

    #[test]
    fn single_part_and_tiny_inputs() {
        assert_eq!(plan_shards(&[5, 5, 5], 1), vec![0..3]);
        assert_eq!(plan_shards(&[], 4), vec![0..0]);
        assert_eq!(plan_shards(&[7], 4), vec![0..1]);
    }

    #[test]
    fn zero_costs_fall_back_to_even_rows() {
        let shards = plan_shards(&[0u64; 10], 3);
        assert_covering(&shards, 10);
        assert!(shards.iter().all(|r| r.end - r.start <= 4));
    }

    #[test]
    fn heavy_head_is_isolated() {
        // one row carrying half the cost should get (roughly) its own shard
        let mut costs = vec![1u64; 64];
        costs[0] = 64;
        let shards = plan_shards(&costs, 4);
        assert_covering(&shards, 64);
        let first = &shards[0];
        assert!(first.end - first.start <= 2,
                "hub row not isolated: {shards:?}");
    }

    #[test]
    fn frontier_plan_balances_star_graph() {
        // star: node 0 is a hub (deg 63), leaves have deg 1 — the
        // per-level cost + quantile-cut path the sampler runs
        let edges: Vec<(u32, u32)> = (1..64u32).map(|i| (0, i)).collect();
        let csr = Csr::from_edges(64, &edges, 256, true).unwrap();
        let frontier: Vec<i32> = (0..64).collect();
        let k = 16;
        let costs: Vec<u64> =
            frontier.iter().map(|&u| sample_cost(&csr, u, k)).collect();
        let shards = plan_shards(&costs, 4);
        assert_covering(&shards, 64);
        let cost_of = |r: &Range<usize>| -> u64 {
            frontier[r.clone()].iter().map(|&u| sample_cost(&csr, u, k)).sum()
        };
        let total: u64 = cost_of(&(0..64));
        for r in &shards {
            if r.end > r.start {
                // no shard should carry more than ~2x its fair share
                assert!(cost_of(r) <= total / 2,
                        "unbalanced shard {r:?} in {shards:?}");
            }
        }
    }

    #[test]
    fn invalid_rows_have_unit_cost() {
        let csr = Csr::from_edges(4, &[(0, 1)], 8, true).unwrap();
        assert_eq!(sample_cost(&csr, -1, 5), 1);
        assert_eq!(sample_cost(&csr, 99, 5), 1);
        assert_eq!(sample_cost(&csr, 2, 5), 1); // isolated
        assert_eq!(sample_cost(&csr, 0, 5), 2); // deg 1
    }

    #[test]
    fn resize_weights_preserves_relative_speeds() {
        // empty input yields uniform weights at the requested length;
        // zero parts yields the empty vector
        assert_eq!(resize_weights(&[], 3), vec![1.0; 3]);
        assert!(resize_weights(&[1.0, 2.0], 0).is_empty());
        // same length: renormalized to mean 1, ordering preserved
        let same = resize_weights(&[2.0, 1.0, 1.0], 3);
        assert!((same.iter().sum::<f64>() / 3.0 - 1.0).abs() < 1e-12);
        assert!(same[0] > same[1]);
        // truncation keeps the survivors' relative speeds
        let cut = resize_weights(&[2.0, 0.5, 0.5, 1.0], 2);
        assert_eq!(cut.len(), 2);
        assert!((cut[0] / cut[1] - 4.0).abs() < 1e-12, "{cut:?}");
        assert!((cut.iter().sum::<f64>() / 2.0 - 1.0).abs() < 1e-12);
        // padding adds uniform workers and renormalizes
        let grown = resize_weights(&[2.0, 0.5], 4);
        assert_eq!(grown.len(), 4);
        assert!((grown[0] / grown[1] - 4.0).abs() < 1e-12);
        assert!((grown[2] - grown[3]).abs() < 1e-12);
        assert!((grown.iter().sum::<f64>() / 4.0 - 1.0).abs() < 1e-12);
    }

    /// Property: random costs and part counts always produce ordered,
    /// covering shards.
    #[test]
    fn prop_random_plans_cover() {
        let mut r = SplitMix64::new(17);
        for _ in 0..200 {
            let n = r.next_below(200) as usize;
            let parts = 1 + r.next_below(12) as usize;
            let costs: Vec<u64> =
                (0..n).map(|_| r.next_below(50)).collect();
            let shards = plan_shards(&costs, parts);
            assert_covering(&shards, n);
            assert!(shards.len() <= parts.max(1));
        }
    }
}
