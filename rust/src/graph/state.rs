//! Planner-state persistence — the adaptive feedback loop across
//! sessions.
//!
//! The adaptive shard planner learns per-worker speed weights from
//! measured shard times ([`super::CostModel::observe`]), but a model
//! lives exactly one training session: every run used to restart from
//! uniform weights and re-learn the same machine (ROADMAP
//! "Adaptive-planner feedback persistence"; SALIENT's persistent
//! pipeline profiling makes the same observation, arXiv 2110.08450).
//! This module is the durable half of the loop: a small versioned JSON
//! file (`results/planner_state.json` by default, `--planner-state
//! <path|off>` on the CLI) that round-trips the adaptive weights plus
//! run metadata, keyed by `(host, thread count, planner flavor)` so
//! state measured on one machine/shape never warm-starts another.
//!
//! Robustness contract (pinned by the unit tests below and
//! `rust/tests/adaptive.rs`): loading a missing, truncated,
//! corrupt-JSON, wrong-version, or wrong-shape file **warns and falls
//! back to an empty state** — a damaged state file can cost warm-start
//! quality, never a run. Entries that fail validation individually
//! (non-finite / non-positive weights, bad counters) are skipped, not
//! fatal. Saving is write-the-whole-file: load-merge-save at shutdown
//! preserves entries for other keys, and the whole window is guarded by
//! [`crate::util::FileLock`] with per-entry freshness merging
//! ([`StateEntry::is_fresher`]) so two sessions sharing the file (e.g.
//! `fsa serve` shutting down while `fsa train` exits) cannot clobber
//! each other's freshly observed weights for the same key.
//!
//! Determinism scope: warm-started weights move *cut positions* only.
//! Sampled values, aggregates, and loss trajectories are bitwise
//! independent of any plan (the counter RNG is order-independent), so
//! persistence cannot change results — only shard balance.

use std::collections::BTreeMap;
use std::path::Path;

use crate::json::Value;

use super::PlannerChoice;

/// Schema version of `planner_state.json`. Files with any other version
/// are ignored wholesale (warn + empty) — weights learned under a
/// different schema are not worth a migration.
pub const STATE_VERSION: u64 = 1;

/// Identity of one planner-state entry: measured worker speeds are a
/// property of this machine at this worker count under this flavor.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct StateKey {
    pub host: String,
    pub threads: usize,
    pub planner: PlannerChoice,
}

impl StateKey {
    /// The key for the current process: detected host, resolved worker
    /// count, and the session's planner flavor.
    pub fn for_session(threads: usize, planner: PlannerChoice) -> StateKey {
        StateKey { host: host_id(), threads, planner }
    }

    /// Canonical string form (the JSON object key).
    pub fn as_string(&self) -> String {
        format!("{}|t{}|{}", self.host, self.threads, self.planner.as_str())
    }
}

/// One persisted adaptive session: the learned weights plus the
/// metadata warm-start decisions need (how much evidence backs them and
/// how stale it is).
#[derive(Clone, Debug, PartialEq)]
pub struct StateEntry {
    /// Per-worker relative speed weights (mean ≈ 1; all finite > 0).
    pub weights: Vec<f64>,
    /// Sharded passes the EWMA has folded in (session + inherited).
    pub steps_observed: u64,
    /// Unix seconds of the save — the EWMA's staleness marker.
    pub saved_unix: u64,
}

impl StateEntry {
    fn validate(&self) -> bool {
        !self.weights.is_empty()
            && self.weights.iter().all(|w| w.is_finite() && *w > 0.0)
    }

    /// Whether this entry carries strictly fresher evidence than
    /// `other`: more observed passes wins, and at equal evidence the
    /// later save does. Equal on both axes is *not* fresher — an
    /// incumbent entry is kept over an identical-vintage challenger.
    pub fn is_fresher(&self, other: &StateEntry) -> bool {
        self.steps_observed > other.steps_observed
            || (self.steps_observed == other.steps_observed
                && self.saved_unix > other.saved_unix)
    }
}

/// The in-memory view of one planner-state file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlannerState {
    entries: BTreeMap<String, StateEntry>,
}

impl PlannerState {
    /// Load a state file. A missing file is a silent empty state (first
    /// run); anything unreadable — truncated, corrupt JSON, wrong
    /// version, wrong shape — warns once and returns an empty state.
    /// Never panics, never errors.
    pub fn load(path: &Path) -> PlannerState {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return PlannerState::default();
            }
            Err(e) => {
                eprintln!("warning: planner-state {path:?} unreadable ({e}); \
                           starting from uniform weights");
                return PlannerState::default();
            }
        };
        let value = match crate::json::parse(&text) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("warning: planner-state {path:?} is not valid \
                           JSON ({e}); starting from uniform weights");
                return PlannerState::default();
            }
        };
        match Self::from_json(&value) {
            Ok(s) => s,
            Err(msg) => {
                eprintln!("warning: planner-state {path:?}: {msg}; \
                           starting from uniform weights");
                PlannerState::default()
            }
        }
    }

    /// Decode the parsed JSON; `Err` carries a human-readable reason.
    /// Individually malformed entries are skipped (with a warning), not
    /// fatal — one bad entry must not discard the others.
    pub fn from_json(value: &Value) -> Result<PlannerState, String> {
        let version = value
            .get("version")
            .and_then(Value::as_u64)
            .ok_or("missing version field")?;
        if version != STATE_VERSION {
            return Err(format!(
                "version {version} != supported {STATE_VERSION}"));
        }
        let raw = value
            .get("entries")
            .and_then(Value::as_obj)
            .ok_or("missing/malformed entries object")?;
        let mut entries = BTreeMap::new();
        for (key, v) in raw {
            match Self::entry_from_json(v) {
                Some(e) => {
                    entries.insert(key.clone(), e);
                }
                None => {
                    eprintln!("warning: planner-state entry {key:?} is \
                               malformed; skipping it");
                }
            }
        }
        Ok(PlannerState { entries })
    }

    fn entry_from_json(v: &Value) -> Option<StateEntry> {
        let weights: Vec<f64> = v
            .get("weights")?
            .as_arr()?
            .iter()
            .map(Value::as_f64)
            .collect::<Option<_>>()?;
        let entry = StateEntry {
            weights,
            steps_observed: v.get("steps_observed")?.as_u64()?,
            saved_unix: v.get("saved_unix")?.as_u64()?,
        };
        entry.validate().then_some(entry)
    }

    /// Encode to the canonical JSON value (BTreeMap ⇒ stable key order,
    /// so write→load→write is byte-idempotent).
    pub fn to_json(&self) -> Value {
        let mut entries = BTreeMap::new();
        for (key, e) in &self.entries {
            let mut obj = BTreeMap::new();
            obj.insert("weights".into(),
                       Value::Arr(e.weights.iter().copied()
                                  .map(Value::Num).collect()));
            obj.insert("steps_observed".into(),
                       Value::Num(e.steps_observed as f64));
            obj.insert("saved_unix".into(), Value::Num(e.saved_unix as f64));
            entries.insert(key.clone(), Value::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("version".into(), Value::Num(STATE_VERSION as f64));
        root.insert("entries".into(), Value::Obj(entries));
        Value::Obj(root)
    }

    /// Write the state file (parent directory created on demand).
    /// Atomic (tmp + fsync + rename): a crash mid-save leaves the
    /// previous file intact, never a truncated one.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        crate::util::atomic_write(path,
                                  format!("{}\n", self.to_json()).as_bytes())
    }

    /// One whole lock-guarded load-merge-save cycle: take the file
    /// lock, re-read the file *inside* the lock (another session may
    /// have saved since our last load), merge `entry` by freshness, and
    /// save. Returns whether the entry won the merge. If the lock
    /// cannot be acquired (held-and-live for the full retry budget) the
    /// cycle proceeds unlocked — a best-effort save beats no save.
    pub fn merge_save(path: &Path, key: &StateKey, entry: StateEntry)
                      -> std::io::Result<bool> {
        let _guard = crate::util::FileLock::acquire(path);
        let mut state = PlannerState::load(path);
        let installed = state.put_if_fresher(key, entry);
        if installed {
            state.save(path)?;
        }
        Ok(installed)
    }

    pub fn get(&self, key: &StateKey) -> Option<&StateEntry> {
        self.entries.get(&key.as_string())
    }

    /// Insert/replace the entry for `key` (invalid entries are dropped
    /// rather than persisted — the file must always load clean).
    pub fn put(&mut self, key: &StateKey, entry: StateEntry) {
        if entry.validate() {
            self.entries.insert(key.as_string(), entry);
        }
    }

    /// [`PlannerState::put`] that defers to an incumbent entry with
    /// fresher (or equal-vintage) evidence. Returns whether the entry
    /// was installed. This is the merge rule that fixes the concurrent
    /// load-merge-save lost update: a stale challenger never overwrites
    /// weights another session observed for longer.
    pub fn put_if_fresher(&mut self, key: &StateKey, entry: StateEntry)
                          -> bool {
        if !entry.validate() {
            return false;
        }
        match self.entries.get(&key.as_string()) {
            Some(cur) if !entry.is_fresher(cur) => false,
            _ => {
                self.entries.insert(key.as_string(), entry);
                true
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Current unix time in seconds (the `saved_unix` staleness stamp).
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Best-effort stable host identity: `$HOSTNAME`, `/etc/hostname`, or a
/// fixed fallback. Only ever compared for equality — two hosts mapping
/// to the same id merely share warm-start state.
pub fn host_id() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    for p in ["/etc/hostname", "/proc/sys/kernel/hostname"] {
        if let Ok(h) = std::fs::read_to_string(p) {
            let h = h.trim().to_string();
            if !h.is_empty() {
                return h;
            }
        }
    }
    "localhost".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fsa_planner_state_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn key(threads: usize) -> StateKey {
        StateKey {
            host: "testhost".into(),
            threads,
            planner: PlannerChoice::Adaptive,
        }
    }

    fn entry(weights: &[f64], steps: u64) -> StateEntry {
        StateEntry { weights: weights.to_vec(), steps_observed: steps,
                     saved_unix: 1_700_000_000 }
    }

    #[test]
    fn save_load_round_trips_entries() {
        let p = tmp("round_trip.json");
        let mut s = PlannerState::default();
        s.put(&key(4), entry(&[1.5, 0.5, 1.0, 1.0], 42));
        s.put(&key(8), entry(&[1.0; 8], 7));
        s.save(&p).unwrap();
        let back = PlannerState::load(&p);
        assert_eq!(back, s);
        let e = back.get(&key(4)).unwrap();
        assert_eq!(e.weights, vec![1.5, 0.5, 1.0, 1.0]);
        assert_eq!(e.steps_observed, 42);
        assert_eq!(e.saved_unix, 1_700_000_000);
        assert!(back.get(&key(2)).is_none(), "wrong key must miss");
    }

    #[test]
    fn missing_file_is_a_silent_empty_state() {
        let s = PlannerState::load(&tmp("does_not_exist.json"));
        assert!(s.is_empty());
    }

    /// The fuzz battery the ISSUE names: truncated, corrupt-JSON,
    /// wrong-version, and wrong-shape files must warn + fall back to
    /// empty (uniform weights), never panic.
    #[test]
    fn corrupt_files_fall_back_to_uniform_not_panic() {
        let cases: &[(&str, &str)] = &[
            ("truncated.json", r#"{"version":1,"entries":{"h|t4|ada"#),
            ("garbage.json", "not json at all"),
            ("empty.json", ""),
            ("wrong_version.json", r#"{"version":999,"entries":{}}"#),
            ("no_version.json", r#"{"entries":{}}"#),
            ("entries_not_obj.json", r#"{"version":1,"entries":42}"#),
            ("root_array.json", r#"[1,2,3]"#),
            ("version_string.json",
             r#"{"version":"1","entries":{}}"#),
        ];
        for (name, text) in cases {
            let p = tmp(name);
            std::fs::write(&p, text).unwrap();
            let s = PlannerState::load(&p);
            assert!(s.is_empty(), "{name}: expected empty fallback");
        }
        // binary garbage too
        let p = tmp("binary.json");
        std::fs::write(&p, [0xFFu8, 0x00, 0x92, 0x13]).unwrap();
        assert!(PlannerState::load(&p).is_empty());
    }

    #[test]
    fn malformed_entries_are_skipped_not_fatal() {
        let p = tmp("mixed_entries.json");
        std::fs::write(&p, format!(
            r#"{{"version":{STATE_VERSION},"entries":{{
                "good|t2|adaptive":{{"weights":[1.2,0.8],
                                     "steps_observed":5,"saved_unix":9}},
                "no_weights|t2|adaptive":{{"steps_observed":5,
                                           "saved_unix":9}},
                "bad_weights|t2|adaptive":{{"weights":[0.0,1.0],
                                            "steps_observed":5,
                                            "saved_unix":9}},
                "weights_not_numbers|t2|adaptive":{{"weights":["x"],
                                                    "steps_observed":5,
                                                    "saved_unix":9}},
                "entry_not_obj|t2|adaptive":17
            }}}}"#)).unwrap();
        let s = PlannerState::load(&p);
        assert_eq!(s.len(), 1, "only the valid entry survives");
        let k = StateKey { host: "good".into(), threads: 2,
                           planner: PlannerChoice::Adaptive };
        assert_eq!(s.get(&k).unwrap().weights, vec![1.2, 0.8]);
    }

    #[test]
    fn put_refuses_invalid_entries() {
        let mut s = PlannerState::default();
        s.put(&key(2), entry(&[], 1));
        s.put(&key(2), entry(&[f64::NAN, 1.0], 1));
        s.put(&key(2), entry(&[-1.0, 1.0], 1));
        s.put(&key(2), entry(&[0.0, 1.0], 1));
        assert!(s.is_empty());
    }

    /// Property: write→load→write is byte-idempotent for random states
    /// (BTreeMap key order + the round-tripping f64 writer).
    #[test]
    fn prop_write_load_write_is_idempotent() {
        let mut r = SplitMix64::new(314);
        for trial in 0..50 {
            let mut s = PlannerState::default();
            for i in 0..r.next_below(6) {
                let parts = 1 + r.next_below(12) as usize;
                let weights: Vec<f64> = (0..parts)
                    .map(|_| 0.25 + r.next_below(1500) as f64 / 400.0)
                    .collect();
                let k = StateKey {
                    host: format!("host{}", r.next_below(3)),
                    threads: parts,
                    planner: if i % 2 == 0 { PlannerChoice::Adaptive }
                             else { PlannerChoice::Quantile },
                };
                s.put(&k, entry(&weights, r.next_below(1_000_000)));
            }
            let p = tmp(&format!("idem_{trial}.json"));
            s.save(&p).unwrap();
            let first = std::fs::read(&p).unwrap();
            let loaded = PlannerState::load(&p);
            assert_eq!(loaded, s, "trial {trial}: load changed the state");
            loaded.save(&p).unwrap();
            let second = std::fs::read(&p).unwrap();
            assert_eq!(first, second,
                       "trial {trial}: write→load→write not idempotent");
        }
    }

    #[test]
    fn freshness_orders_by_steps_then_save_time() {
        let base = entry(&[1.0, 1.0], 10);
        let mut more_steps = entry(&[1.1, 0.9], 11);
        assert!(more_steps.is_fresher(&base));
        assert!(!base.is_fresher(&more_steps));
        more_steps.steps_observed = 10;
        assert!(!more_steps.is_fresher(&base),
                "equal vintage must not be fresher");
        more_steps.saved_unix += 1;
        assert!(more_steps.is_fresher(&base),
                "equal steps, later save wins");
    }

    #[test]
    fn put_if_fresher_keeps_the_fresher_incumbent() {
        let mut s = PlannerState::default();
        assert!(s.put_if_fresher(&key(4), entry(&[1.2, 0.8], 50)));
        // stale challenger loses
        assert!(!s.put_if_fresher(&key(4), entry(&[9.0, 9.0], 49)));
        assert_eq!(s.get(&key(4)).unwrap().weights, vec![1.2, 0.8]);
        // fresher challenger wins
        assert!(s.put_if_fresher(&key(4), entry(&[1.3, 0.7], 51)));
        assert_eq!(s.get(&key(4)).unwrap().weights, vec![1.3, 0.7]);
        // invalid entries are still refused
        assert!(!s.put_if_fresher(&key(4), entry(&[f64::NAN], 99)));
    }

    /// The ISSUE's lost-update regression: two sessions each do
    /// load-merge-save on the shared file, interleaved so both loaded
    /// before either saved. With plain `put`+`save` the last writer
    /// clobbers the same-key entry; `merge_save` re-loads inside the
    /// lock and merges by freshness, so both survive — the shared key
    /// keeps the fresher weights and the disjoint keys keep both.
    #[test]
    fn interleaved_save_cycles_do_not_lose_updates() {
        let p = tmp("interleaved.json");
        let _ = std::fs::remove_file(&p);
        // seed the file, as both sessions would have loaded it
        let mut seeded = PlannerState::default();
        seeded.put(&key(4), entry(&[1.0, 1.0], 10));
        seeded.save(&p).unwrap();

        // session A: observed 200 passes on the t4 key + its own t8 key
        // session B: observed only 20 passes on the t4 key + its t2 key
        // B saves *after* A (the clobbering order in the bug).
        assert!(PlannerState::merge_save(
            &p, &key(4), entry(&[1.5, 0.5], 200)).unwrap());
        assert!(PlannerState::merge_save(
            &p, &key(8), entry(&[1.0; 8], 200)).unwrap());
        assert!(!PlannerState::merge_save(
            &p, &key(4), entry(&[0.9, 1.1], 20)).unwrap(),
            "stale writer must lose the shared key");
        assert!(PlannerState::merge_save(
            &p, &key(2), entry(&[1.0, 1.0], 20)).unwrap());

        let merged = PlannerState::load(&p);
        assert_eq!(merged.len(), 3);
        assert_eq!(merged.get(&key(4)).unwrap().weights, vec![1.5, 0.5],
                   "session A's longer-observed weights must survive \
                    session B saving last");
        assert_eq!(merged.get(&key(4)).unwrap().steps_observed, 200);
        assert!(merged.get(&key(8)).is_some());
        assert!(merged.get(&key(2)).is_some());
        assert!(!p.with_file_name("interleaved.json.lock").exists(),
                "lock file must not linger");
    }

    #[test]
    fn session_key_uses_detected_host() {
        let k = StateKey::for_session(4, PlannerChoice::Adaptive);
        assert!(!k.host.is_empty());
        assert_eq!(k.threads, 4);
        let s = k.as_string();
        assert!(s.ends_with("|t4|adaptive"), "{s}");
        assert!(unix_now() > 1_600_000_000 || unix_now() == 0);
    }
}
