//! CSR graph storage and construction.
//!
//! The paper's operator consumes "contiguous CSR (int32)" (§4); this module
//! is that substrate: an `i32` CSR with a static edge *capacity* (`e_cap`),
//! because the AOT-compiled executables have static shapes — `col` is padded
//! to `e_cap` and `rowptr` never points into the pad (DESIGN.md §6).

pub mod cost;
pub mod shard;
pub mod state;

use std::sync::{Arc, OnceLock};

use anyhow::{bail, ensure, Result};

pub use cost::{lock_model, CostModel, DegreeSummary, ImbalanceAcc,
               PlannerChoice, ShardClock, ShardStats, SharedCostModel,
               VirtualClock, WallClock};
pub use shard::{plan_shards, plan_shards_weighted, resize_weights,
                sample_cost};
pub use state::{PlannerState, StateEntry, StateKey};

/// Compressed sparse row adjacency with a padded edge capacity.
#[derive(Clone, Debug)]
pub struct Csr {
    /// Node count.
    pub n: usize,
    /// `n + 1` row pointers; `rowptr[n]` = live edge count.
    pub rowptr: Vec<i32>,
    /// Column indices, padded with 0 beyond `rowptr[n]` up to `e_cap`.
    pub col: Vec<i32>,
    /// Lazily built degree-quantile sketch for the cost planner
    /// ([`Csr::degree_summary`]); cloning a `Csr` shares the built
    /// summary via the `Arc`.
    summary: OnceLock<Arc<DegreeSummary>>,
}

impl Csr {
    /// Assemble from raw parts (tests / fixtures); [`Csr::from_edges`] is
    /// the validated constructor.
    pub fn new(n: usize, rowptr: Vec<i32>, col: Vec<i32>) -> Csr {
        Csr { n, rowptr, col, summary: OnceLock::new() }
    }

    /// The graph's degree-quantile sketch, built on first use and cached
    /// for the lifetime of the `Csr` (the planner's per-dataset
    /// precompute — the `Runtime::graph_bufs` reuse pattern).
    pub fn degree_summary(&self) -> Arc<DegreeSummary> {
        self.summary
            .get_or_init(|| Arc::new(DegreeSummary::build(self)))
            .clone()
    }

    /// Build from a directed edge list. When `symmetrize` is set both
    /// directions are inserted (the paper makes all graphs undirected, §5);
    /// parallel edges and self-loops are removed either way.
    pub fn from_edges(n: usize, edges: &[(u32, u32)], e_cap: usize,
                      symmetrize: bool) -> Result<Csr> {
        let mut all: Vec<(u32, u32)> = Vec::with_capacity(
            edges.len() * if symmetrize { 2 } else { 1 });
        for &(u, v) in edges {
            ensure!((u as usize) < n && (v as usize) < n,
                    "edge ({u},{v}) out of range for n={n}");
            if u == v {
                continue; // drop self-loops
            }
            all.push((u, v));
            if symmetrize {
                all.push((v, u));
            }
        }
        all.sort_unstable();
        all.dedup();
        if all.len() > e_cap {
            bail!("edge count {} exceeds capacity {e_cap}", all.len());
        }

        let mut rowptr = vec![0i32; n + 1];
        for &(u, _) in &all {
            rowptr[u as usize + 1] += 1;
        }
        for i in 0..n {
            rowptr[i + 1] += rowptr[i];
        }
        let mut col = vec![0i32; e_cap];
        for (i, &(_, v)) in all.iter().enumerate() {
            col[i] = v as i32;
        }
        let csr = Csr::new(n, rowptr, col);
        csr.validate()?;
        Ok(csr)
    }

    /// Live (non-pad) edge count.
    pub fn num_edges(&self) -> usize {
        self.rowptr[self.n] as usize
    }

    /// Padded capacity (= HLO static shape of `col`).
    pub fn e_cap(&self) -> usize {
        self.col.len()
    }

    #[inline]
    pub fn degree(&self, u: i32) -> i32 {
        let u = u as usize;
        self.rowptr[u + 1] - self.rowptr[u]
    }

    #[inline]
    pub fn neighbors(&self, u: i32) -> &[i32] {
        let u = u as usize;
        &self.col[self.rowptr[u] as usize..self.rowptr[u + 1] as usize]
    }

    /// Structural invariants: monotone rowptr, in-range columns, cap respected.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.rowptr.len() == self.n + 1, "rowptr length");
        ensure!(self.rowptr[0] == 0, "rowptr[0] != 0");
        for i in 0..self.n {
            ensure!(self.rowptr[i] <= self.rowptr[i + 1],
                    "rowptr not monotone at {i}");
        }
        let e = self.num_edges();
        ensure!(e <= self.col.len(),
                "live edges {e} exceed capacity {}", self.col.len());
        for (i, &c) in self.col[..e].iter().enumerate() {
            ensure!((0..self.n as i32).contains(&c),
                    "col[{i}]={c} out of range");
        }
        Ok(())
    }

    /// True when for every (u,v) the reverse edge exists. Neighbor lists
    /// are sorted by construction ([`Csr::from_edges`] sorts), so a binary
    /// search alone decides membership — O(E·log d) total, cheap enough
    /// for dataset-sized graphs in test assertions.
    pub fn is_symmetric(&self) -> bool {
        (0..self.n as i32).all(|u| {
            self.neighbors(u)
                .iter()
                .all(|&v| self.neighbors(v).binary_search(&u).is_ok())
        })
    }

    /// Degree distribution statistics (drives the dataset-shape checks).
    pub fn degree_stats(&self) -> DegreeStats {
        let mut degs: Vec<i32> = (0..self.n as i32).map(|u| self.degree(u)).collect();
        degs.sort_unstable();
        let sum: i64 = degs.iter().map(|&d| d as i64).sum();
        let n = self.n.max(1);
        DegreeStats {
            min: *degs.first().unwrap_or(&0),
            max: *degs.last().unwrap_or(&0),
            mean: sum as f64 / n as f64,
            median: degs[n / 2],
            p99: degs[((n as f64 * 0.99) as usize).min(n - 1)],
            isolated: degs.iter().filter(|&&d| d == 0).count(),
        }
    }
}

/// Summary statistics of a degree distribution.
#[derive(Clone, Copy, Debug)]
pub struct DegreeStats {
    pub min: i32,
    pub max: i32,
    pub mean: f64,
    pub median: i32,
    pub p99: i32,
    pub isolated: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn path_graph(n: usize) -> Csr {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        Csr::from_edges(n, &edges, 4 * n, true).unwrap()
    }

    #[test]
    fn builds_path_graph() {
        let g = path_graph(5);
        assert_eq!(g.num_edges(), 8); // 4 undirected edges, both directions
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.neighbors(2), &[1, 3]);
        assert!(g.is_symmetric());
    }

    #[test]
    fn dedups_and_drops_self_loops() {
        let g = Csr::from_edges(3, &[(0, 1), (0, 1), (1, 0), (2, 2)], 8, true)
            .unwrap();
        assert_eq!(g.num_edges(), 2); // only 0<->1 survives
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn respects_capacity() {
        assert!(Csr::from_edges(3, &[(0, 1), (1, 2), (0, 2)], 2, true).is_err());
        assert!(Csr::from_edges(3, &[(0, 1)], 2, true).is_ok());
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(Csr::from_edges(3, &[(0, 7)], 8, true).is_err());
    }

    #[test]
    fn directed_mode_keeps_one_direction() {
        let g = Csr::from_edges(3, &[(0, 1)], 4, false).unwrap();
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 0);
        assert!(!g.is_symmetric());
    }

    #[test]
    fn stats_on_star_graph() {
        let edges: Vec<(u32, u32)> = (1..10u32).map(|i| (0, i)).collect();
        let g = Csr::from_edges(10, &edges, 64, true).unwrap();
        let s = g.degree_stats();
        assert_eq!(s.max, 9);
        assert_eq!(s.min, 1);
        assert!((s.mean - 1.8).abs() < 1e-9);
        assert_eq!(s.isolated, 0);
    }

    /// Property test: random edge lists always produce valid symmetric CSR.
    #[test]
    fn prop_random_graphs_valid() {
        let mut r = SplitMix64::new(5);
        for trial in 0..50 {
            let n = 2 + r.next_below(60) as usize;
            let m = r.next_below(4 * n as u64) as usize;
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (r.next_below(n as u64) as u32,
                          r.next_below(n as u64) as u32))
                .collect();
            let g = Csr::from_edges(n, &edges, 2 * m + 16, true)
                .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            g.validate().unwrap();
            assert!(g.is_symmetric(), "trial {trial} not symmetric");
            // neighbor lists sorted (from_edges sorts) => binary search ok
            for u in 0..n as i32 {
                let ns = g.neighbors(u);
                assert!(ns.windows(2).all(|w| w[0] < w[1]),
                        "trial {trial}: neighbors of {u} not strictly sorted");
            }
        }
    }
}
